"""End-to-end driver: train the ~100M-parameter LM for a few hundred steps.

Demonstrates the full training substrate: deterministic data pipeline,
AdamW + warmup-cosine, remat, fault-tolerant checkpointing (kill the process
and rerun — it resumes bitwise), and the paper's technique as gradient
compression (--compress enables rank-r PowerIter compression with error
feedback; DESIGN.md Sec. 2.2).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200 [--compress]
"""

import argparse

import jax

from repro import configs
from repro.data.tokens import TokenPipeline
from repro.runtime.health import HealthMonitor
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--compress", action="store_true",
                    help="rank-4 PowerIter gradient compression")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    ap.add_argument("--small", action="store_true",
                    help="smoke-size model (CI)")
    args = ap.parse_args()

    cfg = configs.get("lm100m")
    if args.small:
        cfg = cfg.smoke()
    print(f"arch {cfg.name}: {cfg.param_count()/1e6:.0f}M params; "
          f"compress={'rank-4 PowerIter' if args.compress else 'off'}")

    pipeline = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                             global_batch=args.batch, seed=0)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=3e-4, weight_decay=0.01),
        warmup_steps=20, total_steps=args.steps,
        compress_rank=4 if args.compress else 0,
        checkpoint_dir=args.ckpt_dir, checkpoint_every=50,
        remat=True)
    trainer = Trainer(cfg, tcfg, pipeline, key=jax.random.PRNGKey(0),
                      health_monitor=HealthMonitor())
    if trainer.try_resume():
        print(f"resumed from step {trainer.state.step}")

    hist = trainer.run(args.steps - trainer.state.step, log_every=10)
    if hist:
        print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
              f"over {len(hist)} steps")
    if trainer.health.straggler_count():
        print(f"stragglers observed: {trainer.health.straggler_count()}")
    trainer.save(async_=False)
    print("checkpoint saved; rerun to resume.")


if __name__ == "__main__":
    main()
