"""Event-detecting serving: a fleet monitoring T²/SPE on the streaming path.

The paper's third application (Sec. 2.4.3) is *event detection*: a
network-scale anomaly invisible at any single node shows up as significant
energy on components the healthy distribution does not excite.  This
example runs that evaluator on the device tier: a fleet of networks streams
through the jitted scan driver, every round passes through the fused Pallas
monitoring kernel (project + T² + SPE in one pass, the reconstruction never
leaves VMEM), and the detector re-arms its Wilson-Hilferty thresholds over
a healthy window after the warmup basis refresh.

Half the networks get an injected localized AC plateau
(:func:`repro.sensors.dataset.inject_ac_event` — the Fig.-8 event family: a
~8 m footprint, ~5 C at the site, network-coherent but small against each
sensor's own variance).  The acceptance gate is the TPR/FPR envelope of
tests/test_applications.py, now asserted ON DEVICE against the live basis:

* detection rate inside the injected windows  > 80 %
* false-alarm rate outside                    <  5 %

Run:  PYTHONPATH=src python examples/event_fleet.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import berkeley_like_layout
from repro.sensors.dataset import inject_ac_event
from repro.streaming import (DetectionConfig, StreamConfig,
                             batched_stream_run, stream_init)

N_NETWORKS = 8
N_ROUNDS = 40
N_PER_ROUND = 8
P = 32                   # sensors per network
Q = 3                    # principal components maintained
ALPHA = 1e-3
CALIB_ROUNDS = 8
WARMUP = 6
EVENT_NETWORKS = (1, 3, 4, 6)
EVENT_START_ROUND = 22   # well after arming (warmup + calibration window)
EVENT_ROUNDS = 8
EVENT_AMP = -5.0         # cooling plateau, degrees at the site
EVENT_FOOTPRINT = 8.0    # meters
NOISE = 0.8


def fleet_streams(seed=0):
    """(networks, rounds, n, p): a dominant top-q group of sensors over a
    flat noise floor — the banded-local-covariance substrate the scheduler
    actually fits (a dense global factor would not be band-representable),
    with a quiet residual space for a localized event to land in."""
    rng = np.random.default_rng(seed)
    scale = np.concatenate([[4.0, 3.4, 2.8], np.full(P - 3, NOISE)])
    x = rng.normal(size=(N_NETWORKS, N_ROUNDS, N_PER_ROUND, P)) * scale
    return x.astype(np.float32)


def inject_events(xs, positions, seed=1):
    """Plant one localized plateau per event network; returns the modified
    fleet block and the (networks, rounds, n) ground-truth epoch mask."""
    rng = np.random.default_rng(seed)
    truth = np.zeros(xs.shape[:3], bool)
    epochs = N_ROUNDS * N_PER_ROUND
    # keep the footprint off the high-variance sensors: energy landing on
    # the tracked subspace is absorbed by the basis, not detected — the
    # Sec.-2.4.3 premise is an event the healthy components do NOT span
    d_top = np.linalg.norm(positions[:, None, :] - positions[None, :3, :],
                           axis=-1).min(axis=1)
    candidates = np.nonzero(d_top > 10.0)[0]
    for b in EVENT_NETWORKS:
        site = int(rng.choice(candidates))
        start = EVENT_START_ROUND * N_PER_ROUND
        dur = EVENT_ROUNDS * N_PER_ROUND
        flat, window = inject_ac_event(
            xs[b].reshape(epochs, P), positions, site=site, start=start,
            duration=dur, amplitude=EVENT_AMP,
            footprint_m=EVENT_FOOTPRINT, ramp_epochs=3)
        xs[b] = flat.reshape(N_ROUNDS, N_PER_ROUND, P)
        truth[b] = window.reshape(N_ROUNDS, N_PER_ROUND)
    return xs, truth


def main() -> None:
    print("=== T²/SPE event-detection fleet ===\n")
    positions = berkeley_like_layout(p=P, seed=7)
    cfg = StreamConfig(p=P, q=Q, halfwidth=4, forgetting=0.98,
                       drift_threshold=0.5, warmup_rounds=WARMUP,
                       detection=DetectionConfig(alpha=ALPHA,
                                                 calib_rounds=CALIB_ROUNDS))
    xs, truth = inject_events(fleet_streams(), positions)
    print(f"fleet: {N_NETWORKS} networks x {N_ROUNDS} rounds, p={P}, q={Q}; "
          f"events on networks {EVENT_NETWORKS} at rounds "
          f"[{EVENT_START_ROUND}, {EVENT_START_ROUND + EVENT_ROUNDS})\n")

    keys = jax.random.split(jax.random.PRNGKey(2), N_NETWORKS)
    states = jax.vmap(lambda k: stream_init(cfg, k))(keys)
    t0 = time.perf_counter()
    fin, met = batched_stream_run(cfg, states, jnp.asarray(xs))
    jax.block_until_ready(met.rho)
    elapsed = time.perf_counter() - t0

    det = met.detection
    events = np.asarray(det.events) > 0.5          # (networks, rounds, n)
    calibrating = np.asarray(det.calibrating) > 0.5  # (networks, rounds)
    # score only epochs where the detector was armed (outside warmup +
    # healthy windows — alarms are suppressed inside them by design)
    armed = ~calibrating
    armed[:, :WARMUP + 1] = False
    armed_e = np.repeat(armed[:, :, None], N_PER_ROUND, axis=2)
    tpr = events[truth & armed_e].mean()
    fpr = events[~truth & armed_e].mean()

    print(f"{'network':>8} {'alarms':>7} {'event epochs':>13} "
          f"{'T² thr':>8} {'SPE thr':>8} {'bill':>9}")
    t2_thr = np.asarray(fin.det.t2_threshold)
    spe_thr = np.asarray(fin.det.spe_threshold)
    bills = np.asarray(fin.sched.comm_packets)
    for b in range(N_NETWORKS):
        n_alarms = int(events[b].sum())
        n_truth = int(truth[b].sum())
        print(f"{b:>8} {n_alarms:>7} {n_truth:>13} "
              f"{t2_thr[b]:>8.1f} {spe_thr[b]:>8.1f} {bills[b]:>9.0f}")

    print(f"\ndetection rate inside injected windows: {tpr:.1%}")
    print(f"false-alarm rate outside:               {fpr:.2%}")
    print(f"(streamed {N_NETWORKS * N_ROUNDS} network-rounds in "
          f"{elapsed:.1f} s)\n")
    assert tpr > 0.8, f"TPR {tpr:.1%} below the 80% acceptance gate"
    assert fpr < 0.05, f"FPR {fpr:.2%} above the 5% acceptance gate"
    print("OK: the device tier reproduces the Sec.-2.4.3 envelope — "
          "localized events caught network-wide, alarms stay rare.")


if __name__ == "__main__":
    main()
