"""Streaming distributed PCA over a fleet of sensor networks.

The online continuation of the paper (DESIGN.md Sec. 8): measurements arrive
round by round, each network folds them into its banded covariance with an
exponential forgetting factor (the Pallas cov-update kernel on the hot path),
and a recompute scheduler refreshes the principal-component basis only when
retained variance drifts — booking the paper-style communication cost of
every refresh (Table 1 / costs.py).

The fleet is vmap-batched: all networks stream in ONE jitted program (the
"millions of users" serving shape; on a mesh the networks axis shards over
the data axis, see repro.streaming.driver.sharded_stream_run).  Halfway
through the stream, half of the fleet suffers a distribution shift — watch
the scheduler fire on exactly those networks.

Run:  PYTHONPATH=src python examples/streaming_pca.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costs
from repro.streaming import StreamConfig, batched_stream_run, stream_init

N_NETWORKS = 64
N_ROUNDS = 120
N_PER_ROUND = 8          # measurement epochs per round
P = 32                   # sensors per network
Q = 3                    # principal components maintained
SHIFT_ROUND = 60         # distribution shift for the second half of the fleet


def fleet_streams(key) -> jnp.ndarray:
    """(networks, rounds, n, p) measurement stream.

    Every network observes sensors with a smoothly decaying variance profile
    (distinct eigenvalues, so the top-q subspace is well defined).  From
    SHIFT_ROUND on, networks 32..63 see the profile reversed — the energy
    moves to the other end of the network, the paper's 'air conditioning
    turns on' regime change.
    """
    k1, k2 = jax.random.split(key)
    base = jnp.linspace(4.0, 1.0, P)
    shifted = base[::-1]
    x = jax.random.normal(k1, (N_NETWORKS, N_ROUNDS, N_PER_ROUND, P))
    rounds = jnp.arange(N_ROUNDS)[None, :, None, None]
    nets = jnp.arange(N_NETWORKS)[:, None, None, None]
    use_shifted = (rounds >= SHIFT_ROUND) & (nets >= N_NETWORKS // 2)
    scale = jnp.where(use_shifted, shifted[None, None, None, :],
                      base[None, None, None, :])
    return x * scale


def main() -> None:
    print("=== Streaming distributed PCA: 64-network fleet ===\n")
    cfg = StreamConfig(p=P, q=Q, halfwidth=4, forgetting=0.9,
                       drift_threshold=0.1, refresh_iters=8,
                       warmup_rounds=8, n_max=8, c_max=4)
    print(f"fleet: {N_NETWORKS} networks x {N_ROUNDS} rounds x "
          f"{N_PER_ROUND} epochs/round, p={P} sensors, q={Q} components")
    print(f"policy: forgetting {cfg.forgetting}, refresh when retained "
          f"variance drops > {cfg.drift_threshold:.0%} since last refresh\n")

    key = jax.random.PRNGKey(0)
    xs = fleet_streams(key)
    states = jax.vmap(lambda k: stream_init(cfg, k))(
        jax.random.split(jax.random.PRNGKey(1), N_NETWORKS))

    t0 = time.perf_counter()
    final, metrics = batched_stream_run(cfg, states, xs)
    jax.block_until_ready(metrics.rho)
    dt = time.perf_counter() - t0

    rho = np.asarray(metrics.rho)                  # (networks, rounds)
    fired = np.asarray(metrics.did_refresh)
    refreshes = np.asarray(final.sched.refreshes)
    comm = np.asarray(final.sched.comm_packets)

    total_rounds = N_NETWORKS * N_ROUNDS
    print(f"streamed {total_rounds} network-rounds in {dt:.1f} s "
          f"({total_rounds / dt:.0f} rounds/s, one jitted vmap+scan program)")

    stable, shifted = slice(0, N_NETWORKS // 2), slice(N_NETWORKS // 2, None)
    print("\n-- scheduler activity ------------------------------------")
    print(f"refreshes/network: stable fleet half  "
          f"{refreshes[stable].mean():.2f} (warmup fit only is 1.0)")
    print(f"                   shifted fleet half {refreshes[shifted].mean():.2f}")
    counts = np.bincount(np.where(fired[shifted])[1], minlength=N_ROUNDS)
    first_post = int(np.nonzero(counts[SHIFT_ROUND:])[0][0]) + SHIFT_ROUND
    print(f"total refreshes: {int(refreshes.sum())} "
          f"(first post-shift trigger at round {first_post}; "
          f"shift injected at round {SHIFT_ROUND})")

    print("\n-- retained variance -------------------------------------")
    print(f"end of stream: stable half  {rho[stable, -1].mean():.3f}  "
          f"(pre-shift level {rho[stable, SHIFT_ROUND - 1].mean():.3f})")
    drifted_low = rho[shifted, SHIFT_ROUND:].min(axis=1).mean()
    print(f"               shifted half {rho[shifted, -1].mean():.3f}  "
          f"(drifted low point {drifted_low:.3f} before the refresh caught it)")

    print("\n-- communication bill (packets, highest-loaded node) -----")
    sched = cfg.scheduler()
    round_c, refresh_c = sched.round_cost(), sched.refresh_cost(P)
    print(f"per round (cov fold + drift probe): {round_c:.0f}")
    print(f"per refresh (ortho iteration + basis flood): {refresh_c:.0f}")
    print(f"accumulated/network: stable {comm[stable].mean():.0f}, "
          f"shifted {comm[shifted].mean():.0f}")
    every_round = round_c + refresh_c
    print(f"refresh-every-round baseline would pay "
          f"{N_ROUNDS * every_round:.0f}/network — the scheduler spends "
          f"{comm.mean() / (N_ROUNDS * every_round):.1%} of that")

    # the paper's Table-1 framing for one refresh at this scale
    rep = costs.streaming_refresh_cost(P, Q, cfg.n_max, cfg.c_max,
                                       cfg.refresh_iters)
    print(f"\nTable-1 view of one refresh: comm {rep.communication:.0f}, "
          f"compute O({rep.computation:.0f}), memory O({rep.memory:.0f})")

    assert int(refreshes.sum()) >= 1, "no refresh triggered"
    print("\nOK: fleet streamed, drift caught, refreshes scheduled.")


if __name__ == "__main__":
    main()
