"""Serving example: continuous-batching engine with batched requests.

Loads (or initializes) a small model, submits a burst of requests with
different prompts/lengths, and drives the slot-based engine: prefill on
admission, one decode step per tick for every active slot, refill on
completion.

Run:  PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import Engine, Request, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--small", action="store_true", default=True)
    args = ap.parse_args()

    cfg = configs.get("lm100m").smoke() if args.small \
        else configs.get("lm100m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(slots=args.slots, max_len=128))

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        req = Request(prompt=rng.integers(0, cfg.vocab_size, plen)
                      .astype(np.int32),
                      max_new_tokens=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.perf_counter()
    ticks = 0
    while any(not r.done for r in reqs):
        n = engine.step()
        ticks += 1
        if n == 0 and not engine.queue:
            break
    dt = time.perf_counter() - t0

    total_tokens = sum(len(r.output) for r in reqs)
    print(f"{len(reqs)} requests, {total_tokens} tokens in {ticks} ticks, "
          f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s on CPU)")
    for i, r in enumerate(reqs[:4]):
        print(f"req{i}: prompt[{len(r.prompt)}] -> {r.output}")


if __name__ == "__main__":
    main()
