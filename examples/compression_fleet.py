"""Compressed serving: a fleet streaming ε-supervised PCAg scores.

The paper's validating experiment (Sec. 5) is *compression*: ship q scores
instead of p raw readings, feed them back, and let every node police its own
reconstruction — whoever's error strictly exceeds ε ships the raw value, so
the sink is ALWAYS within the closed bound |x − x̂| ≤ ε.  This example runs
that protocol on the device tier: a fleet of networks streams through the
jitted scan driver with the fused Pallas project/reconstruct/flag kernel on
every round, compressing against each slot's live (drift-scheduled) basis.

Two sweeps, one acceptance gate each:

* ε sweep (full-precision scores): the notification rate falls as ε grows —
  the paper's accuracy-vs-communication dial — and at EVERY swept ε the
  worst sink error across the whole fleet and stream must be ≤ ε
  (asserted; this is the Sec.-2.4.1 guarantee, not a statistical claim);
* bit-width sweep (fixed ε): quantizing the score records (uniform
  per-component quantizer) cuts the bits on air while the guarantee holds
  at every width — coarser scores only raise the notification rate.

Run:  PYTHONPATH=src python examples/compression_fleet.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.streaming import (CompressionConfig, StreamConfig,
                             batched_stream_run, stream_init)

N_NETWORKS = 8
N_ROUNDS = 30
N_PER_ROUND = 8
P = 32                   # sensors per network
Q = 3                    # principal components maintained
EPSILONS = (0.1, 0.25, 0.5, 1.0, 2.0)
BIT_WIDTHS = (0, 16, 8, 6, 4, 2)     # 0 = full-precision scores
EPS_FOR_BITS = 0.5


def fleet_streams(key) -> jnp.ndarray:
    """(networks, rounds, n, p): a dominant top-q subspace plus a weak tail,
    so PCAg compression has signal to keep and noise to drop."""
    scale = jnp.concatenate([jnp.array([4.0, 3.4, 2.8]),
                             jnp.linspace(1.2, 0.8, P - 3)])
    x = jax.random.normal(key, (N_NETWORKS, N_ROUNDS, N_PER_ROUND, P))
    return x * scale[None, None, None, :]


def run_fleet(compression: CompressionConfig):
    cfg = StreamConfig(p=P, q=Q, halfwidth=4, forgetting=0.95,
                       drift_threshold=0.08, warmup_rounds=5,
                       compression=compression)
    xs = fleet_streams(jax.random.PRNGKey(0))
    keys = jax.random.split(jax.random.PRNGKey(1), N_NETWORKS)
    states = jax.vmap(lambda k: stream_init(cfg, k))(keys)
    fin, met = batched_stream_run(cfg, states, xs)
    jax.block_until_ready(met.rho)
    return fin, met


def main() -> None:
    print("=== ε-supervised compression fleet ===\n")
    print(f"fleet: {N_NETWORKS} networks x {N_ROUNDS} rounds, p={P}, q={Q} "
          f"({P / Q:.1f}x raw-to-score ratio)\n")
    readings = N_NETWORKS * N_ROUNDS * N_PER_ROUND * P

    print("-- ε sweep (full-precision scores) ------------------------")
    print(f"{'ε':>6} {'worst sink err':>15} {'notif rate':>11} "
          f"{'extras/round':>13} {'bill/network':>13}")
    t0 = time.perf_counter()
    for eps in EPSILONS:
        fin, met = run_fleet(CompressionConfig(epsilon=eps))
        comp = met.compression
        worst = float(np.asarray(comp.max_err).max())
        extras = float(np.asarray(comp.extra_packets).sum())
        rate = extras / readings
        bill = float(np.asarray(fin.sched.comm_packets).mean())
        print(f"{eps:>6.2f} {worst:>15.4f} {rate:>10.1%} "
              f"{extras / (N_NETWORKS * N_ROUNDS):>13.1f} {bill:>13.0f}")
        assert worst <= eps + 1e-6, \
            f"sink error {worst} exceeded the ε={eps} guarantee"
    print(f"(swept {len(EPSILONS)} ε values in "
          f"{time.perf_counter() - t0:.1f} s)\n")

    print(f"-- bit-width sweep (ε = {EPS_FOR_BITS}) -------------------------")
    print(f"{'bits':>6} {'worst sink err':>15} {'notif rate':>11} "
          f"{'score bits/network':>19}")
    for bits in BIT_WIDTHS:
        fin, met = run_fleet(CompressionConfig(epsilon=EPS_FOR_BITS,
                                               score_bits=bits))
        comp = met.compression
        worst = float(np.asarray(comp.max_err).max())
        extras = float(np.asarray(comp.extra_packets).sum())
        bits_air = float(np.asarray(comp.bits_on_air).sum()) / N_NETWORKS
        label = "fp32" if bits == 0 else f"{bits:>4}"
        print(f"{label:>6} {worst:>15.4f} {extras / readings:>10.1%} "
              f"{bits_air:>19.0f}")
        assert worst <= EPS_FOR_BITS + 1e-6, \
            f"sink error {worst} broke the guarantee at {bits}-bit scores"

    print("\nOK: sink within ε at every swept ε and every bit width — "
          "coarser scores trade notifications for bits, never accuracy.")


if __name__ == "__main__":
    main()
