"""Event detection on low-variance components (paper Sec. 2.4.3).

Train the PCA basis on healthy data, then inject a network-scale anomaly
that is invisible at any single node (a correlated pattern orthogonal to
the normal subspace) and detect it with the chi-square test on the
low-variance component scores.

Run:  PYTHONPATH=src python examples/event_detection.py
"""

import numpy as np

from repro.core.events import LowVarianceDetector
from repro.core.pca import DistributedPCA
from repro.sensors.dataset import berkeley_surrogate


def main() -> None:
    data = berkeley_surrogate(p=52, n_epochs=7200, seed=0)
    X = data.measurements
    # 2.5 days train / 10 h calibration / 20 h deployment
    train, cal, test = X[:3600], X[3600:4800], X[4800:].copy()

    # full basis: leading components = signal, trailing = noise floor
    res = DistributedPCA(q=52, method="eigh").fit(train)
    q_sig = 10
    W_low = res.components[:, q_sig:30]
    lam_low = res.eigenvalues[q_sig:30]

    det = LowVarianceDetector(W_low, lam_low, res.mean, alpha=1e-3)
    # the chi-square threshold assumes stationarity; calibrate empirically
    # on a healthy window (production practice — see events.calibrate)
    chi2_thr = det.threshold
    det.calibrate(cal)

    # inject an event: a coherent pattern in the noise subspace,
    # ~1.2 C max across sensors — small against the ~6 C diurnal swing
    # any single node rides, but network-coherent
    pattern = W_low[:, 3] + 0.5 * W_low[:, 7]
    pattern = pattern / np.abs(pattern).max() * 1.2
    event_epochs = slice(1000, 1040)
    test[event_epochs] += pattern[None, :]

    out = det.detect(test)
    window = np.zeros(len(test), bool)
    window[event_epochs] = True
    tpr = out.events[window].mean()
    fpr = out.events[~window].mean()
    print(f"low-variance detector (20 comps, chi2 thr {chi2_thr:.1f} -> "
          f"calibrated {det.threshold:.1f})")
    print(f"  detection rate inside event window: {tpr:.1%}")
    print(f"  false alarm rate outside:           {fpr:.2%}")
    print(f"  max statistic inside window: {out.statistic[window].max():.1f} "
          f"vs outside median {np.median(out.statistic[~window]):.1f}")
    assert tpr > 0.8 and fpr < 0.05, "detector quality regression"


if __name__ == "__main__":
    main()
