"""Quickstart: the paper's full WSN pipeline on the Berkeley surrogate.

1. build the sensor network (52 nodes, 10 m radio range, routing tree),
2. estimate the covariance under the local covariance hypothesis,
3. extract principal components with the distributed power iteration,
4. compress measurements via in-network principal component aggregation,
5. compare network loads against the default (send-everything) scheme.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import costs
from repro.core.compression import SupervisedCompressor, scores_in_network
from repro.core.pca import DistributedPCA, retained_variance
from repro.core.topology import build_topology
from repro.sensors.dataset import berkeley_surrogate, kfold_blocks


def main() -> None:
    print("=== Distributed PCA for WSN: quickstart ===\n")
    data = berkeley_surrogate(p=52, n_epochs=7200, seed=0)
    tr, te = kfold_blocks(data.n_epochs, k=10)[0]
    train, test = data.measurements[tr], data.measurements[te]

    topo = build_topology(data.positions, radio_range=10.0)
    print(f"network: p={topo.p}, radio 10 m, tree depth "
          f"{topo.tree.depth.max()}, max children "
          f"{topo.tree.children_counts().max()}, "
          f"max neighborhood {topo.neighborhood_sizes().max()}")

    # distributed PCA: local covariance hypothesis + power iteration
    pca = DistributedPCA(q=5, method="power", t_max=30, delta=1e-3,
                         cov_mode="masked",
                         mask=np.asarray(topo.covariance_mask()))
    res = pca.fit(train)
    kept = res.components[:, res.valid]
    frac = retained_variance(test, kept, res.mean)
    print(f"\ndistributed PCA: {kept.shape[1]} components kept, "
          f"retained variance on held-out data = {frac:.1%}")
    print(f"eigenvalues: {np.round(res.eigenvalues, 2)}")

    # in-network score computation for one epoch (PCAg, Sec. 2.3)
    x_epoch = test[0]
    z, packets = scores_in_network(topo.tree, kept, x_epoch, mean=res.mean)
    print(f"\nPCAg epoch: scores {np.round(z, 2)}")
    print(f"  packets/node: max {packets.max()} "
          f"(default scheme root load: {costs.default_epoch_load(52)})")

    # supervised compression (Sec. 2.4.1): +/-0.5 degC guarantee
    comp = SupervisedCompressor(kept, res.mean, epsilon=0.5)
    out = comp.run(test[:1000])
    notif = out.flagged.mean()
    err = np.abs(out.x_hat - test[:1000]).max()
    print(f"\nsupervised compression (eps=0.5 C): notification rate "
          f"{notif:.1%}, max sink error {err:.3f} C")

    # load table
    print("\nload comparison (packets/epoch, highest-loaded node):")
    for q in (1, 5, 15, 20):
        load = costs.pcag_epoch_load(q, int(topo.tree.children_counts().max()))
        print(f"  PCAg q={q:2d}: {load:4d}   "
              f"{'wins' if costs.pcag_beats_default(q, 6, 52) else 'loses'}"
              f" vs default {costs.default_epoch_load(52)}")


if __name__ == "__main__":
    main()
