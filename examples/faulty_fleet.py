"""Fault-tolerant streaming PCA: a fleet surviving loss, death, and revival.

The streaming example (examples/streaming_pca.py) assumes a perfect radio.
This one does not: a 32-network fleet streams under 10% per-hop packet loss
(every booked packet pays the expected ARQ retransmissions), and halfway
through, half the fleet suffers a node-death wave — 25% of each victim
network's sensors go dark for 15 rounds before a battery swap revives them.

What to watch:

* dead sensors are *masked*, not zeroed-and-believed: they join no outer
  products and no mean sums (the masked Pallas cov-update path), so the
  basis is never poisoned by phantom readings;
* the scheduler treats the topology churn (death AND revival) as an
  unconditional drift trigger — the basis re-fits the surviving support
  immediately instead of waiting out the forgetting window;
* the bill stays honest: the fault run books lossy Table-1 costs
  (costs.lossy_round_cost) and the churn-triggered refreshes, and still
  lands under 2x the fault-free bill.

The acceptance gate (asserted below): every surviving network ends within
5% of its fault-free retained variance, at <= 2x the fault-free packet bill.

A coda runs the fault-aware serving engine on a network that dies outright:
the per-slot HealthMonitor rules it stalled, the engine retires it, re-plans
the fleet mesh (runtime.elastic), and re-admits the network when its
liveness schedule revives it.

Run:  PYTHONPATH=src python examples/faulty_fleet.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FaultModel, death_wave
from repro.streaming import StreamConfig, batched_stream_run, stream_init

N_NETWORKS = 32
N_ROUNDS = 80
N_PER_ROUND = 8
P = 32                   # sensors per network
Q = 3                    # principal components maintained
LINK_LOSS = 0.1          # per-hop packet loss
WAVE_ROUND = 30          # node-death wave hits here...
REVIVE_ROUND = 45        # ...battery swap here
WAVE_FRACTION = 0.25     # sensors killed per victim network


def fleet_streams(key) -> jnp.ndarray:
    """(networks, rounds, n, p) measurements.

    Three dominant sensors over a weak tail: the top-q subspace has a clear
    eigengap, so retained variance is a stable quantity to compare across
    the faulty and fault-free runs (closely spaced eigenvalues would make
    rho jitter with the refresh phase, faults or not).
    """
    scale = jnp.concatenate([jnp.array([4.0, 3.4, 2.8]),
                             jnp.linspace(1.2, 0.8, P - 3)])
    x = jax.random.normal(key, (N_NETWORKS, N_ROUNDS, N_PER_ROUND, P))
    return x * scale[None, None, None, :]


def fleet_liveness(seed: int = 0) -> np.ndarray:
    """(networks, rounds, p) liveness: wave hits networks 16..31."""
    masks = np.ones((N_NETWORKS, N_ROUNDS, P), np.float32)
    rng = np.random.default_rng(seed)
    for i in range(N_NETWORKS // 2, N_NETWORKS):
        churn = death_wave(rng, P, round=WAVE_ROUND, fraction=WAVE_FRACTION,
                           revive_round=REVIVE_ROUND)
        masks[i] = churn.liveness(P, N_ROUNDS).astype(np.float32)
    return masks


def main() -> None:
    print("=== Fault-tolerant streaming PCA: 32-network fleet ===\n")
    base = dict(p=P, q=Q, halfwidth=4, forgetting=0.95, drift_threshold=0.08,
                refresh_iters=8, warmup_rounds=8, n_max=8, c_max=4)
    cfg_clean = StreamConfig(**base)
    cfg_fault = StreamConfig(**base, link_loss=LINK_LOSS, max_retries=3)
    fm = FaultModel(link_loss=LINK_LOSS, max_retries=3)
    print(f"fleet: {N_NETWORKS} networks x {N_ROUNDS} rounds, p={P}, q={Q}")
    print(f"faults: {LINK_LOSS:.0%} per-hop loss (E[tx] = "
          f"{fm.expected_transmissions():.3f} per packet), death wave at "
          f"round {WAVE_ROUND} ({WAVE_FRACTION:.0%} of sensors in half the "
          f"fleet), revival at round {REVIVE_ROUND}\n")

    xs = fleet_streams(jax.random.PRNGKey(0))
    masks = jnp.asarray(fleet_liveness(seed=1))
    keys = jax.random.split(jax.random.PRNGKey(1), N_NETWORKS)

    t0 = time.perf_counter()
    states_c = jax.vmap(lambda k: stream_init(cfg_clean, k))(keys)
    fin_c, met_c = batched_stream_run(cfg_clean, states_c, xs)
    states_f = jax.vmap(lambda k: stream_init(cfg_fault, k))(keys)
    fin_f, met_f = batched_stream_run(cfg_fault, states_f, xs, masks)
    jax.block_until_ready(met_f.rho)
    dt = time.perf_counter() - t0
    print(f"streamed both runs ({2 * N_NETWORKS * N_ROUNDS} network-rounds) "
          f"in {dt:.1f} s\n")

    rho_c = np.asarray(met_c.rho)[:, -1]
    rho_f = np.asarray(met_f.rho)[:, -1]
    bill_c = np.asarray(fin_c.sched.comm_packets)
    bill_f = np.asarray(fin_f.sched.comm_packets)
    ref_c = np.asarray(fin_c.sched.refreshes)
    ref_f = np.asarray(fin_f.sched.refreshes)
    fired_f = np.asarray(met_f.did_refresh)

    stable = slice(0, N_NETWORKS // 2)
    waved = slice(N_NETWORKS // 2, None)
    print("-- churn response -----------------------------------------")
    print(f"refreshes/network: untouched half {ref_f[stable].mean():.2f}, "
          f"waved half {ref_f[waved].mean():.2f} "
          f"(fault-free run: {ref_c.mean():.2f})")
    wave_hits = fired_f[waved][:, WAVE_ROUND].mean()
    revive_hits = fired_f[waved][:, REVIVE_ROUND].mean()
    print(f"churn triggers: {wave_hits:.0%} of waved networks refreshed at "
          f"the death round, {revive_hits:.0%} at the revival round")

    print("\n-- retained variance at end of stream ---------------------")
    rel = np.abs(rho_f - rho_c) / rho_c
    print(f"fault-free {rho_c.mean():.3f}, faulty {rho_f.mean():.3f}, "
          f"worst relative gap {rel.max():.2%}")

    print("\n-- packet bill --------------------------------------------")
    ratio = bill_f / bill_c
    print(f"fault-free {bill_c.mean():.0f}/network, faulty "
          f"{bill_f.mean():.0f}/network, worst ratio {ratio.max():.2f}x "
          f"(loss factor alone would be {fm.expected_transmissions():.2f}x)")

    assert (rel <= 0.05).all(), \
        f"retained variance drifted >5% on networks {np.nonzero(rel > 0.05)[0]}"
    assert (ratio <= 2.0).all(), \
        f"packet bill exceeded 2x on networks {np.nonzero(ratio > 2.0)[0]}"

    # -- serving-engine coda: a network that dies outright ------------------
    print("\n-- engine: death, stall verdict, revival, re-admission ----")
    from repro.serve.engine import StreamingPCAEngine, StreamRequest
    eng = StreamingPCAEngine(cfg_fault, slots=2, seed=0)
    rng = np.random.default_rng(2)
    live = np.ones((40, P), np.float32)
    live[12:26, :] = 0.0                      # total blackout, then revival
    reqs = [StreamRequest(rounds=rng.normal(size=(40, N_PER_ROUND, P))
                          .astype(np.float32), liveness=live if i == 0 else None)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    dead = reqs[0]
    print(f"network 0: {len(dead.retirements)} dead retirement(s) "
          f"(streamed {dead.retirements[0].rounds} rounds before the stall "
          f"verdict), then re-admitted and completed {dead.result.rounds} "
          f"more rounds")
    print(f"mesh re-plans as the live count moved: "
          f"{[(pl.data, pl.model) for pl in eng.plan_history]}")
    assert dead.done and dead.result.reason == "completed"
    assert len(dead.retirements) == 1 and dead.retirements[0].reason == "dead"

    print("\nOK: fleet survived loss + churn within 5% accuracy at "
          f"{ratio.max():.2f}x <= 2x the fault-free bill.")


if __name__ == "__main__":
    main()
