"""The async double-buffered serving engine (DESIGN.md Sec. 17).

Four claims, each pinned:

1. **Bit-identical parity** — ``pipeline=True`` reorders host work only,
   never device math: sync and pipelined engines produce byte-equal
   results (bases, metrics, Table-1 bills) across the full differential
   matrix — masked and unmasked streams, partial tail chunks, mid-chunk
   dead retirement with revival, multiple submission waves, compression
   and detection books.
2. **Queue semantics** — priority ordering, FIFO within a priority,
   per-tenant quota enforcement, bounded-queue backpressure, and full
   determinism of the admission sequence given an arrival schedule.
3. **No aliasing** — uploads are owned copies: scribbling over the pinned
   host staging buffers immediately after upload never changes device
   results (the CPU ``device_put`` zero-copy hazard).
4. **Telemetry** — the ring recorder observes the loop without touching
   it: step records, JSONL lines, latency percentiles, overlap/prestage
   accounting.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.serve.engine import StreamingPCAEngine, StreamRequest
from repro.serve.queue import AdmissionQueue, QueuePolicy
from repro.serve.telemetry import StepRecord, TelemetryRecorder
from repro.streaming import CompressionConfig, DetectionConfig, StreamConfig

P, Q, N = 8, 2, 4


def _cfg(**kw):
    base = dict(p=P, q=Q, halfwidth=1, forgetting=0.9, drift_threshold=0.1,
                warmup_rounds=2, interpret=True)
    base.update(kw)
    return StreamConfig(**base)


def _req(rng, rounds=6, liveness=None, **kw):
    x = rng.normal(size=(rounds, N, P)).astype(np.float32)
    return StreamRequest(rounds=x, liveness=liveness, **kw)


def _result_fields(res):
    return {f: getattr(res, f) for f in (
        "retained", "refreshes", "comm_packets", "rounds", "reason",
        "total_variance", "compression_max_err",
        "compression_extra_packets", "compression_bits_on_air",
        "detection_events", "detection_alarm_packets",
        "detection_t2_threshold", "detection_spe_threshold")}


def assert_results_identical(a: StreamRequest, b: StreamRequest):
    assert a.done == b.done
    assert (a.result is None) == (b.result is None)
    pairs = list(zip(a.retirements, b.retirements, strict=True))
    if a.result is not None:
        pairs.append((a.result, b.result))
    for ra, rb in pairs:
        np.testing.assert_array_equal(ra.components, rb.components)
        np.testing.assert_array_equal(ra.energies, rb.energies)
        assert _result_fields(ra) == _result_fields(rb)


# ===========================================================================
# 1. Differential matrix: sync vs pipelined, bit-identical
# ===========================================================================
def _run_matrix(pipeline: bool, *, cfg=None, schedule=None, seed=3,
                slots=3, chunk=2):
    """One deterministic serving run.  ``schedule`` is a list of
    per-step submission waves (step index -> list of request builders);
    wave 0 is submitted before the first step."""
    cfg = cfg or _cfg()
    eng = StreamingPCAEngine(cfg, slots=slots, seed=0, chunk=chunk,
                             pipeline=pipeline, telemetry=True)
    rng = np.random.default_rng(seed)
    schedule = schedule or {0: [dict(rounds=6) for _ in range(6)]}
    reqs = []
    step = 0
    for wave_step in sorted(schedule):
        while step < wave_step:
            eng.step()
            step += 1
        for kw in schedule[wave_step]:
            r = _req(rng, **kw)
            reqs.append(r)
            eng.submit(r)
    eng.run_until_done()
    return eng, reqs


def _assert_parity(**kw):
    e_sync, r_sync = _run_matrix(False, **kw)
    e_pipe, r_pipe = _run_matrix(True, **kw)
    for a, b in zip(r_sync, r_pipe, strict=True):
        assert_results_identical(a, b)
    # same retirement ledger (request index + reason, in order)
    ledger = lambda eng, reqs: [(reqs.index(q), why)
                                for q, why in eng.retired_log]
    assert ledger(e_sync, r_sync) == ledger(e_pipe, r_pipe)
    assert e_pipe.pulls["hot"] == 0
    return e_sync, e_pipe


class TestParity:
    def test_unmasked(self):
        _assert_parity()

    def test_partial_tail_chunks(self):
        # lengths 5..10 against chunk=2 and 3: tails of 1 and 2 rounds
        for chunk in (2, 3):
            sched = {0: [dict(rounds=5 + i) for i in range(6)]}
            _assert_parity(schedule=sched, chunk=chunk)

    def test_masked_liveness(self):
        rng = np.random.default_rng(7)
        waves = []
        for i in range(5):
            lv = (rng.uniform(size=(7, P)) > 0.2).astype(np.float32) \
                if i % 2 == 0 else None
            waves.append(dict(rounds=7, liveness=lv))
        _assert_parity(schedule={0: waves})

    def test_mid_chunk_dead_retirement_and_revival(self):
        # all sensors die at round 3 (mid-chunk at K=2) and revive at
        # round 11: long enough dead for the 2.5-step stall verdict; the
        # network must retire dead and re-admit from the revival round
        lv = np.ones((16, P), np.float32)
        lv[3:11] = 0.0
        sched = {0: [dict(rounds=16, liveness=lv), dict(rounds=16)]}
        e_sync, e_pipe = _assert_parity(
            schedule=sched, slots=2,
            cfg=_cfg())
        reasons = [why for _, why in e_sync.retired_log]
        assert "dead" in reasons       # the schedule actually killed it

    def test_multiple_submission_waves(self):
        # late submissions land mid-serving.  A wave that arrives while a
        # slot is IDLE fills it at the next step's admission, changing the
        # slot plan under the prestaged chunk: the pipelined engine must
        # detect the signature mismatch and restage inline, never fold a
        # stale batch.  (A wave landing while all slots are busy only
        # queues — the end-of-step admit handles it before prestaging, so
        # it costs no miss.)
        sched = {0: [dict(rounds=6)],
                 2: [dict(rounds=5), dict(rounds=7)],
                 4: [dict(rounds=6)]}
        e_sync, e_pipe = _assert_parity(schedule=sched, slots=2)
        assert e_pipe._prestage_misses > 1   # waves really invalidated plans

    def test_compression_and_detection_books(self):
        cfg = _cfg(compression=CompressionConfig(epsilon=0.5,
                                                 emit_reconstruction=False),
                   detection=DetectionConfig(alpha=1e-3, calib_rounds=2))
        _assert_parity(cfg=cfg, schedule={0: [dict(rounds=8)
                                              for _ in range(5)]})

    def test_pipelined_prestages_in_steady_state(self):
        _, e_pipe = _assert_parity(
            schedule={0: [dict(rounds=10) for _ in range(3)]}, slots=3)
        assert e_pipe._prestage_hits >= 3
        assert e_pipe._transfer_fences >= 1   # double buffers really cycle


# ===========================================================================
# 2. Queue semantics
# ===========================================================================
class TestAdmissionQueue:
    def test_priority_order_fifo_within_class(self):
        q = AdmissionQueue()
        for name, pri in (("a", 0), ("b", 5), ("c", 0), ("d", 5)):
            q.submit(name, priority=pri)
        order = [q.pop_admissible({}).req for _ in range(4)]
        assert order == ["b", "d", "a", "c"]

    def test_capacity_backpressure(self):
        q = AdmissionQueue(QueuePolicy(capacity=2))
        assert q.submit("a") and q.submit("b")
        assert not q.submit("c")           # full -> rejected
        assert q.rejected == 1 and len(q) == 2
        assert q.submit("d", internal=True)   # continuations bypass
        assert len(q) == 3

    def test_tenant_quota_skips_in_place(self):
        q = AdmissionQueue(QueuePolicy(max_slots_per_tenant=1))
        q.submit("t1-a", tenant="t1", priority=9)
        q.submit("t2-a", tenant="t2")
        # t1 over quota: its top-priority entry is skipped, NOT dropped
        got = q.pop_admissible({"t1": 1})
        assert got.req == "t2-a"
        assert len(q) == 1
        # quota freed -> the skipped entry admits
        assert q.pop_admissible({"t1": 0}).req == "t1-a"

    def test_depth_by_priority(self):
        q = AdmissionQueue()
        for pri in (0, 1, 1, 2):
            q.submit("x", priority=pri)
        assert q.depth_by_priority() == {0: 1, 1: 2, 2: 1}

    def test_policy_validation(self):
        with pytest.raises(ValueError, match="capacity"):
            QueuePolicy(capacity=-1)
        with pytest.raises(ValueError, match="max_slots_per_tenant"):
            QueuePolicy(max_slots_per_tenant=0)


class TestEngineQueueFrontEnd:
    def test_priority_admission_order(self):
        eng = StreamingPCAEngine(_cfg(), slots=1, seed=0, chunk=2)
        rng = np.random.default_rng(0)
        lo, hi = _req(rng, 4, priority=0), _req(rng, 4, priority=3)
        eng.submit(lo)
        eng.submit(hi)
        eng.step()
        assert eng.active[0] is hi         # higher priority won the slot
        eng.run_until_done()
        assert lo.done and hi.done

    def test_tenant_quota_enforced_across_steps(self):
        eng = StreamingPCAEngine(
            _cfg(), slots=3, seed=0, chunk=2,
            queue=QueuePolicy(max_slots_per_tenant=1))
        rng = np.random.default_rng(1)
        mine = [_req(rng, 6, tenant="noisy") for _ in range(3)]
        other = _req(rng, 6, tenant="quiet")
        for r in mine:
            eng.submit(r)
        eng.submit(other)
        max_held = 0
        while eng.step() or eng.queue:
            held = sum(1 for q in eng.active
                       if q is not None and q.tenant == "noisy")
            max_held = max(max_held, held)
        assert max_held == 1               # never more than the quota
        assert all(r.done for r in mine) and other.done

    def test_backpressure_rejects_and_records(self):
        eng = StreamingPCAEngine(_cfg(), slots=1, seed=0, chunk=2,
                                 queue=QueuePolicy(capacity=2),
                                 telemetry=True)
        rng = np.random.default_rng(2)
        assert eng.submit(_req(rng, 4))        # queued
        assert eng.submit(_req(rng, 4))        # queued (at capacity now)
        rejected = _req(rng, 4)
        assert not eng.submit(rejected)        # bounded queue full
        assert eng.queue.rejected == 1
        kinds = [e["kind"] for e in eng.telemetry.events]
        assert "rejected" in kinds
        eng.run_until_done()
        assert not rejected.done               # caller owns the retry

    def test_revival_requeue_bypasses_capacity(self):
        lv = np.ones((14, P), np.float32)
        lv[2:10] = 0.0                         # dies, revives at round 10
        eng = StreamingPCAEngine(_cfg(), slots=1, seed=0, chunk=2,
                                 queue=QueuePolicy(capacity=0))
        rng = np.random.default_rng(3)
        req = _req(rng, 14, liveness=lv)
        # capacity 0: external submit is rejected...
        assert not eng.submit(req)
        eng2 = StreamingPCAEngine(_cfg(), slots=1, seed=0, chunk=2,
                                  queue=QueuePolicy(capacity=1))
        assert eng2.submit(req)
        eng2.run_until_done()
        # ...but the engine's own continuation re-queue is exempt: the
        # dead segment retired AND the revival segment completed
        assert req.done
        assert [r.reason for r in req.retirements] == ["dead"]

    def test_determinism_replay(self):
        def once():
            eng = StreamingPCAEngine(_cfg(), slots=2, seed=0, chunk=2,
                                     pipeline=True,
                                     queue=QueuePolicy(capacity=4),
                                     telemetry=True)
            rng = np.random.default_rng(5)
            lv = np.ones((9, P), np.float32)
            lv[3:7] = 0.0
            waves = {0: [dict(rounds=6, priority=1),
                         dict(rounds=9, liveness=lv)],
                     1: [dict(rounds=5), dict(rounds=7, priority=2)],
                     3: [dict(rounds=6)]}
            reqs, step = [], 0
            for ws in sorted(waves):
                while step < ws:
                    eng.step()
                    step += 1
                for kw in waves[ws]:
                    r = _req(rng, **kw)
                    reqs.append(r)
                    eng.submit(r)
            eng.run_until_done()
            admits = [(e["slot"], e["resume_at"], e["priority"])
                      for e in eng.telemetry.events
                      if e["kind"] == "admitted"]
            ledger = [(reqs.index(q), why) for q, why in eng.retired_log]
            return admits, ledger, [_result_fields(r.result) for r in reqs]

        assert once() == once()


# ===========================================================================
# 3. The device_put aliasing hazard (owned double buffers)
# ===========================================================================
class TestNoAliasing:
    def test_upload_is_owned_copy(self):
        eng = StreamingPCAEngine(_cfg(), slots=1, seed=0)
        host = np.ones((4, 4), np.float32)
        dev = eng._upload(host)
        host[:] = 777.0                    # poison immediately after upload
        np.testing.assert_array_equal(np.asarray(dev),
                                      np.ones((4, 4), np.float32))

    @pytest.mark.parametrize("pipeline", [False, True])
    def test_poisoned_staging_buffers_leave_results_unchanged(self,
                                                              pipeline):
        def run(poison):
            eng = StreamingPCAEngine(_cfg(), slots=2, seed=0, chunk=2,
                                     pipeline=pipeline)
            rng = np.random.default_rng(11)
            lv = (rng.uniform(size=(7, P)) > 0.2).astype(np.float32)
            reqs = [_req(rng, 7, liveness=lv), _req(rng, 6), _req(rng, 5)]
            for r in reqs:
                eng.submit(r)
            while eng.step() or eng.queue:
                if poison:
                    # scribble over BOTH pinned staging buffers right
                    # after the step dispatched its uploads: owned-copy
                    # uploads mean the in-flight device batches (and the
                    # prestaged chunk, in pipelined mode) must not move
                    for buf in eng._host_bufs + eng._mask_bufs:
                        if buf is not None:
                            buf.fill(np.float32(1e9))
            return reqs

        for a, b in zip(run(False), run(True), strict=True):
            assert_results_identical(a, b)


# ===========================================================================
# 4. Telemetry
# ===========================================================================
class TestTelemetry:
    def _rec(self, i, **kw):
        base = dict(step=i, wall_s=0.01, stage_s=0.004, overlap_s=0.003,
                    prestaged=True, live=2, rounds=4, queue_depth=1,
                    admitted=0, retired=0)
        base.update(kw)
        return StepRecord(**base)

    def test_ring_is_bounded_but_totals_are_lifetime(self):
        t = TelemetryRecorder(capacity=8)
        for i in range(20):
            t.record_step(self._rec(i, rounds=2))
        assert len(t.steps) == 8
        assert t.total_steps == 20 and t.total_rounds == 40

    def test_percentiles_and_overlap(self):
        t = TelemetryRecorder()
        for i in range(10):
            t.record_step(self._rec(i, wall_s=0.01 * (i + 1)))
        pct = t.step_latency_percentiles()
        assert pct["p50"] == pytest.approx(0.055)
        assert pct["p99"] <= 0.1
        # wall-weighted overlap: 10 * 0.003 / sum(walls)
        assert t.mean_overlap_fraction() == pytest.approx(0.03 / 0.55)
        assert t.prestage_hit_rate() == 1.0

    def test_jsonl_sink(self, tmp_path):
        path = tmp_path / "telemetry.jsonl"
        with TelemetryRecorder(jsonl_path=str(path)) as t:
            t.record_step(self._rec(0))
            t.record_event("admitted", step=0, slot=1)
        lines = [json.loads(line) for line in path.read_text().splitlines()]
        assert [ln["kind"] for ln in lines] == ["step", "admitted"]
        assert lines[0]["overlap_fraction"] == pytest.approx(0.3)

    def test_reset_clears_window(self):
        t = TelemetryRecorder()
        t.record_step(self._rec(0))
        t.reset()
        assert t.total_steps == 0 and len(t.steps) == 0

    def test_sync_engine_has_zero_overlap(self):
        eng = StreamingPCAEngine(_cfg(), slots=2, seed=0, chunk=2,
                                 telemetry=True)
        rng = np.random.default_rng(0)
        for _ in range(3):
            eng.submit(_req(rng, 6))
        eng.run_until_done()
        s = eng.telemetry.summary()
        assert s["overlap_fraction"] == 0.0
        assert s["prestage_hit_rate"] == 0.0
        assert s["retired"] == 3
        assert s["rounds"] == 18

    def test_pipelined_engine_reports_overlap_and_hits(self):
        eng = StreamingPCAEngine(_cfg(), slots=2, seed=0, chunk=2,
                                 pipeline=True, telemetry=True)
        rng = np.random.default_rng(0)
        for _ in range(3):
            eng.submit(_req(rng, 6))
        eng.run_until_done()
        s = eng.telemetry.summary()
        assert s["prestage_hit_rate"] > 0.5
        assert s["overlap_fraction"] > 0.0
        assert eng.pulls["hot"] == 0
        assert eng.pulls["retire"] > 0


# ===========================================================================
# Benchmark smoke: one tiny sustained-load drive through the bench helper
# ===========================================================================
def test_engine_bench_drive_smoke():
    from benchmarks.engine_bench import _drive, _requests

    rng = np.random.default_rng(0)
    reqs = _requests(rng, 4, 6, masked=True, jitter=3)
    for r in reqs:          # bench helpers emit engine-shaped requests
        assert r.rounds.dtype == np.float32
    cfg = _cfg()
    # the bench drives (p=32) fleets; reuse its helper on the tiny config
    reqs = [_req(np.random.default_rng(1), 6) for _ in range(4)]
    warm = _req(np.random.default_rng(2), 4)
    m = _drive(cfg, slots=2, chunk=2, pipeline=True, reqs=reqs,
               warm_req=warm)
    assert m["requests_per_s"] > 0
    assert 0.0 <= m["overlap"] <= 1.0
    assert m["prestage_hit_rate"] > 0.0
