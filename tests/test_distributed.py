"""Distribution layer: sharding rules, gradient compression, halo exchange,
pipeline schedule (single-device semantics + multi-device via shard_map where
the 1-device mesh suffices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec

from repro.distributed import compression as C
from repro.distributed.sharding import (act_rules, logical_to_pspec,
                                        param_rules)
from repro.models.params import P, param_pspecs


class TestShardingRules:
    def test_param_pspecs_divisibility(self):
        schema = {
            "wq": P((4096, 128, 128), ("embed", "heads", "head_dim")),
            "wk": P((4096, 4, 128), ("embed", "kv_heads", "head_dim")),
        }
        specs = param_pspecs(schema, param_rules(multi_pod=False),
                             mesh_axis_sizes={"data": 16, "model": 16})
        assert specs["wq"] == PartitionSpec("data", "model", None)
        # 4 kv heads cannot shard over 16-way model axis -> replicated
        assert specs["wk"] == PartitionSpec("data", None, None)

    def test_multi_pod_fsdp_axes(self):
        schema = {"w": P((8192, 8192), ("embed", "mlp"))}
        specs = param_pspecs(schema, param_rules(multi_pod=True),
                             mesh_axis_sizes={"pod": 2, "data": 16,
                                              "model": 16})
        assert specs["w"] == PartitionSpec(("pod", "data"), "model")

    def test_act_rules_seq_sharding(self):
        spec = logical_to_pspec(("batch", "seq", "act_embed"),
                                act_rules(multi_pod=False, seq_shard=True))
        assert spec == PartitionSpec("data", "data", None) or \
            spec == PartitionSpec(("data",), ("data",), None)


class TestGradCompression:
    def _fake_grads(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "w1": jax.random.normal(k1, (64, 96)),
            "stacked": jax.random.normal(k2, (4, 48, 64)),
            "bias": jax.random.normal(k3, (96,)),
        }

    def test_rank_r_exact_on_rank_r_matrix(self):
        """A rank-r gradient is reproduced exactly after 1-2 iterations."""
        rng = np.random.default_rng(0)
        u = rng.normal(size=(64, 4)).astype(np.float32)
        v = rng.normal(size=(96, 4)).astype(np.float32)
        g = {"w": jnp.asarray(u @ v.T)}
        state = C.init_compressor(g, rank=4, key=jax.random.PRNGKey(0))
        for _ in range(3):
            out, state = C.compress_gradients(g, state)
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                                   rtol=1e-3, atol=1e-3)

    def test_error_feedback_accumulates_residual(self):
        g = self._fake_grads(jax.random.PRNGKey(1))
        state = C.init_compressor(g, rank=2, key=jax.random.PRNGKey(2))
        out, state2 = C.compress_gradients(g, state)
        # compressed + error == original (up to fp32 rounding)
        err = state2.error["w1"]
        np.testing.assert_allclose(np.asarray(out["w1"] + err),
                                   np.asarray(g["w1"]), rtol=1e-4, atol=1e-4)

    def test_small_leaves_pass_through(self):
        g = self._fake_grads(jax.random.PRNGKey(3))
        state = C.init_compressor(g, rank=2, key=jax.random.PRNGKey(4))
        out, _ = C.compress_gradients(g, state)
        np.testing.assert_array_equal(np.asarray(out["bias"]),
                                      np.asarray(g["bias"]))

    def test_stacked_leading_dims(self):
        g = self._fake_grads(jax.random.PRNGKey(5))
        state = C.init_compressor(g, rank=2, key=jax.random.PRNGKey(6))
        out, state2 = C.compress_gradients(g, state)
        assert out["stacked"].shape == (4, 48, 64)
        assert state2.q["stacked"].shape == (4, 64, 2)

    def test_error_feedback_sgd_converges(self):
        """Least squares by compressed-gradient SGD reaches the solution —
        the error-feedback guarantee that makes the scheme production-safe."""
        rng = np.random.default_rng(7)
        A = rng.normal(size=(128, 32)).astype(np.float32) / np.sqrt(128)
        w_true = rng.normal(size=(32, 16)).astype(np.float32)
        Y = A @ w_true
        w = {"w": jnp.zeros((32, 16))}
        state = C.init_compressor(w, rank=2, key=jax.random.PRNGKey(8))
        lr = 0.3
        for _ in range(600):
            grad = {"w": jnp.asarray(A.T @ (A @ np.asarray(w["w"]) - Y))}
            cg, state = C.compress_gradients(grad, state)
            w = {"w": w["w"] - lr * cg["w"]}
        rel = np.linalg.norm(np.asarray(w["w"]) - w_true) / np.linalg.norm(w_true)
        assert rel < 0.05, rel

    def test_compression_ratio(self):
        g = {"w": jnp.zeros((1024, 1024)), "b": jnp.zeros((1024,))}
        ratio = C.compression_ratio(g, rank=4)
        # 1024*1024 -> 4*2048 (+1024 exact) ~ 0.0088
        assert ratio < 0.02


class TestHaloExchange:
    def test_single_device_ring(self):
        """halo_exchange on a 1-element axis: no neighbors -> zeros."""
        from repro.core.aggregation import halo_exchange
        mesh = jax.make_mesh((1,), ("p",))
        from jax.experimental.shard_map import shard_map

        def f(x):
            l, r = halo_exchange(x, 2, "p")
            return l, r

        x = jnp.arange(8.0).reshape(1, 8)
        fm = shard_map(f, mesh=mesh,
                       in_specs=PartitionSpec("p", None),
                       out_specs=(PartitionSpec("p", None),
                                  PartitionSpec("p", None)))
        l, r = fm(x)
        np.testing.assert_array_equal(np.asarray(l), np.zeros((1, 2)))
        np.testing.assert_array_equal(np.asarray(r), np.zeros((1, 2)))


class TestPipeline:
    def test_bubble_fraction(self):
        from repro.distributed.pipeline import bubble_fraction
        assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
        assert bubble_fraction(1, 8) == 0.0

    def test_single_stage_identity(self):
        """With one stage the pipeline is just layer_fn over microbatches."""
        from jax.experimental.shard_map import shard_map
        from repro.distributed.pipeline import pipeline_apply
        mesh = jax.make_mesh((1,), ("pipe",))
        w = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8))
                        .astype(np.float32))
        x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 8))
                        .astype(np.float32))

        def layer(p, h):
            return jnp.tanh(h @ p)

        def run(p, h):
            return pipeline_apply(layer, p, h, n_microbatches=2,
                                  axis_name="pipe")

        fm = shard_map(run, mesh=mesh,
                       in_specs=(PartitionSpec(), PartitionSpec()),
                       out_specs=PartitionSpec(), check_rep=False)
        out = fm(w, x)
        np.testing.assert_allclose(np.asarray(out),
                                   np.tanh(np.asarray(x) @ np.asarray(w)),
                                   rtol=1e-5, atol=1e-5)
