"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle.

Sweeps shapes/dtypes per the deliverable spec and asserts allclose against
ref.py.  interpret=True executes the kernel bodies on CPU; the BlockSpec
tilings are the ones used on real TPU.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:        # optional dev dependency (requirements-dev)
    # no-op stand-ins so the module still imports; the property tests
    # themselves are skipped by the importorskip fixture below
    def given(*args, **kwargs):
        return lambda f: f

    def settings(*args, **kwargs):
        return lambda f: f

    class _StubStrategies:
        def integers(self, *args, **kwargs):
            return None

    st = _StubStrategies()

from repro.kernels import ops, ref

def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _check(a, b, dtype, scale: float = 1.0):
    """Kernels accumulate in fp32; for bf16 inputs the oracle is evaluated in
    fp32 too, and tolerance covers bf16 *input representation* error (~2^-8
    relative per operand) scaled by the reduction length."""
    if dtype == jnp.bfloat16:
        atol, rtol = 0.02 * max(scale, 1.0) ** 0.5, 2e-2
    else:
        atol, rtol = 1e-5 * max(scale, 1.0) ** 0.5, 1e-5
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        atol=atol, rtol=rtol)


class TestBandedMatvec:
    @pytest.mark.parametrize("p,h,block_p", [
        (128, 1, 64), (256, 4, 128), (512, 8, 128), (384, 16, 128),
        (1024, 2, 512), (128, 0, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, p, h, block_p, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(p + h))
        band = _rand(k1, (2 * h + 1, p), dtype)
        v = _rand(k2, (p,), dtype)
        out = ops.banded_matvec(band, v, block_p=block_p, interpret=True)
        oracle = ref.banded_matvec(band.astype(jnp.float32),
                                   v.astype(jnp.float32))
        _check(out, oracle, dtype, scale=2 * h + 1)

    def test_matches_dense_matvec(self):
        from repro.core import covariance as cov
        rng = np.random.default_rng(0)
        p, h = 256, 4
        c = rng.normal(size=(p, p)).astype(np.float32)
        c = np.where(cov.mask_from_band(p, h), c, 0.0)
        band = cov.dense_to_band(jnp.asarray(c), h)
        v = jnp.asarray(rng.normal(size=p).astype(np.float32))
        out = ops.banded_matvec(band, v, interpret=True)
        np.testing.assert_allclose(np.asarray(out), c @ np.asarray(v),
                                   rtol=2e-4, atol=2e-4)


class TestBandedMatmul:
    @pytest.mark.parametrize("p,q,h,block_p", [
        (128, 4, 2, 64), (256, 16, 8, 128), (512, 32, 4, 256), (384, 8, 12, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, p, q, h, block_p, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(p * q + h))
        band = _rand(k1, (2 * h + 1, p), dtype)
        V = _rand(k2, (p, q), dtype)
        out = ops.banded_matmul(band, V, block_p=block_p, interpret=True)
        oracle = ref.banded_matmul(band.astype(jnp.float32),
                                   V.astype(jnp.float32))
        _check(out, oracle, dtype, scale=2 * h + 1)


class TestCovUpdate:
    @pytest.mark.parametrize("n,p,h,bp,bn", [
        (64, 128, 2, 64, 32), (128, 256, 8, 128, 64), (32, 512, 4, 256, 32),
        (96, 384, 1, 128, 32),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, n, p, h, bp, bn, dtype):
        x = _rand(jax.random.PRNGKey(n + p), (n, p), dtype)
        out = ops.cov_band_update(x, h, block_p=bp, block_n=bn, interpret=True)
        expected = ref.cov_band_update(x.astype(jnp.float32), h)
        # fp32 accumulation in the kernel: compare fp32-cast input oracle
        tol = 1e-4 if dtype == jnp.float32 else 0.15
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                                   rtol=tol, atol=tol * 8 * n ** 0.5)

    def test_accumulation_over_batch_blocks(self):
        """Grid revisiting must equal a single-pass reduction."""
        x = _rand(jax.random.PRNGKey(3), (128, 128), jnp.float32)
        out1 = ops.cov_band_update(x, 3, block_p=64, block_n=128, interpret=True)
        out2 = ops.cov_band_update(x, 3, block_p=64, block_n=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-5, atol=1e-3)

    def test_matches_core_banded_update(self):
        from repro.core import covariance as cov
        x = _rand(jax.random.PRNGKey(4), (64, 256), jnp.float32)
        h = 5
        st_ = cov.banded_update(cov.banded_init(256, h), x)
        out = ops.cov_band_update(x, h, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(st_.band),
                                   rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("B,n,p,h", [
        (3, 7, 37, 2),       # both prime: _pick_block falls back to 1
        (2, 10, 53, 3),      # n divisible by 2 only, p prime
        (4, 16, 48, 1),      # p divisible by 16 but not 128-aligned
    ])
    def test_batched_matches_per_network_loop_nondivisible(self, B, n, p, h):
        """Regression for _pick_block's fallback path: shapes where neither
        axis divides the preferred tile sizes must still agree with a
        per-network Python loop over the single-network kernel (and the
        oracle).  Pins the fallback-to-1 behavior for prime p."""
        from repro.kernels.ops import _pick_block
        if p in (37, 53):
            assert _pick_block(p) == 1           # the path under test
        x = _rand(jax.random.PRNGKey(B * n + p), (B, n, p), jnp.float32)
        out = ops.cov_band_update_batched(x, h, interpret=True)
        assert out.shape == (B, 2 * h + 1, p)
        for i in range(B):
            single = ops.cov_band_update(x[i], h, interpret=True)
            np.testing.assert_allclose(np.asarray(out[i]), np.asarray(single),
                                       rtol=1e-5, atol=1e-5)
            oracle = ref.cov_band_update(x[i], h)
            np.testing.assert_allclose(np.asarray(out[i]), np.asarray(oracle),
                                       rtol=1e-4, atol=1e-4)


class TestPcaProject:
    @pytest.mark.parametrize("n,p,q,bn,bk", [
        (128, 256, 8, 64, 128), (64, 512, 32, 32, 256), (256, 128, 4, 128, 64),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_project(self, n, p, q, bn, bk, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(n + p + q))
        x = _rand(k1, (n, p), dtype)
        w = _rand(k2, (p, q), dtype)
        out = ops.pca_project(x, w, block_n=bn, block_k=bk, interpret=True)
        expected = ref.pca_project(x.astype(jnp.float32), w.astype(jnp.float32))
        tol = 1e-4 if dtype == jnp.float32 else 0.1
        np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                                   rtol=tol, atol=tol * p ** 0.5)

    @pytest.mark.parametrize("n,p,q,bn,bp", [
        (128, 256, 8, 64, 128), (64, 512, 16, 32, 256),
    ])
    def test_reconstruct(self, n, p, q, bn, bp):
        k1, k2 = jax.random.split(jax.random.PRNGKey(1))
        z = _rand(k1, (n, q), jnp.float32)
        w = _rand(k2, (p, q), jnp.float32)
        out = ops.pca_reconstruct(z, w, block_n=bn, block_p=bp, interpret=True)
        _check(out, ref.pca_reconstruct(z, w), jnp.float32)

    @pytest.mark.parametrize("n,p,q", [
        (100, 97, 5),        # p prime: the old auto-pick tiled by 1
        (37, 53, 3),         # both prime
        (100, 48, 4),        # n awkward, p fine
    ])
    def test_project_reconstruct_nondivisible(self, n, p, q):
        """Regression: awkward (prime/odd) shapes must work through the
        padded wrappers and be BIT-IDENTICAL to the zero-padded kernel
        (the padded oracle): padded feature columns multiply zero basis
        rows, so every fp32 partial sum they add is exactly 0.0."""
        from repro.kernels.ops import _pad_dim, _pick_block_padded
        from repro.kernels.pca_project import (pca_project_pallas,
                                               pca_reconstruct_pallas)
        k1, k2 = jax.random.split(jax.random.PRNGKey(n * p + q))
        x = _rand(k1, (n, p), jnp.float32)
        w = _rand(k2, (p, q), jnp.float32)

        z = ops.pca_project(x, w, interpret=True)
        assert z.shape == (n, q)
        _check(z, ref.pca_project(x, w), jnp.float32, scale=p)
        bn = _pick_block_padded(n, 128)
        bk = _pick_block_padded(p, 512)
        xp = jnp.pad(x, ((0, _pad_dim(n, bn) - n), (0, _pad_dim(p, bk) - p)))
        wp = jnp.pad(w, ((0, _pad_dim(p, bk) - p), (0, 0)))
        oracle = pca_project_pallas(xp, wp, block_n=bn, block_k=bk,
                                    interpret=True)[:n]
        np.testing.assert_array_equal(np.asarray(z), np.asarray(oracle))

        xh = ops.pca_reconstruct(z, w, interpret=True)
        assert xh.shape == (n, p)
        _check(xh, ref.pca_reconstruct(z, w), jnp.float32, scale=q)
        bp = _pick_block_padded(p, 512)
        zp = jnp.pad(z, ((0, _pad_dim(n, bn) - n), (0, 0)))
        oracle_r = pca_reconstruct_pallas(zp, wp, block_n=bn, block_p=bp,
                                          interpret=True)[:n, :p]
        np.testing.assert_array_equal(np.asarray(xh), np.asarray(oracle_r))

    def test_explicit_nondividing_block_pads_instead_of_crashing(self):
        """An explicit block that does not divide the axis used to trip the
        kernel asserts; the wrappers now pad-to-block and slice."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(7))
        x = _rand(k1, (100, 97), jnp.float32)
        w = _rand(k2, (97, 4), jnp.float32)
        z = ops.pca_project(x, w, block_n=32, block_k=64, interpret=True)
        _check(z, ref.pca_project(x, w), jnp.float32, scale=97)
        xh = ops.pca_reconstruct(z, w, block_n=32, block_p=64, interpret=True)
        _check(xh, ref.pca_reconstruct(z, w), jnp.float32, scale=4)

    def test_divisible_shapes_bit_identical_to_unpadded_kernel(self):
        """The padding path must be invisible on divisible shapes: the
        wrapper output equals the direct kernel call bit-for-bit."""
        from repro.kernels.pca_project import pca_project_pallas
        k1, k2 = jax.random.split(jax.random.PRNGKey(9))
        x = _rand(k1, (128, 256), jnp.float32)
        w = _rand(k2, (256, 8), jnp.float32)
        out = ops.pca_project(x, w, block_n=64, block_k=128, interpret=True)
        direct = pca_project_pallas(x, w, block_n=64, block_k=128,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(direct))

    def test_project_reconstruct_roundtrip_orthonormal(self):
        """W orthonormal + X in span(W)  =>  reconstruct(project(X)) == X."""
        rng = np.random.default_rng(0)
        p, q, n = 256, 16, 64
        W = np.linalg.qr(rng.normal(size=(p, q)))[0].astype(np.float32)
        Z0 = rng.normal(size=(n, q)).astype(np.float32)
        X = Z0 @ W.T
        z = ops.pca_project(jnp.asarray(X), jnp.asarray(W), interpret=True)
        xh = ops.pca_reconstruct(z, jnp.asarray(W), interpret=True)
        np.testing.assert_allclose(np.asarray(xh), X, rtol=1e-4, atol=1e-4)


class TestKernelProperties:
    """Hypothesis sweeps over irregular (but block-divisible) shapes."""

    @pytest.fixture(autouse=True)
    def _require_hypothesis(self):
        pytest.importorskip("hypothesis")

    @settings(max_examples=20, deadline=None)
    @given(pb=st.integers(1, 8), h=st.integers(0, 6), seed=st.integers(0, 2**16))
    def test_matvec_property(self, pb, h, seed):
        p = 64 * pb
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        band = _rand(k1, (2 * h + 1, p), jnp.float32)
        v = _rand(k2, (p,), jnp.float32)
        out = ops.banded_matvec(band, v, block_p=64, interpret=True)
        _check(out, ref.banded_matvec(band, v), jnp.float32)

    @settings(max_examples=15, deadline=None)
    @given(nb=st.integers(1, 4), pb=st.integers(1, 4), q=st.integers(1, 24),
           seed=st.integers(0, 2**16))
    def test_project_property(self, nb, pb, q, seed):
        n, p = 32 * nb, 64 * pb
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = _rand(k1, (n, p), jnp.float32)
        w = _rand(k2, (p, q), jnp.float32)
        out = ops.pca_project(x, w, block_n=32, block_k=64, interpret=True)
        _check(out, ref.pca_project(x, w), jnp.float32)

    @settings(max_examples=10, deadline=None)
    @given(h=st.integers(0, 5), seed=st.integers(0, 2**16))
    def test_cov_update_symmetry(self, h, seed):
        """band[h+k, i] == band[h-k, i+k] (S_ij == S_ji)."""
        x = _rand(jax.random.PRNGKey(seed), (32, 128), jnp.float32)
        band = np.asarray(ops.cov_band_update(x, h, interpret=True))
        p = 128
        for k in range(1, h + 1):
            lhs = band[h + k, : p - k]     # S_{i, i+k}
            rhs = band[h - k, k:]          # S_{i+k, i}
            np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-3)
