"""Device-resident compression tier vs. the NumPy oracle.

The differential suite for the fused ε-supervised Pallas pass
(kernels/pca_project.py::supervised_compress_pallas), the streaming
compressor stage, the cost booking, and the serving engine integration —
always against `core/compression.py`, which stays the host-side oracle.

Shared convention under test (ISSUE satellite): flag on the *strict*
``err > eps``, guarantee asserted as the *closed* ``<= eps`` everywhere,
identically on the device tier and the NumPy oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:        # optional dev dependency
    def given(*args, **kwargs):
        return lambda f: f

    def settings(*args, **kwargs):
        return lambda f: f

    class _StubStrategies:
        def integers(self, *args, **kwargs):
            return None

        def floats(self, *args, **kwargs):
            return None

    st = _StubStrategies()

from repro.core import costs
from repro.core.compression import SupervisedCompressor, pcag_primitives, scores
from repro.kernels import ops, ref
from repro.streaming import (CompressionConfig, StreamConfig, compress_round,
                             quantize_scores, stream_init, stream_run)

P, Q, H = 32, 3, 4


def _data(seed, n, p, q):
    rng = np.random.default_rng(seed)
    scale = np.linspace(3.0, 0.7, p)
    x = (rng.normal(size=(n, p)) * scale).astype(np.float32)
    W = np.linalg.qr(rng.normal(size=(p, q)))[0].astype(np.float32)
    mean = x.mean(axis=0).astype(np.float32)
    return x, W, mean


def _flags_match(fl_dev, fl_ref, err, eps, tol=1e-4):
    """Flags must agree wherever the error is not within float noise of the
    open/closed boundary (two correct implementations may disagree only
    there)."""
    fl_dev, fl_ref = np.asarray(fl_dev), np.asarray(fl_ref)
    borderline = np.abs(np.asarray(err) - eps) < tol
    assert (fl_dev == fl_ref)[~borderline].all()


class TestFusedKernelVsOracles:
    @pytest.mark.parametrize("n,p,q", [
        (64, 32, 3),          # block-divisible
        (100, 97, 5),         # non-divisible (prime p)
        (7, 13, 2),           # tiny, below every preferred tile
    ])
    @pytest.mark.parametrize("eps", [0.0, 0.4, 1e30])
    def test_matches_jnp_ref(self, n, p, q, eps):
        """Fused kernel == unfused jnp reference, all-alive."""
        x, W, mean = _data(n * p + q, n, p, q)
        z, xh, fl = ops.supervised_compress(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            epsilon=eps, interpret=True)
        zr, xr, fr = ref.supervised_compress(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            jnp.ones((n, p), jnp.float32), eps)
        np.testing.assert_allclose(np.asarray(z), np.asarray(zr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(xh), np.asarray(xr),
                                   rtol=1e-5, atol=1e-5)
        _flags_match(fl, fr, np.abs(x - np.asarray(xr)), eps)
        # the guarantee, closed bound, on the substituted sink view
        x_sink = np.where(np.asarray(fl), x, np.asarray(xh))
        assert np.abs(x_sink - x).max() <= eps + 1e-5

    @pytest.mark.parametrize("n,p,q", [(64, 32, 3), (100, 97, 5)])
    def test_matches_numpy_oracle_fp32(self, n, p, q):
        """Device tier vs core/compression.py at the SAME dtype (fp32) —
        the satellite dtype fix makes this comparison meaningful."""
        eps = 0.35
        x, W, mean = _data(seed=5, n=n, p=p, q=q)
        comp = SupervisedCompressor(W, mean, epsilon=eps, dtype=np.float32)
        assert comp.W.dtype == np.float32          # dtype defaulted from W
        out = comp.run(x)
        z, xh, fl = ops.supervised_compress(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            epsilon=eps, interpret=True)
        zo = scores(W, x, mean)                    # dtype defaults to fp32
        assert zo.dtype == np.float32
        np.testing.assert_allclose(np.asarray(z), zo, rtol=1e-4, atol=1e-4)
        x_sink = np.where(np.asarray(fl), x, np.asarray(xh))
        np.testing.assert_allclose(x_sink, out.x_hat, rtol=1e-4, atol=1e-4)
        _flags_match(fl, out.flagged, np.abs(x - np.asarray(xh)), eps)
        # both paths honor the closed bound
        assert np.abs(out.x_hat - x).max() <= eps + 1e-6
        assert np.abs(x_sink - x).max() <= eps + 1e-5

    def test_float64_oracle_is_default_for_float64_input(self):
        """dtype parameter: float64 in, float64 arithmetic out (back-compat)."""
        rng = np.random.default_rng(0)
        W = np.linalg.qr(rng.normal(size=(8, 2)))[0]
        comp = SupervisedCompressor(W, np.zeros(8), epsilon=0.1)
        assert comp.W.dtype == np.float64
        out = comp.run(rng.normal(size=(4, 8)))
        assert out.x_hat.dtype == np.float64
        assert scores(W, rng.normal(size=(4, 8))).dtype == np.float64

    def test_epsilon_edges(self):
        """ε = 0: every live sensor with any error notifies and the sink is
        exact; ε = inf-ish: nobody notifies and the sink is pure PCAg."""
        x, W, mean = _data(seed=3, n=16, p=P, q=Q)
        z, xh, fl = ops.supervised_compress(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            epsilon=0.0, interpret=True)
        x_sink = np.where(np.asarray(fl), x, np.asarray(xh))
        np.testing.assert_array_equal(x_sink[np.asarray(fl)],
                                      x[np.asarray(fl)])
        assert np.abs(x_sink - x).max() == 0.0     # <= 0: exact
        _, xh2, fl2 = ops.supervised_compress(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            epsilon=1e30, interpret=True)
        assert not np.asarray(fl2).any()

    def test_masked_dead_sensors(self):
        """Dead sensors send no score record (their contribution to Z is
        absent), never notify, and are owed no bound."""
        x, W, mean = _data(seed=11, n=24, p=P, q=Q)
        alive = np.ones(P, np.float32)
        alive[5] = alive[17] = 0.0
        z, xh, fl = ops.supervised_compress(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            epsilon=0.3, mask=jnp.asarray(alive), interpret=True)
        # scores equal the oracle computed on the masked centered data
        zo = ((x - mean) * alive) @ W
        np.testing.assert_allclose(np.asarray(z), zo, rtol=1e-4, atol=1e-4)
        assert not np.asarray(fl)[:, [5, 17]].any()
        # live sensors still honor the bound
        x_sink = np.where(np.asarray(fl), x, np.asarray(xh))
        live_cols = alive > 0
        assert np.abs(x_sink - x)[:, live_cols].max() <= 0.3 + 1e-5

    def test_batched_matches_per_network_loop(self):
        Bn = 3
        rng = np.random.default_rng(2)
        xb = rng.normal(size=(Bn, 10, 29)).astype(np.float32)   # odd p
        wb = rng.normal(size=(Bn, 29, 4)).astype(np.float32)
        zb, xhb, flb = ops.supervised_compress_batched(
            jnp.asarray(xb), jnp.asarray(wb), epsilon=0.5, interpret=True)
        assert zb.shape == (Bn, 10, 4) and xhb.shape == (Bn, 10, 29)
        for i in range(Bn):
            zi, xi, fi = ops.supervised_compress(
                jnp.asarray(xb[i]), jnp.asarray(wb[i]), epsilon=0.5,
                interpret=True)
            np.testing.assert_array_equal(np.asarray(zb[i]), np.asarray(zi))
            np.testing.assert_array_equal(np.asarray(flb[i]), np.asarray(fi))


class TestQuantizer:
    def test_identity_at_zero_bits(self):
        z = jnp.asarray(np.random.default_rng(0).normal(size=(8, 3)),
                        jnp.float32)
        zq, scale = quantize_scores(z, 0)
        assert scale is None
        np.testing.assert_array_equal(np.asarray(zq), np.asarray(z))

    def test_rejects_one_bit(self):
        z = jnp.zeros((4, 2), jnp.float32)
        with pytest.raises(ValueError):
            quantize_scores(z, 1)
        with pytest.raises(ValueError):
            CompressionConfig(epsilon=0.1, score_bits=1)
        with pytest.raises(ValueError):
            CompressionConfig(epsilon=-1.0)

    def test_rejects_bad_word_bits(self):
        with pytest.raises(ValueError):
            CompressionConfig(epsilon=0.1, word_bits=0)
        with pytest.raises(ValueError):
            CompressionConfig(epsilon=0.1, word_bits=-8)
        with pytest.raises(ValueError):
            CompressionConfig(epsilon=0.1, score_bits=16, word_bits=8)

    def test_error_bounded_and_shrinks_with_bits(self):
        """Round-to-nearest: |z - z_q| <= scale/2; more bits, less error."""
        z = jnp.asarray(np.random.default_rng(1).normal(size=(64, 4)),
                        jnp.float32)
        errs = []
        for bits in (2, 4, 8, 12):
            zq, scale = quantize_scores(z, bits)
            err = np.abs(np.asarray(zq) - np.asarray(z))
            assert (err <= np.asarray(scale)[None, :] / 2 + 1e-7).all()
            errs.append(err.max())
        assert errs == sorted(errs, reverse=True)

    def test_guarantee_survives_quantization(self):
        """Nodes flag against the dequantized reconstruction the sink uses,
        so even 2-bit scores keep the sink within ε."""
        x, W, mean = _data(seed=4, n=20, p=P, q=Q)
        for bits in (2, 4, 8):
            out = compress_round(jnp.asarray(W), jnp.asarray(mean),
                                 jnp.asarray(x),
                                 CompressionConfig(epsilon=0.25,
                                                   score_bits=bits),
                                 c_max=4, interpret=True)
            assert float(out.max_err) <= 0.25 + 1e-5


class TestStreamingIntegration:
    def _cfg(self, **kw):
        return StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                            drift_threshold=0.08, warmup_rounds=4,
                            interpret=True, **kw)

    def _xs(self, rounds=10, n=8):
        scale = jnp.linspace(3.0, 0.7, P)
        return jax.random.normal(jax.random.PRNGKey(0),
                                 (rounds, n, P)) * scale

    def test_guarantee_every_round(self):
        eps = 0.5
        cfg = self._cfg(compression=CompressionConfig(epsilon=eps))
        fin, m = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)),
                            self._xs())
        comp = m.compression
        assert comp is not None and comp.z.shape == (10, 8, Q)
        assert float(np.asarray(comp.max_err).max()) <= eps + 1e-6
        # sink view is epsilon-true against the raw stream, round by round
        xs = np.asarray(self._xs())
        x_sink = np.asarray(comp.x_sink)
        assert np.abs(x_sink - xs).max() <= eps + 1e-5

    def test_booked_bill_reconciles_exactly(self):
        """bill(with compression) - bill(without) == the supervised epoch
        bill rebuilt from the metrics' own extras, round by round."""
        eps = 0.4
        ccfg = CompressionConfig(epsilon=eps)
        cfg_c = self._cfg(compression=ccfg)
        cfg_0 = self._cfg()
        xs = self._xs()
        fin_c, m_c = stream_run(cfg_c, stream_init(cfg_c,
                                                   jax.random.PRNGKey(1)), xs)
        fin_0, m_0 = stream_run(cfg_0, stream_init(cfg_0,
                                                   jax.random.PRNGKey(1)), xs)
        assert m_0.compression is None
        flagfree = costs.quantized_supervised_round_cost(
            Q, cfg_c.c_max, 0).communication
        extras = np.asarray(m_c.compression.extra_packets, np.float64)
        expected = (flagfree * len(extras) + extras.sum())
        np.testing.assert_allclose(
            float(fin_c.sched.comm_packets) - float(fin_0.sched.comm_packets),
            expected, rtol=1e-5)
        # compression must not perturb the learning path at all
        np.testing.assert_array_equal(np.asarray(fin_c.sched.W),
                                      np.asarray(fin_0.sched.W))
        np.testing.assert_array_equal(np.asarray(m_c.rho),
                                      np.asarray(m_0.rho))

    def test_lossy_booking_scales_by_expected_transmissions(self):
        from repro.core.faults import expected_transmissions
        eps, loss = 0.4, 0.2
        ccfg = CompressionConfig(epsilon=eps)
        cfg_c = self._cfg(compression=ccfg, link_loss=loss, max_retries=3)
        cfg_0 = self._cfg(link_loss=loss, max_retries=3)
        xs = self._xs()
        fin_c, m_c = stream_run(cfg_c, stream_init(cfg_c,
                                                   jax.random.PRNGKey(1)), xs)
        fin_0, _ = stream_run(cfg_0, stream_init(cfg_0,
                                                 jax.random.PRNGKey(1)), xs)
        factor = expected_transmissions(loss, 3)
        flagfree = costs.quantized_supervised_round_cost(
            Q, cfg_c.c_max, 0).communication
        extras = np.asarray(m_c.compression.extra_packets, np.float64)
        expected = (flagfree * len(extras) + extras.sum()) * factor
        np.testing.assert_allclose(
            float(fin_c.sched.comm_packets) - float(fin_0.sched.comm_packets),
            expected, rtol=1e-4)

    def test_masked_stream_owes_no_bound_to_dead(self):
        eps = 0.5
        cfg = self._cfg(compression=CompressionConfig(epsilon=eps))
        xs = self._xs()
        masks = np.ones((10, P), np.float32)
        masks[5:, :10] = 0.0                      # a death wave
        fin, m = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)),
                            xs, jnp.asarray(masks))
        comp = m.compression
        assert float(np.asarray(comp.max_err).max()) <= eps + 1e-6
        # dead sensors never notify
        fl = np.asarray(comp.flagged)             # (rounds, n, p)
        assert not fl[5:, :, :10].any()

    def test_sharded_agrees_with_batched_under_compression(self):
        from repro.streaming import batched_stream_run, sharded_stream_run
        from repro.streaming.driver import batched_stream_init
        cfg = self._cfg(compression=CompressionConfig(epsilon=0.5))
        Bn = 2
        states = batched_stream_init(cfg, jax.random.PRNGKey(0), Bn)
        xsb = jax.random.normal(jax.random.PRNGKey(1), (Bn, 6, 8, P))
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        fin_v, m_v = batched_stream_run(cfg, states, xsb)
        fin_s, m_s = sharded_stream_run(cfg, mesh, states, xsb)
        np.testing.assert_allclose(
            np.asarray(m_v.compression.max_err),
            np.asarray(m_s.compression.max_err), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(fin_v.sched.comm_packets),
                                   np.asarray(fin_s.sched.comm_packets))

    def test_emit_reconstruction_off_drops_arrays(self):
        cfg = self._cfg(compression=CompressionConfig(
            epsilon=0.5, emit_reconstruction=False))
        fin, m = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)),
                            self._xs(rounds=4))
        assert m.compression.x_sink is None
        assert m.compression.flagged is None
        assert m.compression.z.shape == (4, 8, Q)


class TestEngineIntegration:
    def test_results_carry_compression_books(self):
        eps = 0.6
        from repro.serve.engine import StreamingPCAEngine, StreamRequest
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                           warmup_rounds=3, interpret=True,
                           compression=CompressionConfig(epsilon=eps))
        eng = StreamingPCAEngine(cfg, slots=2, seed=0)
        rng = np.random.default_rng(0)
        reqs = [StreamRequest(rounds=(rng.normal(size=(8, 4, P)) *
                                      np.linspace(3, 0.7, P))
                              .astype(np.float32)) for _ in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        for r in reqs:
            assert r.done and r.result.reason == "completed"
            assert r.result.compression_max_err is not None
            assert r.result.compression_max_err <= eps + 1e-6
            assert r.result.compression_extra_packets >= 0
            assert r.result.compression_bits_on_air > 0
        # slots expose the last round's device output
        assert eng.last_compression is not None
        assert eng.last_compression.z.shape == (2, 4, Q)

    def test_no_compression_results_keep_none_fields(self):
        from repro.serve.engine import StreamingPCAEngine, StreamRequest
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, interpret=True)
        eng = StreamingPCAEngine(cfg, slots=1, seed=0)
        req = StreamRequest(rounds=np.random.default_rng(0)
                            .normal(size=(4, 4, P)).astype(np.float32))
        eng.submit(req)
        eng.run_until_done()
        assert req.result.compression_max_err is None


class TestCosts:
    def test_quantized_zero_bits_reproduces_unquantized(self):
        a = costs.supervised_round_cost(5, 4, flagged=7)
        b = costs.quantized_supervised_round_cost(5, 4, 0, flagged=7)
        assert a == b

    def test_quantized_comm_books_scale_flood(self):
        """Quantized scores pay bits/word of the full bill PLUS the q
        full-precision per-component scales on the F flood every round —
        so quantization wins only below word_bits/2 bits."""
        q, c = 5, 4
        unit = q * (c + 1)
        full = costs.supervised_round_cost(q, c).communication
        assert full == 2 * unit
        for bits in (2, 8, 16):
            comm = costs.quantized_supervised_round_cost(
                q, c, bits).communication
            np.testing.assert_allclose(comm, full * bits / 32 + unit)
        assert costs.quantized_supervised_round_cost(
            q, c, 8).communication < full
        np.testing.assert_allclose(
            costs.quantized_supervised_round_cost(q, c, 16).communication,
            full)    # break-even at word_bits / 2

    def test_flagged_raws_stay_full_word(self):
        q, c = 5, 4
        comm = costs.quantized_supervised_round_cost(
            q, c, 8, flagged=10).communication
        np.testing.assert_allclose(
            comm,
            costs.supervised_round_cost(q, c).communication / 4
            + q * (c + 1) + 10)

    @pytest.mark.parametrize("bits", [0, 2, 8, 16])
    def test_split_sums_to_cost_model(self, bits):
        """epoch_packet_split (the driver/metrics source of truth) must sum
        exactly to the cost model's flag-free communication."""
        from repro.streaming.compressor import epoch_packet_split
        cfg = CompressionConfig(epsilon=0.5, score_bits=bits)
        a_pk, f_pk = epoch_packet_split(Q, 4, cfg)
        np.testing.assert_allclose(
            a_pk + f_pk,
            costs.quantized_supervised_round_cost(Q, 4, bits).communication)


class TestPacketProperty:
    """Booked score/extra packets == simulator-counted packets."""

    @pytest.fixture(autouse=True)
    def _require_hypothesis(self):
        pytest.importorskip("hypothesis")

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), loss=st.floats(0.0, 0.5),
           retries=st.integers(0, 4), q=st.integers(1, 6))
    def test_score_epoch_booked_equals_counted(self, seed, loss, retries, q):
        """One supervised epoch's A phase (q-sized score records through
        lossy_aggregate_tree) books exactly the packets the simulator
        counts, and at zero loss the highest-node load is the
        q(C*+1) of supervised_round_cost's A half."""
        from repro.core.faults import FaultModel
        from repro.core.topology import build_topology, grid_layout

        rng = np.random.default_rng(seed)
        topo = build_topology(grid_layout(4, 5, jitter=0.2, seed=seed),
                              radio_range=1.8)
        tree = topo.tree
        p = tree.p
        W = rng.normal(size=(p, q))
        x = rng.normal(size=p)
        from repro.core.aggregation import lossy_aggregate_tree
        res = lossy_aggregate_tree(
            tree, [(i, x[i]) for i in range(p)], pcag_primitives(W),
            FaultModel(link_loss=loss, max_retries=retries), rng)
        booked = costs.lossy_epoch_load(tree, res.record_sizes, res.attempts,
                                        res.delivered, res.active)
        np.testing.assert_array_equal(booked, res.packets)
        assert (res.record_sizes == q).all()        # score records are q wide
        if loss == 0.0:
            # the value is the oracle scores and the max-node load is the
            # A half of supervised_round_cost at the tree's own C*
            np.testing.assert_allclose(res.value, scores(W, x), atol=1e-9)
            children = np.bincount(tree.parent[tree.parent >= 0],
                                   minlength=p)
            c_max = int(children.max())
            assert res.packets.max() == q * (c_max + 1)
            half_a = costs.supervised_round_cost(q, c_max).communication / 2
            assert res.packets.max() == half_a

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16), eps=st.floats(0.05, 1.0))
    def test_extras_booked_equals_flag_count(self, seed, eps):
        """The oracle's extra_packets books one raw packet per notification
        — exactly what the sink substitutes (and what the streaming tier
        adds to the bill per round)."""
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(12, 16)).astype(np.float32)
        W = np.linalg.qr(rng.normal(size=(16, 3)))[0].astype(np.float32)
        comp = SupervisedCompressor(W, x.mean(axis=0), epsilon=eps)
        out = comp.run(x)
        assert out.extra_packets.sum() == out.flagged.sum()
        subst = (out.x_hat == x) & out.flagged
        assert subst.sum() == out.flagged.sum()
        dev = compress_round(jnp.asarray(W),
                             jnp.asarray(x.mean(axis=0)), jnp.asarray(x),
                             CompressionConfig(epsilon=float(eps)),
                             c_max=4, interpret=True)
        assert float(dev.extra_packets) == np.asarray(dev.flagged).sum()
