"""Hierarchical fleet differential suite (DESIGN.md Sec. 13).

The two-level decomposition's acceptance properties:

1. ``regions=1`` IS the flat driver — bit-exact states and metrics (one
   region is the whole fleet; the merge selects the identity),
2. the merged fleet basis matches flat single-device PCA (dense ``eigh`` on
   the full sample covariance) within principal-angle tolerance across
   region counts 1 / 2 / 8, on block-structured data,
3. masked and forgetting<1 variants stay differentially tied to the flat
   per-region driver,
4. the cross-region merge's Table-1 bill is booked-equals-counted: the
   (q_local + 1)-record region-head aggregation simulated over lossy links
   reproduces :func:`repro.core.costs.lossy_epoch_load`, and at zero loss
   collapses to :func:`repro.core.costs.merge_round_cost` (hypothesis),
5. the region-aware serving engine merges retired regions into an
   orthonormal fleet basis with the same bill,
6. the ``test_mh_*`` worker tests run the merge collectives on a REAL
   8-device region mesh (tests/multihost.py relaunch; also a dedicated CI
   job step).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import multihost
from repro.core import costs
from repro.launch.mesh import make_fleet_mesh
from repro.streaming import (StreamConfig, batched_stream_run, merge_fleet,
                             fleet_basis_dense, hierarchical_stream_init,
                             hierarchical_stream_run, stream_init, stream_run)
from repro.streaming.hierarchy import region_energies

P_REGION, Q, H = 8, 2, 7


def _cfg(**kw):
    base = dict(p=P_REGION, q=Q, halfwidth=H, forgetting=1.0,
                drift_threshold=0.05, warmup_rounds=2, refresh_iters=16)
    base.update(kw)
    return StreamConfig(**base)


def _block_data(seed, n_regions, n_rounds, n_per_round=8):
    """Per-region low-rank rounds with well-separated energy scales.

    Region r draws from q=2 fixed orthogonal directions with geometrically
    separated gains (2^r), so the global energy ranking is unambiguous
    (no near-ties for the merge's top-q selection to flip on sample
    noise); regions are statistically independent, so the full-fleet
    covariance is block diagonal in expectation and the global top
    components are region-supported — the regime where the decomposable
    merge provably recovers flat PCA.  ``halfwidth=7`` covers every sensor
    pair of an 8-sensor region: the banded estimate is the full per-region
    covariance, isolating hierarchy error from band truncation.
    """
    rng = np.random.default_rng(seed)
    xs = np.zeros((n_regions, n_rounds, n_per_round, P_REGION), np.float32)
    for r in range(n_regions):
        basis, _ = np.linalg.qr(rng.normal(size=(P_REGION, Q)))
        gains = (2.0 ** r) * np.array([3.0, 1.8])
        z = rng.normal(size=(n_rounds, n_per_round, Q))
        clean = np.einsum("tnk,pk->tnp", z * gains, basis)
        noise = 0.05 * rng.normal(size=(n_rounds, n_per_round, P_REGION))
        xs[r] = (clean + noise).astype(np.float32)
    return jnp.asarray(xs)


def _principal_angle(U, V):
    """Largest principal angle (radians) between the column spaces."""
    Uq, _ = np.linalg.qr(np.asarray(U))
    Vq, _ = np.linalg.qr(np.asarray(V))
    s = np.linalg.svd(Uq.T @ Vq, compute_uv=False)
    return float(np.arccos(np.clip(s.min(), -1.0, 1.0)))


def _align_columns(W, W_ref):
    """Flip W's column signs to match W_ref (a PCA basis is sign-free per
    component; ±1 scaling is exact in float, so bitwise checks survive)."""
    s = np.sign(np.sum(np.asarray(W) * np.asarray(W_ref), axis=0))
    s[s == 0] = 1.0
    return np.asarray(W) * s


def _strip_W(state):
    """The state pytree with the basis zeroed (compared separately)."""
    return state._replace(sched=state.sched._replace(
        W=jnp.zeros_like(state.sched.W)))


def _run_hierarchy(cfg, xs, masks=None, q_fleet=None):
    n_regions = xs.shape[0]
    mesh = make_fleet_mesh(region=1)
    states = hierarchical_stream_init(cfg, jax.random.PRNGKey(5), n_regions)
    return hierarchical_stream_run(cfg, mesh, states, xs, masks,
                                   q_fleet=q_fleet)


class TestRegionsOneIsFlat:
    def test_bitwise_matches_flat_driver(self):
        """One region on a one-device region mesh IS stream_run, bit for
        bit: covariance band, counters, liveness, packets, and the per-round
        metrics are all exactly equal, and the merge selects the identity.
        The one exception is the refreshed basis itself — the lane-batched
        refresh lowers its QR/eigh differently from the unbatched one, so W
        is compared up to column sign and float32 ulps."""
        cfg = _cfg()
        xs = _block_data(0, 1, 10)
        fin_h, m_h, fleet = _run_hierarchy(cfg, xs)
        flat0 = stream_init(cfg, jax.random.split(jax.random.PRNGKey(5), 1)[0])
        fin_f, m_f = stream_run(cfg, flat0, xs[0])
        for a, b in zip(jax.tree.leaves(_strip_W(fin_h)),
                        jax.tree.leaves(_strip_W(fin_f))):
            np.testing.assert_array_equal(np.asarray(a)[0], np.asarray(b))
        np.testing.assert_allclose(
            _align_columns(np.asarray(fin_h.sched.W)[0], fin_f.sched.W),
            np.asarray(fin_f.sched.W), rtol=0, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(m_h.rho)[0],
                                      np.asarray(m_f.rho))
        np.testing.assert_array_equal(np.asarray(m_h.comm_packets)[0],
                                      np.asarray(m_f.comm_packets))
        # the merge over one region selects exactly its q columns
        assert set(np.asarray(fleet.basis.col)) == set(range(Q))
        assert np.all(np.asarray(fleet.basis.region) == 0)
        assert np.all(np.diff(np.asarray(fleet.basis.lam)) <= 1e-7)

    def test_merge_epochs_min_one(self):
        """A fleet whose regions never refresh still pays for the final
        merge that produced the returned basis."""
        cfg = _cfg(drift_threshold=0.9, warmup_rounds=100)
        xs = _block_data(1, 2, 4)
        _, _, fleet = _run_hierarchy(cfg, xs)
        assert int(fleet.merge_epochs) == 1
        expected = costs.lossy_merge_cost(
            cfg.q, cfg.c_max, cfg.link_loss, cfg.max_retries).communication
        assert float(fleet.merge_packets) == pytest.approx(expected)


class TestMergeVsFlatPCA:
    @pytest.mark.parametrize("n_regions", [1, 2, 8])
    def test_principal_angle_vs_dense_eigh(self, n_regions):
        """The merged fleet basis spans the flat single-device PCA subspace
        (dense eigh of the full sample covariance) within tolerance."""
        cfg = _cfg(drift_threshold=0.01, warmup_rounds=2)
        xs = _block_data(2 + n_regions, n_regions, 32)
        fin, _, fleet = _run_hierarchy(cfg, xs, q_fleet=Q)
        dense = fleet_basis_dense(fleet.basis, fin.sched.W)
        # flat reference: every sensor of every region in one matrix
        flat = np.moveaxis(np.asarray(xs), 0, 2)          # (T, n, R, p)
        flat = flat.reshape(-1, n_regions * P_REGION)
        C = np.cov(flat, rowvar=False, bias=True)
        w, v = np.linalg.eigh(C)
        ref = v[:, np.argsort(w)[::-1][:Q]]
        angle = _principal_angle(dense, ref)
        assert angle < 0.15, f"principal angle {angle:.3f} rad"

    def test_q_fleet_too_large_raises(self):
        cfg = _cfg()
        xs = _block_data(3, 2, 4)
        with pytest.raises(ValueError, match="q_fleet"):
            _run_hierarchy(cfg, xs, q_fleet=2 * Q + 1)


class TestVariants:
    def test_masked_matches_flat_per_region(self):
        """Liveness masks thread through: each region's final state equals
        the flat masked driver's, and the merge stays well formed."""
        cfg = _cfg()
        n_regions, n_rounds = 2, 8
        xs = _block_data(4, n_regions, n_rounds)
        rng = np.random.default_rng(7)
        masks = jnp.asarray(
            (rng.random((n_regions, n_rounds, P_REGION)) > 0.2)
            .astype(np.float32))
        fin_h, _, fleet = _run_hierarchy(cfg, xs, masks=masks)
        keys = jax.random.split(jax.random.PRNGKey(5), n_regions)
        for r in range(n_regions):
            fin_f, _ = stream_run(cfg, stream_init(cfg, keys[r]),
                                  xs[r], masks[r])
            for a, b in zip(jax.tree.leaves(_strip_W(fin_h)),
                            jax.tree.leaves(_strip_W(fin_f))):
                np.testing.assert_array_equal(np.asarray(a)[r],
                                              np.asarray(b))
            np.testing.assert_array_equal(
                _align_columns(np.asarray(fin_h.sched.W)[r],
                               fin_f.sched.W),
                np.asarray(fin_f.sched.W))
        assert np.isfinite(float(fleet.basis.rho))

    def test_forgetting_variant(self):
        """forgetting<1 flows through both levels: per-region states match
        the flat driver and the merge energies stay sorted/positive."""
        cfg = _cfg(forgetting=0.9)
        n_regions = 2
        xs = _block_data(5, n_regions, 10)
        fin_h, _, fleet = _run_hierarchy(cfg, xs)
        keys = jax.random.split(jax.random.PRNGKey(5), n_regions)
        for r in range(n_regions):
            fin_f, _ = stream_run(cfg, stream_init(cfg, keys[r]), xs[r])
            # vmap lanes vs the single-network run agree to float32 ulps
            # (lane-batched QR/eigh aren't bit-scheduled identically)
            np.testing.assert_allclose(
                _align_columns(np.asarray(fin_h.sched.W)[r],
                               fin_f.sched.W),
                np.asarray(fin_f.sched.W), rtol=2e-6, atol=2e-6)
        lam = np.asarray(fleet.basis.lam)
        assert np.all(np.diff(lam) <= 1e-7) and np.all(lam > 0)
        assert 0.0 < float(fleet.basis.rho) <= 1.0 + 1e-6


class TestEngineFleet:
    def test_region_tagged_streams_merge(self):
        from repro.serve.engine import StreamingPCAEngine, StreamRequest

        cfg = _cfg()
        eng = StreamingPCAEngine(cfg, slots=2, seed=0)
        n_regions = 3
        xs = _block_data(9, n_regions, 8)
        for r in range(n_regions):
            eng.submit(StreamRequest(rounds=np.asarray(xs[r]), region=r))
        eng.run_until_done()
        summ = eng.fleet_summary()
        assert summ.regions == tuple(range(n_regions))
        assert summ.basis.shape == (n_regions * P_REGION, Q)
        gram = summ.basis.T @ summ.basis
        np.testing.assert_allclose(gram, np.eye(Q), atol=1e-5)
        assert 0.0 < summ.rho <= 1.0 + 1e-6
        assert summ.merge_packets == pytest.approx(
            costs.lossy_merge_cost(cfg.q, cfg.c_max, cfg.link_loss,
                                   cfg.max_retries).communication)

    def test_fleet_summary_empty_raises(self):
        from repro.serve.engine import StreamingPCAEngine

        eng = StreamingPCAEngine(_cfg(), slots=1, seed=0)
        with pytest.raises(ValueError, match="no retired region"):
            eng.fleet_summary()


# ---------------------------------------------------------------------------
# Multi-host: the merge collectives on a REAL 8-device region mesh
# ---------------------------------------------------------------------------
@pytest.mark.skipif(multihost.in_worker(),
                    reason="outer launcher — already inside the worker")
def test_multihost_suite():
    """Relaunch this module on 8 forced host devices and run the mh_
    selection there (shard_map's all_gather/psum actually cross devices)."""
    proc = multihost.relaunch_in_worker(__file__, n_devices=8, select="mh_")
    assert proc.returncode == 0, \
        proc.stdout[-2000:] + "\n" + proc.stderr[-2000:]


needs_worker = pytest.mark.skipif(
    not multihost.in_worker(),
    reason="needs 8 forced devices (run via test_multihost_suite or the CI "
           "multihost step)")


@needs_worker
def test_mh_eight_region_mesh_matches_host_merge():
    """8 regions, one per device: the cross-device gather/psum merge equals
    the host-side computation on the same final states."""
    assert jax.device_count() >= 8
    cfg = _cfg()
    n_regions = 8
    xs = _block_data(11, n_regions, 8)
    mesh = make_fleet_mesh(region=8)
    states = hierarchical_stream_init(cfg, jax.random.PRNGKey(5), n_regions)
    fin, metrics, fleet = hierarchical_stream_run(cfg, mesh, states, xs)
    # host reference: same per-region streaming, merge computed locally
    fin_ref, m_ref = batched_stream_run(cfg, states, xs)
    for a, b in zip(jax.tree.leaves(fin), jax.tree.leaves(fin_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    lam_ref, den_ref = jax.vmap(region_energies)(fin_ref)
    basis_ref = merge_fleet(lam_ref, jnp.sum(den_ref), cfg.q)
    np.testing.assert_allclose(np.asarray(fleet.basis.lam_table),
                               np.asarray(lam_ref), rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(fleet.basis.region),
                                  np.asarray(basis_ref.region))
    np.testing.assert_array_equal(np.asarray(fleet.basis.col),
                                  np.asarray(basis_ref.col))
    np.testing.assert_allclose(float(fleet.basis.rho),
                               float(basis_ref.rho), rtol=1e-6)


@needs_worker
def test_mh_sharded_data_axis_matches_batched():
    """The PR 5 data-axis sharded runner on 8 real devices still equals the
    single-device batched driver (regression guard for the mesh split)."""
    from repro.streaming import sharded_stream_run

    assert jax.device_count() >= 8
    cfg = _cfg()
    n_networks = 8
    xs = _block_data(13, n_networks, 6)
    states = hierarchical_stream_init(cfg, jax.random.PRNGKey(5), n_networks)
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    fin_s, m_s = sharded_stream_run(cfg, mesh, states, xs)
    fin_b, m_b = batched_stream_run(cfg, states, xs)
    for a, b in zip(jax.tree.leaves(fin_s), jax.tree.leaves(fin_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_s.rho), np.asarray(m_b.rho),
                               rtol=1e-6, atol=1e-6)


@needs_worker
def test_mh_fleet_mesh_spans_local_devices():
    from repro.launch.mesh import mesh_axis_sizes

    mesh = make_fleet_mesh()
    sizes = mesh_axis_sizes(mesh)
    assert sizes["region"] == jax.device_count()
    assert sizes["data"] == 1
