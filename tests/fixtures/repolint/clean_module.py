"""Fixture: a module the lints should pass untouched."""
import jax
import jax.numpy as jnp


@jax.jit
def traced(x):
    return jnp.sum(x * 2.0)


def host_side(arr):
    # host pulls outside jitted regions are fine
    return float(arr.sum())


def build_table(n: int):
    return jnp.arange(n)          # call-time jnp is fine
