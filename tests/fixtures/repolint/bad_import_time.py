"""Fixture: jnp computation at module import time (module + class scope).

Line numbers are asserted by tests/test_repolint.py — keep edits append-only.
"""
import jax.numpy as jnp

_TABLE = jnp.arange(16)                            # line 7: module scope


class Config:
    SCALE = jnp.ones((4,))                         # line 11: class scope


try:
    _EYE = jnp.eye(3)                              # line 15: inside try
except RuntimeError:
    _EYE = None


def lazy_ok():
    return jnp.zeros((4,))                         # fine: runs at call time


_SUPPRESSED = jnp.zeros(())  # repolint: ok — tiny sentinel, deliberate
