"""Fixture costs module for the unreferenced-cost-helper rule.

``referenced_cost`` appears in the fixture tests corpus below;
``orphan_cost`` deliberately does not.  Line numbers are asserted by
tests/test_repolint.py — keep edits append-only.
"""


def referenced_cost(q: int) -> int:
    return 2 * q


def orphan_cost(q: int) -> int:                    # line 13: unreferenced
    return 3 * q


def _private_cost(q: int) -> int:                  # fine: private
    return q


def not_a_cost_helper(q: int) -> int:              # fine: no *_cost suffix
    return q
