"""Fixture: every flavour of host pull inside jitted code paths.

Line numbers are asserted by tests/test_repolint.py — keep edits append-only.
"""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def decorated_pull(x):
    return float(jnp.sum(x))                       # line 13: float(...)


@functools.partial(jax.jit, static_argnums=())
def partial_decorated_pull(x):
    return x.sum().item()                          # line 18: .item()


def _named_body(x):
    return int(jnp.argmax(x))                      # line 22: int(...)


stepped = jax.jit(jax.vmap(lambda x: bool(jnp.any(x))))   # line 25: bool(...)
named = jax.jit(_named_body)


def not_jitted(x):
    return float(jnp.sum(x))                       # fine: host-side helper


@jax.jit
def suppressed_pull(x):
    return float(jnp.sum(x))  # repolint: ok — deliberate sync point
