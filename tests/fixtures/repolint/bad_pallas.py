"""Fixture: deliberate pallas_call hygiene violations.

Line numbers are pinned in tests/test_repolint.py — keep edits line-stable.
"""

import jax
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def hardcoded_interpret(x):
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def implicit_dtype(x):
    out = jax.ShapeDtypeStruct(x.shape)
    return pl.pallas_call(_copy_kernel, out_shape=out)(x)


def suppressed_interpret(x):
    return pl.pallas_call(
        _copy_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,  # repolint: ok
    )(x)


def no_pallas_in_scope(shape):
    # dtype-less ShapeDtypeStruct OUTSIDE any pallas_call scope: the rule
    # must not fire here (launch/dryrun.py-style usage is legitimate).
    return jax.ShapeDtypeStruct(shape)
