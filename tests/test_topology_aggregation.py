"""Topology, routing tree, aggregation service + cost-model validation.

Validates the closed-form cost models (paper Sec. 2.1.3 / Table 1) against
actual packet counts from the routing-tree simulator, and reproduces the
paper's headline numbers for a 52-node network (Sec. 4.4):
* default scheme: root sustains 2p-1 = 103 packets/epoch,
* PCAg q=1 on the 10 m tree: highest load = C*+1 (= 7 in the paper),
* crossover near q ~ 15.
"""

import numpy as np
import pytest

from repro.core import costs
from repro.core.aggregation import NORM_PRIMITIVES, aggregate_tree
from repro.core.compression import pcag_primitives, scores_in_network
from repro.core.topology import (berkeley_like_layout, build_topology,
                                 bandwidth_reduce, graph_bandwidth, grid_layout)


@pytest.fixture(scope="module")
def topo10():
    pos = berkeley_like_layout(p=52, seed=7)
    return build_topology(pos, radio_range=10.0)


class TestRoutingTree:
    def test_tree_is_valid(self, topo10):
        t = topo10.tree
        assert t.parent[t.root] == -1
        # every non-root has a parent with depth-1
        for i in range(t.p):
            if i != t.root:
                assert t.parent[i] >= 0
                assert t.depth[i] == t.depth[t.parent[i]] + 1

    def test_subtree_sizes_sum(self, topo10):
        t = topo10.tree
        sizes = t.subtree_sizes()
        assert sizes[t.root] == t.p
        assert sizes.min() >= 1

    def test_default_load_root_is_2p_minus_1(self, topo10):
        """Paper Sec. 4.4: root processes 2p-1 = 103 packets for p=52."""
        t = topo10.tree
        load = t.load_default()
        assert load[t.root] == 2 * 52 - 1 == 103
        assert load.max() == load[t.root]

    def test_pcag_load_formula(self, topo10):
        t = topo10.tree
        c_max = int(t.children_counts().max())
        load = t.load_aggregation(q=1)
        assert load.max() == c_max + 1
        # paper's Eq. 7 regime: q=1 always beats default
        assert costs.pcag_beats_default(1, c_max, 52)

    def test_crossover_matches_eq7(self, topo10):
        """PCAg stops winning when q(C*+1) > 2p-1 (paper: ~15 comps @ 10 m)."""
        t = topo10.tree
        c_max = int(t.children_counts().max())
        qs = np.arange(1, 53)
        wins = np.array([costs.pcag_beats_default(q, c_max, 52) for q in qs])
        crossover = int(qs[~wins][0]) if (~wins).any() else 53
        assert 8 <= crossover <= 30  # paper: ~15 for its tree (C*=6)

    def test_radio_range_shrinks_depth(self):
        pos = berkeley_like_layout(p=52, seed=7)
        depths = []
        for r in (8.0, 15.0, 50.0):
            topo = build_topology(pos, radio_range=r)
            depths.append(int(topo.tree.depth.max()))
        assert depths[0] > depths[1] > depths[2] == 1  # 50 m: all root children

    def test_disconnected_raises(self):
        pos = np.array([[0.0, 0.0], [100.0, 100.0], [0.0, 1.0]])
        with pytest.raises(ValueError, match="disconnected"):
            build_topology(pos, radio_range=5.0)


class TestAggregationService:
    def test_norm_example(self, topo10):
        """Sec. 2.1.2's Euclidean-norm service returns the exact norm."""
        rng = np.random.default_rng(0)
        x = rng.normal(size=52)
        res = aggregate_tree(topo10.tree, list(x), NORM_PRIMITIVES)
        assert abs(res.value - np.linalg.norm(x)) < 1e-9

    def test_packet_counts_match_formula(self, topo10):
        """Actual simulator packets == q*(C_i+1) for scalar records."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=52)
        res = aggregate_tree(topo10.tree, list(x), NORM_PRIMITIVES)
        np.testing.assert_array_equal(res.packets,
                                      topo10.tree.load_aggregation(q=1))

    def test_pcag_in_network_scores_exact(self, topo10):
        """In-network PCAg == centralized W^T x (Sec. 2.3)."""
        rng = np.random.default_rng(2)
        W = np.linalg.qr(rng.normal(size=(52, 5)))[0]
        x = rng.normal(size=52)
        z_net, packets = scores_in_network(topo10.tree, W, x)
        np.testing.assert_allclose(z_net, W.T @ x, atol=1e-10)
        np.testing.assert_array_equal(packets,
                                      topo10.tree.load_aggregation(q=5))

    def test_vector_record_packets_scale_with_q(self, topo10):
        rng = np.random.default_rng(3)
        x = rng.normal(size=52)
        loads = []
        for q in (1, 5, 15):
            W = np.linalg.qr(rng.normal(size=(52, q)))[0]
            _, packets = scores_in_network(topo10.tree, W, x)
            loads.append(packets.max())
        assert loads[1] == 5 * loads[0]
        assert loads[2] == 15 * loads[0]


class TestCostModels:
    def test_distributed_cov_load_matches_neighborhoods(self, topo10):
        n = topo10.neighborhood_sizes()
        load = topo10.load_covariance_update()
        np.testing.assert_array_equal(load, n + 1)
        rep = costs.distributed_covariance(int(n.max()), T=100)
        assert rep.communication == 100 * (int(n.max()) + 1)

    def test_table1_orders(self):
        rep = costs.table1(p=52, T=1440, q=5, n_max=10, c_max=6)
        # centralized cov comm O(pT) >> distributed O(n_max T)
        assert rep["covariance/centralized"].communication > \
            rep["covariance/distributed"].communication
        # centralized eig comp O(p^3) >> distributed per-node
        assert rep["eigenvectors/centralized"].computation > \
            rep["eigenvectors/distributed"].computation

    def test_pim_load_quadratic_in_q(self, topo10):
        """Paper Fig. 14: network load grows ~quadratically with q."""
        iters = [20] * 15
        l5 = topo10.load_pim_total(5, iters[:5]).max()
        l10 = topo10.load_pim_total(10, iters[:10]).max()
        l15 = topo10.load_pim_total(15, iters).max()
        # superlinear growth
        assert l10 > 1.9 * l5
        assert l15 > 1.4 * l10


class TestBandwidthReduction:
    def test_rcm_reduces_bandwidth(self):
        pos = grid_layout(8, 8, spacing=1.0, jitter=0.2, seed=0)
        # shuffle labels to destroy locality
        rng = np.random.default_rng(0)
        perm0 = rng.permutation(64)
        topo = build_topology(pos[perm0], radio_range=1.6)
        bw_before = graph_bandwidth(topo.adjacency)
        perm = bandwidth_reduce(topo.adjacency)
        bw_after = graph_bandwidth(topo.adjacency, perm)
        assert bw_after < bw_before
        assert bw_after <= 20  # grid graphs reorder to ~2*cols

    def test_rcm_is_permutation(self):
        pos = grid_layout(5, 5)
        topo = build_topology(pos, radio_range=1.5)
        perm = bandwidth_reduce(topo.adjacency)
        assert sorted(perm.tolist()) == list(range(25))
