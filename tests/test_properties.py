"""Hypothesis property tests on system invariants.

The whole module is hypothesis-driven, so it skips as a unit when the
optional dev dependency (requirements-dev.txt) is absent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import costs
from repro.core.pca import DistributedPCA, retained_variance
from repro.core.spatiotemporal import stack_windows
from repro.data.tokens import TokenPipeline


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), p=st.integers(4, 24),
       n=st.integers(40, 200))
def test_eigh_pca_invariants(seed, p, n):
    """Orthonormal basis, non-negative descending eigenvalues, retained
    variance in [0, 1] and monotone in q."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, p)) @ rng.normal(size=(p, p))
    q = min(4, p)
    res = DistributedPCA(q=q, method="eigh").fit(x)
    W = res.components
    np.testing.assert_allclose(W.T @ W, np.eye(q), atol=1e-3)
    lam = res.eigenvalues
    assert np.all(np.diff(lam) <= 1e-5)
    assert np.all(lam >= -1e-4)
    f = retained_variance(x, W, res.mean)
    assert -1e-6 <= f <= 1 + 1e-6
    f1 = retained_variance(x, W[:, :1], res.mean)
    assert f >= f1 - 1e-9


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_projection_idempotent(seed):
    """Projecting a reconstruction changes nothing (P^2 = P)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(100, 10))
    res = DistributedPCA(q=3, method="eigh").fit(x)
    z = DistributedPCA.transform(res, x)
    xh = DistributedPCA.inverse_transform(res, z)
    z2 = DistributedPCA.transform(res, xh)
    np.testing.assert_allclose(np.asarray(z2), np.asarray(z),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(q=st.integers(1, 30), c_max=st.integers(1, 20), p=st.integers(8, 200))
def test_eq7_consistency(q, c_max, p):
    """Eq. (7) is exactly the crossover of the two load formulas."""
    wins = costs.pcag_beats_default(q, c_max, p)
    assert wins == (costs.pcag_epoch_load(q, c_max)
                    <= costs.default_epoch_load(p))


@settings(max_examples=10, deadline=None)
@given(w=st.integers(1, 5), seed=st.integers(0, 2**16))
def test_stack_windows_preserves_lag0(w, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(20, 3))
    s = stack_windows(x, w)
    np.testing.assert_array_equal(s[:, 0::w], x[w - 1:])


@settings(max_examples=8, deadline=None)
@given(idx=st.integers(0, 50), seed=st.integers(0, 2**10))
def test_token_pipeline_pure_function_of_index(idx, seed):
    p1 = TokenPipeline(vocab_size=64, seq_len=32, global_batch=2, seed=seed)
    p2 = TokenPipeline(vocab_size=64, seq_len=32, global_batch=2, seed=seed)
    np.testing.assert_array_equal(p1.batch_at(idx), p2.batch_at(idx))
    assert p1.batch_at(idx).min() >= 0
    assert p1.batch_at(idx).max() < 64
