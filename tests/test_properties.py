"""Hypothesis property tests on system invariants.

The whole module is hypothesis-driven, so it skips as a unit when the
optional dev dependency (requirements-dev.txt) is absent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import costs
from repro.core.aggregation import (NORM_PRIMITIVES, aggregate_tree,
                                    lossy_aggregate_tree)
from repro.core.faults import FaultModel
from repro.core.pca import DistributedPCA, retained_variance
from repro.core.spatiotemporal import stack_windows
from repro.core.topology import build_topology, grid_layout, repair_tree
from repro.data.tokens import TokenPipeline


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), p=st.integers(4, 24),
       n=st.integers(40, 200))
def test_eigh_pca_invariants(seed, p, n):
    """Orthonormal basis, non-negative descending eigenvalues, retained
    variance in [0, 1] and monotone in q."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, p)) @ rng.normal(size=(p, p))
    q = min(4, p)
    res = DistributedPCA(q=q, method="eigh").fit(x)
    W = res.components
    np.testing.assert_allclose(W.T @ W, np.eye(q), atol=1e-3)
    lam = res.eigenvalues
    assert np.all(np.diff(lam) <= 1e-5)
    assert np.all(lam >= -1e-4)
    f = retained_variance(x, W, res.mean)
    assert -1e-6 <= f <= 1 + 1e-6
    f1 = retained_variance(x, W[:, :1], res.mean)
    assert f >= f1 - 1e-9


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_projection_idempotent(seed):
    """Projecting a reconstruction changes nothing (P^2 = P)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(100, 10))
    res = DistributedPCA(q=3, method="eigh").fit(x)
    z = DistributedPCA.transform(res, x)
    xh = DistributedPCA.inverse_transform(res, z)
    z2 = DistributedPCA.transform(res, xh)
    np.testing.assert_allclose(np.asarray(z2), np.asarray(z),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(q=st.integers(1, 30), c_max=st.integers(1, 20), p=st.integers(8, 200))
def test_eq7_consistency(q, c_max, p):
    """Eq. (7) is exactly the crossover of the two load formulas."""
    wins = costs.pcag_beats_default(q, c_max, p)
    assert wins == (costs.pcag_epoch_load(q, c_max)
                    <= costs.default_epoch_load(p))


@settings(max_examples=10, deadline=None)
@given(w=st.integers(1, 5), seed=st.integers(0, 2**16))
def test_stack_windows_preserves_lag0(w, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(20, 3))
    s = stack_windows(x, w)
    np.testing.assert_array_equal(s[:, 0::w], x[w - 1:])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), rows=st.integers(3, 6),
       cols=st.integers(3, 6), kill=st.floats(0.0, 0.6))
def test_repair_yields_connected_rooted_tree(seed, rows, cols, kill):
    """For any death schedule sparing the root, the repaired tree is a valid
    tree rooted at the sink spanning exactly the reachable alive nodes."""
    rng = np.random.default_rng(seed)
    p = rows * cols
    topo = build_topology(grid_layout(rows, cols, jitter=0.2, seed=seed),
                          radio_range=1.8)
    alive = rng.random(p) >= kill
    alive[topo.tree.root] = True                 # the schedule spares the root
    tree, attached = repair_tree(topo, alive)

    assert attached[tree.root] and tree.parent[tree.root] == -1
    assert not attached[~alive].any()            # dead nodes never attach
    for i in np.nonzero(attached)[0]:
        i = int(i)
        if i == tree.root:
            continue
        par = int(tree.parent[i])
        # parent is an attached radio neighbor one hop closer to the root
        assert par >= 0 and attached[par] and topo.adjacency[i, par]
        assert tree.depth[i] == tree.depth[par] + 1
        # walking parents reaches the root (connectedness, no cycles)
        steps = 0
        while i != tree.root:
            i = int(tree.parent[i])
            steps += 1
            assert steps <= p
    # attached == BFS-reachable on the alive-induced subgraph: any alive node
    # left out must have no alive neighbor that is attached
    for i in np.nonzero(alive & ~attached)[0]:
        nbrs = np.nonzero(topo.adjacency[int(i)])[0]
        assert not (alive[nbrs] & attached[nbrs]).any()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), loss=st.floats(0.0, 0.6),
       retries=st.integers(0, 4), kill=st.floats(0.0, 0.5))
def test_lossy_packets_booked_equals_counted(seed, loss, retries, kill):
    """costs.lossy_epoch_load on the simulator's transcript reproduces the
    simulator's own per-node packet counts, for any loss/churn schedule."""
    rng = np.random.default_rng(seed)
    topo = build_topology(grid_layout(4, 5, jitter=0.2, seed=seed),
                          radio_range=1.8)
    alive = rng.random(20) >= kill
    alive[topo.tree.root] = True
    tree, attached = repair_tree(topo, alive)
    x = rng.normal(size=20)
    res = lossy_aggregate_tree(tree, list(x), NORM_PRIMITIVES,
                               FaultModel(link_loss=loss, max_retries=retries),
                               rng, active=attached)
    booked = costs.lossy_epoch_load(tree, res.record_sizes, res.attempts,
                                    res.delivered, res.active)
    np.testing.assert_array_equal(booked, res.packets)
    if loss == 0.0:
        # zero loss on the full tree: reliable simulator and Sec. 2.1.3 formula
        if attached.all():
            rel = aggregate_tree(tree, list(x), NORM_PRIMITIVES)
            np.testing.assert_array_equal(res.packets, rel.packets)
            np.testing.assert_array_equal(res.packets,
                                          tree.load_aggregation(q=1))


@settings(max_examples=8, deadline=None)
@given(idx=st.integers(0, 50), seed=st.integers(0, 2**10))
def test_token_pipeline_pure_function_of_index(idx, seed):
    p1 = TokenPipeline(vocab_size=64, seq_len=32, global_batch=2, seed=seed)
    p2 = TokenPipeline(vocab_size=64, seq_len=32, global_batch=2, seed=seed)
    np.testing.assert_array_equal(p1.batch_at(idx), p2.batch_at(idx))
    assert p1.batch_at(idx).min() >= 0
    assert p1.batch_at(idx).max() < 64
