"""Multi-host test harness: relaunch a test module on N forced devices.

Device count locks at first jax init, so a test that needs a real multi-
device mesh (shard_map collectives crossing >1 device) cannot run in the
main pytest process.  The pattern (generalizing tests/test_moe_shardmap.py):

* the OUTER test — collected in the normal suite — calls
  :func:`relaunch_in_worker` on its own file with a ``-k`` selector,
* the WORKER tests — named so the selector picks them up — are skipped in
  the main process (:func:`in_worker` is False there) and run for real in
  the subprocess, where ``XLA_FLAGS=--xla_force_host_platform_device_count``
  was exported before python started.

CI also runs the worker selection directly as its own job step (exporting
``REPRO_MULTIHOST_ACTIVE=1`` and the XLA flag), so multi-device failures
surface with full pytest reporting, not just a subprocess returncode.
"""

from __future__ import annotations

import os
import subprocess
import sys

ENV_FLAG = "REPRO_MULTIHOST_ACTIVE"


def in_worker() -> bool:
    """True inside the forced-device subprocess (or the CI multihost step)."""
    return bool(os.environ.get(ENV_FLAG))


def relaunch_in_worker(test_file: str, n_devices: int = 8,
                       select: str | None = None,
                       timeout: int = 540) -> subprocess.CompletedProcess:
    """Re-run ``test_file`` under pytest with ``n_devices`` forced host
    devices; returns the completed process (caller asserts on returncode)."""
    env = dict(os.environ)
    env[ENV_FLAG] = "1"
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}")
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "pytest", "-x", "-q", test_file]
    if select:
        cmd += ["-k", select]
    return subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, cwd=os.path.dirname(src))
