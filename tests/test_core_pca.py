"""Core PCA behaviour: covariance, PIM, deflation, orthogonal iteration."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import covariance as cov
from repro.core import power_iteration as pim
from repro.core.pca import DistributedPCA, retained_variance


def _random_spd(p, seed=0, decay=0.6):
    """SPD matrix with geometrically decaying spectrum (well-separated)."""
    rng = np.random.default_rng(seed)
    Q, _ = np.linalg.qr(rng.normal(size=(p, p)))
    lam = decay ** np.arange(p) * 10.0
    return (Q * lam) @ Q.T, Q, lam


class TestStreamingCovariance:
    def test_matches_numpy_cov(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(500, 16)).astype(np.float32)
        st = cov.cov_init(16)
        # stream in uneven batches — recursion of Eq. (10)
        for chunk in np.array_split(x, [50, 120, 333]):
            st = cov.cov_update(st, jnp.asarray(chunk))
        c = np.asarray(cov.cov_estimate(st))
        expected = np.cov(x.T, bias=True)
        np.testing.assert_allclose(c, expected, rtol=0, atol=5e-4)

    def test_mask_zeroes_out_of_neighborhood(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(200, 8)).astype(np.float32)
        mask = np.abs(np.subtract.outer(range(8), range(8))) <= 1
        st = cov.cov_update(cov.cov_init(8, mask=mask), jnp.asarray(x))
        c = np.asarray(cov.cov_estimate(st))
        assert np.all(c[~mask] == 0.0)
        dense = np.cov(x.T, bias=True)
        np.testing.assert_allclose(c[mask], dense[mask], atol=5e-4)

    def test_banded_equals_masked_dense(self):
        rng = np.random.default_rng(2)
        p, h = 24, 3
        x = rng.normal(size=(300, p)).astype(np.float32)
        bst = cov.banded_update(cov.banded_init(p, h), jnp.asarray(x))
        band = cov.banded_estimate(bst)
        dense_from_band = np.asarray(cov.band_to_dense(band))
        mask = cov.mask_from_band(p, h)
        mst = cov.cov_update(cov.cov_init(p, mask=mask), jnp.asarray(x))
        dense = np.asarray(cov.cov_estimate(mst))
        np.testing.assert_allclose(dense_from_band, dense, atol=1e-4)

    def test_band_round_trip(self):
        rng = np.random.default_rng(3)
        p, h = 17, 4
        c = rng.normal(size=(p, p))
        c = np.where(cov.mask_from_band(p, h), c, 0.0)
        band = cov.dense_to_band(jnp.asarray(c), h)
        back = np.asarray(cov.band_to_dense(band))
        np.testing.assert_allclose(back, c, atol=1e-6)

    def test_banded_matvec_ref(self):
        rng = np.random.default_rng(4)
        p, h = 33, 5
        c = rng.normal(size=(p, p))
        c = np.where(cov.mask_from_band(p, h), c, 0.0)
        band = cov.dense_to_band(jnp.asarray(c), h)
        v = rng.normal(size=(p,))
        np.testing.assert_allclose(
            np.asarray(cov.banded_matvec_ref(band, jnp.asarray(v))),
            c @ v, rtol=1e-5, atol=1e-5)

    def test_banded_matmul_ref(self):
        rng = np.random.default_rng(5)
        p, h, q = 29, 4, 6
        c = rng.normal(size=(p, p))
        c = np.where(cov.mask_from_band(p, h), c, 0.0)
        band = cov.dense_to_band(jnp.asarray(c), h)
        V = rng.normal(size=(p, q))
        np.testing.assert_allclose(
            np.asarray(cov.banded_matmul_ref(band, jnp.asarray(V))),
            c @ V, rtol=1e-5, atol=1e-5)


class TestPowerIteration:
    def test_converges_to_principal_eigenvector(self):
        C, Q, lam = _random_spd(20, seed=0)
        res = pim.power_iteration(lambda v: jnp.asarray(C) @ v,
                                  jnp.ones(20, jnp.float32),
                                  t_max=200, delta=1e-7)
        v = np.asarray(res.v)
        cos = abs(v @ Q[:, 0])
        assert cos > 0.999
        assert abs(float(res.eigenvalue) - lam[0]) < 1e-2

    def test_negative_eigenvalue_sign_detection(self):
        # matrix whose dominant eigenvalue is negative
        rng = np.random.default_rng(7)
        Q, _ = np.linalg.qr(rng.normal(size=(10, 10)))
        lam = np.array([-5.0, 2.0, 1.0] + [0.1] * 7)
        C = (Q * lam) @ Q.T
        res = pim.power_iteration(lambda v: jnp.asarray(C, jnp.float32) @ v,
                                  jnp.asarray(rng.normal(size=10), jnp.float32),
                                  t_max=300, delta=1e-7)
        assert float(res.eigenvalue) < 0
        assert abs(float(res.eigenvalue) + 5.0) < 1e-2

    def test_deflation_recovers_top_q(self):
        C, Q, lam = _random_spd(30, seed=1)
        res = pim.deflated_power_iteration(
            lambda v: jnp.asarray(C, jnp.float32) @ v, 30, 5,
            jax.random.PRNGKey(0), t_max=300, delta=1e-7)
        W = np.asarray(res.W)
        for k in range(5):
            cos = abs(W[:, k] @ Q[:, k])
            assert cos > 0.99, f"component {k}: cos={cos}"
            assert abs(float(res.eigenvalues[k]) - lam[k]) < 0.05 * lam[k]
        assert bool(res.valid.all())

    def test_deflation_validity_mask_on_indefinite(self):
        rng = np.random.default_rng(8)
        Q, _ = np.linalg.qr(rng.normal(size=(12, 12)))
        lam = np.array([6.0, 3.0, -2.0, 1.0] + [0.05] * 8)  # indefinite
        C = (Q * lam) @ Q.T
        res = pim.deflated_power_iteration(
            lambda v: jnp.asarray(C, jnp.float32) @ v, 12, 5,
            jax.random.PRNGKey(1), t_max=400, delta=1e-7)
        lams = np.asarray(res.eigenvalues)
        valid = np.asarray(res.valid)
        # first negative eigenvalue invalidates itself and everything after
        first_neg = int(np.argmax(lams < 0))
        assert lams[first_neg] < 0
        assert not valid[first_neg:].any()
        assert valid[:first_neg].all()

    def test_orthogonal_iteration_matches_deflation(self):
        C, Q, lam = _random_spd(40, seed=2)
        res = pim.orthogonal_iteration(
            lambda V: jnp.asarray(C, jnp.float32) @ V, 40, 6,
            jax.random.PRNGKey(2), t_max=300, delta=1e-8)
        W = np.asarray(res.W)
        # orthonormal
        np.testing.assert_allclose(W.T @ W, np.eye(6), atol=1e-4)
        for k in range(6):
            assert abs(W[:, k] @ Q[:, k]) > 0.99
            assert abs(float(res.eigenvalues[k]) - lam[k]) < 0.05 * lam[k]

    def test_orthogonal_iteration_jits(self):
        C, _, _ = _random_spd(16, seed=3)
        Cj = jnp.asarray(C, jnp.float32)

        @jax.jit
        def run(key):
            return pim.orthogonal_iteration(lambda V: Cj @ V, 16, 4, key,
                                            t_max=100, delta=1e-6).W

        W = run(jax.random.PRNGKey(0))
        assert W.shape == (16, 4)
        assert not np.isnan(np.asarray(W)).any()


class TestDistributedPCAFacade:
    def test_eigh_vs_power_vs_ortho_agree(self):
        rng = np.random.default_rng(9)
        # correlated data: latent factors
        z = rng.normal(size=(2000, 3))
        A = rng.normal(size=(3, 20))
        x = z @ A + 0.05 * rng.normal(size=(2000, 20))
        results = {m: DistributedPCA(q=3, method=m, t_max=500, delta=1e-7).fit(x)
                   for m in ("eigh", "power", "ortho")}
        for m in ("power", "ortho"):
            for k in range(3):
                cos = abs(results[m].components[:, k]
                          @ results["eigh"].components[:, k])
                assert cos > 0.99, (m, k, cos)

    def test_retained_variance_increases_with_q(self):
        rng = np.random.default_rng(10)
        z = rng.normal(size=(1000, 4))
        A = rng.normal(size=(4, 12))
        x = z @ A + 0.1 * rng.normal(size=(1000, 12))
        fracs = []
        for q in (1, 2, 4, 8):
            r = DistributedPCA(q=q, method="eigh").fit(x)
            fracs.append(retained_variance(x, r.components, r.mean))
        assert all(b >= a - 1e-9 for a, b in zip(fracs, fracs[1:]))
        assert fracs[-1] > 0.97  # 4 latent factors -> 8 comps capture ~all
