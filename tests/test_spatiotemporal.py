"""Spatiotemporal PCAg (the paper's stated future-work extension)."""

import numpy as np
import pytest

from repro.core.pca import DistributedPCA, retained_variance
from repro.core.spatiotemporal import (SpatioTemporalPCA, st_scores_in_network,
                                       stack_windows, spatiotemporal_mask)
from repro.core.topology import build_topology
from repro.sensors.dataset import berkeley_surrogate, kfold_blocks


class TestStacking:
    def test_shapes_and_layout(self):
        x = np.arange(20, dtype=float).reshape(10, 2)   # 2 sensors
        s = stack_windows(x, 3)
        assert s.shape == (8, 6)
        # row 0 = epoch 2: sensor 0 block = [x0[2], x0[1], x0[0]]
        np.testing.assert_array_equal(s[0, :3], [4.0, 2.0, 0.0])
        np.testing.assert_array_equal(s[0, 3:], [5.0, 3.0, 1.0])

    def test_mask_block_structure(self):
        m = np.array([[True, False], [False, True]])
        st = spatiotemporal_mask(m, 2)
        assert st.shape == (4, 4)
        assert st[0, 1] and not st[0, 2]


class TestSpatioTemporalPCA:
    @pytest.fixture(scope="class")
    def data(self):
        d = berkeley_surrogate(p=52, n_epochs=3600, seed=0)
        tr, te = kfold_blocks(3600, k=5)[0]
        return d, d.measurements[tr], d.measurements[te]

    def test_beats_spatial_pca_at_equal_q(self, data):
        """Temporal correlation is real signal: ST-PCA at window 4 should
        retain at least as much variance per component as spatial PCA."""
        _, train, test = data
        q = 5
        spatial = DistributedPCA(q=q, method="eigh").fit(train)
        f_spatial = retained_variance(test, spatial.components, spatial.mean)

        st = SpatioTemporalPCA(q=q, window=4)
        res = st.fit(train)
        test_stacked = stack_windows(test, 4)
        f_st = retained_variance(test_stacked, res.components, res.mean)
        assert f_st > f_spatial - 0.02   # at least comparable
        assert f_st > 0.85

    def test_reconstruct_current_shape_and_quality(self, data):
        """The lag-0 reconstruction (post dead-parameter fix: the sensor
        count comes from the fitted basis, not a caller argument) returns
        the (N - w + 1, p) current-epoch block and tracks the truth."""
        _, train, test = data
        w = 4
        st = SpatioTemporalPCA(q=6, window=w)
        res = st.fit(train)
        rec = st.reconstruct_current(res, test)
        current = test[w - 1:]                     # lag-0 epochs
        assert rec.shape == current.shape
        # reconstruction error well under the raw signal energy
        err = np.mean((rec - current) ** 2)
        sig = np.mean((current - current.mean(axis=0)) ** 2)
        assert err < 0.5 * sig

    def test_in_network_scores_match_centralized(self, data):
        d, train, _ = data
        topo = build_topology(d.positions, radio_range=10.0)
        w, q = 3, 4
        st = SpatioTemporalPCA(q=q, window=w)
        res = st.fit(train)
        # one epoch's histories: lag 0 first
        t = 100
        histories = [train[t - np.arange(w), i] for i in range(52)]
        stacked = stack_windows(train[: t + 1], w)[-1] - res.mean
        expected = res.components.T @ stacked
        z, packets = st_scores_in_network(topo.tree, res.components,
                                          histories, w)
        # scores are centered by the mean at the sink in deployment;
        # emulate by subtracting W^T mean
        z_centered = z - res.components.T @ res.mean
        np.testing.assert_allclose(z_centered, expected, atol=1e-8)
        # network cost identical to plain PCAg with the same q
        np.testing.assert_array_equal(packets,
                                      topo.tree.load_aggregation(q=q))

    def test_masked_st_pca_valid(self, data):
        d, train, test = data
        topo = build_topology(d.positions, radio_range=15.0)
        st = SpatioTemporalPCA(q=4, window=2,
                               spatial_mask=np.asarray(topo.covariance_mask()))
        res = st.fit(train)
        kept = res.components[:, res.valid]
        assert kept.shape[1] >= 2
        f = retained_variance(stack_windows(test, 2), kept, res.mean)
        assert f > 0.7
