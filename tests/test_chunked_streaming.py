"""Chunked streaming path: fused multi-round cov kernel, chunk driver, engine.

The contract under test (ISSUE 5 / DESIGN.md Sec. 12):
1. the chunk kernel matches the weighted-sum oracle (ref.py) on divisible,
   non-divisible and masked shapes, and at K=1/w=1 is BIT-identical to the
   per-round kernel,
2. ``chunked_stream_run(..., probe_every=1)`` is bit-identical to
   ``stream_run`` — states and metrics, masked and unmasked, with
   forgetting < 1 and with compression/detection stages attached,
3. chunk mode keeps the per-epoch cost booking exact (booked == counted)
   including K∤R tail chunks,
4. the chunk body is structurally one cov launch + one refresh select per
   chunk (the amortization claim, verified on the jaxpr),
5. the chunked engine retires streams exactly like the per-round engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.streaming import (
    CompressionConfig, DetectionConfig, StreamConfig, batched_stream_run,
    chunked_stream_run, online_init, online_update, online_update_chunk,
    sharded_stream_run, stream_init, stream_run,
)
from repro.streaming.driver import batched_stream_init, chunk_stream_step

P, H, Q = 32, 4, 3


def _rounds(key, n_rounds, n, p=P):
    return jax.random.normal(key, (n_rounds, n, p)) \
        * jnp.linspace(4.0, 1.0, p)[None, None, :]


def _assert_trees_equal(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


class TestChunkKernel:
    def test_matches_weighted_oracle(self):
        rng = np.random.default_rng(0)
        xs = jnp.asarray(rng.normal(size=(5, 8, P)).astype(np.float32))
        w = jnp.asarray((0.9 ** np.arange(4, -1, -1)).astype(np.float32))
        out = ops.cov_band_update_chunk(xs, w, H, interpret=True)
        want = ref.cov_band_update_chunk(xs, w, H)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_k1_w1_bit_identical_to_per_round(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(1, 16, P)).astype(np.float32))
        one = ops.cov_band_update_chunk(x, jnp.ones(1), H, interpret=True)
        per = ops.cov_band_update(x[0], H, interpret=True)
        np.testing.assert_array_equal(np.asarray(one), np.asarray(per))
        # masked variant against the masked per-round kernel
        m = jnp.asarray((rng.random((1, P)) > 0.3).astype(np.float32))
        onem = ops.cov_band_update_chunk(x, jnp.ones(1), H, mask=m,
                                         interpret=True)
        perm = ops.cov_band_update_masked(x[0], m[0], H, interpret=True)
        np.testing.assert_array_equal(np.asarray(onem), np.asarray(perm))

    def test_masked_and_nondivisible_shapes(self):
        """Prime p and odd n take the pad-to-block path (zero-weight pad
        rows, sliced feature pad) and still match the oracle; liveness
        (K, p) and dropout (K, n, p) masks both work."""
        rng = np.random.default_rng(2)
        for (k, n, p, h) in ((3, 5, 29, 3), (4, 8, 32, 4), (2, 7, 16, 2)):
            xs = jnp.asarray(rng.normal(size=(k, n, p)).astype(np.float32))
            w = jnp.asarray((0.8 ** np.arange(k - 1, -1, -1))
                            .astype(np.float32))
            out = ops.cov_band_update_chunk(xs, w, h, interpret=True)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref.cov_band_update_chunk(
                    xs, w, h)), rtol=1e-4, atol=1e-4)
            for mshape in ((k, p), (k, n, p)):
                m = jnp.asarray((rng.random(mshape) > 0.25)
                                .astype(np.float32))
                got = ops.cov_band_update_chunk(xs, w, h, mask=m,
                                                interpret=True)
                np.testing.assert_allclose(
                    np.asarray(got),
                    np.asarray(ref.cov_band_update_chunk_masked(xs, m, w, h)),
                    rtol=1e-4, atol=1e-4)

    def test_zero_weight_rounds_contribute_nothing(self):
        rng = np.random.default_rng(3)
        xs = jnp.asarray(rng.normal(size=(4, 8, P)).astype(np.float32))
        w = jnp.asarray([1.0, 0.0, 0.5, 0.0], jnp.float32)
        out = ops.cov_band_update_chunk(xs, w, H, interpret=True)
        want = ref.cov_band_update_chunk(xs[:3:2], w[:3:2], H)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_batched_matches_per_network(self):
        rng = np.random.default_rng(4)
        xb = jnp.asarray(rng.normal(size=(3, 4, 8, P)).astype(np.float32))
        w = jnp.asarray((0.9 ** np.arange(3, -1, -1)).astype(np.float32))
        ob = ops.cov_band_update_chunk_batched(xb, w, H, interpret=True)
        for i in range(3):
            np.testing.assert_allclose(
                np.asarray(ob[i]),
                np.asarray(ops.cov_band_update_chunk(xb[i], w, H,
                                                     interpret=True)),
                rtol=1e-6, atol=1e-6)

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError):
            ops.cov_band_update_chunk(jnp.zeros((8, P)), jnp.ones(8), H)
        with pytest.raises(ValueError):
            ops.cov_band_update_chunk(jnp.zeros((2, 8, P)), jnp.ones(3), H)
        with pytest.raises(ValueError):
            ops.cov_band_update_chunk(jnp.zeros((2, 8, P)), jnp.ones(2), H,
                                      mask=jnp.ones((3, P)))


class TestChunkedOnlineCov:
    def test_chunk_fold_equals_sequential_fold(self):
        """One fused chunk == K sequential per-round updates (allclose:
        the decay powers are folded differently) for every mask flavor."""
        rng = np.random.default_rng(5)
        xs = jnp.asarray(rng.normal(size=(6, 8, P)).astype(np.float32))
        masks_l = jnp.asarray((rng.random((6, P)) > 0.2).astype(np.float32))
        masks_d = jnp.asarray((rng.random((6, 8, P)) > 0.2)
                              .astype(np.float32))
        for masks in (None, masks_l, masks_d):
            seq = online_init(P, H)
            for t in range(6):
                m = None if masks is None else masks[t]
                seq = online_update(seq, xs[t], forgetting=0.9, mask=m,
                                    interpret=True)
            chk = online_update_chunk(online_init(P, H), xs, forgetting=0.9,
                                      masks=masks, interpret=True)
            for a, b in zip(seq, chk):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-4, atol=1e-4)

    def test_round_valid_tail_equals_short_chunk(self):
        """Pad rounds flagged invalid are absent: fold(xs[:4] padded to 6,
        rv=[1,1,1,1,0,0]) == fold(xs[:4])."""
        rng = np.random.default_rng(6)
        xs = jnp.asarray(rng.normal(size=(4, 8, P)).astype(np.float32))
        padded = jnp.concatenate([xs, jnp.zeros((2, 8, P))], axis=0)
        rv = jnp.asarray([1, 1, 1, 1, 0, 0], jnp.float32)
        a = online_update_chunk(online_init(P, H), xs, forgetting=0.9,
                                interpret=True)
        b = online_update_chunk(online_init(P, H), padded, forgetting=0.9,
                                round_valid=rv, interpret=True)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-6, atol=1e-6)


class TestProbeEveryOneDifferential:
    """The acceptance pin: chunked_stream_run(K, probe_every=1) must be
    BIT-identical to stream_run — every state leaf and every metric leaf."""

    def _cfg(self, **kw):
        base = dict(p=P, q=Q, halfwidth=H, forgetting=0.9,
                    drift_threshold=0.05, warmup_rounds=5, interpret=True)
        base.update(kw)
        return StreamConfig(**base)

    @pytest.mark.parametrize("chunk", [2, 4, 5])
    def test_plain(self, chunk):
        cfg = self._cfg()
        xs = _rounds(jax.random.PRNGKey(0), 14, 8)
        st = stream_init(cfg, jax.random.PRNGKey(7))
        _assert_trees_equal(stream_run(cfg, st, xs),
                            chunked_stream_run(cfg, st, xs, chunk=chunk,
                                               probe_every=1),
                            f"chunk={chunk}")

    def test_masked_and_forgetting(self):
        cfg = self._cfg(forgetting=0.8)
        xs = _rounds(jax.random.PRNGKey(1), 13, 8)
        masks = (jax.random.uniform(jax.random.PRNGKey(2), (13, P)) > 0.2) \
            .astype(jnp.float32)
        st = stream_init(cfg, jax.random.PRNGKey(8))
        _assert_trees_equal(
            stream_run(cfg, st, xs, masks),
            chunked_stream_run(cfg, st, xs, masks, chunk=4, probe_every=1),
            "masked")

    def test_with_compression_and_detection(self):
        cfg = self._cfg(
            compression=CompressionConfig(epsilon=0.5, score_bits=4),
            detection=DetectionConfig(alpha=1e-3, calib_rounds=4),
            link_loss=0.1)
        xs = _rounds(jax.random.PRNGKey(3), 12, 8)
        st = stream_init(cfg, jax.random.PRNGKey(9))
        _assert_trees_equal(
            stream_run(cfg, st, xs),
            chunked_stream_run(cfg, st, xs, chunk=3, probe_every=1),
            "stages")

    def test_batched_and_sharded_threading(self):
        cfg = self._cfg()
        B = 4
        states = batched_stream_init(cfg, jax.random.PRNGKey(0), B)
        xsb = jax.random.normal(jax.random.PRNGKey(1), (B, 12, 8, P))
        _assert_trees_equal(
            batched_stream_run(cfg, states, xsb),
            batched_stream_run(cfg, states, xsb, chunk=4, probe_every=1),
            "batched")
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        fin_b, m_b = batched_stream_run(cfg, states, xsb, chunk=4)
        fin_s, m_s = sharded_stream_run(cfg, mesh, states, xsb, chunk=4)
        _assert_trees_equal((fin_b, m_b), (fin_s, m_s), "sharded")


class TestChunkModeSemantics:
    def _cfg(self, **kw):
        base = dict(p=P, q=Q, halfwidth=H, forgetting=0.9,
                    drift_threshold=0.05, warmup_rounds=5, interpret=True)
        base.update(kw)
        return StreamConfig(**base)

    def test_tail_chunk_booked_equals_counted(self):
        """K∤R: the tail chunk folds and books only its real rounds —
        total bill is exactly R round records + refreshes refresh floods."""
        cfg = self._cfg()
        R = 14                                       # 3 full chunks + tail 2
        xs = _rounds(jax.random.PRNGKey(4), R, 8)
        st = stream_init(cfg, jax.random.PRNGKey(10))
        fin, metrics = chunked_stream_run(cfg, st, xs, chunk=4)
        assert int(fin.rounds) == R
        assert metrics.rho.shape == (4,)             # one row per decision
        sched = cfg.scheduler()
        expected = (R * sched.round_cost()
                    + int(fin.sched.refreshes) * sched.refresh_cost(P))
        assert float(fin.sched.comm_packets) == pytest.approx(expected,
                                                              rel=1e-6)

    def test_tail_with_stages_booked_equals_counted(self):
        comp = CompressionConfig(epsilon=0.4, score_bits=4,
                                 emit_reconstruction=False)
        det = DetectionConfig(alpha=1e-3, calib_rounds=3,
                              emit_statistics=False)
        cfg = self._cfg(compression=comp, detection=det)
        R = 11                                       # 2 full chunks + tail 3
        xs = _rounds(jax.random.PRNGKey(5), R, 8)
        st = stream_init(cfg, jax.random.PRNGKey(11))
        fin, metrics = chunked_stream_run(cfg, st, xs, chunk=4)
        from repro.streaming.compressor import compression_round_cost
        from repro.streaming.detector import detection_packet_split
        sched = cfg.scheduler()
        flagfree_c = compression_round_cost(Q, cfg.c_max, comp)
        flagfree_d, per_alarm = detection_packet_split(Q, cfg.c_max)
        extras = float(np.asarray(metrics.compression.extra_packets).sum())
        alarms = float(np.asarray(metrics.detection.alarms).sum())
        expected = (R * (sched.round_cost() + flagfree_c + flagfree_d)
                    + int(fin.sched.refreshes) * sched.refresh_cost(P)
                    + extras + alarms * per_alarm)
        assert float(fin.sched.comm_packets) == pytest.approx(expected,
                                                              rel=1e-5)

    def test_chunk_compression_metrics_scale_per_epoch(self):
        """The fixed A/F record (and its bits) is per EPOCH: a chunk's
        metrics row must carry live×(A+F), not one record per dispatch —
        summed over the run, booked bits == R fixed floods + the run's own
        flagged extras."""
        from repro.streaming.compressor import epoch_packet_split
        comp = CompressionConfig(epsilon=0.4, score_bits=4,
                                 emit_reconstruction=False)
        cfg = self._cfg(compression=comp)
        R = 11                                       # K∤R tail included
        xs = _rounds(jax.random.PRNGKey(8), R, 8)
        st = stream_init(cfg, jax.random.PRNGKey(14))
        _, metrics = chunked_stream_run(cfg, st, xs, chunk=4)
        a_pk, f_pk = epoch_packet_split(Q, cfg.c_max, comp)
        extras = float(np.asarray(metrics.compression.extra_packets).sum())
        want_bits = (a_pk + f_pk) * comp.word_bits * R \
            + extras * comp.word_bits
        got_bits = float(np.asarray(metrics.compression.bits_on_air).sum())
        assert got_bits == pytest.approx(want_bits, rel=1e-6)
        assert float(np.asarray(
            metrics.compression.score_packets).sum()) \
            == pytest.approx(a_pk * R, rel=1e-6)

    def test_chunk_cov_state_matches_per_round_fold(self):
        """Decisions are amortized but the covariance is not: after R
        rounds the chunked covariance equals the per-round fold."""
        cfg = self._cfg()
        xs = _rounds(jax.random.PRNGKey(6), 14, 8)
        st = stream_init(cfg, jax.random.PRNGKey(12))
        fin_c, _ = chunked_stream_run(cfg, st, xs, chunk=4)
        fin_r, _ = stream_run(cfg, st, xs)
        for a, b in zip(fin_r.cov, fin_c.cov):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)

    def test_probe_every_validation(self):
        cfg = self._cfg()
        xs = _rounds(jax.random.PRNGKey(0), 8, 8)
        st = stream_init(cfg, jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            chunked_stream_run(cfg, st, xs, chunk=4, probe_every=3)
        with pytest.raises(ValueError):
            chunked_stream_run(cfg, st, xs, chunk=0)

    def test_churn_triggers_at_chunk_boundary(self):
        """A mid-chunk death wave must still raise the churn trigger at
        the next boundary decision."""
        cfg = self._cfg(drift_threshold=10.0)        # drift never triggers
        R = 16
        xs = _rounds(jax.random.PRNGKey(7), R, 8)
        masks = np.ones((R, P), np.float32)
        masks[10:, :8] = 0.0                         # death inside chunk 2
        st = stream_init(cfg, jax.random.PRNGKey(13))
        fin, metrics = chunked_stream_run(cfg, st, xs,
                                          jnp.asarray(masks), chunk=4)
        fired = np.asarray(metrics.did_refresh)
        assert bool(fired[2])                        # boundary after round 10
        assert int(fin.sched.refreshes) >= 2         # warmup + churn


class TestLaunchCounts:
    """The structural amortization claim: ONE cov pallas launch and at most
    one refresh select (eigh) per chunk body, independent of K."""

    @staticmethod
    def _count(jaxpr, names):
        # shared recursive walker (repro.analysis) — descends into cond
        # branches, scan/while bodies, pjit calls and shard_map sub-jaxprs
        from repro.analysis.jaxpr_lint import count_primitives
        return count_primitives(jaxpr, names)

    def test_one_launch_one_select_per_chunk(self):
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                           warmup_rounds=4, interpret=True)
        st = stream_init(cfg, jax.random.PRNGKey(0))
        for K in (1, 4, 8):
            jx = jax.make_jaxpr(
                lambda s, x: chunk_stream_step(cfg, s, x))(
                st, jnp.zeros((K, 8, P)))
            counts = self._count(jx.jaxpr, {"pallas_call", "eigh"})
            assert counts.get("pallas_call", 0) == 1, (K, counts)
            assert counts.get("eigh", 0) <= 1, (K, counts)


class TestChunkedEngine:
    def _cfg(self):
        return StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                            drift_threshold=0.05, warmup_rounds=4,
                            interpret=True)

    def test_chunked_engine_retires_all_streams_exact_rounds(self):
        from repro.serve.engine import StreamingPCAEngine, StreamRequest
        eng = StreamingPCAEngine(self._cfg(), slots=3, seed=0, chunk=4)
        rng = np.random.default_rng(0)
        reqs = [StreamRequest(rounds=rng.normal(
            size=(9 + 3 * i, 8, P)).astype(np.float32)) for i in range(5)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        assert all(r.done for r in reqs)
        for r in reqs:
            # tails shorter than the chunk fold only their real rounds
            assert r.result.rounds == r.rounds.shape[0]
            assert r.result.refreshes >= 1
            assert r.result.comm_packets > 0

    def test_chunk1_engine_bitwise_matches_per_round_driver(self):
        """chunk=1 keeps the engine on the per-round trajectory exactly:
        a single-slot engine reproduces stream_run bit-for-bit."""
        from repro.serve.engine import StreamingPCAEngine, StreamRequest
        cfg = self._cfg()
        eng = StreamingPCAEngine(cfg, slots=1, seed=0, chunk=1)
        rng = np.random.default_rng(1)
        req = StreamRequest(rounds=rng.normal(
            size=(12, 8, P)).astype(np.float32))
        eng.submit(req)
        eng.run_until_done()
        st = stream_init(cfg, jax.random.split(jax.random.PRNGKey(0), 1)[0])
        fin, _ = stream_run(cfg, st, jnp.asarray(req.rounds))
        np.testing.assert_array_equal(req.result.components,
                                      np.asarray(fin.sched.W))
        assert req.result.comm_packets == float(fin.sched.comm_packets)
        assert req.result.refreshes == int(fin.sched.refreshes)

    def test_chunked_engine_books_match_chunked_driver(self):
        """A single-slot chunked engine == chunked_stream_run with the
        same chunk (the engine is the driver plus slot management)."""
        from repro.serve.engine import StreamingPCAEngine, StreamRequest
        cfg = self._cfg()
        K = 4
        eng = StreamingPCAEngine(cfg, slots=1, seed=0, chunk=K)
        rng = np.random.default_rng(2)
        req = StreamRequest(rounds=rng.normal(
            size=(14, 8, P)).astype(np.float32))     # K∤R tail
        eng.submit(req)
        eng.run_until_done()
        st = stream_init(cfg, jax.random.split(jax.random.PRNGKey(0), 1)[0])
        fin, _ = chunked_stream_run(cfg, st, jnp.asarray(req.rounds),
                                    chunk=K)
        # books and counters are exact; the basis is allclose only — the
        # engine's vmapped cond→select refresh batches eigh/cholesky, which
        # rounds differently than the driver's unbatched cond branch
        np.testing.assert_allclose(req.result.components,
                                   np.asarray(fin.sched.W),
                                   rtol=1e-5, atol=1e-5)
        assert req.result.comm_packets == float(fin.sched.comm_packets)
        assert req.result.rounds == int(fin.rounds)
        assert req.result.refreshes == int(fin.sched.refreshes)

    def test_chunked_engine_deterministic_with_faults(self):
        from repro.serve.engine import StreamingPCAEngine, StreamRequest

        def run():
            cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                               drift_threshold=0.1, warmup_rounds=4,
                               link_loss=0.1, interpret=True)
            eng = StreamingPCAEngine(cfg, slots=2, seed=0, chunk=3)
            reqs = []
            for i in range(4):
                rng = np.random.default_rng(300 + i)
                live = np.ones((17, P), np.float32)
                if i == 1:
                    live[6:12, :] = 0.0              # blackout + revival
                if i == 3:
                    live[9:, :] = 0.0                # dies for good
                reqs.append(StreamRequest(
                    rounds=rng.normal(size=(17, 8, P)).astype(np.float32),
                    liveness=live))
            for r in reqs:
                eng.submit(r)
            eng.run_until_done()
            return reqs

        r1, r2 = run(), run()
        for a, b in zip(r1, r2):
            assert a.done and b.done
            assert a.result.reason == b.result.reason
            np.testing.assert_array_equal(a.result.components,
                                          b.result.components)
            assert a.result.comm_packets == b.result.comm_packets
