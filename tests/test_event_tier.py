"""Device-resident event-detection tier vs. the NumPy oracle.

The differential suite for the fused T²/SPE monitoring pass
(kernels/pca_project.py::pca_monitor_pallas), the streaming detector stage,
the Sec.-2.4.3 cost booking, and the serving-engine integration — always
against `core/events.py`, which stays the host-side oracle.

Also pins the satellite fixes that ride this PR: the quantile helpers'
edge-case behavior (alpha validation + clamped tails) and the detection
packet bill's booked==counted property on the lossy simulator.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:        # optional dev dependency
    def given(*args, **kwargs):
        return lambda f: f

    def settings(*args, **kwargs):
        return lambda f: f

    class _StubStrategies:
        def integers(self, *args, **kwargs):
            return None

        def floats(self, *args, **kwargs):
            return None

    st = _StubStrategies()

from repro.core import costs
from repro.core.events import (LowVarianceDetector, _chi2_quantile,
                               _norm_quantile)
from repro.kernels import ops, ref
from repro.streaming import (DetectionConfig, StreamConfig, stream_init,
                             stream_run, wilson_hilferty)
from repro.streaming.detector import detection_packet_split

P, Q, H = 32, 3, 4


def _data(seed, n, p, q):
    rng = np.random.default_rng(seed)
    scale = np.linspace(3.0, 0.7, p)
    x = (rng.normal(size=(n, p)) * scale).astype(np.float32)
    W = np.linalg.qr(rng.normal(size=(p, q)))[0].astype(np.float32)
    mean = x.mean(axis=0).astype(np.float32)
    lam = rng.uniform(0.5, 4.0, q).astype(np.float32)
    return x, W, mean, lam


class TestMonitorKernelVsOracles:
    @pytest.mark.parametrize("n,p,q", [
        (64, 32, 3),          # block-divisible
        (100, 97, 5),         # non-divisible (prime p)
        (7, 13, 2),           # tiny, below every preferred tile
    ])
    def test_matches_jnp_ref_and_events_oracle(self, n, p, q):
        """Fused kernel == unfused jnp reference == core/events.py, all-alive."""
        x, W, mean, lam = _data(n * p + q, n, p, q)
        z, t2, spe = ops.pca_monitor(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            jnp.asarray(1.0 / lam), interpret=True)
        zr, t2r, sper = ref.pca_monitor(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            jnp.asarray(1.0 / lam), jnp.ones((n, p), jnp.float32))
        np.testing.assert_allclose(np.asarray(z), np.asarray(zr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(t2), np.asarray(t2r),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(spe), np.asarray(sper),
                                   rtol=1e-4, atol=1e-4)
        # T² against the Sec.-2.4.3 evaluator (float64 host oracle, fp32 tol)
        det = LowVarianceDetector(W, lam, mean, alpha=1e-3)
        np.testing.assert_allclose(np.asarray(t2), det.statistic(x),
                                   rtol=1e-3, atol=1e-3)
        # SPE against the residual-energy definition
        xc = x - mean
        resid = xc - (xc @ W) @ W.T
        np.testing.assert_allclose(np.asarray(spe), (resid ** 2).sum(axis=1),
                                   rtol=1e-3, atol=1e-3)

    def test_masked_dead_sensors_excluded(self):
        """Dead sensors contribute no score record and no residual energy."""
        x, W, mean, lam = _data(seed=11, n=24, p=P, q=Q)
        alive = np.ones(P, np.float32)
        alive[5] = alive[17] = 0.0
        z, t2, spe = ops.pca_monitor(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            jnp.asarray(1.0 / lam), mask=jnp.asarray(alive), interpret=True)
        xm = (x - mean) * alive
        zo = xm @ W
        np.testing.assert_allclose(np.asarray(z), zo, rtol=1e-4, atol=1e-4)
        speo = (((xm - zo @ W.T) * alive) ** 2).sum(axis=1)
        np.testing.assert_allclose(np.asarray(spe), speo,
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(t2),
                                   (zo * zo / lam[None, :]).sum(axis=1),
                                   rtol=1e-3, atol=1e-3)

    def test_dropout_mask_2d(self):
        """Per-reading (n, p) dropout masks work like the oracle's."""
        x, W, mean, lam = _data(seed=12, n=20, p=P, q=Q)
        rng = np.random.default_rng(3)
        mask = (rng.random((20, P)) >= 0.3).astype(np.float32)
        z, t2, spe = ops.pca_monitor(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            jnp.asarray(1.0 / lam), mask=jnp.asarray(mask), interpret=True)
        zr, t2r, sper = ref.pca_monitor(
            jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean),
            jnp.asarray(1.0 / lam), jnp.asarray(mask))
        np.testing.assert_allclose(np.asarray(z), np.asarray(zr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(t2), np.asarray(t2r),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(spe), np.asarray(sper),
                                   rtol=1e-4, atol=1e-4)

    def test_batched_matches_per_network_loop(self):
        Bn = 3
        rng = np.random.default_rng(2)
        xb = rng.normal(size=(Bn, 10, 29)).astype(np.float32)   # odd p
        wb = rng.normal(size=(Bn, 29, 4)).astype(np.float32)
        zb, t2b, speb = ops.pca_monitor_batched(
            jnp.asarray(xb), jnp.asarray(wb), interpret=True)
        assert zb.shape == (Bn, 10, 4) and t2b.shape == (Bn, 10)
        for i in range(Bn):
            zi, t2i, spei = ops.pca_monitor(
                jnp.asarray(xb[i]), jnp.asarray(wb[i]), interpret=True)
            np.testing.assert_array_equal(np.asarray(zb[i]), np.asarray(zi))
            np.testing.assert_array_equal(np.asarray(t2b[i]), np.asarray(t2i))
            np.testing.assert_array_equal(np.asarray(speb[i]),
                                          np.asarray(spei))


class TestQuantileEdges:
    """Satellite: alpha validation + clamped tails in the quantile helpers."""

    def test_extreme_alphas_finite_and_monotone(self):
        qs = [_chi2_quantile(20, a) for a in (1 - 1e-12, 0.5, 1e-12)]
        assert all(np.isfinite(v) for v in qs)
        assert qs[0] < qs[1] < qs[2]        # smaller alpha, larger threshold
        zs = [_norm_quantile(u) for u in (1e-12, 0.5, 1 - 1e-12)]
        assert all(np.isfinite(v) for v in zs)
        assert zs[0] < zs[1] < zs[2]
        assert zs[1] == pytest.approx(0.0, abs=1e-12)

    def test_helpers_never_return_inf_even_at_0_1(self):
        """The clamp keeps raw helper calls finite (the old code returned
        ±inf via log(0) in the tail branches)."""
        assert np.isfinite(_norm_quantile(0.0))
        assert np.isfinite(_norm_quantile(1.0))
        assert np.isfinite(_chi2_quantile(5, 0.0))
        assert np.isfinite(_chi2_quantile(5, 1.0))

    @pytest.mark.parametrize("alpha", [0.0, 1.0, -0.1, 1.5])
    def test_detector_rejects_degenerate_alpha(self, alpha):
        W = np.eye(8, 2)
        with pytest.raises(ValueError):
            LowVarianceDetector(W, np.ones(2), np.zeros(8), alpha=alpha)
        with pytest.raises(ValueError):
            DetectionConfig(alpha=alpha)

    def test_detection_config_validation(self):
        with pytest.raises(ValueError):
            DetectionConfig(calib_rounds=0)
        with pytest.raises(ValueError):
            DetectionConfig(min_lambda=0.0)

    def test_wilson_hilferty_matches_host_helper(self):
        cfg = DetectionConfig(alpha=1e-3)
        for df in (1.0, 3.0, 20.0, 57.5):
            dev = float(wilson_hilferty(jnp.asarray(df), cfg.z_alpha))
            host = _chi2_quantile(df, 1e-3)
            assert dev == pytest.approx(host, rel=1e-5)


class TestStreamingDetection:
    def _cfg(self, **kw):
        kw.setdefault("detection", DetectionConfig(alpha=1e-3,
                                                   calib_rounds=5))
        return StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                            drift_threshold=0.5, warmup_rounds=4,
                            interpret=True, **kw)

    def _xs(self, rounds=24, n=8, event_round=None, seed=0):
        rng = np.random.default_rng(seed)
        scale = np.concatenate([[4.0, 3.4, 2.8], np.full(P - 3, 0.8)])
        xs = (rng.normal(size=(rounds, n, P)) * scale).astype(np.float32)
        if event_round is not None:
            pat = np.zeros(P, np.float32)
            pat[20:26] = 5.0                    # off the tracked subspace
            xs[event_round] += pat
        return xs

    def test_calibration_window_then_armed(self):
        cfg = self._cfg()
        fin, m = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)),
                            jnp.asarray(self._xs()))
        det = m.detection
        assert det is not None and det.t2.shape == (24, 8)
        calib = np.asarray(det.calibrating) > 0.5
        # warmup refresh at round 4 opens the window for rounds 4..8
        assert calib[4:9].all() and not calib[:4].any() and not calib[9:].any()
        # thresholds are +inf until the window closes, finite after
        thr = np.asarray(det.spe_threshold)
        assert np.isinf(thr[:9]).all() and np.isfinite(thr[9:]).all()
        # alarms never fire while suppressed
        assert float(np.asarray(det.alarms)[:9].sum()) == 0.0

    def test_event_round_raises_alarms_healthy_rounds_stay_quiet(self):
        cfg = self._cfg()
        xs = self._xs(event_round=15)
        fin, m = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)),
                            jnp.asarray(xs))
        alarms = np.asarray(m.detection.alarms)
        assert alarms[15] >= 6                 # most event epochs flagged
        healthy = np.concatenate([alarms[9:15], alarms[16:]])
        assert healthy.sum() <= 2              # stray alarms stay rare
        # per-epoch event flags and the scalar alarm counts agree
        assert np.asarray(m.detection.events).sum() == alarms.sum()

    def test_detection_does_not_perturb_learning(self):
        cfg_d = self._cfg()
        cfg_0 = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                             drift_threshold=0.5, warmup_rounds=4,
                             interpret=True)
        xs = jnp.asarray(self._xs())
        fin_d, m_d = stream_run(cfg_d, stream_init(cfg_d,
                                                   jax.random.PRNGKey(1)), xs)
        fin_0, m_0 = stream_run(cfg_0, stream_init(cfg_0,
                                                   jax.random.PRNGKey(1)), xs)
        assert m_0.detection is None
        np.testing.assert_array_equal(np.asarray(fin_d.sched.W),
                                      np.asarray(fin_0.sched.W))
        np.testing.assert_array_equal(np.asarray(m_d.rho),
                                      np.asarray(m_0.rho))

    def test_booked_bill_reconciles_exactly(self):
        """bill(with detection) - bill(without) == rounds x the flag-free
        monitoring scalar + alarms x the per-alarm F flood, rebuilt from
        the metrics' own alarm counts."""
        cfg_d = self._cfg()
        cfg_0 = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                             drift_threshold=0.5, warmup_rounds=4,
                             interpret=True)
        xs = jnp.asarray(self._xs(event_round=15))
        fin_d, m_d = stream_run(cfg_d, stream_init(cfg_d,
                                                   jax.random.PRNGKey(1)), xs)
        fin_0, _ = stream_run(cfg_0, stream_init(cfg_0,
                                                 jax.random.PRNGKey(1)), xs)
        flagfree, per_alarm = detection_packet_split(Q, cfg_d.c_max)
        alarms = np.asarray(m_d.detection.alarms, np.float64)
        expected = flagfree * len(alarms) + per_alarm * alarms.sum()
        np.testing.assert_allclose(
            float(fin_d.sched.comm_packets) - float(fin_0.sched.comm_packets),
            expected, rtol=1e-5)

    def test_lossy_booking_scales_by_expected_transmissions(self):
        from repro.core.faults import expected_transmissions
        loss = 0.2
        cfg_d = self._cfg(link_loss=loss, max_retries=3)
        cfg_0 = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                             drift_threshold=0.5, warmup_rounds=4,
                             link_loss=loss, max_retries=3, interpret=True)
        xs = jnp.asarray(self._xs(event_round=15))
        fin_d, m_d = stream_run(cfg_d, stream_init(cfg_d,
                                                   jax.random.PRNGKey(1)), xs)
        fin_0, _ = stream_run(cfg_0, stream_init(cfg_0,
                                                 jax.random.PRNGKey(1)), xs)
        factor = expected_transmissions(loss, 3)
        flagfree, per_alarm = detection_packet_split(Q, cfg_d.c_max)
        alarms = np.asarray(m_d.detection.alarms, np.float64)
        expected = (flagfree * len(alarms) + per_alarm * alarms.sum()) * factor
        np.testing.assert_allclose(
            float(fin_d.sched.comm_packets) - float(fin_0.sched.comm_packets),
            expected, rtol=1e-4)

    def test_refresh_reopens_window_and_rearms(self):
        """A churn-triggered refresh mid-stream must suppress alarms for the
        new healthy window and re-arm with fresh thresholds."""
        cfg = self._cfg()
        xs = self._xs(rounds=30)
        masks = np.ones((30, P), np.float32)
        masks[14:, 28:] = 0.0                  # death wave at round 14
        fin, m = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)),
                            jnp.asarray(xs), jnp.asarray(masks))
        fired = np.asarray(m.did_refresh)
        assert fired[14]                       # churn refresh
        calib = np.asarray(m.detection.calibrating) > 0.5
        assert calib[14:19].all() and not calib[19:].any()
        assert float(np.asarray(m.detection.alarms)[14:19].sum()) == 0.0
        thr = np.asarray(m.detection.spe_threshold)
        assert np.isfinite(thr[20:]).all()

    def test_blackout_window_never_arms_alarm_siren(self):
        """Regression: a calibration window spent fully dead used to close
        on all-zero statistics, moment-match a hugely NEGATIVE SPE
        threshold, and alarm on every armed epoch forever.  Dead rounds
        must not advance the window, and the re-armed thresholds after
        revival must be positive with no alarm storm."""
        cfg = self._cfg()
        xs = self._xs(rounds=30)
        masks = np.ones((30, P), np.float32)
        masks[4:13, :] = 0.0                   # total blackout over the
        #                                        whole post-refresh window
        fin, m = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)),
                            jnp.asarray(xs), jnp.asarray(masks))
        spe_thr = np.asarray(m.detection.spe_threshold)
        armed = np.isfinite(spe_thr)
        assert (spe_thr[armed] > 0).all()      # never a non-positive arm
        alarms = np.asarray(m.detection.alarms)
        assert alarms.sum() <= 2               # no storm after revival

    def test_masked_stream_dead_sensors_never_alarm_spuriously(self):
        """Dead sensors are excluded from the statistics, so a death wave
        plus the churn recalibration leaves the armed stream quiet."""
        cfg = self._cfg()
        xs = self._xs(rounds=30)
        masks = np.ones((30, P), np.float32)
        masks[14:, :6] = 0.0
        fin, m = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)),
                            jnp.asarray(xs), jnp.asarray(masks))
        alarms = np.asarray(m.detection.alarms)
        assert alarms[19:].sum() <= 2          # re-armed and quiet

    def test_emit_statistics_off_drops_arrays(self):
        cfg = self._cfg(detection=DetectionConfig(
            alpha=1e-3, calib_rounds=5, emit_statistics=False))
        fin, m = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)),
                            jnp.asarray(self._xs(rounds=6)))
        assert m.detection.t2 is None
        assert m.detection.spe is None
        assert m.detection.events is None
        assert m.detection.alarms.shape == (6,)

    def test_sharded_agrees_with_batched_under_detection(self):
        from repro.streaming import batched_stream_run, sharded_stream_run
        from repro.streaming.driver import batched_stream_init
        cfg = self._cfg()
        Bn = 2
        states = batched_stream_init(cfg, jax.random.PRNGKey(0), Bn)
        xsb = jnp.stack([jnp.asarray(self._xs(rounds=12, seed=s))
                         for s in range(Bn)])
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        fin_v, m_v = batched_stream_run(cfg, states, xsb)
        fin_s, m_s = sharded_stream_run(cfg, mesh, states, xsb)
        np.testing.assert_allclose(
            np.asarray(m_v.detection.t2), np.asarray(m_s.detection.t2),
            rtol=1e-6)
        np.testing.assert_allclose(np.asarray(fin_v.sched.comm_packets),
                                   np.asarray(fin_s.sched.comm_packets))


class TestDetectionCosts:
    def test_round_cost_shape(self):
        """Flag-free: one extra record element through C*+1 packets; each
        alarm floods one more scalar down the tree."""
        c = costs.detection_round_cost(5, 4)
        assert c.communication == 5.0           # (c_max + 1)
        c7 = costs.detection_round_cost(5, 4, alarms=7)
        assert c7.communication == 5.0 * 8
        assert c7.computation == c.computation  # alarms cost radio, not flops

    def test_split_sums_to_cost_model(self):
        flagfree, per_alarm = detection_packet_split(Q, 4)
        np.testing.assert_allclose(
            flagfree, costs.detection_round_cost(Q, 4).communication)
        np.testing.assert_allclose(
            flagfree + 3 * per_alarm,
            costs.detection_round_cost(Q, 4, alarms=3).communication)

    def test_monitoring_is_marginal_next_to_drift_probe(self):
        """The design premise: monitoring rides the drift record — its
        flag-free bill must be a small fraction of the streaming round."""
        round_c = costs.streaming_round_cost(8, Q, 4).communication
        det_c = costs.detection_round_cost(Q, 4).communication
        assert det_c < 0.25 * round_c


class TestPacketProperty:
    """Booked detection packets == simulator-counted packets."""

    @pytest.fixture(autouse=True)
    def _require_hypothesis(self):
        pytest.importorskip("hypothesis")

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**16), loss=st.floats(0.0, 0.5),
           retries=st.integers(0, 4))
    def test_monitor_epoch_booked_equals_counted(self, seed, loss, retries):
        """The detection A phase (the extra residual-energy scalar riding
        the drift aggregation) as one scalar-record epoch through
        lossy_aggregate_tree: lossy_epoch_load books exactly the packets
        the simulator counts, and at zero loss the highest-node load is
        detection_round_cost's flag-free C*+1."""
        from repro.core.aggregation import lossy_aggregate_tree
        from repro.core.aggregation import AggregationPrimitives
        from repro.core.faults import FaultModel
        from repro.core.topology import build_topology, grid_layout

        rng = np.random.default_rng(seed)
        topo = build_topology(grid_layout(4, 5, jitter=0.2, seed=seed),
                              radio_range=1.8)
        tree = topo.tree
        p = tree.p
        resid_sq = rng.normal(size=p) ** 2
        prim = AggregationPrimitives(
            init=lambda ih: np.asarray([ih[1]]),      # the SPE partial
            merge=lambda a, b: a + b,
            evaluate=lambda rec: rec[0],
        )
        res = lossy_aggregate_tree(
            tree, [(i, resid_sq[i]) for i in range(p)], prim,
            FaultModel(link_loss=loss, max_retries=retries), rng)
        booked = costs.lossy_epoch_load(tree, res.record_sizes, res.attempts,
                                        res.delivered, res.active)
        np.testing.assert_array_equal(booked, res.packets)
        assert (res.record_sizes == 1).all()      # one scalar rides the tree
        if loss == 0.0:
            # the evaluator sees the exact network-wide residual energy and
            # the max-node load is the flag-free detection_round_cost
            assert res.value == pytest.approx(resid_sq.sum())
            children = np.bincount(tree.parent[tree.parent >= 0],
                                   minlength=p)
            c_max = int(children.max())
            assert res.packets.max() == c_max + 1
            assert res.packets.max() == costs.detection_round_cost(
                Q, c_max).communication


class TestEngineIntegration:
    def _requests(self, with_events=True):
        from repro.serve.engine import StreamRequest
        scale = np.concatenate([[4.0, 3.4, 2.8], np.full(P - 3, 0.8)])
        reqs = []
        for i in range(3):
            rng = np.random.default_rng(100 + i)
            rounds = (rng.normal(size=(20, 4, P)) * scale).astype(np.float32)
            if with_events and i != 1:
                pat = np.zeros(P, np.float32)
                pat[20:26] = 5.0
                rounds[14] += pat                  # event after arming
            reqs.append(StreamRequest(rounds=rounds))
        return reqs

    def _cfg(self):
        return StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                            drift_threshold=0.5, warmup_rounds=3,
                            interpret=True,
                            detection=DetectionConfig(alpha=1e-3,
                                                      calib_rounds=4))

    def test_results_carry_detection_books(self):
        from repro.serve.engine import StreamingPCAEngine
        eng = StreamingPCAEngine(self._cfg(), slots=2, seed=0)
        reqs = self._requests()
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        factor = 1.0
        _, per_alarm = detection_packet_split(Q, 4)
        for i, r in enumerate(reqs):
            assert r.done and r.result.reason == "completed"
            assert r.result.detection_events is not None
            assert np.isfinite(r.result.detection_t2_threshold)
            assert np.isfinite(r.result.detection_spe_threshold)
            np.testing.assert_allclose(
                r.result.detection_alarm_packets,
                r.result.detection_events * per_alarm * factor, rtol=1e-6)
        # the event-carrying streams alarmed, the quiet one (almost) not
        assert reqs[0].result.detection_events >= 4
        assert reqs[2].result.detection_events >= 4
        assert reqs[1].result.detection_events <= 2
        assert eng.last_detection is not None
        assert eng.last_detection.alarms.shape == (2,)

    def test_no_detection_results_keep_none_fields(self):
        from repro.serve.engine import StreamingPCAEngine, StreamRequest
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, interpret=True)
        eng = StreamingPCAEngine(cfg, slots=1, seed=0)
        req = StreamRequest(rounds=np.random.default_rng(0)
                            .normal(size=(4, 4, P)).astype(np.float32))
        eng.submit(req)
        eng.run_until_done()
        assert req.result.detection_events is None

    def test_determinism_replay_with_event_schedule(self):
        """Two engine runs over the same event-carrying streams are
        identical: alarm counts, bills, thresholds, bases (bitwise)."""
        from repro.serve.engine import StreamingPCAEngine

        def run():
            eng = StreamingPCAEngine(self._cfg(), slots=2, seed=0)
            reqs = self._requests()
            for r in reqs:
                eng.submit(r)
            eng.run_until_done()
            return reqs

        a_reqs = run()
        b_reqs = run()
        for a, b in zip(a_reqs, b_reqs):
            assert a.result.detection_events == b.result.detection_events
            assert (a.result.detection_alarm_packets
                    == b.result.detection_alarm_packets)
            assert (a.result.detection_t2_threshold
                    == b.result.detection_t2_threshold)
            assert a.result.comm_packets == b.result.comm_packets
            np.testing.assert_array_equal(a.result.components,
                                          b.result.components)
