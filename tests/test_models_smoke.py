"""Per-architecture smoke tests: reduced config, one forward + train-grad +
decode step on CPU; asserts output shapes and absence of NaNs.

Also checks decode-vs-forward consistency (the cached path must reproduce the
full-sequence path) for each family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T

ARCH_NAMES = configs.ASSIGNED


def _batch_for(cfg, B=2, S=32, key=jax.random.PRNGKey(0)):
    k1, k2 = jax.random.split(key)
    batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_input"] = jax.random.normal(
            k2, (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
class TestSmoke:
    def test_forward_and_grad(self, arch):
        cfg = configs.get(arch).smoke()
        params = T.init_params(cfg, jax.random.PRNGKey(1))
        batch = _batch_for(cfg)
        logits, aux = T.forward(params, cfg, batch["tokens"],
                                enc_input=batch.get("enc_input"), remat=False)
        B, S = batch["tokens"].shape
        assert logits.shape == (B, S, cfg.vocab_size)
        assert not np.isnan(np.asarray(logits)).any()

        def loss(p):
            return T.lm_loss(p, cfg, batch, remat=True)[0]

        l, g = jax.value_and_grad(loss)(params)
        assert np.isfinite(float(l))
        gnorm = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                             for x in jax.tree.leaves(g)))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0

    def test_prefill_then_decode(self, arch):
        cfg = configs.get(arch).smoke()
        params = T.init_params(cfg, jax.random.PRNGKey(2))
        B, S_prompt, cache_len = 2, 8, 16
        state = T.init_decode_state(cfg, B, cache_len, dtype=jnp.float32,
                                    enc_len=8)
        prompt = jax.random.randint(jax.random.PRNGKey(6), (B, S_prompt), 0,
                                    cfg.vocab_size)
        enc_input = None
        if cfg.family == "encdec":
            enc_input = jax.random.normal(jax.random.PRNGKey(3),
                                          (B, 8, cfg.d_model))
        logits0, state = T.prefill(params, cfg, prompt, state,
                                   enc_input=enc_input)
        assert logits0.shape == (B, cfg.vocab_size)
        tok = jnp.argmax(logits0, -1)[:, None].astype(jnp.int32)
        logits, state2 = T.decode_step(params, cfg, tok, state,
                                       jnp.asarray(S_prompt, jnp.int32))
        assert logits.shape == (B, cfg.vocab_size)
        assert not np.isnan(np.asarray(logits)).any()


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-2.7b",
                                  "granite-moe-3b-a800m", "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Greedy decode over a short sequence must reproduce the full forward
    logits position by position (cache-path correctness)."""
    cfg = configs.get(arch).smoke()
    if cfg.family == "moe":
        # capacity dropping is a train-time batch effect that single-token
        # decode cannot reproduce; disable drops for the consistency check
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(cfg, jax.random.PRNGKey(4))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                                cfg.vocab_size)
    full_logits, _ = T.forward(params, cfg, tokens, remat=False)

    # prefill the first token (hybrid: also populates the meta prefix),
    # then decode the rest step by step
    state = T.init_decode_state(cfg, B, S, dtype=jnp.float32)
    lg0, state = T.prefill(params, cfg, tokens[:, :1], state)
    step_logits = [np.asarray(lg0)]
    for t in range(1, S):
        lg, state = T.decode_step(params, cfg, tokens[:, t:t + 1], state,
                                  jnp.asarray(t, jnp.int32))
        step_logits.append(np.asarray(lg))
    step_logits = np.stack(step_logits, axis=1)       # (B, S, V)
    np.testing.assert_allclose(step_logits, np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


def test_config_exactness():
    """Every assigned config matches the spec numbers."""
    expect = {
        "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab_size=50280,
                            d_state=128),
        "chameleon-34b": dict(n_layers=48, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=22016, vocab_size=65536),
        "qwen2-7b": dict(n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
                         d_ff=18944, vocab_size=152064, qkv_bias=True),
        "llama3-405b": dict(n_layers=126, d_model=16384, n_heads=128,
                            n_kv_heads=8, d_ff=53248, vocab_size=128256),
        "llama3.2-1b": dict(n_layers=16, d_model=2048, n_heads=32,
                            n_kv_heads=8, d_ff=8192, vocab_size=128256),
        "phi3-medium-14b": dict(n_layers=40, d_model=5120, n_heads=40,
                                n_kv_heads=10, d_ff=17920, vocab_size=100352),
        "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, d_ff=512, vocab_size=49155,
                                     n_experts=40, top_k=8),
        "moonshot-v1-16b-a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                    n_kv_heads=16, d_ff=1408,
                                    vocab_size=163840, n_experts=64, top_k=6),
        "seamless-m4t-medium": dict(n_layers=12, enc_layers=12, d_model=1024,
                                    n_heads=16, n_kv_heads=16, d_ff=4096,
                                    vocab_size=256206),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab_size=32001,
                           d_state=16),
    }
    for name, fields in expect.items():
        cfg = configs.get(name)
        for f, val in fields.items():
            assert getattr(cfg, f) == val, (name, f, getattr(cfg, f), val)


def test_param_counts_plausible():
    """Sanity-check approximate parameter counts against the arch names."""
    tol = 0.45
    expect = {"llama3-405b": 405e9, "qwen2-7b": 7.6e9, "llama3.2-1b": 1.2e9,
              "phi3-medium-14b": 14e9, "mamba2-2.7b": 2.7e9,
              "chameleon-34b": 34e9, "hymba-1.5b": 1.5e9}
    for name, n in expect.items():
        got = configs.get(name).param_count()
        assert abs(got - n) / n < tol, (name, got, n)
