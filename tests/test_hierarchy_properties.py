"""Hypothesis property tests for the fleet-merge Table-1 accounting.

The cross-region merge of the two-level hierarchy (DESIGN.md Sec. 13) books
one region-head aggregation epoch of a (q_local + 1)-element record per
merge.  Booked must equal counted at the fleet level exactly as it does
inside one network (tests/test_properties.py): simulating that epoch with
:func:`repro.core.aggregation.lossy_aggregate_tree` over lossy links must
reproduce :func:`repro.core.costs.lossy_epoch_load`, and at zero loss the
busiest head's load plus the scalar selection flood must collapse to the
closed-form :func:`repro.core.costs.merge_round_cost`.

Skips as a unit when the optional dev dependency is absent.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import costs
from repro.core.aggregation import (AggregationPrimitives,
                                    lossy_aggregate_tree)
from repro.core.faults import FaultModel, expected_transmissions
from repro.core.topology import build_topology, grid_layout

SUM_PRIMITIVES = AggregationPrimitives(
    init=lambda v: np.asarray(v, dtype=np.float64),
    merge=lambda a, b: a + b,
    evaluate=lambda rec: rec,
)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), q_local=st.integers(1, 6),
       loss=st.sampled_from([0.0, 0.1, 0.4]), retries=st.integers(0, 3))
def test_merge_packets_booked_equals_counted(seed, q_local, loss, retries):
    """(q_local+1)-element records up the region tree under lossy links
    reproduce lossy_epoch_load; at zero loss the highest region-head load
    + the scalar verdict flood IS merge_round_cost, and the root record is
    the exact elementwise sum (what the psum/all_gather merge consumes)."""
    rng = np.random.default_rng(seed)
    topo = build_topology(grid_layout(3, 4, jitter=0.2, seed=seed),
                          radio_range=1.8)
    tree = topo.tree
    records = [rng.random(q_local + 1) for _ in range(tree.p)]
    res = lossy_aggregate_tree(
        tree, records, SUM_PRIMITIVES,
        FaultModel(link_loss=loss, max_retries=retries), rng)
    booked = costs.lossy_epoch_load(tree, res.record_sizes, res.attempts,
                                    res.delivered, res.active)
    np.testing.assert_array_equal(booked, res.packets)
    if loss == 0.0:
        np.testing.assert_array_equal(
            res.packets, tree.load_aggregation(q=q_local + 1))
        c_star = int(tree.children_counts().max())
        assert res.packets.max() + 1 == costs.merge_round_cost(
            q_local, c_star).communication
        np.testing.assert_allclose(
            res.value, np.sum(np.stack(records), axis=0))


@settings(max_examples=10, deadline=None)
@given(q_local=st.integers(1, 8), c_regions=st.integers(1, 6),
       loss=st.sampled_from([0.0, 0.2, 0.5]), retries=st.integers(0, 4))
def test_lossy_merge_cost_is_arq_scaled(q_local, c_regions, loss, retries):
    """ARQ scales the radio bill only — compute/memory keep their reliable
    order, matching every other lossy_* cost helper."""
    rel = costs.merge_round_cost(q_local, c_regions)
    lossy = costs.lossy_merge_cost(q_local, c_regions, loss, retries)
    factor = expected_transmissions(loss, retries)
    assert lossy.communication == pytest.approx(rel.communication * factor)
    assert lossy.computation == rel.computation
    assert lossy.memory == rel.memory
