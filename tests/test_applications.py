"""Application-layer behaviour: supervised compression (+/- eps guarantee),
low-variance event detection, and the production-scale PIM steps."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import covariance as cov
from repro.core import production as prod
from repro.core.compression import SupervisedCompressor
from repro.core.events import LowVarianceDetector
from repro.core.pca import DistributedPCA
from repro.sensors.dataset import berkeley_surrogate, kfold_blocks


@pytest.fixture(scope="module")
def fitted():
    data = berkeley_surrogate(p=52, n_epochs=3600, seed=0)
    tr, te = kfold_blocks(data.n_epochs, k=5)[0]
    train, test = data.measurements[tr], data.measurements[te]
    res = DistributedPCA(q=5, method="eigh").fit(train)
    return res, train, test


class TestSupervisedCompression:
    def test_epsilon_guarantee_holds(self, fitted):
        """Sec. 2.4.1: every sink value within +/- eps of the truth."""
        res, train, test = fitted
        comp = SupervisedCompressor(res.components, res.mean, epsilon=0.5)
        out = comp.run(test[:500])
        assert np.abs(out.x_hat - test[:500]).max() <= 0.5 + 1e-12

    def test_notification_rate_decreases_with_epsilon(self, fitted):
        res, train, test = fitted
        rates = []
        for eps in (0.1, 0.5, 2.0):
            comp = SupervisedCompressor(res.components, res.mean, epsilon=eps)
            rates.append(comp.run(test[:500]).flagged.mean())
        assert rates[0] >= rates[1] >= rates[2]
        assert rates[2] < 0.25   # 2 C tolerance: few notifications

    def test_flagged_entries_are_exact(self, fitted):
        res, train, test = fitted
        comp = SupervisedCompressor(res.components, res.mean, epsilon=0.3)
        out = comp.run(test[:200])
        np.testing.assert_array_equal(out.x_hat[out.flagged],
                                      test[:200][out.flagged])


class TestEventDetection:
    def test_detects_injected_low_variance_event(self):
        data = berkeley_surrogate(p=52, n_epochs=7200, seed=0)
        X = data.measurements
        train, cal, test = X[:3600], X[3600:4800], X[4800:].copy()
        res = DistributedPCA(q=52, method="eigh").fit(train)
        W_low = res.components[:, 10:30]
        det = LowVarianceDetector(W_low, res.eigenvalues[10:30], res.mean,
                                  alpha=1e-3)
        det.calibrate(cal)
        pattern = W_low[:, 3] + 0.5 * W_low[:, 7]
        pattern = pattern / np.abs(pattern).max() * 1.2
        test[1000:1040] += pattern[None, :]
        out = det.detect(test)
        win = np.zeros(len(test), bool)
        win[1000:1040] = True
        assert out.events[win].mean() > 0.8
        assert out.events[~win].mean() < 0.05

    def test_calibration_reduces_false_alarms(self):
        data = berkeley_surrogate(p=52, n_epochs=3600, seed=1)
        X = data.measurements
        res = DistributedPCA(q=52, method="eigh").fit(X[:1800])
        det = LowVarianceDetector(res.components[:, 10:30],
                                  res.eigenvalues[10:30], res.mean,
                                  alpha=1e-3)
        fpr_chi2 = det.detect(X[1800:]).events.mean()
        det.calibrate(X[1800:2400])
        fpr_cal = det.detect(X[2400:]).events.mean()
        assert fpr_cal <= fpr_chi2 + 1e-9


class TestProductionSteps:
    """The pod-scale step functions, validated on a small banded problem."""

    def _banded_problem(self, p=256, h=8, seed=0):
        rng = np.random.default_rng(seed)
        # SPD banded matrix: A^T A of a banded A stays banded (2h)
        a = rng.normal(size=(p, p)) * cov.mask_from_band(p, h // 2)
        c = a @ a.T + 0.1 * np.eye(p)
        c = np.where(cov.mask_from_band(p, h), c, 0.0)
        band = cov.dense_to_band(jnp.asarray(c, jnp.float32), h)
        return band, c

    def test_pim_block_step_converges(self):
        band, c = self._banded_problem()
        evals, evecs = np.linalg.eigh(c)
        v = jax.random.normal(jax.random.PRNGKey(0), (256, 4), jnp.float32)
        v, _ = prod.pim_block_step(band, v)
        for _ in range(100):
            v, rayleigh = prod.pim_block_step(band, v)
        got = np.sort(np.asarray(rayleigh))[::-1]
        want = evals[::-1][:4]
        np.testing.assert_allclose(got, want, rtol=2e-2)

    def test_pim_block_orthonormal(self):
        band, _ = self._banded_problem()
        v = jax.random.normal(jax.random.PRNGKey(1), (256, 4), jnp.float32)
        v, _ = prod.pim_block_step(band, v)
        np.testing.assert_allclose(np.asarray(v.T @ v), np.eye(4), atol=1e-4)

    def test_pim_deflated_step_matches_matvec(self):
        band, c = self._banded_problem()
        v = jax.random.normal(jax.random.PRNGKey(2), (256,), jnp.float32)
        v = v / jnp.linalg.norm(v)
        w_prev = jnp.zeros((256, 3), jnp.float32)
        for _ in range(200):
            v, lam = prod.pim_deflated_step(band, v, w_prev)
        evals = np.linalg.eigvalsh(c)
        assert abs(float(lam) - evals[-1]) < 1e-2 * evals[-1]

    def test_transform_step_centered_scores(self):
        band, _ = self._banded_problem()
        rng = np.random.default_rng(3)
        w = jnp.asarray(np.linalg.qr(rng.normal(size=(256, 4)))[0],
                        jnp.float32)
        mean = jnp.asarray(rng.normal(size=256), jnp.float32)
        x = jnp.asarray(rng.normal(size=(16, 256)), jnp.float32)
        z = prod.transform_step(w, mean, x)
        expected = (np.asarray(x) - np.asarray(mean)) @ np.asarray(w)
        np.testing.assert_allclose(np.asarray(z), expected, atol=1e-4)
