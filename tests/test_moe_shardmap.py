"""Expert-parallel shard_map MoE: exactness vs the global-view path.

Multi-device meshes can't be created in the main test process (device count
locks at first jax init), so the equivalence checks run in a subprocess with
4 forced host devices — covering both the divisible (E % nm == 0) and the
gcd-subgroup (granite-style) paths.
"""

import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    import sys
    sys.path.insert(0, "src")
    from repro import configs
    from repro.models import moe as MOE
    from repro.models.params import init_params
    from repro.distributed.sharding import activation_sharding, act_rules

    def check(n_experts, mesh_shape):
        cfg = dataclasses.replace(
            configs.get("granite-moe-3b-a800m").smoke(),
            n_experts=n_experts, top_k=2, capacity_factor=8.0)
        p = init_params(MOE.moe_schema(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        y_ref, _ = MOE.moe_apply(p, cfg, x)
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        with mesh, activation_sharding(mesh, act_rules(False)):
            y_sm, _ = jax.jit(lambda p, x: MOE.moe_apply(p, cfg, x))(p, x)
        err = float(np.max(np.abs(np.asarray(y_ref) - np.asarray(y_sm))))
        assert err < 2e-5, (n_experts, mesh_shape, err)

    check(4, (2, 2))      # divisible: E % nm == 0
    check(4, (1, 4))      # divisible, model-only
    check(6, (1, 4))      # gcd subgroup: g = gcd(6, 4) = 2, dup = 2
    check(6, (2, 2))      # gcd trivial: g = gcd(6, 2) = 2
    print("OK")
""")


@pytest.mark.parametrize("rep", [0])
def test_shard_map_moe_matches_global_path(rep, tmp_path):
    proc = subprocess.run([sys.executable, "-c", SCRIPT],
                          capture_output=True, text=True, timeout=560,
                          cwd=".")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
