"""Attention-path equivalences: chunked (flash-style) vs full-materialized,
sliding windows, meta prefix, GQA grouping."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers as L


def _mini_cfg(**kw):
    base = configs.get("llama3.2-1b").smoke()
    return dataclasses.replace(base, **kw)


def _qkv(cfg, B, Sq, Sk, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, K, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, K, Dh), jnp.float32)
    return q, k, v


class TestChunkedAttention:
    @pytest.mark.parametrize("window,n_meta", [(0, 0), (16, 0), (16, 8)])
    def test_matches_full_causal(self, window, n_meta):
        cfg = _mini_cfg()
        B, S = 2, 128
        q, k, v = _qkv(cfg, B, S, S)
        pos = jnp.arange(S)
        qp = pos[:, None]
        kp = pos[None, :]
        mask = kp <= qp
        w = jnp.asarray(window)
        in_w = jnp.where(w > 0, (qp - kp) < w, True)
        if n_meta:
            in_w = in_w | (kp < n_meta)
        full = L._gqa_attend(q, k, v, (mask & in_w)[None, None, None],
                             cfg.head_dim)
        chunked = L._chunked_attend(q, k, v, pos, pos, causal=True,
                                    window=window, n_meta=n_meta,
                                    head_dim=cfg.head_dim,
                                    q_chunk=32, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_full_bidirectional(self):
        cfg = _mini_cfg()
        q, k, v = _qkv(cfg, 2, 96, 64)
        full = L._gqa_attend(q, k, v, None, cfg.head_dim)
        chunked = L._chunked_attend(q, k, v, jnp.arange(96), jnp.arange(64),
                                    causal=False, window=0, n_meta=0,
                                    head_dim=cfg.head_dim,
                                    q_chunk=32, kv_chunk=32)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)

    def test_non_power_of_two_lengths(self):
        cfg = _mini_cfg()
        S = 96 + 33  # 129 = 3 * 43
        q, k, v = _qkv(cfg, 1, S, S)
        pos = jnp.arange(S)
        mask = (pos[None, :] <= pos[:, None])[None, None, None]
        full = L._gqa_attend(q, k, v, mask, cfg.head_dim)
        chunked = L._chunked_attend(q, k, v, pos, pos, causal=True, window=0,
                                    n_meta=0, head_dim=cfg.head_dim,
                                    q_chunk=64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   rtol=2e-4, atol=2e-4)

    def test_threshold_dispatch_consistency(self):
        """attention_apply must give identical results through both paths."""
        cfg = _mini_cfg()
        params = {
            "wq": jax.random.normal(jax.random.PRNGKey(1),
                                    (cfg.d_model, cfg.n_heads, cfg.head_dim),
                                    jnp.float32) * 0.05,
            "wk": jax.random.normal(jax.random.PRNGKey(2),
                                    (cfg.d_model, cfg.n_kv_heads,
                                     cfg.head_dim), jnp.float32) * 0.05,
            "wv": jax.random.normal(jax.random.PRNGKey(3),
                                    (cfg.d_model, cfg.n_kv_heads,
                                     cfg.head_dim), jnp.float32) * 0.05,
            "wo": jax.random.normal(jax.random.PRNGKey(4),
                                    (cfg.n_heads, cfg.head_dim, cfg.d_model),
                                    jnp.float32) * 0.05,
        }
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 64, cfg.d_model))
        pos = jnp.arange(64)
        full = L.attention_apply(params, cfg, x, pos, causal=True)
        old = L.CHUNKED_ATTN_THRESHOLD
        try:
            L.CHUNKED_ATTN_THRESHOLD = 1  # force chunked path
            chunked = L.attention_apply(params, cfg, x, pos, causal=True)
        finally:
            L.CHUNKED_ATTN_THRESHOLD = old
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                                   rtol=5e-4, atol=5e-4)


class TestSSMChunking:
    def test_ssd_chunk_size_invariance(self):
        """SSD output must not depend on the chunk size (exact recurrence)."""
        from repro.models import ssm as SSM
        from repro.models.params import init_params
        cfg = configs.get("mamba2-2.7b").smoke()
        p = init_params(SSM.ssm_schema(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
        y16 = SSM.ssd_apply(p, cfg, x, chunk=16)
        y64 = SSM.ssd_apply(p, cfg, x, chunk=64)
        np.testing.assert_allclose(np.asarray(y16), np.asarray(y64),
                                   rtol=2e-3, atol=2e-3)

    def test_ssd_decode_matches_chunked(self):
        """Sequential ssd_decode_step == chunked ssd_apply."""
        from repro.models import ssm as SSM
        from repro.models.params import init_params
        cfg = configs.get("mamba2-2.7b").smoke()
        p = init_params(SSM.ssm_schema(cfg), jax.random.PRNGKey(2))
        B, S = 1, 12
        x = jax.random.normal(jax.random.PRNGKey(3), (B, S, cfg.d_model))
        y_full = SSM.ssd_apply(p, cfg, x, chunk=S)
        cache = SSM.init_ssm_cache(cfg, B)
        ys = []
        for t in range(S):
            y, cache = SSM.ssd_decode_step(p, cfg, x[:, t:t + 1], cache)
            ys.append(np.asarray(y))
        y_seq = np.concatenate(ys, axis=1)
        np.testing.assert_allclose(y_seq, np.asarray(y_full),
                                   rtol=2e-3, atol=2e-3)
