"""The source lints are themselves tested: fixture files deliberately
violate each rule and the findings must name the exact file:line
(tests/fixtures/repolint/ — line numbers pinned in the fixtures).
"""

import pathlib

import pytest

from repro.analysis.repolint import (RULES, lint_cost_references, lint_file,
                                     lint_tree, repo_paths, run_repolint)

FIXTURES = pathlib.Path(__file__).parent / "fixtures" / "repolint"


def _by_rule(findings, rule):
    return [f for f in findings if f.rule == rule]


class TestHostPullRule:
    def test_flags_each_pull_with_exact_location(self):
        path = FIXTURES / "bad_host_pull.py"
        findings = _by_rule(lint_file(path), "tracer-host-pull")
        got = {(f.line, f.message.split(" ")[0]) for f in findings}
        assert got == {(13, "float(...)"),      # @jax.jit decorated
                       (18, ".item()"),         # partial(jax.jit, ...)
                       (22, "int(...)"),        # named def passed to jax.jit
                       (25, "bool(...)")}       # lambda inside jit(vmap(...))
        assert all(f.file.endswith("bad_host_pull.py") for f in findings)

    def test_suppression_comment_exempts_line(self):
        findings = _by_rule(lint_file(FIXTURES / "bad_host_pull.py"),
                            "tracer-host-pull")
        assert 34 not in {f.line for f in findings}   # "# repolint: ok" line

    def test_finding_text_is_file_line_rule(self):
        f = _by_rule(lint_file(FIXTURES / "bad_host_pull.py"),
                     "tracer-host-pull")[0]
        assert f.text().startswith(f"{f.file}:{f.line}: [tracer-host-pull]")


class TestImportTimeJnpRule:
    def test_flags_module_class_and_try_scope(self):
        path = FIXTURES / "bad_import_time.py"
        findings = _by_rule(lint_file(path), "import-time-jnp")
        assert {f.line for f in findings} == {7, 11, 15}
        assert all(f.file.endswith("bad_import_time.py") for f in findings)

    def test_function_bodies_and_suppressed_lines_exempt(self):
        findings = _by_rule(lint_file(FIXTURES / "bad_import_time.py"),
                            "import-time-jnp")
        flagged = {f.line for f in findings}
        assert 21 not in flagged                  # def body: runs at call time
        assert 24 not in flagged                  # "# repolint: ok" line


class TestCostReferenceRule:
    def test_orphan_helper_named_with_line(self, tmp_path):
        tests_dir = tmp_path / "tests"
        tests_dir.mkdir()
        (tests_dir / "test_x.py").write_text(
            "from fake_costs import referenced_cost\n")
        findings = lint_cost_references(FIXTURES / "fake_costs.py", tests_dir)
        assert len(findings) == 1
        f = findings[0]
        assert (f.rule, f.line) == ("unreferenced-cost-helper", 13)
        assert "orphan_cost" in f.message
        assert f.file.endswith("fake_costs.py")

    def test_no_findings_when_all_referenced(self, tmp_path):
        tests_dir = tmp_path / "tests"
        tests_dir.mkdir()
        (tests_dir / "test_x.py").write_text(
            "uses referenced_cost and orphan_cost\n")
        assert lint_cost_references(FIXTURES / "fake_costs.py",
                                    tests_dir) == []


class TestPallasHygieneRule:
    def test_flags_interpret_true_and_implicit_dtype(self):
        path = FIXTURES / "bad_pallas.py"
        findings = _by_rule(lint_file(path), "pallas-call-hygiene")
        assert {f.line for f in findings} == {18, 23}
        by_line = {f.line: f.message for f in findings}
        assert "interpret=True" in by_line[18]
        assert "ShapeDtypeStruct" in by_line[23]
        assert all(f.file.endswith("bad_pallas.py") for f in findings)

    def test_suppression_and_non_pallas_scope_exempt(self):
        findings = _by_rule(lint_file(FIXTURES / "bad_pallas.py"),
                            "pallas-call-hygiene")
        flagged = {f.line for f in findings}
        assert 31 not in flagged     # "# repolint: ok" line
        assert 38 not in flagged     # scope without a pallas_call

    def test_other_rules_silent_on_fixture(self):
        findings = lint_file(FIXTURES / "bad_pallas.py")
        assert {f.rule for f in findings} == {"pallas-call-hygiene"}


class TestTreeAndRepo:
    def test_clean_module_passes(self):
        assert lint_file(FIXTURES / "clean_module.py") == []

    def test_lint_tree_collects_and_sorts(self):
        findings = lint_tree(FIXTURES)
        assert findings == sorted(findings, key=lambda f: (f.file, f.line))
        rules_seen = {f.rule for f in findings}
        assert rules_seen == {"tracer-host-pull", "import-time-jnp",
                              "pallas-call-hygiene"}

    def test_repo_is_clean(self):
        """The repo itself must satisfy its own lints — the same property
        ``python -m repro.analysis.check`` enforces in CI."""
        findings = run_repolint()
        assert findings == [], "\n".join(f.text() for f in findings)

    def test_repo_paths_resolve(self):
        pkg, costs_path, tests_dir = repo_paths()
        assert (pkg / "analysis" / "repolint.py").exists()
        assert costs_path.exists()
        assert tests_dir.is_dir()

    def test_rules_tuple_is_the_public_contract(self):
        assert RULES == ("tracer-host-pull", "import-time-jnp",
                         "unreferenced-cost-helper", "pallas-call-hygiene")
