"""Program-contract subsystem tests (DESIGN.md Sec. 15).

Four layers:

* the jaxpr walker itself (recursion, loop weighting, collective counts);
* the contract registry: the required ids exist and every registered
  contract passes against the live repo;
* break-detection: deliberately violating an invariant (a second pallas
  launch, an extra cross-host psum, a dropped donation) FAILS with a
  report naming the violated contract/rule — the property that makes the
  checker worth wiring into CI;
* booked == counted for the scheduler's per-round/per-refresh bill against
  :func:`repro.core.costs.lossy_round_cost` /
  :func:`repro.core.costs.lossy_refresh_cost` (the cost pair the repolint
  ``unreferenced-cost-helper`` rule flagged as unpinned).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts
from repro.analysis.jaxpr_lint import (CollectiveBudget, ForbidInLoops,
                                       Fp32Accumulators, NoF64,
                                       PrimitiveBudget, collective_counts,
                                       count_primitive, count_primitives)
from repro.core import costs
from repro.streaming.driver import (StreamConfig, chunk_stream_step,
                                    stream_init, stream_run)

REQUIRED_CONTRACTS = (
    "chunk.body", "chunk.body.split", "chunk.fused.fp32", "chunk.fused.bf16",
    "driver.hot-loop", "dtype.policy", "hierarchy.refresh", "engine.step",
    "engine.step.pipelined",
)


# ===========================================================================
# The walker
# ===========================================================================
class TestWalker:
    def test_counts_inside_cond_branches(self):
        def f(x):
            return jax.lax.cond(x.sum() > 0,
                                lambda v: jnp.sin(v),
                                lambda v: jnp.sin(jnp.sin(v)), x)

        jx = jax.make_jaxpr(f)(jnp.ones(3))
        # both branches count (repo convention for launch budgets)
        assert count_primitive(jx, "sin") == 3

    def test_loop_weighted_scan_multiplies_length(self):
        def f(x):
            return jax.lax.scan(lambda c, _: (jnp.sin(c), None), x,
                                None, length=5)[0]

        jx = jax.make_jaxpr(f)(jnp.ones(3))
        assert count_primitive(jx, "sin") == 1
        assert count_primitive(jx, "sin", loop_weighted=True) == 5

    def test_loop_weighted_fori_and_nesting(self):
        def f(x):
            def body(_, c):
                return jax.lax.scan(lambda a, __: (jnp.sin(a), None), c,
                                    None, length=3)[0]
            return jax.lax.fori_loop(0, 4, body, x)

        jx = jax.make_jaxpr(f)(jnp.ones(3))
        assert count_primitive(jx, "sin", loop_weighted=True) == 12

    def test_while_loop_trip_from_cond_literal(self):
        def f(x):
            return jax.lax.while_loop(lambda c: c[0] < 7,
                                      lambda c: (c[0] + 1, jnp.sin(c[1])),
                                      (jnp.int32(0), x))[1]

        jx = jax.make_jaxpr(f)(jnp.ones(3))
        assert count_primitive(jx, "sin", loop_weighted=True) == 7

    def test_count_primitives_matches_single_counts(self):
        cfg = StreamConfig(p=12, q=3, halfwidth=2, warmup_rounds=4)
        st = stream_init(cfg, jax.random.PRNGKey(0))
        jx = jax.make_jaxpr(
            lambda s, x: chunk_stream_step(cfg, s, x))(
            st, jnp.zeros((4, 4, 12), jnp.float32))
        many = count_primitives(jx, {"pallas_call", "eigh"})
        assert many["pallas_call"] == count_primitive(jx, "pallas_call") == 1
        assert many["eigh"] == count_primitive(jx, "eigh") == 1

    def test_collective_counts_through_shard_map(self):
        # the shard_map param is a RAW Jaxpr (no ClosedJaxpr wrapper) —
        # exactly the case the old ad-hoc test helpers failed to descend
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.array(jax.devices()[:1]), ("r",))
        f = shard_map(lambda x: jax.lax.psum(jnp.sum(x), "r"), mesh=mesh,
                      in_specs=P("r"), out_specs=P(), check_rep=False)
        jx = jax.make_jaxpr(f)(jnp.ones((1, 3)))
        assert collective_counts(jx) == {"r": {"psum": 1}}


# ===========================================================================
# The registry against the live repo
# ===========================================================================
class TestRegisteredContracts:
    def test_required_contracts_registered(self):
        reg = contracts.load_entry_points()
        missing = [cid for cid in REQUIRED_CONTRACTS if cid not in reg]
        assert not missing, f"unregistered contracts: {missing}"
        assert len(reg) >= 6

    @pytest.mark.parametrize("cid", REQUIRED_CONTRACTS)
    def test_contract_passes_on_repo(self, cid):
        contracts.load_entry_points()
        results = contracts.check_contract(contracts.get_contract(cid))
        assert results, f"{cid} produced no rule results"
        bad = [r.line() for r in results if not r.ok]
        assert not bad, "\n".join(bad)

    def test_hierarchy_refresh_collective_budget(self):
        """Satellite: exactly one all_gather + one psum on the 'region'
        axis per hierarchical refresh/merge — asserted on the raw counts,
        independently of the CollectiveBudget rule implementation."""
        contracts.load_entry_points()
        c = contracts.get_contract("hierarchy.refresh")
        (label, jx), = c.trace().items()
        counts = collective_counts(jx)
        assert set(counts) == {"region"}, (label, counts)
        assert counts["region"] == {"all_gather": 1, "psum": 1}


# ===========================================================================
# Break-detection: violated invariants FAIL with a named report
# ===========================================================================
class TestBreakDetection:
    def _chunk_jaxpr(self, wrap=None):
        cfg = StreamConfig(p=12, q=3, halfwidth=2, warmup_rounds=4)
        st = stream_init(cfg, jax.random.PRNGKey(0))
        step = (lambda s, x: chunk_stream_step(cfg, s, x))
        fn = wrap(step) if wrap is not None else step
        return jax.make_jaxpr(fn)(st, jnp.zeros((4, 4, 12), jnp.float32))

    def test_second_pallas_call_fails_budget(self):
        def twice(step):
            def f(s, x):
                s1, m = step(s, x)
                return step(s1, x)[0], m          # a second launch
            return f

        rep = PrimitiveBudget("pallas_call", exact=1).check(
            self._chunk_jaxpr(twice))
        assert not rep.ok
        assert rep.rule == "budget:pallas_call"
        assert "2" in rep.detail and "1" in rep.detail

    def test_extra_psum_fails_collective_budget(self):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.array(jax.devices()[:1]), ("region",))

        def merge(x):
            g = jax.lax.all_gather(x, "region", tiled=True)
            tot = jax.lax.psum(jnp.sum(x), "region")
            extra = jax.lax.psum(jnp.max(x), "region")   # the violation
            return jnp.sum(g) + tot + extra

        f = shard_map(merge, mesh=mesh, in_specs=P("region"), out_specs=P(),
                      check_rep=False)
        jx = jax.make_jaxpr(f)(jnp.ones((1, 3)))
        rule = CollectiveBudget(axis="region",
                                budgets=(("all_gather", 1), ("psum", 1)))
        rep = rule.check(jx)
        assert not rep.ok
        assert rep.rule == "collectives:region"
        assert "psum" in rep.detail

    def test_host_callback_in_loop_fails(self):
        def f(x):
            def body(c, _):
                jax.debug.callback(lambda v: None, c.sum())
                return jnp.sin(c), None
            return jax.lax.scan(body, x, None, length=3)[0]

        jx = jax.make_jaxpr(f)(jnp.ones(3))
        rep = ForbidInLoops().check(jx)
        assert not rep.ok and "debug_callback" in rep.detail

    def test_f64_fails_dtype_rule(self):
        with jax.experimental.enable_x64():
            jx = jax.make_jaxpr(
                lambda x: jnp.sum(x.astype(jnp.float64)))(jnp.ones(3))
        rep = NoF64().check(jx)
        assert not rep.ok

    def test_bf16_scan_carry_fails_fp32_accumulators(self):
        def f(x):
            return jax.lax.scan(lambda c, _: (c * jnp.bfloat16(0.5), None),
                                x.astype(jnp.bfloat16), None, length=3)[0]

        rep = Fp32Accumulators().check(jax.make_jaxpr(f)(jnp.ones(3)))
        assert not rep.ok and "bfloat16" in rep.detail

    def test_check_contract_reports_broken_trace_as_failure(self):
        broken = contracts.Contract(
            id="x.broken", where="nowhere", claim="trace crashes",
            trace=lambda: (_ for _ in ()).throw(RuntimeError("gone")),
            rules=(NoF64(),))
        results = contracts.check_contract(broken)
        assert len(results) == 1
        assert not results[0].ok and results[0].rule == "trace"

    def test_dropped_donation_fails_runtime_check(self):
        cfg = StreamConfig(p=8, q=2, halfwidth=1, warmup_rounds=2)
        st = stream_init(cfg, jax.random.PRNGKey(0))
        x = jnp.zeros((2, 4, 8), jnp.float32)
        donated = jax.jit(lambda s, xc: chunk_stream_step(cfg, s, xc),
                          donate_argnums=(0,))
        plain = jax.jit(lambda s, xc: chunk_stream_step(cfg, s, xc))
        assert contracts.donation_report(donated, st, x, argnum=0).ok
        rep = contracts.donation_report(plain, st, x, argnum=0)
        assert not rep.ok and "donate" in rep.detail

    def test_retrace_report_counts_cache_entries(self):
        f = jax.jit(lambda x: x + 1)
        for _ in range(3):
            f(jnp.ones(4)).block_until_ready()
        assert contracts.retrace_report(f, 3).ok


# ===========================================================================
# Booked == counted: the scheduler's bill against the cost-model helpers
# ===========================================================================
class TestSchedulerBillMatchesCostModel:
    @pytest.mark.parametrize("link_loss", [0.0, 0.1])
    def test_comm_packets_equals_rounds_plus_refreshes(self, link_loss):
        cfg = StreamConfig(p=12, q=3, halfwidth=2, forgetting=0.95,
                           drift_threshold=0.05, warmup_rounds=4,
                           link_loss=link_loss, interpret=True)
        rng = np.random.default_rng(0)
        R = 16
        xs = jnp.asarray(rng.normal(size=(R, 6, cfg.p)).astype(np.float32))
        fin, _ = stream_run(cfg, stream_init(cfg, jax.random.PRNGKey(1)), xs)

        per_round = costs.lossy_round_cost(
            cfg.n_max, cfg.q, cfg.c_max, cfg.link_loss,
            cfg.max_retries).communication
        per_refresh = costs.lossy_refresh_cost(
            cfg.p, cfg.q, cfg.n_max, cfg.c_max, cfg.refresh_iters,
            cfg.link_loss, cfg.max_retries).communication
        refreshes = int(fin.sched.refreshes)
        assert refreshes >= 1                    # warmup refresh fired
        expected = R * per_round + refreshes * per_refresh
        np.testing.assert_allclose(float(fin.sched.comm_packets), expected,
                                   rtol=1e-5)
