"""One-pass fused streaming mega-kernel: differential + structural suite.

The contract under test (ISSUE 7 / DESIGN.md Sec. 14):
1. the fused kernel's outputs are BIT-identical at fp32 to the three split
   kernels it replaces (cov_band_update_chunk + supervised_compress +
   pca_monitor) — divisible, non-divisible/prime-p, masked, zero-weight
   tail shapes — and tolerance-bounded against the jnp oracle,
2. the pure-jnp stage twin (the driver's post-refresh fix-up) is bitwise
   equal to the kernel's stage outputs, fp32 and bf16, at multi-block
   shapes,
3. the fused driver path is bit-identical to the split path — states and
   metrics, per-round and chunked, masked and unmasked, through refresh
   rounds — and ``probe_every=1`` reproduces ``stream_run`` exactly,
4. the chunked step with compression AND detection traces to exactly ONE
   ``pallas_call`` per chunk body (cond branches included) — down from 3,
5. bf16 tile mode runs the same program within tolerance of fp32,
6. satellite regressions: the per-round cov wrappers pad prime/odd p to
   the target block (no silent block_p=1 tiling), kernel wrappers honour
   an explicit out_dtype, the bf16 checkpoint round-trip holds through
   the fused path, and the roofline tile targets are backend-aware.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.launch.tiling import block_targets
from repro.streaming import (
    CompressionConfig, DetectionConfig, StreamConfig, batched_stream_run,
    chunked_stream_run, stream_init, stream_run,
)
from repro.streaming.driver import batched_stream_init, chunk_stream_step
from repro.train import checkpoint


def _assert_trees_equal(a, b, msg=""):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def _operands(rows, p, q, seed=0, masked=False, zero_tail=False):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, p)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.2, 1.0, size=(rows,)), jnp.float32)
    if zero_tail:
        w = w.at[-max(rows // 4, 1):].set(0.0)
    basis, _ = jnp.linalg.qr(
        jnp.asarray(rng.normal(size=(p, q)), jnp.float32))
    mean = jnp.asarray(rng.normal(size=(p,)), jnp.float32)
    il = jnp.asarray(rng.uniform(0.5, 2.0, size=(q,)), jnp.float32)
    mask = jnp.asarray(rng.random((rows, p)) > 0.2, jnp.float32) \
        if masked else None
    return x, w, basis, mean, il, mask


# jit with operands as ARGUMENTS (the wrappers' real calling structure):
# closure-constant jits compile different programs and void the bit claims
def _run_fused(x, w, basis, mean, il, mask, *, h, eps, precision="fp32"):
    f = jax.jit(functools.partial(
        ops.fused_stream_update, halfwidth=h, epsilon=eps,
        with_compress=True, with_monitor=True, precision=precision))
    if mask is None:
        return f(x, w, basis, mean, il)
    return f(x, w, basis, mean, il, mask=mask)


def _run_split(x, w, basis, mean, il, mask, *, h, eps):
    n_rows, p = x.shape

    def split(x, w, basis, mean, il, *m):
        mk = m[0] if m else None
        band = ops.cov_band_update_chunk(
            x[:, None, :], w, h,
            mask=mk[:, None, :] if mk is not None else None)
        z, xh, fl = ops.supervised_compress(x, basis, mean, epsilon=eps,
                                            mask=mk)
        _, t2, spe = ops.pca_monitor(x, basis, mean, il, mask=mk)
        return band, z, xh, fl, t2, spe

    f = jax.jit(split)
    if mask is None:
        return f(x, w, basis, mean, il)
    return f(x, w, basis, mean, il, mask)


SHAPES = [
    (32, 24, 4, False, False),   # divisible everything
    (32, 24, 4, True, False),    # masked
    (15, 17, 3, False, False),   # non-divisible rows, prime p
    (15, 17, 3, True, True),     # prime p, masked, zero-weight tail
    (8, 8, 2, False, True),      # K=1-sized, zero-weight tail
    (1, 8, 2, False, False),     # single row (probe_every=1 shape)
    (40, 12, 3, True, False),    # multi-row-block, masked
]


class TestFusedKernelDifferential:
    @pytest.mark.parametrize("rows,p,q,masked,zt", SHAPES)
    def test_fp32_bitwise_vs_split_kernels(self, rows, p, q, masked, zt):
        x, w, basis, mean, il, mask = _operands(rows, p, q, seed=rows + p,
                                                masked=masked, zero_tail=zt)
        got = _run_fused(x, w, basis, mean, il, mask, h=2, eps=0.4)
        want = _run_split(x, w, basis, mean, il, mask, h=2, eps=0.4)
        for name, a, b in zip(("band", "z", "x_hat", "flags", "t2", "spe"),
                              got, want):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{name} rows={rows} p={p} masked={masked}")

    @pytest.mark.parametrize("rows,p,q,masked,zt", SHAPES[:4])
    def test_matches_jnp_oracle(self, rows, p, q, masked, zt):
        x, w, basis, mean, il, mask = _operands(rows, p, q, seed=3,
                                                masked=masked, zero_tail=zt)
        band, z, xh, fl, t2, spe = _run_fused(x, w, basis, mean, il, mask,
                                              h=2, eps=0.4)
        oband, oz, oxh, ofl, ot2, ospe = ref.fused_stream(
            x, w, basis, mean, il, 2, 0.4, mask=mask)
        np.testing.assert_allclose(band, oband, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(z, oz, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(xh, oxh, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(fl), np.asarray(ofl))
        np.testing.assert_allclose(t2, ot2, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(spe, ospe, rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("precision", ["fp32", "bf16"])
    @pytest.mark.parametrize("rows,p,q,masked,zt",
                             [(32, 24, 4, False, False),
                              (14, 32, 3, True, False),
                              (40, 8, 2, False, True)])
    def test_twin_bitwise_matches_kernel_stages(self, precision, rows, p, q,
                                                masked, zt):
        x, w, basis, mean, il, mask = _operands(rows, p, q, seed=7,
                                                masked=masked, zero_tail=zt)
        _, z, xh, fl, t2, spe = _run_fused(x, w, basis, mean, il, mask,
                                           h=2, eps=0.4, precision=precision)
        twin = jax.jit(functools.partial(
            ops.fused_stream_stages_blocked, epsilon=0.4,
            with_compress=True, with_monitor=True, precision=precision))
        if mask is None:
            tz, txh, tfl, tt2, tspe = twin(x, basis, mean, il)
        else:
            tz, txh, tfl, tt2, tspe = twin(x, basis, mean, il, mask=mask)
        for name, a, b in zip(("z", "x_hat", "flags", "t2", "spe"),
                              (z, xh, fl, t2, spe),
                              (tz, txh, tfl, tt2, tspe)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"twin {name} precision={precision}")

    def test_bf16_tolerance_vs_fp32(self):
        x, w, basis, mean, il, _ = _operands(32, 24, 4, seed=11)
        f32 = _run_fused(x, w, basis, mean, il, None, h=2, eps=0.4)
        b16 = _run_fused(x, w, basis, mean, il, None, h=2, eps=0.4,
                         precision="bf16")
        for a, b in zip(f32[:3], b16[:3]):      # band, z, x_hat
            assert b.dtype == jnp.float32       # fp32 accumulators out
            scale = float(jnp.max(jnp.abs(a))) + 1e-6
            assert float(jnp.max(jnp.abs(a - b))) / scale < 0.02

    def test_band_only_rejected(self):
        x, w, basis, mean, il, _ = _operands(8, 8, 2)
        with pytest.raises(AssertionError):
            ops.fused_stream_update(x, w, basis, mean, il, halfwidth=2,
                                    with_compress=False, with_monitor=False)


class TestFusedDriverDifferential:
    P, Q, H, N, R = 12, 3, 2, 4, 24

    def _cfg(self, comp=True, det=True, **kw):
        return StreamConfig(
            p=self.P, q=self.Q, halfwidth=self.H, forgetting=0.97,
            warmup_rounds=4, link_loss=0.05,
            compression=CompressionConfig(epsilon=0.5) if comp else None,
            detection=DetectionConfig(alpha=1e-3, calib_rounds=3)
            if det else None, **kw)

    def _stream(self, seed=1):
        rng = np.random.default_rng(seed)
        xs = jnp.asarray(rng.normal(size=(self.R, self.N, self.P)),
                         jnp.float32)
        masks = jnp.asarray(rng.random((self.R, self.P)) > 0.15,
                            jnp.float32)
        return xs, masks

    @pytest.mark.parametrize("comp,det", [(True, False), (False, True),
                                          (True, True)])
    @pytest.mark.parametrize("masked", [False, True])
    @pytest.mark.parametrize("chunk,probe", [(4, None), (4, 1), (5, None)])
    def test_fused_bitwise_matches_split(self, comp, det, masked, chunk,
                                         probe):
        cfg = self._cfg(comp, det)
        cfg_split = dataclasses.replace(cfg, fused=False)
        xs, masks = self._stream()
        m = masks if masked else None
        key = jax.random.PRNGKey(0)
        got = chunked_stream_run(cfg, stream_init(cfg, key), xs, m,
                                 chunk=chunk, probe_every=probe)
        want = chunked_stream_run(cfg_split, stream_init(cfg_split, key),
                                  xs, m, chunk=chunk, probe_every=probe)
        # the runs must actually exercise refreshes, or the cond'd twin
        # fix-up (the hard half of the parity claim) is never on trial
        assert bool(jnp.any(got[1].did_refresh))
        _assert_trees_equal(got, want,
                            f"fused vs split comp={comp} det={det} "
                            f"masked={masked} chunk={chunk} probe={probe}")

    @pytest.mark.parametrize("masked", [False, True])
    def test_probe_every_one_reproduces_stream_run(self, masked):
        cfg = self._cfg()
        xs, masks = self._stream()
        m = masks if masked else None
        key = jax.random.PRNGKey(0)
        want = stream_run(cfg, stream_init(cfg, key), xs, m)
        got = chunked_stream_run(cfg, stream_init(cfg, key), xs, m,
                                 chunk=4, probe_every=1)
        _assert_trees_equal(got, want, f"probe_every=1 masked={masked}")

    def test_batched_fused_bitwise_matches_split(self):
        cfg = self._cfg()
        cfg_split = dataclasses.replace(cfg, fused=False)
        rng = np.random.default_rng(5)
        B = 3
        xsb = jnp.asarray(rng.normal(size=(B, 16, self.N, self.P)),
                          jnp.float32)
        states = batched_stream_init(cfg, jax.random.PRNGKey(0), B)
        states_s = batched_stream_init(cfg_split, jax.random.PRNGKey(0), B)
        got = batched_stream_run(cfg, states, xsb, chunk=4)
        want = batched_stream_run(cfg_split, states_s, xsb, chunk=4)
        _assert_trees_equal(got, want, "batched fused vs split")

    def test_quantized_scores_keep_split_path(self):
        # score_bits > 0 needs whole-round scales between projection and
        # reconstruction: the config must route to the split path and stay
        # bit-identical whatever cfg.fused says
        cfg = self._cfg(comp=False, det=True)
        cfg = dataclasses.replace(
            cfg, compression=CompressionConfig(epsilon=0.5, score_bits=4))
        cfg_split = dataclasses.replace(cfg, fused=False)
        xs, _ = self._stream()
        key = jax.random.PRNGKey(0)
        got = chunked_stream_run(cfg, stream_init(cfg, key), xs, chunk=4)
        want = chunked_stream_run(cfg_split, stream_init(cfg_split, key),
                                  xs, chunk=4)
        _assert_trees_equal(got, want, "quantized config")

    def test_bf16_driver_tolerance(self):
        cfg = self._cfg()
        cfg_bf = dataclasses.replace(cfg, precision="bf16")
        xs, _ = self._stream()
        key = jax.random.PRNGKey(0)
        s32, _ = chunked_stream_run(cfg, stream_init(cfg, key), xs, chunk=4)
        s16, _ = chunked_stream_run(cfg_bf, stream_init(cfg_bf, key), xs,
                                    chunk=4)
        band32, band16 = s32.cov.band, s16.cov.band
        scale = float(jnp.max(jnp.abs(band32))) + 1e-6
        assert float(jnp.max(jnp.abs(band32 - band16))) / scale < 0.02

    def test_bad_precision_rejected(self):
        with pytest.raises(ValueError):
            self._cfg(precision="fp16")


# recursive jaxpr primitive counting now lives in the shared analysis walker
from repro.analysis.jaxpr_lint import count_primitive as _count_primitive


class TestFusedLaunchStructure:
    def _cfg(self, fused=True):
        return StreamConfig(
            p=12, q=3, halfwidth=2, warmup_rounds=4,
            compression=CompressionConfig(epsilon=0.5),
            detection=DetectionConfig(alpha=1e-3, calib_rounds=3),
            fused=fused)

    @pytest.mark.parametrize("K", [1, 4, 8])
    def test_one_pallas_call_per_chunk_body(self, K):
        cfg = self._cfg()
        st = stream_init(cfg, jax.random.PRNGKey(0))
        xc = jnp.zeros((K, 4, 12), jnp.float32)
        jx = jax.make_jaxpr(
            lambda s, x: chunk_stream_step(cfg, s, x))(st, xc)
        # recursive count: lax.cond branches (the twin fix-up) included
        assert _count_primitive(jx.jaxpr, "pallas_call") == 1

    def test_split_path_pays_three(self):
        cfg = self._cfg(fused=False)
        st = stream_init(cfg, jax.random.PRNGKey(0))
        xc = jnp.zeros((4, 4, 12), jnp.float32)
        jx = jax.make_jaxpr(
            lambda s, x: chunk_stream_step(cfg, s, x))(st, xc)
        assert _count_primitive(jx.jaxpr, "pallas_call") == 3


class TestPrimePBlockRegression:
    """Satellite 1: the per-round cov wrappers must pad prime/odd p to the
    block target instead of falling through the divisor ladder to
    block_p=1 (an up-to-512x tiling degradation the chunk path already
    avoided)."""

    @pytest.mark.parametrize("p", [17, 23, 51])
    def test_per_round_pads_prime_p(self, p):
        rng = np.random.default_rng(p)
        x = jnp.asarray(rng.normal(size=(16, p)), jnp.float32)
        got = ops.cov_band_update(x, 2)
        want = ref.cov_band_update(x, 2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        # bit-exactness of the internal pad: identical to padding the
        # features externally to the picked block and slicing the band
        bp = ops._pick_block_padded(p, ops._targets("cov")[1])
        assert bp > 1, "prime p fell through to block_p=1 again"
        pad = (-p) % bp
        xp = jnp.pad(x, ((0, 0), (0, pad)))
        ext = ops.cov_band_update(xp, 2)[:, :p]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ext))

    def test_masked_per_round_pads_prime_p(self):
        p = 17
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, p)), jnp.float32)
        mask = jnp.asarray(rng.random((16, p)) > 0.3, jnp.float32)
        got = ops.cov_band_update_masked(x, mask, 2)
        want = ref.cov_band_update_masked(x, mask, 2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_chunk_k1_bitwise_matches_per_round_at_prime_p(self):
        p = 17
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(1, 16, p)), jnp.float32)
        chunk = ops.cov_band_update_chunk(x, jnp.ones(1), 2)
        per = ops.cov_band_update(x[0], 2)
        np.testing.assert_array_equal(np.asarray(chunk), np.asarray(per))


class TestDtypePolicy:
    """Satellite 2: wrapper output dtype is an explicit policy, not a
    hard-coded fp32 cast."""

    def test_cov_update_default_fp32_and_override(self):
        x = jnp.ones((8, 16), jnp.bfloat16)
        assert ops.cov_band_update(x, 2).dtype == jnp.float32
        assert ops.cov_band_update(
            x, 2, out_dtype=jnp.bfloat16).dtype == jnp.bfloat16

    def test_banded_matvec_follows_band_dtype(self):
        band = jnp.ones((5, 16), jnp.bfloat16)
        v = jnp.ones((16,), jnp.bfloat16)
        assert ops.banded_matvec(band, v).dtype == jnp.bfloat16
        assert ops.banded_matvec(
            band, v, out_dtype=jnp.float32).dtype == jnp.float32

    def test_bf16_checkpoint_roundtrip_through_fused_path(self, tmp_path):
        # the PR 4 restore fix (np.savez round-trips extension dtypes as
        # raw void bytes) pinned through the fused driver: a bf16-staged
        # engine state must survive save/restore bit-exactly AND resume
        # the fused stream on the same trajectory
        cfg = StreamConfig(p=12, q=3, halfwidth=2, warmup_rounds=4,
                           precision="bf16",
                           compression=CompressionConfig(epsilon=0.5),
                           detection=DetectionConfig(alpha=1e-3,
                                                     calib_rounds=3))
        rng = np.random.default_rng(2)
        xs = jnp.asarray(rng.normal(size=(16, 4, 12)), jnp.float32)
        key = jax.random.PRNGKey(0)
        mid, _ = chunked_stream_run(cfg, stream_init(cfg, key), xs[:8],
                                    chunk=4)
        # a bf16 engine stages chunk buffers and the flooded basis in bf16
        staged = {"state": mid,
                  "basis_bf16": mid.sched.W.astype(jnp.bfloat16),
                  "buffer_bf16": xs[8:].astype(jnp.bfloat16)}
        checkpoint.save(str(tmp_path), 1, staged)
        restored, _ = checkpoint.restore(str(tmp_path), staged)
        assert restored["basis_bf16"].dtype == jnp.bfloat16
        assert restored["buffer_bf16"].dtype == jnp.bfloat16
        _assert_trees_equal(restored, staged, "bf16 checkpoint roundtrip")
        want = chunked_stream_run(cfg, mid, xs[8:], chunk=4)
        got = chunked_stream_run(cfg, restored["state"],
                                 restored["buffer_bf16"]
                                 .astype(jnp.float32), chunk=4)
        # resumed fused-bf16 stream continues the same trajectory up to
        # the bf16 staging quantization of the buffered rounds
        np.testing.assert_allclose(
            np.asarray(got[0].cov.band), np.asarray(want[0].cov.band),
            rtol=0.02, atol=1e-3)


class TestTileTargets:
    """Roofline-informed block targets (launch/tiling.py)."""

    def test_non_tpu_keeps_historical(self):
        for kind in ("cov", "stage", "fused", "banded"):
            assert block_targets(kind, backend="cpu") == \
                {"rows": 128, "features": 512}

    def test_tpu_targets_derived(self):
        t32 = block_targets("fused", "fp32", backend="tpu")
        t16 = block_targets("fused", "bf16", backend="tpu")
        assert t32["features"] == 512
        assert t16["features"] == 1024          # half the bytes per lane
        assert t32["rows"] >= 128 and t32["rows"] & (t32["rows"] - 1) == 0
        # VMEM bound: the double-buffered working set must fit half of it
        assert 4 * 2 * t32["rows"] * t32["features"] * 4 <= 16 * 2**20

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            block_targets("attention")
