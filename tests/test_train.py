"""Training substrate: optimizer, data pipeline, checkpoint/resume (bitwise),
fault-injection restart, elastic planning, health monitoring, serving."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.tokens import TokenPipeline
from repro.runtime.elastic import plan_mesh
from repro.runtime.health import HealthMonitor, StragglerPolicy
from repro.train import checkpoint as CKPT
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   warmup_cosine)
from repro.train.trainer import TrainConfig, Trainer

SMOKE = configs.get("llama3.2-1b").smoke()


def _tiny_pipeline(**kw):
    return TokenPipeline(vocab_size=SMOKE.vocab_size, seq_len=16,
                         global_batch=4, seed=1, **kw)


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        w = {"w": jnp.asarray(np.random.default_rng(0)
                              .normal(size=(8, 8)).astype(np.float32))}
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
        st = adamw_init(w, cfg)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        start = float(loss(w))
        for _ in range(80):
            g = jax.grad(loss)(w)
            w, st, _ = adamw_update(w, g, st, cfg, cfg.lr)
        assert float(loss(w)) < 1e-2 * start

    def test_warmup_cosine_shape(self):
        lrs = [float(warmup_cosine(jnp.asarray(s), peak_lr=1.0, warmup=10,
                                   total=100)) for s in range(100)]
        assert lrs[0] == 0.0
        assert abs(lrs[10] - 1.0) < 0.11
        assert lrs[99] < 0.2
        assert max(lrs) <= 1.0 + 1e-6

    def test_grad_clip(self):
        from repro.train.optimizer import clip_by_global_norm, global_norm
        g = {"a": jnp.ones((100,)) * 10}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(100.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


class TestDataPipeline:
    def test_deterministic(self):
        p1, p2 = _tiny_pipeline(), _tiny_pipeline()
        np.testing.assert_array_equal(next(p1), next(p2))

    def test_resume_cursor(self):
        p1 = _tiny_pipeline()
        next(p1); next(p1)
        state = p1.state_dict()
        p2 = _tiny_pipeline()
        p2.load_state_dict(state)
        np.testing.assert_array_equal(next(p1), next(p2))

    def test_host_sharding_partitions_batch(self):
        full = _tiny_pipeline().batch_at(0)
        h0 = TokenPipeline(vocab_size=SMOKE.vocab_size, seq_len=16,
                           global_batch=4, seed=1, host_id=0, n_hosts=2)
        h1 = TokenPipeline(vocab_size=SMOKE.vocab_size, seq_len=16,
                           global_batch=4, seed=1, host_id=1, n_hosts=2)
        np.testing.assert_array_equal(
            np.concatenate([h0.batch_at(0), h1.batch_at(0)]), full)

    def test_has_learnable_structure(self):
        """Bigram structure: next-token entropy < unigram entropy."""
        p = TokenPipeline(vocab_size=64, seq_len=512, global_batch=8, seed=0)
        toks = p.batch_at(0).ravel()
        # a simple predictor: most common successor of previous token
        from collections import Counter, defaultdict
        succ = defaultdict(Counter)
        for a, b in zip(toks[:-1], toks[1:]):
            succ[a][b] += 1
        correct = sum(c.most_common(1)[0][1] for c in succ.values())
        acc = correct / (len(toks) - 1)
        assert acc > 0.2   # far above 1/64 chance


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
        CKPT.save(str(tmp_path), 5, tree, extra={"step": 5})
        out, extra = CKPT.restore(str(tmp_path), tree)
        assert extra["step"] == 5
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))

    def test_roundtrip_bfloat16(self, tmp_path):
        """Regression: np.savez stores bfloat16 as raw |V2 void bytes, and
        restore used to die with 'No cast function available' — breaking
        EVERY resume of a bf16 training run (examples/train_lm.py).  The
        manifest's dtype record now reinterprets the bytes."""
        tree = {"w": jnp.arange(12.0, dtype=jnp.bfloat16).reshape(3, 4),
                "b": jnp.ones((2,), jnp.float32)}
        CKPT.save(str(tmp_path), 1, tree)
        out, _ = CKPT.restore(str(tmp_path), tree)
        assert out["w"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(out["w"], np.float32), np.asarray(tree["w"],
                                                         np.float32))

    def test_atomic_no_partial_visible(self, tmp_path):
        tree = {"a": jnp.ones((4,))}
        CKPT.save(str(tmp_path), 1, tree)
        # simulate a crash leaving a tmp dir behind
        os.makedirs(tmp_path / "step_00000002.tmp")
        assert CKPT.latest_step(str(tmp_path)) == 1

    def test_retention_gc(self, tmp_path):
        tree = {"a": jnp.ones((2,))}
        for s in range(6):
            CKPT.save(str(tmp_path), s, tree, keep=3)
        steps = sorted(int(n[5:]) for n in os.listdir(tmp_path))
        assert steps == [3, 4, 5]

    def test_shape_mismatch_raises(self, tmp_path):
        CKPT.save(str(tmp_path), 1, {"a": jnp.ones((4,))})
        with pytest.raises(CKPT.CheckpointError, match="shape mismatch"):
            CKPT.restore(str(tmp_path), {"a": jnp.ones((5,))})

    def test_elastic_reshard_placement(self, tmp_path):
        """Restore under a different sharding than the save used."""
        from jax.sharding import NamedSharding, PartitionSpec
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        CKPT.save(str(tmp_path), 1, tree)
        mesh = jax.make_mesh((1,), ("data",))
        sh = {"w": NamedSharding(mesh, PartitionSpec("data", None))}
        out, _ = CKPT.restore(str(tmp_path), tree, shardings=sh)
        assert out["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))


class TestTrainerEndToEnd:
    def _make(self, tmp_path, **tkw):
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3),
                           warmup_steps=2, total_steps=50,
                           checkpoint_dir=str(tmp_path), checkpoint_every=5,
                           remat=False, **tkw)
        return Trainer(SMOKE, tcfg, _tiny_pipeline(),
                       key=jax.random.PRNGKey(0))

    def test_loss_decreases(self, tmp_path):
        tr = self._make(tmp_path)
        hist = tr.run(20, log_every=0)
        first = np.mean([h["loss"] for h in hist[:4]])
        last = np.mean([h["loss"] for h in hist[-4:]])
        assert last < first

    def test_bitwise_resume_after_crash(self, tmp_path):
        """Train 10, 'crash', resume from step 10, continue to 15 — losses
        must match an uninterrupted 15-step run exactly."""
        tr1 = self._make(tmp_path / "a")
        tr1.run(15, log_every=0)
        losses_full = [h["loss"] for h in tr1.history]

        tr2 = self._make(tmp_path / "b")
        tr2.run(10, log_every=0)
        tr2.save(async_=False)
        # crash: rebuild everything from scratch and resume
        tr3 = self._make(tmp_path / "b")
        assert tr3.try_resume()
        assert tr3.state.step == 10
        tr3.run(5, log_every=0)
        losses_resumed = [h["loss"] for h in tr2.history] + \
            [h["loss"] for h in tr3.history]
        np.testing.assert_allclose(losses_full, losses_resumed,
                                   rtol=0, atol=0)

    def test_compressed_training_converges(self, tmp_path):
        tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), warmup_steps=2,
                           total_steps=50, compress_rank=4, remat=False)
        tr = Trainer(SMOKE, tcfg, _tiny_pipeline(), key=jax.random.PRNGKey(0))
        hist = tr.run(20, log_every=0)
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_microbatched_equals_full_batch_loss_scale(self, tmp_path):
        """Gradient accumulation: same data, same first-step loss."""
        tcfg1 = TrainConfig(optimizer=AdamWConfig(lr=0.0), warmup_steps=1,
                            total_steps=5, microbatches=1, remat=False)
        tcfg2 = TrainConfig(optimizer=AdamWConfig(lr=0.0), warmup_steps=1,
                            total_steps=5, microbatches=2, remat=False)
        t1 = Trainer(SMOKE, tcfg1, _tiny_pipeline(), key=jax.random.PRNGKey(0))
        t2 = Trainer(SMOKE, tcfg2, _tiny_pipeline(), key=jax.random.PRNGKey(0))
        h1 = t1.run(1, log_every=0)
        h2 = t2.run(1, log_every=0)
        assert h1[0]["loss"] == pytest.approx(h2[0]["loss"], rel=1e-4)


class TestRuntime:
    def test_straggler_detection(self):
        mon = HealthMonitor(StragglerPolicy(straggler_factor=2.0,
                                            min_samples=4))
        for s in range(8):
            mon.heartbeat(step=s, duration=1.0)
        mon.heartbeat(step=9, duration=5.0)
        assert mon.straggler_count() == 1

    def test_stall_detection(self):
        now = [0.0]
        mon = HealthMonitor(StragglerPolicy(stall_timeout=10.0),
                            clock=lambda: now[0])
        mon.heartbeat(step=1, duration=1.0)
        now[0] = 5.0
        assert not mon.stalled()
        now[0] = 20.0
        assert mon.stalled()

    def test_elastic_plan(self):
        plan = plan_mesh(192, prefer_model=16, global_batch=256)
        assert plan.n_devices == 192
        assert plan.model == 16
        assert plan.global_batch % plan.data == 0
        # odd device counts still yield a plan
        plan2 = plan_mesh(7, prefer_model=16, global_batch=256)
        assert plan2.n_devices == 7


class TestServing:
    def test_engine_continuous_batching(self):
        from repro.serve.engine import Engine, Request, ServeConfig
        from repro.models import transformer as T
        cfg = SMOKE
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(slots=2, max_len=32))
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 4)
                        .astype(np.int32), max_new_tokens=4)
                for _ in range(5)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        for r in reqs:
            assert r.done
            assert len(r.output) == 4
            assert all(0 <= t < cfg.vocab_size for t in r.output)

    def test_prefill_buckets_identical_first_token(self):
        """Prompts are padded to power-of-two buckets (masked prefill):
        the first token must be identical to the exact-length prefill for
        every length, and distinct lengths inside one bucket must reuse
        ONE compiled prefill (compile count O(log max_len))."""
        from repro.serve.engine import Engine, Request, ServeConfig
        from repro.models import transformer as T
        cfg = SMOKE
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        eng = Engine(cfg, params, ServeConfig(slots=1, max_len=32))
        rng = np.random.default_rng(3)
        lengths = [2, 3, 5, 7, 9, 12]
        buckets = {eng._bucket_len(s) for s in lengths}
        assert buckets == {8, 16}                    # not one trace per length
        for s_len in lengths:
            prompt = rng.integers(0, cfg.vocab_size, s_len).astype(np.int32)
            req = Request(prompt=prompt, max_new_tokens=1)
            eng.submit(req)
            eng.run_until_done()
            state = T.init_decode_state(cfg, 1, 32, dtype=jnp.float32)
            logits, _ = T.prefill(params, cfg, jnp.asarray(prompt[None]),
                                  state)
            want = int(jnp.argmax(logits, -1)[0])
            assert req.output[0] == want, s_len
            np.testing.assert_allclose(
                np.asarray(logits[0]),
                np.asarray(self._bucketed_logits(eng, params, cfg, prompt)),
                rtol=1e-5, atol=1e-5)

    @staticmethod
    def _bucketed_logits(eng, params, cfg, prompt):
        """The engine's own bucketed prefill logits for a prompt."""
        from repro.models import transformer as T
        padded = np.zeros(eng._bucket_len(len(prompt)), np.int32)
        padded[:len(prompt)] = prompt
        state = T.init_decode_state(cfg, 1, eng.scfg.max_len,
                                    dtype=jnp.float32)
        logits, _ = T.prefill(params, cfg, jnp.asarray(padded[None]), state,
                              valid_len=jnp.asarray(len(prompt), jnp.int32))
        return logits[0]

    def test_engine_matches_direct_decode(self):
        """Engine output == direct prefill+decode for a single request."""
        from repro.serve.engine import Engine, Request, ServeConfig
        from repro.models import transformer as T
        cfg = SMOKE
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        prompt = np.arange(4, dtype=np.int32) + 7

        eng = Engine(cfg, params, ServeConfig(slots=1, max_len=32))
        req = Request(prompt=prompt, max_new_tokens=3)
        eng.submit(req)
        eng.run_until_done()

        state = T.init_decode_state(cfg, 1, 32, dtype=jnp.float32)
        logits, state = T.prefill(params, cfg, jnp.asarray(prompt[None]),
                                  state)
        toks = [int(jnp.argmax(logits, -1)[0])]
        t = len(prompt)
        for _ in range(2):
            lg, state = T.decode_step(params, cfg,
                                      jnp.asarray([[toks[-1]]], jnp.int32),
                                      state, jnp.asarray(t, jnp.int32))
            toks.append(int(jnp.argmax(lg, -1)[0]))
            t += 1
        assert req.output == toks
