"""Fault-injection layer: differential anchors + recovery behavior.

The two differential tests are the trust anchors of the whole fault layer
(ISSUE 2): at zero loss the lossy simulator must be *bit-identical* to the
reliable path, and with an all-ones mask the masked Pallas cov-update must
be *bit-identical* to the unmasked kernel — faults are strictly additive,
never a behavioral fork.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs
from repro.core.aggregation import (NORM_PRIMITIVES, aggregate_tree,
                                    lossy_aggregate_tree)
from repro.core.faults import (FaultModel, NodeChurn, death_wave,
                               dropout_mask, expected_transmissions)
from repro.core.topology import berkeley_like_layout, build_topology, repair_tree
from repro.kernels import ops, ref

P, H = 32, 4


@pytest.fixture(scope="module")
def topo10():
    return build_topology(berkeley_like_layout(p=52, seed=7), radio_range=10.0)


class TestLossyTreeDifferential:
    def test_zero_loss_bit_identical(self, topo10):
        """loss=0.0: same value bits, same packet counts, no rng consumed."""
        x = np.random.default_rng(0).normal(size=52)
        rel = aggregate_tree(topo10.tree, list(x), NORM_PRIMITIVES)
        rng = np.random.default_rng(123)
        state_before = rng.bit_generator.state
        lossy = lossy_aggregate_tree(topo10.tree, list(x), NORM_PRIMITIVES,
                                     FaultModel(link_loss=0.0), rng)
        assert lossy.value == rel.value          # bitwise, not allclose
        np.testing.assert_array_equal(lossy.packets, rel.packets)
        np.testing.assert_array_equal(lossy.record_sizes, rel.record_sizes)
        assert lossy.delivered.all() and (lossy.attempts <= 1).all()
        assert rng.bit_generator.state == state_before

    def test_lossy_attempts_bounded_and_overhead_positive(self, topo10):
        x = np.random.default_rng(1).normal(size=52)
        fm = FaultModel(link_loss=0.3, max_retries=2)
        res = lossy_aggregate_tree(topo10.tree, list(x), NORM_PRIMITIVES,
                                   fm, np.random.default_rng(5))
        nonroot = np.arange(52) != topo10.tree.root
        assert (res.attempts[nonroot] >= 1).all()
        assert (res.attempts <= fm.max_retries + 1).all()
        rel = aggregate_tree(topo10.tree, list(x), NORM_PRIMITIVES)
        assert res.packets.sum() >= rel.packets.sum()
        # without retries, 30% loss over 51 hops loses some record w.h.p.
        res0 = lossy_aggregate_tree(topo10.tree, list(x), NORM_PRIMITIVES,
                                    FaultModel(link_loss=0.3, max_retries=0),
                                    np.random.default_rng(5))
        assert not res0.delivered.all()

    def test_lost_subtree_drops_from_value(self):
        """A failed hop loses exactly the sender's merged subtree."""
        # 3-node chain: 2 -> 1 -> 0(root); kill every transmission
        pos = np.array([[2.0, 0.0], [1.0, 0.0], [0.0, 0.0]])
        topo = build_topology(pos, radio_range=1.5, root=2)
        fm = FaultModel(link_loss=0.999999, max_retries=0)
        rng = np.random.default_rng(0)
        res = lossy_aggregate_tree(topo.tree, [3.0, 4.0, 12.0],
                                   NORM_PRIMITIVES, fm, rng)
        # only the root's own measurement survives
        assert res.value == pytest.approx(12.0)

    def test_unrepaired_alive_mask_fails_fast(self, topo10):
        """A raw alive mask (dead interior node, children not re-homed) is
        rejected instead of merging into a dead parent's record."""
        x = np.random.default_rng(4).normal(size=52)
        counts = topo10.tree.children_counts()
        victim = int(np.argmax(counts))
        if victim == topo10.tree.root:
            victim = int(np.argsort(-counts)[1])
        alive = np.ones(52, dtype=bool)
        alive[victim] = False
        with pytest.raises(ValueError, match="repair"):
            lossy_aggregate_tree(topo10.tree, list(x), NORM_PRIMITIVES,
                                 FaultModel(), np.random.default_rng(0),
                                 active=alive)

    def test_active_mask_excludes_dead_nodes(self, topo10):
        x = np.random.default_rng(2).normal(size=52)
        alive = np.ones(52, dtype=bool)
        dead = [i for i in range(52) if i != topo10.tree.root][:5]
        alive[dead] = False
        tree2, attached = repair_tree(topo10, alive)
        res = lossy_aggregate_tree(tree2, list(x), NORM_PRIMITIVES,
                                   FaultModel(), np.random.default_rng(3),
                                   active=attached)
        assert res.packets[dead].sum() == 0
        expected = np.linalg.norm(x[attached])
        assert res.value == pytest.approx(expected, abs=1e-9)


class TestMaskedKernelDifferential:
    @pytest.mark.parametrize("n,p,h,bp,bn", [
        (64, 128, 2, 64, 32), (128, 256, 8, 128, 64), (32, 512, 4, 256, 32),
        (96, 384, 1, 128, 32), (64, 128, 3, 32, 16),
    ])
    def test_all_ones_mask_bit_identical(self, n, p, h, bp, bn):
        """All-alive mask: identical grid schedule => identical float bits."""
        x = jax.random.normal(jax.random.PRNGKey(n + p), (n, p), jnp.float32)
        unmasked = ops.cov_band_update(x, h, block_p=bp, block_n=bn,
                                       interpret=True)
        masked = ops.cov_band_update_masked(x, jnp.ones((p,)), h, block_p=bp,
                                            block_n=bn, interpret=True)
        np.testing.assert_array_equal(np.asarray(masked), np.asarray(unmasked))
        oracle = ref.cov_band_update(x, h)
        np.testing.assert_allclose(np.asarray(masked), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("mask_kind", ["sensor", "per_reading"])
    def test_random_mask_matches_oracle(self, mask_kind):
        n, p, h = 64, 128, 3
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(k1, (n, p), jnp.float32)
        shape = (p,) if mask_kind == "sensor" else (n, p)
        mask = (jax.random.uniform(k2, shape) > 0.3).astype(jnp.float32)
        out = ops.cov_band_update_masked(x, mask, h, block_p=64, block_n=32,
                                         interpret=True)
        oracle = ref.cov_band_update_masked(x, mask, h)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-4)

    def test_dead_sensor_contributes_nothing(self):
        """Masking sensor j zeroes every band entry whose product touches j."""
        n, p, h = 32, 64, 2
        x = jax.random.normal(jax.random.PRNGKey(1), (n, p), jnp.float32)
        mask = jnp.ones((p,)).at[10].set(0.0)
        out = np.asarray(ops.cov_band_update_masked(x, mask, h,
                                                    interpret=True))
        for k in range(2 * h + 1):
            assert out[k, 10] == 0.0                     # row i = 10
            j = 10 - (k - h)
            if 0 <= j < p:
                assert out[k, j] == 0.0                  # partner i+k-h = 10

    def test_mask_shape_rejected(self):
        x = jnp.zeros((16, 32))
        with pytest.raises(ValueError):
            ops.cov_band_update_masked(x, jnp.ones((16, 31)), 2,
                                       interpret=True)


class TestFaultModel:
    def test_expected_transmissions(self):
        assert expected_transmissions(0.0, 3) == 1.0
        assert expected_transmissions(0.5, 1) == pytest.approx(1.5)
        # unbounded retries limit: 1 / (1 - loss)
        assert expected_transmissions(0.1, 200) == pytest.approx(1 / 0.9)

    def test_validation(self):
        with pytest.raises(ValueError):
            FaultModel(link_loss=1.0)
        with pytest.raises(ValueError):
            FaultModel(max_retries=-1)
        with pytest.raises(ValueError):
            expected_transmissions(-0.1, 3)

    def test_churn_liveness_schedule(self):
        churn = NodeChurn(deaths=((3, 1), (5, 2)), revivals=((7, 1),))
        live = churn.liveness(p=4, n_rounds=9)
        assert live[:3].all()
        assert not live[3:7, 1].any() and live[7:, 1].all()
        assert not live[5:, 2].any()
        assert live[:, 0].all() and live[:, 3].all()

    def test_death_wave_spares_and_revives(self):
        rng = np.random.default_rng(0)
        churn = death_wave(rng, 20, round=4, fraction=0.5, spare=[0],
                           revive_round=8)
        live = churn.liveness(20, 10)
        assert live[:, 0].all()                    # spared
        assert (~live[4]).sum() == 10              # ceil(0.5 * 20)
        assert live[8:].all()                      # everyone back

    def test_dropout_mask_rate(self):
        m = dropout_mask(np.random.default_rng(0), (2000, 10), 0.2)
        assert 0.75 < m.mean() < 0.85
        assert dropout_mask(np.random.default_rng(0), (5, 5), 0.0).all()


class TestRepair:
    def test_fault_free_repair_is_noop(self, topo10):
        tree2, attached = repair_tree(topo10, np.ones(52, dtype=bool))
        np.testing.assert_array_equal(tree2.parent, topo10.tree.parent)
        np.testing.assert_array_equal(tree2.depth, topo10.tree.depth)
        assert attached.all()

    def test_orphans_reattach(self, topo10):
        """Killing an internal node re-homes its subtree, not just its kids."""
        counts = topo10.tree.children_counts()
        victim = int(np.argmax(counts))            # busiest internal node
        if victim == topo10.tree.root:
            victim = int(np.argsort(-counts)[1])
        alive = np.ones(52, dtype=bool)
        alive[victim] = False
        tree2, attached = repair_tree(topo10, alive)
        assert not attached[victim] and tree2.parent[victim] == -2
        for i in np.nonzero(attached)[0]:
            if i == tree2.root:
                continue
            par = tree2.parent[i]
            assert par >= 0 and attached[par]
            assert tree2.depth[i] == tree2.depth[par] + 1
            assert topo10.adjacency[i, par]        # only radio-range links

    def test_dead_root_raises(self, topo10):
        alive = np.ones(52, dtype=bool)
        alive[topo10.tree.root] = False
        with pytest.raises(ValueError, match="root"):
            repair_tree(topo10, alive)


class TestMaskedStreaming:
    def _cfg(self, **kw):
        from repro.streaming import StreamConfig
        base = dict(p=P, q=3, halfwidth=H, forgetting=0.9,
                    drift_threshold=0.1, warmup_rounds=5, interpret=True)
        base.update(kw)
        return StreamConfig(**base)

    def test_all_ones_mask_matches_unmasked_run(self):
        from repro.streaming import stream_init, stream_run
        cfg = self._cfg()
        xs = jax.random.normal(jax.random.PRNGKey(0), (15, 8, P))
        st = stream_init(cfg, jax.random.PRNGKey(7))
        fin0, m0 = stream_run(cfg, st, xs)
        fin1, m1 = stream_run(cfg, st, xs, jnp.ones((15, P)))
        np.testing.assert_array_equal(np.asarray(m0.rho), np.asarray(m1.rho))
        np.testing.assert_array_equal(np.asarray(fin0.sched.W),
                                      np.asarray(fin1.sched.W))

    def test_churn_triggers_refresh(self):
        from repro.streaming import stream_init, stream_run
        cfg = self._cfg()
        scale = jnp.linspace(4.0, 1.0, P)
        xs = jax.random.normal(jax.random.PRNGKey(1), (24, 8, P)) * scale
        masks = np.ones((24, P), np.float32)
        masks[12:, 4:10] = 0.0                     # death wave at round 12
        st = stream_init(cfg, jax.random.PRNGKey(7))
        fin, m = stream_run(cfg, st, xs, jnp.asarray(masks))
        fired = np.asarray(m.did_refresh)
        assert fired[cfg.warmup_rounds]            # warmup refresh
        assert fired[12]                           # churn refresh, immediately
        assert not fired[13:].any()                # churn fires once, not per round

    def test_dead_sensor_variance_decays(self):
        """Masked sensors' live variance estimate decays toward zero."""
        from repro.streaming import online_init, online_update
        from repro.streaming.online_cov import online_estimate
        xs = jax.random.normal(jax.random.PRNGKey(2), (16, P)) * 3.0
        st = online_init(P, H)
        st = online_update(st, xs, interpret=True)
        mask = jnp.ones((P,)).at[0].set(0.0)
        for _ in range(12):
            st = online_update(st, xs, forgetting=0.5, mask=mask,
                               interpret=True)
        est = np.asarray(online_estimate(st))
        assert est[H, 0] < 0.05 * est[H, 1:].mean()

    def test_lossy_config_books_scaled_costs(self):
        cfg = self._cfg(link_loss=0.1, max_retries=3)
        sched = cfg.scheduler()
        clean = self._cfg().scheduler()
        factor = expected_transmissions(0.1, 3)
        assert sched.round_cost() == pytest.approx(clean.round_cost() * factor)
        assert sched.refresh_cost(P) == pytest.approx(
            clean.refresh_cost(P) * factor)
