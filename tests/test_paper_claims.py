"""Validation of the paper's experimental claims (Sec. 4) on the surrogate.

Claims checked (paper Sec. 4.3-4.6):
* Fig 7: PC1 retains ~80 % of variance; >=90 % by ~4-5 comps; >=95 % by ~10.
* Fig 11: the local covariance hypothesis loses accuracy as the radio range
  shrinks, but stays far above a random basis; loss shrinks with more comps.
* Fig 13: PIM with few iterations converges for PC1; later components need
  more iterations; ~20 iterations matches the centralized QR solution.
* Sec. 4.6: with large radio ranges the masked matrix can go indefinite and
  the sign criterion stops the extraction early — yet retained variance of
  the kept components stays high.
"""

import numpy as np
import pytest

from repro.core.pca import DistributedPCA, retained_variance
from repro.core.topology import build_topology
from repro.sensors.dataset import berkeley_surrogate, kfold_blocks


@pytest.fixture(scope="module")
def data():
    # half resolution (7200 epochs) keeps the test fast; stats are unchanged
    return berkeley_surrogate(p=52, n_epochs=7200, seed=0)


@pytest.fixture(scope="module")
def split(data):
    tr, te = kfold_blocks(data.n_epochs, k=10)[0]
    return data.measurements[tr], data.measurements[te]


class TestFig7RetainedVariance:
    def test_pc1_dominates(self, split):
        train, test = split
        r = DistributedPCA(q=1, method="eigh").fit(train)
        frac = retained_variance(test, r.components, r.mean)
        assert frac > 0.70, f"PC1 retains {frac:.2%}, paper reports ~80%"

    def test_90_percent_by_5_components(self, split):
        train, test = split
        r = DistributedPCA(q=5, method="eigh").fit(train)
        frac = retained_variance(test, r.components, r.mean)
        assert frac > 0.90

    def test_95_percent_by_10_components(self, split):
        train, test = split
        r = DistributedPCA(q=10, method="eigh").fit(train)
        frac = retained_variance(test, r.components, r.mean)
        assert frac > 0.93  # paper: ~95 +/- 5%

    def test_train_upper_bounds_test(self, split):
        train, test = split
        r = DistributedPCA(q=5, method="eigh").fit(train)
        frac_test = retained_variance(test, r.components, r.mean)
        r_te = DistributedPCA(q=5, method="eigh").fit(test)
        frac_upper = retained_variance(test, r_te.components, r_te.mean)
        assert frac_upper >= frac_test - 1e-6


class TestFig11LocalCovariance:
    @pytest.mark.parametrize("radio_range", [8.0, 15.0, 30.0])
    def test_masked_beats_random_basis(self, data, split, radio_range):
        train, test = split
        topo = build_topology(data.positions, radio_range=radio_range)
        r = DistributedPCA(q=5, method="eigh", cov_mode="masked",
                           mask=np.asarray(topo.covariance_mask())).fit(train)
        frac = retained_variance(test, r.components[:, r.valid], r.mean)
        rng = np.random.default_rng(0)
        Wr = np.linalg.qr(rng.normal(size=(52, 5)))[0]
        frac_rand = retained_variance(test, Wr, train.mean(axis=0))
        assert frac > frac_rand + 0.2
        assert frac > 0.6

    def test_accuracy_improves_with_radio_range(self, data, split):
        train, test = split
        fracs = []
        for r_m in (8.0, 30.0):
            topo = build_topology(data.positions, radio_range=r_m)
            r = DistributedPCA(q=5, method="eigh", cov_mode="masked",
                               mask=np.asarray(topo.covariance_mask())).fit(train)
            fracs.append(retained_variance(test, r.components[:, r.valid], r.mean))
        assert fracs[1] >= fracs[0] - 0.02  # larger range >= smaller range


class TestFig13PIMConvergence:
    def test_few_iterations_suffice_for_pc1(self, split):
        train, test = split
        exact = DistributedPCA(q=1, method="eigh").fit(train)
        approx = DistributedPCA(q=1, method="power", t_max=5, delta=0.0).fit(train)
        f_exact = retained_variance(test, exact.components, exact.mean)
        f_approx = retained_variance(test, approx.components, approx.mean)
        assert abs(f_exact - f_approx) < 0.02  # paper: 5 iters enough for PC1

    def test_20_iterations_match_centralized(self, split):
        train, test = split
        exact = DistributedPCA(q=5, method="eigh").fit(train)
        approx = DistributedPCA(q=5, method="power", t_max=20,
                                delta=1e-3).fit(train)
        f_exact = retained_variance(test, exact.components, exact.mean)
        f_approx = retained_variance(
            test, approx.components[:, approx.valid], approx.mean)
        assert f_approx > f_exact - 0.03  # paper: ~20 iters ≈ centralized

    def test_under_iterated_later_components_degrade(self, split):
        """Paper: 5 iterations is NOT enough from the 2nd component on."""
        train, test = split
        full = DistributedPCA(q=5, method="power", t_max=50, delta=1e-4).fit(train)
        starved = DistributedPCA(q=5, method="power", t_max=2, delta=0.0).fit(train)
        f_full = retained_variance(test, full.components[:, full.valid], full.mean)
        f_starved = retained_variance(
            test, starved.components[:, starved.valid], starved.mean)
        assert f_full >= f_starved - 0.01


class TestSec46EarlyStop:
    def test_indefinite_masked_cov_stops_early_but_retains(self, data, split):
        """Large radio ranges can make the masked matrix indefinite; the sign
        criterion stops extraction (Sec. 4.6) while retained variance of the
        valid components stays high (paper: >90 %)."""
        train, test = split
        topo = build_topology(data.positions, radio_range=30.0)
        r = DistributedPCA(q=15, method="power", t_max=60, delta=1e-4,
                           cov_mode="masked",
                           mask=np.asarray(topo.covariance_mask())).fit(train)
        kept = r.components[:, r.valid]
        # the stop point is data-dependent (paper: 5-10 comps on its trace;
        # the surrogate's masked spectrum goes indefinite earlier) — the
        # claim under test is early stop + high retained variance.
        assert 2 <= kept.shape[1] < 15
        frac = retained_variance(test, kept, r.mean)
        assert frac > 0.90  # paper Sec. 4.6: 'more than 90% of the variance'
