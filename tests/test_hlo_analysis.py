"""HLO collective parsing + loop-aware trip-count correction + roofline."""

import numpy as np
import pytest

from repro.launch import hlo_analysis as H


SAMPLE_HLO = """
%wrapped_add (a: f32[], b: f32[]) -> f32[] {
  ROOT %add = f32[] add(%a, %b)
}

%body_spmd (param: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %x = f32[128,256] get-tuple-element(%p), index=1
  %ag = f32[128,1024]{1,0} all-gather(%x), channel_id=1, replica_groups=[64,4]<=[256], dimensions={1}
  %ar = f32[128,256] all-reduce(%x), channel_id=2, replica_groups=[16,16]<=[256], to_apply=%wrapped_add
  ROOT %t = (s32[], f32[128,256]) tuple(%x, %ar)
}

%cond_spmd (param.1: (s32[], f32[128,256])) -> pred[] {
  %p1 = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p1), index=0
  %constant.9 = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i, %constant.9), direction=LT
}

ENTRY %main_spmd (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  %w = (s32[], f32[128,256]) while(%tup), condition=%cond_spmd, body=%body_spmd
  %cp = f32[128,256] collective-permute(%a), channel_id=3, source_target_pairs={{0,1},{1,2}}
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


class TestCollectiveParse:
    def test_counts(self):
        st = H.parse_collectives(SAMPLE_HLO, 256, loop_aware=False)
        assert st.counts["all-gather"] == 1
        assert st.counts["all-reduce"] == 1
        assert st.counts["collective-permute"] == 1

    def test_wire_model_naive(self):
        st = H.parse_collectives(SAMPLE_HLO, 256, loop_aware=False)
        ag = 128 * 1024 * 4 * (3 / 4)          # result N * (g-1)/g, g=4
        ar = 2 * 128 * 256 * 4 * (15 / 16)     # 2N(g-1)/g, g=16
        cp = 128 * 256 * 4
        assert st.wire_bytes["all-gather"] == pytest.approx(ag)
        assert st.wire_bytes["all-reduce"] == pytest.approx(ar)
        assert st.wire_bytes["collective-permute"] == pytest.approx(cp)

    def test_loop_aware_scales_body_by_trip_count(self):
        naive = H.parse_collectives(SAMPLE_HLO, 256, loop_aware=False)
        aware = H.parse_collectives(SAMPLE_HLO, 256, loop_aware=True)
        assert aware.loop_corrected
        # body collectives x12, top-level permute x1
        assert aware.wire_bytes["all-gather"] == pytest.approx(
            12 * naive.wire_bytes["all-gather"])
        assert aware.wire_bytes["all-reduce"] == pytest.approx(
            12 * naive.wire_bytes["all-reduce"])
        assert aware.wire_bytes["collective-permute"] == pytest.approx(
            naive.wire_bytes["collective-permute"])

    def test_loop_aware_on_real_compile(self):
        """End-to-end: scan with known trip count on the 1-device mesh."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("model",))

        def fn(x):
            def body(c, _):
                return c * 2.0, None
            out, _ = jax.lax.scan(body, x, None, length=9)
            return out.sum()

        x = jax.ShapeDtypeStruct((64,), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None)))
        compiled = jax.jit(fn).lower(x).compile()
        st = H.parse_collectives(compiled.as_text(), 1, loop_aware=True)
        # single device: no collectives, but the parse must not crash and
        # must detect loop structure
        assert st.total_wire_bytes == 0.0


class TestUnknownTrips:
    """A while condition with no integer constant (data-dependent bound)
    must surface as *unknown*, not silently count as 1."""

    # same program, but the condition compares two loop-carried values
    UNKNOWN_HLO = SAMPLE_HLO.replace(
        "%constant.9 = s32[] constant(12)",
        "%constant.9 = s32[] get-tuple-element(%p1), index=0")

    def test_fallback_trip_policy(self):
        assert H.fallback_trip([3, 12]) == 12
        assert H.fallback_trip([0]) == 1          # floor
        assert H.fallback_trip(()) is None        # unknown, not 1

    def test_unknown_body_recorded_with_x1_floor(self):
        naive = H.parse_collectives(SAMPLE_HLO, 256, loop_aware=False)
        st = H.parse_collectives(self.UNKNOWN_HLO, 256, loop_aware=True)
        assert st.unknown_trips == ("body_spmd",)
        assert not st.trips_known
        # the floor: body contributes x1, same as the naive parse
        assert st.wire_bytes["all-gather"] == pytest.approx(
            naive.wire_bytes["all-gather"])

    def test_roofline_refuses_unknown_trips(self):
        st = H.parse_collectives(self.UNKNOWN_HLO, 256, loop_aware=True)
        with pytest.raises(ValueError, match="unknown_trip"):
            H.roofline_terms({"flops": 1e12, "bytes accessed": 1e9}, st)
        terms = H.roofline_terms({"flops": 1e12, "bytes accessed": 1e9},
                                 st, allow_unknown_trips=True)
        assert terms.compute_s > 0

    def test_explicit_bound_restores_certainty(self):
        naive = H.parse_collectives(self.UNKNOWN_HLO, 256, loop_aware=False)
        st = H.parse_collectives(self.UNKNOWN_HLO, 256, loop_aware=True,
                                 unknown_trip=12)
        assert st.trips_known
        assert st.wire_bytes["all-reduce"] == pytest.approx(
            12 * naive.wire_bytes["all-reduce"])
        H.roofline_terms({"flops": 1e12, "bytes accessed": 1e9}, st)


class TestRoofline:
    def test_terms_and_dominance(self):
        st = H.parse_collectives(SAMPLE_HLO, 256, loop_aware=False)
        terms = H.roofline_terms({"flops": 1e15, "bytes accessed": 1e9}, st)
        assert terms.compute_s == pytest.approx(1e15 / H.PEAK_FLOPS)
        assert terms.memory_s == pytest.approx(1e9 / H.HBM_BW)
        assert terms.dominant == "compute"
        assert terms.bound_s == terms.compute_s

    def test_analytic_cell_models(self):
        from repro.launch.analytic import cell_model
        # train flops ~ 6ND for a dense model
        m = cell_model("llama3.2-1b", "train_4k", 256, microbatches=2)
        from repro import configs
        n = configs.get("llama3.2-1b").param_count()
        d = 256 * 4096
        assert m.flops_global == pytest.approx(6 * n * d, rel=0.25)
        # decode flops = 2NB + attention over the 32k cache (dominant here)
        md = cell_model("llama3.2-1b", "decode_32k", 256)
        attn = 16 * 4 * 128 * 32768 * 32 * 64
        assert md.flops_global == pytest.approx(2 * n * 128 + attn, rel=0.1)
        # wsn transform: 2npq
        mw = cell_model("wsn-1m", "transform", 256)
        assert mw.flops_global == pytest.approx(2 * 256 * 1_048_576 * 32)

    def test_dryrun_cell_enumeration(self):
        from repro.launch.dryrun import all_cells, skipped_cells
        cells = all_cells()
        skips = skipped_cells()
        # 40 assigned cells = run cells (LM) + documented skips
        lm_cells = [c for c in cells if c[0] != "wsn-1m"]
        assert len(lm_cells) + len(skips) == 40
        # cov / pim_block / pim_deflated / transform / hier_merge
        assert len([c for c in cells if c[0] == "wsn-1m"]) == 5
        for arch, shape, why in skips:
            assert shape == "long_500k"
            assert "sub-quadratic" in why
