"""wsn-1m at fleet scale: config sanity, smoke dry-run, weak-scaling rows.

PR-level acceptance for the production config (DESIGN.md Sec. 13): the
two-level shape is internally consistent, every dry-run cell of the real
1M-sensor system lowers and compiles in smoke mode on forced host devices
(subprocess — device count locks at first jax init in this process), and
the weak-scaling benchmark emits the >= 3-region-count curve plus the
end-to-end wsn-1m smoke-replica row that CI records as BENCH_scale.json.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


class TestWSNConfig:
    def test_two_level_shape_consistent(self):
        from repro.configs.wsn_1m import CONFIG

        assert CONFIG.p == 1_048_576
        assert CONFIG.p % CONFIG.n_regions == 0
        assert CONFIG.region_p * CONFIG.n_regions == CONFIG.p
        # a region must be wider than the covariance band it maintains
        assert CONFIG.region_p > 2 * CONFIG.halfwidth + 1
        assert CONFIG.q <= CONFIG.region_p

    def test_smoke_replica_preserves_ratios(self):
        from repro.configs.wsn_1m import CONFIG

        smoke = CONFIG.smoke()
        assert smoke.name == "wsn-1m-smoke"
        assert smoke.p % smoke.n_regions == 0
        assert smoke.region_p > 2 * smoke.halfwidth + 1
        assert smoke.q <= smoke.region_p
        # seconds-scale: small enough to stream end to end in CI
        assert smoke.p <= 8192 and smoke.batch_epochs <= 16

    def test_indivisible_regions_raise(self):
        import dataclasses

        from repro.configs.wsn_1m import CONFIG

        bad = dataclasses.replace(CONFIG, n_regions=1000)
        with pytest.raises(ValueError, match="divisible"):
            bad.region_p


class TestDryrunSmoke:
    def test_all_wsn_cells_compile(self, tmp_path):
        """The real wsn-1m cell list (cov/pim/transform/hier_merge) lowers
        and compiles at the smoke replica's shapes on 8 forced devices —
        the CI gate that the production config actually executes."""
        out = tmp_path / "dryrun_smoke.jsonl"
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
            + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
             "--out", str(out)],
            capture_output=True, text=True, timeout=540, cwd=REPO, env=env)
        assert proc.returncode == 0, proc.stderr[-2000:]
        rows = [json.loads(line) for line in out.read_text().splitlines()]
        assert len(rows) == 5, [r["shape"] for r in rows]
        bad = [r for r in rows if not r["ok"]]
        assert not bad, [(r["shape"], r.get("error")) for r in bad]
        assert {r["shape"] for r in rows} == {
            "cov_update", "pim_block", "pim_deflated", "transform",
            "hier_merge"}


class TestScaleBench:
    def test_weak_scaling_rows(self):
        if REPO not in sys.path:
            sys.path.insert(0, REPO)
        from benchmarks import scale_bench

        rows = scale_bench.run(smoke=True, regions=(1, 2))
        names = [r["name"] for r in rows]
        assert names == ["scale/regions1", "scale/regions2",
                         "scale/wsn_1m_smoke"]
        for r in rows:
            assert r["us_per_call"] > 0
            fields = r["derived"].split("|")
            assert len(fields) == 4
            assert "rounds/s" in fields[0]
            rho = float(fields[1].split()[-1])
            assert np.isfinite(rho) and 0.0 <= rho <= 1.0
