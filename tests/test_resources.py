"""Static resource certifier (repro.analysis.resources; DESIGN.md Sec. 16).

Three layers under test:

* derivation — VMEM/HBM/flop bills read off traced ``pallas_call`` params
  (fetch-on-change HBM semantics, dtype-aware byte accounting), collective
  payloads read off merge collectives;
* budgets — adversarial fixtures that MUST fail: an oversized BlockSpec
  (``budget:vmem``), an operand re-streamed across the grid
  (``budget:hbm``), a padded merge record (``wire:region``);
* reconciliation — booked == traced against ``costs.merge_record_elems``
  and ``ops.kernel_block_plan``, and the committed baseline round-trip.

Everything traces only (``jax.make_jaxpr``); nothing executes or compiles.
"""

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analysis import resources as R
from repro.analysis.jaxpr_lint import (PrimitiveBudget, UnknownTripError,
                                       count_primitive, while_trip_count)
from repro.core import costs
from repro.kernels import ops


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def _trace_copy(shape=(8, 8), block=None):
    """One well-behaved pallas_call: every block fetched exactly once."""
    block = block or shape

    def fn(x):
        grid = (shape[0] // block[0],)
        return pl.pallas_call(
            _copy_kernel,
            grid=grid,
            in_specs=[pl.BlockSpec(block, lambda i: (i, 0))],
            out_specs=pl.BlockSpec(block, lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
        )(x)

    return jax.make_jaxpr(fn)(jnp.zeros(shape, jnp.float32))


def _trace_restream():
    """Adversarial: the input block index ignores the slow grid axis's
    progress and cycles, so the operand is re-streamed from HBM once per
    outer step — the exact extra-round-trip pattern budget:hbm exists to
    catch.  x (2, 8) is read twice (4 fetches of 2 blocks)."""

    def fn(x):
        return pl.pallas_call(
            _copy_kernel,
            grid=(2, 2),
            in_specs=[pl.BlockSpec((1, 8), lambda i, j: (j, 0))],
            out_specs=pl.BlockSpec((1, 8), lambda i, j: (i * 2 + j, 0)),
            out_shape=jax.ShapeDtypeStruct((4, 8), jnp.float32),
        )(x)

    return jax.make_jaxpr(fn)(jnp.zeros((2, 8), jnp.float32))


def _trace_oversized():
    """Adversarial: a (4096, 1024) fp32 block = 16MiB per operand; with
    in + out double-buffered that is 64MiB of VMEM against the 16MiB
    budget."""

    def fn(x):
        return pl.pallas_call(
            _copy_kernel,
            grid=(1,),
            in_specs=[pl.BlockSpec((4096, 1024), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((4096, 1024), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((4096, 1024), jnp.float32),
        )(x)

    return jax.make_jaxpr(fn)(jnp.zeros((4096, 1024), jnp.float32))


def _trace_merge(q_gathered: int):
    """The hierarchy merge shape in miniature: ONE tiled all_gather of a
    (1, q) energy record + ONE psum of the scalar trace partial, on the
    'region' mesh axis."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("region",))

    def local(lam, den):
        table = jax.lax.all_gather(lam, "region", tiled=True)
        total = jax.lax.psum(jnp.sum(den), "region")
        return table, total

    fn = shard_map(local, mesh=mesh, in_specs=(P("region"), P("region")),
                   out_specs=(P(), P()), check_rep=False)
    return jax.make_jaxpr(fn)(jnp.zeros((1, q_gathered), jnp.float32),
                              jnp.zeros((1,), jnp.float32))


def _trace_fused(precision: str, rows=32, p=16, q=2):
    x = jnp.zeros((rows, p), jnp.float32)
    w = jnp.ones((rows,), jnp.float32)
    basis = jnp.zeros((p, q), jnp.float32)

    def fn(x, w, b):
        return ops.fused_stream_update(
            x, w, b, halfwidth=1, with_compress=False, with_monitor=True,
            precision=precision, interpret=True)

    return jax.make_jaxpr(fn)(x, w, basis)


class TestDerivation:
    def test_single_pass_copy_bill(self):
        kernels = R.pallas_resources(_trace_copy(shape=(8, 8), block=(2, 8)))
        assert len(kernels) == 1
        k = kernels[0]
        assert k.grid == (4,)
        nbytes = 8 * 8 * 4
        assert k.hbm_read_bytes == nbytes          # each block once
        assert k.hbm_write_bytes == nbytes
        assert k.vmem_bytes == 2 * 2 * (2 * 8 * 4)  # in+out, double-buffered
        # flop model: one mul per output element per grid cell
        assert k.flops == 8 * 8
        assert all(o.exact for o in k.inputs + k.outputs)

    def test_entry_aggregation_and_passes(self):
        entry = R.entry_resources(_trace_copy(shape=(8, 8), block=(2, 8)))
        assert entry.launches == 1
        assert entry.hbm_passes == pytest.approx(1.0)
        assert entry.intensity == pytest.approx(
            entry.flops / (entry.hbm_read_bytes + entry.hbm_write_bytes))
        q = entry.quantities()
        assert q["hbm_passes"] == pytest.approx(1.0)
        assert q["launches"] == 1

    def test_restream_counts_extra_fetches(self):
        k = R.pallas_resources(_trace_restream())[0]
        (xin,) = k.inputs
        assert xin.fetches == 4                    # 2 blocks x 2 sweeps
        assert xin.passes == pytest.approx(2.0)
        (out,) = k.outputs
        assert out.passes == pytest.approx(1.0)

    def test_merge_collective_payload(self):
        (coll,) = [c for c in R.collective_resources(_trace_merge(2))
                   if c.primitive == "all_gather"]
        assert coll.axes == ("region",)
        assert coll.record_elems == 2
        assert coll.payload_bytes == 2 * 4
        (red,) = [c for c in R.collective_resources(_trace_merge(2))
                  if c.primitive == "psum"]
        assert red.scalar_operands == 1


class TestDtypeAccounting:
    """bf16 fused path: tile loads halve, accumulators stay fp32 — the
    byte bill must keep the two populations separate."""

    def test_bf16_tiles_half_fp32_accumulators_full(self):
        (kf,) = R.pallas_resources(_trace_fused("fp32"))
        (kb,) = R.pallas_resources(_trace_fused("bf16"))
        by_dtype = kb.bytes_by_dtype()
        assert by_dtype.get("bfloat16", 0) > 0
        assert by_dtype.get("float32", 0) > 0
        # every downcast tile operand moves exactly half its fp32 bytes
        fp32_in = {o.origin: o for o in kf.inputs}
        tiles = [o for o in kb.inputs if o.dtype == "bfloat16"]
        assert tiles, "bf16 trace has no bf16 tile operands"
        for o in tiles:
            assert 2 * o.fetched_bytes == fp32_in[o.origin].fetched_bytes
        # outputs (band accumulator, z, t2/spe) all fp32 in BOTH traces
        assert all(o.dtype == "float32" for o in kb.outputs)
        assert kb.hbm_read_bytes < kf.hbm_read_bytes
        assert kb.hbm_write_bytes == kf.hbm_write_bytes

    def test_block_plan_is_the_traced_grid(self):
        """booked == traced for tiling: the plan the wrapper picks is the
        grid the pallas_call was traced with."""
        plan = ops.kernel_block_plan("fused", rows=32, p=16)
        (k,) = R.pallas_resources(_trace_fused("fp32"))
        assert k.grid == plan["grid"]
        assert plan["grid"] == (plan["feature_blocks"], plan["row_blocks"])


class TestBudgets:
    def test_oversized_blockspec_fails_vmem(self):
        rep = R.VmemBudget().check(_trace_oversized())
        assert rep.rule == "budget:vmem"
        assert not rep.ok
        assert "VMEM" in rep.detail and ">" in rep.detail

    def test_vmem_passes_and_reports_headroom(self):
        rep = R.VmemBudget().check(_trace_copy())
        assert rep.ok
        assert "%" in rep.detail and "double-buffered" in rep.detail

    def test_vmem_requires_a_kernel(self):
        jx = jax.make_jaxpr(lambda x: x + 1.0)(jnp.zeros((4,)))
        assert not R.VmemBudget().check(jx).ok

    def test_restream_fails_hbm_budget(self):
        rep = R.HbmTrafficBudget(max_passes=1.0).check(_trace_restream())
        assert rep.rule == "budget:hbm"
        assert not rep.ok
        assert "passes" in rep.detail

    def test_single_pass_origin_pin(self):
        # generous pass cap, but the named operand must be one tile-load
        rep = R.HbmTrafficBudget(max_passes=3.0,
                                 single_pass=("x_ref",)
                                 ).check(_trace_restream())
        assert not rep.ok
        assert "x_ref" in rep.detail
        assert R.HbmTrafficBudget(max_passes=1.0,
                                  single_pass=("x_ref",)
                                  ).check(_trace_copy()).ok

    def test_padded_merge_record_fails_wire_budget(self):
        booked = costs.merge_record_elems(2)       # q energies + trace
        good = R.WireBytesBudget(axis="region", record_elems=booked)
        assert good.check(_trace_merge(2)).ok      # 2 gathered + 1 scalar
        bad = good.check(_trace_merge(4))          # padded to 4 energies
        assert not bad.ok
        assert f"booked {booked}" in bad.detail
        assert good.name == "wire:region"

    def test_wire_budget_requires_collectives(self):
        rep = R.WireBytesBudget(axis="region", record_elems=3).check(
            _trace_copy())
        assert not rep.ok and "no collectives" in rep.detail


class TestUnknownTrips:
    """A data-dependent while bound may not silently count as 1."""

    def _dynamic_while(self):
        def fn(n):
            return jax.lax.while_loop(
                lambda c: c[0] < c[1],
                lambda c: (c[0] + 1.0, c[1]),
                (jnp.float32(0.0), n))[0]

        return jax.make_jaxpr(fn)(jnp.float32(5.0))

    def test_trip_count_is_none(self):
        jx = self._dynamic_while()
        whiles = [e for e in jx.jaxpr.eqns if e.primitive.name == "while"]
        assert whiles and while_trip_count(whiles[0]) is None

    def test_loop_weighted_count_raises(self):
        jx = self._dynamic_while()
        with pytest.raises(UnknownTripError):
            count_primitive(jx, "add", loop_weighted=True)
        # un-weighted per-trace counting still works
        assert count_primitive(jx, "add", loop_weighted=False) >= 1

    def test_primitive_budget_fails_loudly(self):
        rep = PrimitiveBudget("add", max=100,
                              loop_weighted=True).check(self._dynamic_while())
        assert not rep.ok
        assert "unknown" in rep.detail


class TestBaseline:
    def test_committed_baseline_matches_derived(self):
        results = R.check_against_baseline()
        bad = [r for r in results if not r.ok]
        assert not bad, "\n".join(
            f"{r.entry}/{r.quantity}: {r.detail}" for r in bad)
        # the acceptance surface: every entry reports the core quantities
        entries = {r.entry for r in results}
        assert any(e.startswith("hierarchy.refresh") for e in entries)
        quantities = {r.quantity for r in results}
        assert {"vmem_peak_bytes", "hbm_read_bytes", "hbm_passes",
                "flops"} <= quantities
        assert any(q.startswith("wire.region.") for q in quantities)

    def test_missing_baseline_fails_with_instruction(self, tmp_path):
        (res,) = R.check_against_baseline(path=str(tmp_path / "nope.json"))
        assert not res.ok and "--bless-resources" in res.detail

    def test_regression_carries_delta(self, tmp_path):
        derived = {"e[x]": {"flops": 110}}
        path = tmp_path / "base.json"
        R.bless({"e[x]": {"flops": 100}}, str(path))
        (res,) = [r for r in R.check_against_baseline(derived, str(path))
                  if not r.ok]
        assert res.quantity == "flops" and "+10.0%" in res.detail
        assert res.rule() == "resources:flops"
