"""Streaming subsystem: online covariance, scheduler, batched driver, engine.

The three acceptance properties from the subsystem spec:
1. the online covariance with forgetting=1 matches the batch estimator on a
   static stream (the decayed sums reduce to the plain Eq. 9-10 sums),
2. the recompute scheduler stays quiet on a stationary stream and fires on an
   injected distribution shift,
3. the vmap-batched fleet driver (and the shard_map-sharded runner) agree
   with the per-network python loop.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs
from repro.core import covariance as cov
from repro.kernels import ops
from repro.streaming import (
    RecomputeScheduler, StreamConfig, batched_stream_run, online_estimate,
    online_init, online_update, retained_fraction, sharded_stream_run,
    stream_covariance, stream_init, stream_run,
)
from repro.streaming.driver import batched_stream_init
from repro.streaming.online_cov import online_total_variance

P, H, Q = 32, 4, 3


def _rounds(key, n_rounds, n, p=P, scales=None):
    """Rounds of sensor measurements with a per-sensor variance profile."""
    x = jax.random.normal(key, (n_rounds, n, p))
    if scales is not None:
        x = x * jnp.asarray(scales)[None, None, :]
    return x


def _shifted_profile():
    """Two variance profiles concentrating energy at opposite ends.

    Strictly decreasing scales keep the top-q eigenvalues simple (no ties),
    so the tracked subspace is well defined and the retained fraction is
    stable on a stationary stream.
    """
    a = np.linspace(4.0, 1.0, P).astype(np.float32)
    b = a[::-1].copy()
    return a, b


class TestOnlineCovariance:
    def test_static_stream_matches_batch(self):
        """forgetting=1.0: streaming fold == one-shot batch statistics."""
        xs = _rounds(jax.random.PRNGKey(0), 6, 16)
        state, _ = stream_covariance(online_init(P, H), xs, forgetting=1.0,
                                     interpret=True)
        flat = xs.reshape(-1, P)
        batch = cov.banded_update(cov.banded_init(P, H), flat)
        np.testing.assert_allclose(np.asarray(state.band),
                                   np.asarray(batch.band), rtol=1e-4,
                                   atol=1e-3)
        np.testing.assert_allclose(np.asarray(online_estimate(state)),
                                   np.asarray(cov.banded_estimate(batch)),
                                   rtol=1e-4, atol=1e-4)

    def test_forgetting_discounts_history(self):
        """With beta<1 the estimate tracks the recent distribution."""
        key = jax.random.PRNGKey(1)
        a, b = _shifted_profile()
        old = _rounds(key, 30, 16, scales=a)
        new = _rounds(jax.random.PRNGKey(2), 30, 16, scales=b)
        st = online_init(P, H)
        st, _ = stream_covariance(st, old, forgetting=0.7, interpret=True)
        st, _ = stream_covariance(st, new, forgetting=0.7, interpret=True)
        est = np.asarray(online_estimate(st))
        variances = est[H]                  # center diagonal
        # energy must now sit on the second half of the sensors
        assert variances[P // 2:].mean() > 3 * variances[: P // 2].mean()

    def test_total_variance_matches_estimate_trace(self):
        xs = _rounds(jax.random.PRNGKey(3), 4, 16)
        st, _ = stream_covariance(online_init(P, H), xs, interpret=True)
        tr = float(online_total_variance(st))
        assert tr == pytest.approx(float(np.trace(
            np.asarray(cov.band_to_dense(online_estimate(st))))), rel=1e-5)

    def test_all_ones_mask_bit_identical_to_unmasked(self):
        """The masked-statistics fix must keep the all-alive path exact:
        every state leaf (including the new per-sensor counts) and the
        estimate are bit-identical between mask=None and an all-ones mask."""
        x = np.asarray(_rounds(jax.random.PRNGKey(4), 1, 16))[0]
        st0 = online_init(P, H)
        for masked in (np.ones(P, np.float32), np.ones((16, P), np.float32)):
            a = online_update(st0, jnp.asarray(x), forgetting=0.9,
                              interpret=True)
            b = online_update(st0, jnp.asarray(x), forgetting=0.9,
                              mask=jnp.asarray(masked), interpret=True)
            for leaf_a, leaf_b in zip(a, b):
                np.testing.assert_array_equal(np.asarray(leaf_a),
                                              np.asarray(leaf_b))
            np.testing.assert_array_equal(
                np.asarray(online_estimate(a)), np.asarray(online_estimate(b)))

    def test_dropout_mean_and_variance_unbiased(self):
        """The pre-fix path normalized every sensor by the ROUND count, so a
        sensor present in half the rows had its mean halved and its variance
        inflated by the phantom zero rows.  Per-sensor counts repair both:
        a constant present reading must estimate (mean=c, var=0)."""
        rng = np.random.default_rng(0)
        n, c = 64, 5.0
        x = rng.normal(size=(n, P)).astype(np.float32)
        x[:, 0] = c                                 # sensor 0: constant 5.0
        mask = np.ones((n, P), np.float32)
        mask[::2, 0] = 0.0                          # ... present in half rows
        st = online_update(online_init(P, H), jnp.asarray(x),
                           mask=jnp.asarray(mask), interpret=True)
        t_i = np.asarray(st.t_i)
        assert t_i[0] == n / 2 and t_i[1] == n      # per-sensor counts
        mean0 = float(st.s[0] / t_i[0])
        assert mean0 == pytest.approx(c, rel=1e-6)  # old path: c/2
        est = np.asarray(online_estimate(st))
        assert abs(est[H, 0]) < 1e-3                # old path: ~c^2/4
        # untouched sensors keep the plain sample statistics
        v1 = x[:, 1].var()
        assert est[H, 1] == pytest.approx(v1, rel=1e-3)

    def test_non_nested_dropout_cross_covariance_unbiased(self):
        """Two perfectly correlated sensors with OVERLAPPING but non-nested
        dropout: the cross-covariance must be normalized by the pairwise
        present count (the t_band fix), not the round count or
        min(t_i, t_j) — both of which shrink it toward zero."""
        rng = np.random.default_rng(7)
        n = 128
        x = rng.normal(size=(n, P)).astype(np.float32)
        x[:, 1] = x[:, 0]                       # corr(0, 1) = 1
        mask = np.ones((n, P), np.float32)
        mask[: n // 2, 0] = 0.0                 # sensor 0 absent first half
        mask[n // 4: 3 * n // 4, 1] = 0.0       # sensor 1 absent mid half
        both = (mask[:, 0] > 0) & (mask[:, 1] > 0)   # last quarter only
        st = online_update(online_init(P, H), jnp.asarray(x),
                           mask=jnp.asarray(mask), interpret=True)
        assert float(st.t_band[H + 1, 0]) == both.sum() == n // 4
        est = np.asarray(online_estimate(st))
        # the oracle: second-moment over the common rows minus the product
        # of each sensor's own-window mean
        m0 = x[mask[:, 0] > 0, 0].mean()
        m1 = x[mask[:, 1] > 0, 1].mean()
        want = (x[both, 0] * x[both, 1]).mean() - m0 * m1
        assert est[H + 1, 0] == pytest.approx(want, rel=1e-4)

    def test_death_wave_pairwise_counts_match_batch_oracle(self):
        """After a death wave, the covariance among the SURVIVORS must equal
        the batch estimate over all rounds, and entries pairing a survivor
        with a dead sensor must equal the batch estimate over the rounds
        both were alive (the pairwise t_band window)."""
        xs = np.asarray(_rounds(jax.random.PRNGKey(5), 8, 16))
        dead = [0, 1]
        st = online_init(P, H)
        for r in range(8):
            mask = np.ones(P, np.float32)
            if r >= 4:
                mask[dead] = 0.0                    # die at round 4, stay dead
            st = online_update(st, jnp.asarray(xs[r]),
                               mask=jnp.asarray(mask), interpret=True)
        est = np.asarray(online_estimate(st))
        flat = xs.reshape(-1, P)
        batch_all = np.asarray(cov.banded_estimate(
            cov.banded_update(cov.banded_init(P, H), jnp.asarray(flat))))
        # survivors-only entries: normalized over every round
        assert est[H, 4] == pytest.approx(batch_all[H, 4], rel=1e-3)
        # dead sensor's own variance: over its alive rounds only
        flat_alive = xs[:4].reshape(-1, P)
        batch_alive = np.asarray(cov.banded_estimate(
            cov.banded_update(cov.banded_init(P, H), jnp.asarray(flat_alive))))
        assert est[H, 0] == pytest.approx(batch_alive[H, 0], rel=1e-3)
        # cross entry survivor x dead: pairwise window = the alive rounds
        # (the survivor's mean is taken over its full history, so the mean
        # product differs from the alive-window oracle by O(sampling noise))
        assert est[H + 2, 0] == pytest.approx(batch_alive[H + 2, 0],
                                              abs=0.08)


class TestBatchedKernelWrapper:
    def test_matches_per_network_kernel(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (5, 16, P))
        out = ops.cov_band_update_batched(x, H, interpret=True)
        for i in range(5):
            np.testing.assert_allclose(
                np.asarray(out[i]),
                np.asarray(ops.cov_band_update(x[i], H, interpret=True)),
                rtol=1e-5, atol=1e-5)

    def test_rejects_unbatched_input(self):
        with pytest.raises(ValueError):
            ops.cov_band_update_batched(jnp.zeros((16, P)), H)


class TestScheduler:
    def _stream(self, cfg, xs):
        state = stream_init(cfg, jax.random.PRNGKey(7))
        return stream_run(cfg, state, xs)

    def test_stationary_stream_single_refresh(self):
        """Only the warmup refresh fires when the distribution is static."""
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                           drift_threshold=0.1, warmup_rounds=8,
                           interpret=True)
        a, _ = _shifted_profile()
        xs = _rounds(jax.random.PRNGKey(0), 60, 16, scales=a)
        final, metrics = self._stream(cfg, xs)
        assert int(final.sched.refreshes) == 1
        assert bool(metrics.did_refresh[cfg.warmup_rounds])

    def test_injected_shift_triggers_refresh(self):
        """A variance shift to new sensors must fire a second refresh."""
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                           drift_threshold=0.1, warmup_rounds=8,
                           interpret=True)
        a, b = _shifted_profile()
        xs = jnp.concatenate([
            _rounds(jax.random.PRNGKey(0), 30, 16, scales=a),
            _rounds(jax.random.PRNGKey(1), 30, 16, scales=b),
        ])
        final, metrics = self._stream(cfg, xs)
        fired = np.asarray(metrics.did_refresh)
        assert int(final.sched.refreshes) >= 2
        # the post-shift refresh happens after the shift round, not before
        assert fired[30:].any() and not fired[cfg.warmup_rounds + 1:30].any()
        # each refresh recovers retained variance: rho (measured pre-refresh)
        # jumps between the trigger round and the following round
        rho = np.asarray(metrics.rho)
        last = int(np.where(fired)[0][-1])
        assert rho[last + 1] > rho[last]

    def test_refresh_books_table1_cost(self):
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, warmup_rounds=2,
                           interpret=True)
        xs = _rounds(jax.random.PRNGKey(0), 6, 16)
        final, metrics = self._stream(cfg, xs)
        sched = cfg.scheduler()
        expected = (6 * sched.round_cost()
                    + int(final.sched.refreshes) * sched.refresh_cost(P))
        assert float(final.sched.comm_packets) == pytest.approx(expected)

    def test_refresh_recovers_eigh_subspace(self):
        """ortho_refresh from a stale basis lands on the eigh subspace."""
        from repro.streaming.scheduler import ortho_refresh
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(512, P)).astype(np.float32)
                        * np.linspace(3.0, 0.5, P)[None, :])
        st = online_update(online_init(P, H), x, interpret=True)
        band = online_estimate(st)
        W0 = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(0), (P, Q)))[0]
        W = ortho_refresh(band, W0, iters=50)
        dense = np.asarray(cov.band_to_dense(band))
        evals, evecs = np.linalg.eigh(dense)
        top = evecs[:, np.argsort(-evals)[:Q]]
        # principal angles ~ 0: |top^T W| has singular values ~ 1
        sv = np.linalg.svd(top.T @ np.asarray(W), compute_uv=False)
        assert sv.min() > 0.99


class TestBatchedDriver:
    def _cfg(self):
        return StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                            drift_threshold=0.05, warmup_rounds=5,
                            interpret=True)

    def test_batched_agrees_with_per_network_loop(self):
        cfg = self._cfg()
        B = 4
        key = jax.random.PRNGKey(0)
        states = batched_stream_init(cfg, key, B)
        xsb = jax.random.normal(jax.random.PRNGKey(1), (B, 15, 8, P))
        finb, mb = batched_stream_run(cfg, states, xsb)
        for i in range(B):
            st_i = jax.tree.map(lambda a: a[i], states)
            fin_i, m_i = stream_run(cfg, st_i, xsb[i])
            np.testing.assert_allclose(np.asarray(fin_i.sched.W),
                                       np.asarray(finb.sched.W[i]),
                                       rtol=1e-4, atol=1e-4)
            assert int(fin_i.sched.refreshes) == int(finb.sched.refreshes[i])
            np.testing.assert_allclose(np.asarray(m_i.rho),
                                       np.asarray(mb.rho[i]),
                                       rtol=1e-4, atol=1e-4)

    def test_sharded_agrees_with_batched(self):
        cfg = self._cfg()
        B = 4
        states = batched_stream_init(cfg, jax.random.PRNGKey(0), B)
        xsb = jax.random.normal(jax.random.PRNGKey(1), (B, 12, 8, P))
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        fin_v, m_v = batched_stream_run(cfg, states, xsb)
        fin_s, m_s = sharded_stream_run(cfg, mesh, states, xsb)
        np.testing.assert_allclose(np.asarray(fin_v.sched.W),
                                   np.asarray(fin_s.sched.W),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(m_v.comm_packets),
                                   np.asarray(m_s.comm_packets))

    def test_network_axis_spec_rejects_unknown_axis(self):
        from repro.distributed.sharding import network_axis_spec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with pytest.raises(ValueError):
            network_axis_spec(mesh, "nonexistent")


class TestServeEngine:
    def test_continuous_batching_retires_all_streams(self):
        from repro.serve.engine import StreamingPCAEngine, StreamRequest
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                           drift_threshold=0.05, warmup_rounds=4,
                           interpret=True)
        eng = StreamingPCAEngine(cfg, slots=3, seed=0)
        rng = np.random.default_rng(0)
        reqs = [StreamRequest(rounds=rng.normal(
            size=(10 + 2 * i, 8, P)).astype(np.float32)) for i in range(5)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        assert all(r.done for r in reqs)
        for r in reqs:
            assert r.result is not None
            assert r.result.refreshes >= 1          # warmup refresh at least
            assert r.result.comm_packets > 0
            assert r.result.components.shape == (P, Q)
            assert r.result.rounds == r.rounds.shape[0]

    def test_rejects_mismatched_network_size(self):
        from repro.serve.engine import StreamingPCAEngine, StreamRequest
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, interpret=True)
        eng = StreamingPCAEngine(cfg, slots=2)
        with pytest.raises(ValueError):
            eng.submit(StreamRequest(rounds=np.zeros((4, 8, P + 1),
                                                     np.float32)))

    def test_rejects_heterogeneous_round_shape_and_empty_stream(self):
        """The device batch is shape-homogeneous: n is fixed by the first
        stream, and empty streams never enter a slot."""
        from repro.serve.engine import StreamingPCAEngine, StreamRequest
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, interpret=True)
        eng = StreamingPCAEngine(cfg, slots=2)
        eng.submit(StreamRequest(rounds=np.zeros((3, 8, P), np.float32)))
        with pytest.raises(ValueError):
            eng.submit(StreamRequest(rounds=np.zeros((3, 16, P), np.float32)))
        with pytest.raises(ValueError):
            eng.submit(StreamRequest(rounds=np.zeros((0, 8, P), np.float32)))


class TestEngineFaultDeterminism:
    """Two engine runs with the same seed and fault schedule are identical:
    retirement order, bases (bitwise), and cost bills."""

    def _fault_requests(self):
        from repro.serve.engine import StreamRequest
        reqs = []
        for i in range(5):
            R = 18
            rng = np.random.default_rng(200 + i)
            live = np.ones((R, P), np.float32)
            if i == 1:       # total blackout at round 6, revival at round 12
                live[6:12, :] = 0.0
            if i == 3:       # permanent partial wave (stays above threshold)
                live[9:, :10] = 0.0
            if i == 4:       # dies for good at round 10
                live[10:, :] = 0.0
            rounds = (rng.normal(size=(R, 8, P)).astype(np.float32)
                      * np.linspace(4, 1, P, dtype=np.float32))
            reqs.append(StreamRequest(rounds=rounds, liveness=live))
        return reqs

    def _run(self):
        from repro.serve.engine import StreamingPCAEngine
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                           drift_threshold=0.1, warmup_rounds=4,
                           link_loss=0.1, interpret=True)
        eng = StreamingPCAEngine(cfg, slots=2, seed=0)
        reqs = self._fault_requests()
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        return eng, reqs

    def test_two_runs_identical(self):
        eng1, reqs1 = self._run()
        eng2, reqs2 = self._run()
        order1 = [(reqs1.index(q), why) for q, why in eng1.retired_log]
        order2 = [(reqs2.index(q), why) for q, why in eng2.retired_log]
        assert order1 == order2
        assert eng1.plan_history == eng2.plan_history
        for a, b in zip(reqs1, reqs2):
            assert a.done and b.done
            assert a.result.reason == b.result.reason
            # bitwise: same jitted programs folded in the same order
            np.testing.assert_array_equal(a.result.components,
                                          b.result.components)
            assert a.result.comm_packets == b.result.comm_packets
            assert a.result.rounds == b.result.rounds
            assert len(a.retirements) == len(b.retirements)
            for ra, rb in zip(a.retirements, b.retirements):
                np.testing.assert_array_equal(ra.components, rb.components)
                assert ra.comm_packets == rb.comm_packets

    def test_fault_lifecycle(self):
        """The schedule above exercises every retirement path."""
        eng, reqs = self._run()
        assert reqs[0].result.reason == "completed" and not reqs[0].retirements
        # blackout + revival: one dead retirement, then completed
        assert len(reqs[1].retirements) == 1
        assert reqs[1].retirements[0].reason == "dead"
        assert reqs[1].result.reason == "completed"
        # partial wave above min_alive_fraction: survives to completion
        assert reqs[3].result.reason == "completed" and not reqs[3].retirements
        # permanent death: retired dead, never re-admitted; the partial IS
        # the final result (not duplicated into retirements)
        assert reqs[4].result.reason == "dead"
        assert reqs[4].result.rounds < reqs[4].rounds.shape[0]
        assert not reqs[4].retirements
        # the elastic planner saw the fleet drain below full occupancy and
        # re-planned down to the single-network mesh at the tail
        assert eng.plan_history[0].n_devices == 2
        assert eng.plan_history[-1].n_devices == 1
        assert len(eng.plan_history) >= 2


class TestStreamingCosts:
    def test_round_cost_positive_and_scales_with_q(self):
        c1 = costs.streaming_round_cost(8, 1, 4)
        c5 = costs.streaming_round_cost(8, 5, 4)
        assert 0 < c1.communication < c5.communication

    def test_refresh_dominates_round(self):
        """The design premise: a refresh costs >> one round (else scheduling
        would be pointless)."""
        round_c = costs.streaming_round_cost(8, 5, 4).communication
        refresh_c = costs.streaming_refresh_cost(52, 5, 8, 4, 8).communication
        assert refresh_c > 20 * round_c
