"""Event-tier benchmark: fused T²/SPE monitoring and the TPR/FPR-vs-α sweep.

Three row families, the device-tier analogue of the paper's Sec.-2.4.3
evaluator:

* ``events/monitor`` — the fused Pallas monitoring kernel (project + T² +
  SPE in one pass, reconstruction VMEM-resident) on a fleet batch;
* ``events/oracle`` — the host-side NumPy evaluator
  (:class:`repro.core.events.LowVarianceDetector`) on the same block (the
  path the tier replaced), for the speedup denominator;
* ``events/stream@{alpha}`` — the full streaming fleet (cov fold +
  scheduler + detection stage) with injected localized AC plateaus at each
  swept false-alarm rate: derived column ``tpr|fpr|alarms`` charts the
  Sec.-2.4.3 operating curve (the EXPERIMENTS.md Events sweep).

Run standalone to emit a JSON artifact for the detection trajectory:

    PYTHONPATH=src:. python benchmarks/event_bench.py \
        --smoke --json BENCH_events.json
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed

ALPHAS = (1e-2, 1e-3, 1e-4)
B, N, P, Q, H = 6, 8, 32, 3, 4
NOISE = 0.8
WARMUP, CALIB = 6, 8
EVENT_START, EVENT_ROUNDS = 22, 8


def _fleet_block(rng, n_rounds):
    scale = np.concatenate([[4.0, 3.4, 2.8], np.full(P - 3, NOISE)])
    x = (rng.normal(size=(B, n_rounds, N, P)) * scale).astype(np.float32)
    return x


def _inject(rng, xs, positions):
    """One localized plateau on every odd network; returns the truth mask."""
    from repro.sensors.dataset import inject_ac_event

    n_rounds = xs.shape[1]
    truth = np.zeros(xs.shape[:3], bool)
    d_top = np.linalg.norm(positions[:, None, :] - positions[None, :3, :],
                           axis=-1).min(axis=1)
    candidates = np.nonzero(d_top > 10.0)[0]
    for b in range(1, B, 2):
        site = int(rng.choice(candidates))
        flat, window = inject_ac_event(
            xs[b].reshape(n_rounds * N, P), positions, site=site,
            start=EVENT_START * N, duration=EVENT_ROUNDS * N,
            amplitude=-5.0, footprint_m=8.0, ramp_epochs=5)
        xs[b] = flat.reshape(n_rounds, N, P)
        truth[b] = window.reshape(n_rounds, N)
    return truth


def _kernel_rows(n_repeat: int):
    import jax
    import jax.numpy as jnp

    from repro.core.events import LowVarianceDetector
    from repro.kernels import ops

    out = []
    rng = np.random.default_rng(0)
    x = _fleet_block(rng, 1)[:, 0]                     # (B, N, P)
    # the true top-q basis of the fleet block (axis-aligned by
    # construction), so the derived T2 mean sits near its chi-square
    # expectation q under correct standardization
    W = np.eye(P, Q, dtype=np.float32)
    mean = x.mean(axis=(0, 1)).astype(np.float32)
    lam = np.array([16.0, 11.56, 7.84], np.float32)    # scale^2 of the top 3
    xj, Wj = jnp.asarray(x), jnp.asarray(W)
    mj, lj = jnp.asarray(mean), jnp.asarray(1.0 / lam)

    def call():
        z, t2, spe = ops.pca_monitor_batched(xj, Wj, mj, lj)
        jax.block_until_ready(t2)
        return t2, spe
    call()                                             # compile outside timing
    (t2, spe), us = timed(call, repeat=n_repeat)
    out.append(row("events/monitor", us,
                   f"T2 mean {float(np.asarray(t2).mean()):.2f}"
                   f"|SPE mean {float(np.asarray(spe).mean()):.2f}"))

    det = LowVarianceDetector(W, lam, mean, alpha=1e-3)
    flat = x.reshape(-1, P)
    _, us = timed(lambda: det.statistic(flat), repeat=n_repeat)
    out.append(row("events/oracle", us, "numpy T2 evaluator"))
    return out


def _stream_rows(n_rounds: int, n_repeat: int):
    import jax
    import jax.numpy as jnp

    from repro.core.topology import berkeley_like_layout
    from repro.streaming import (DetectionConfig, StreamConfig,
                                 batched_stream_run, stream_init)

    out = []
    positions = berkeley_like_layout(p=P, seed=7)
    rng = np.random.default_rng(1)
    xs = _fleet_block(rng, n_rounds)
    truth = _inject(np.random.default_rng(2), xs, positions)
    xsj = jnp.asarray(xs)
    keys = jax.random.split(jax.random.PRNGKey(1), B)
    for alpha in ALPHAS:
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.98,
                           drift_threshold=0.5, warmup_rounds=WARMUP,
                           detection=DetectionConfig(alpha=alpha,
                                                     calib_rounds=CALIB))
        states = jax.vmap(lambda k: stream_init(cfg, k))(keys)

        def _run(c=cfg, s=states):
            res = batched_stream_run(c, s, xsj)
            jax.block_until_ready(res[1].rho)
            return res
        _run()                                         # compile outside timing
        (fin, met), us = timed(_run, repeat=n_repeat)
        events = np.asarray(met.detection.events) > 0.5
        armed = ~(np.asarray(met.detection.calibrating) > 0.5)
        armed[:, :WARMUP + 1] = False
        armed_e = np.repeat(armed[:, :, None], N, axis=2)
        scored_t = truth & armed_e
        scored_h = ~truth & armed_e
        tpr = float(events[scored_t].mean()) if scored_t.any() else 0.0
        fpr = float(events[scored_h].mean()) if scored_h.any() else 0.0
        alarms = int(events.sum())
        out.append(row(f"events/stream@{alpha}", us,
                       f"tpr {tpr:.3f}|fpr {fpr:.4f}|{alarms} alarms"))
    return out


def run(smoke: bool = False):
    n_repeat = 2 if smoke else 5
    n_rounds = 34 if smoke else 60
    return _kernel_rows(n_repeat) + _stream_rows(n_rounds, n_repeat)


def main() -> int:
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", help="write rows to this JSON artifact path")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(rows, fh, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
