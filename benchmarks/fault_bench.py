"""Fault benchmark: accuracy and packet overhead vs. per-hop loss rate.

Two sweeps, the fault-tolerant analogue of the paper's Fig. 9/14 frontier:

* ``fault/tree@{loss}`` — the routing-tree simulator under lossy links with
  ARQ: delivered-record fraction and measured packet overhead vs. the
  reliable epoch (overhead converges to ``expected_transmissions`` as the
  retry budget absorbs the loss);
* ``fault/stream@{loss}`` — the streaming fleet under faults scaled by the
  loss rate: measurement dropout in the data, a mid-stream death wave
  killing a ``loss`` fraction of each network's sensors (per-round liveness
  masks through the driver, i.e. the masked Pallas cov-update path + the
  churn-triggered refresh), and lossy Table-1 booking.  Reports
  end-of-stream retained variance and the booked packet bill per network.

CSV derived column: ``delivered|overhead`` for the tree rows,
``retained|packets`` for the streaming rows.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed, topo
from repro.core import costs
from repro.core.aggregation import (NORM_PRIMITIVES, aggregate_tree,
                                    lossy_aggregate_tree)
from repro.core.faults import FaultModel, dropout_mask

LOSS_RATES = (0.0, 0.05, 0.1, 0.2, 0.3)
P, Q, H = 32, 3, 4
N_PER_ROUND = 8


def _tree_sweep(n_epochs: int):
    out = []
    t = topo(10.0)
    rng_x = np.random.default_rng(0)
    values = rng_x.normal(size=(n_epochs, t.p))
    reliable = aggregate_tree(t.tree, list(values[0]), NORM_PRIMITIVES)
    base_packets = int(reliable.packets.sum())
    for loss in LOSS_RATES:
        fm = FaultModel(link_loss=loss, max_retries=3)
        rng = np.random.default_rng(42)

        def epoch():
            delivered = 0
            packets = 0
            for e in range(n_epochs):
                res = lossy_aggregate_tree(t.tree, list(values[e]),
                                           NORM_PRIMITIVES, fm, rng)
                delivered += res.delivered[res.active].mean()
                packets += res.packets.sum()
            return delivered / n_epochs, packets / n_epochs

        (dfrac, packets), us = timed(epoch, repeat=1)
        out.append(row(f"fault/tree@{loss}", us / n_epochs,
                       f"delivered {dfrac:.3f}|{packets / base_packets:.2f}x"))
    return out


def _stream_sweep(n_rounds: int, n_networks: int):
    import jax
    import jax.numpy as jnp

    from repro.streaming import StreamConfig, batched_stream_run, stream_init

    from repro.core.faults import death_wave

    out = []
    scale = np.concatenate([[4.0, 3.4, 2.8], np.linspace(1.2, 0.8, P - 3)])
    xs_np = (np.random.default_rng(0)
             .normal(size=(n_networks, n_rounds, N_PER_ROUND, P)) * scale)
    for loss in LOSS_RATES:
        # measurement dropout at the loss rate (a lost D packet is a
        # missing reading) ...
        keep = dropout_mask(np.random.default_rng(7), xs_np.shape, loss)
        xs = jnp.asarray((xs_np * keep).astype(np.float32))
        # ... plus a mid-stream death wave killing a `loss` fraction of each
        # network's sensors — per-round liveness masks through the driver,
        # exercising the masked kernel and the churn trigger
        masks = np.ones((n_networks, n_rounds, P), np.float32)
        if loss > 0:
            mrng = np.random.default_rng(11)
            for b in range(n_networks):
                churn = death_wave(mrng, P, round=n_rounds // 2,
                                   fraction=loss)
                masks[b] = churn.liveness(P, n_rounds).astype(np.float32)
        masks = jnp.asarray(masks)
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                           drift_threshold=0.08, warmup_rounds=5,
                           link_loss=loss, max_retries=3)
        states = jax.vmap(lambda k: stream_init(cfg, k))(
            jax.random.split(jax.random.PRNGKey(1), n_networks))

        def _run(c=cfg, s=states, x=xs, m=masks):
            res = batched_stream_run(c, s, x, m)
            jax.block_until_ready(res[1].rho)
            return res

        _run()                                   # compile outside timing
        (final, m), us = timed(_run)
        rho_end = float(np.asarray(m.rho)[:, -1].mean())
        packets = float(np.asarray(final.sched.comm_packets).mean())
        out.append(row(f"fault/stream@{loss}", us,
                       f"retained {rho_end:.3f}|{packets:.0f} packets"))
    return out


def run(smoke: bool = False):
    n_epochs = 20 if smoke else 200
    n_rounds = 10 if smoke else 40
    n_networks = 4 if smoke else 8
    return _tree_sweep(n_epochs) + _stream_sweep(n_rounds, n_networks)
