"""Paper Fig. 7: retained variance vs number of principal components.

K-fold block CV on the Berkeley surrogate; reports the test-set retained
variance for q = 1..25 (the paper's claims: ~80 % at q=1, ~90 % at 4-5,
~95 % at 10) and the train-on-test upper bound.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, folds, row, timed
from repro.core.pca import DistributedPCA, retained_variance


def run(qs=(1, 2, 3, 4, 5, 10, 15, 20, 25), k_folds: int = 3) -> list[dict]:
    data = dataset()
    rows = []
    for q in qs:
        fracs, uppers = [], []
        us_total = 0.0
        for tr_idx, te_idx in folds(k_folds):
            train = data.measurements[tr_idx]
            test = data.measurements[te_idx]
            res, us = timed(DistributedPCA(q=q, method="eigh").fit, train,
                            repeat=1)
            us_total += us
            fracs.append(retained_variance(test, res.components, res.mean))
            res_u = DistributedPCA(q=q, method="eigh").fit(test)
            uppers.append(retained_variance(test, res_u.components,
                                            res_u.mean))
        rows.append(row(f"fig7/q={q}", us_total / k_folds,
                        f"test={np.mean(fracs):.4f} "
                        f"upper={np.mean(uppers):.4f}"))
    return rows
