"""Paper Fig. 9 + Fig. 12: per-epoch network load, default vs PCAg vs
covariance update, across radio ranges.

Validated headline numbers (paper Sec. 4.4): root load 2p-1 = 103 for the
default scheme; PCAg q=1 highest load = C*+1; overall aggregated load is
topology-independent.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed, topo


def run(ranges=(8.0, 10.0, 15.0, 20.0, 30.0, 50.0)) -> list[dict]:
    rows = []
    for r in ranges:
        t = topo(r)
        (loads_d, us) = timed(t.tree.load_default, repeat=5)
        loads_a = t.tree.load_aggregation(q=1)
        loads_f = t.tree.load_feedback()
        loads_cov = t.load_covariance_update()
        rows.append(row(
            f"fig9/range={r:g}/default", us,
            f"max={int(loads_d.max())} total={int(loads_d.sum())}"))
        rows.append(row(
            f"fig9/range={r:g}/pcag_q1", us,
            f"max={int(loads_a.max())} total={int((loads_a + loads_f).sum())}"))
        rows.append(row(
            f"fig12/range={r:g}/cov_update", us,
            f"max={int(loads_cov.max())} mean={loads_cov.mean():.1f}"))
    return rows
