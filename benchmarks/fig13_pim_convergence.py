"""Paper Fig. 13: PIM accuracy vs iteration budget, against exact QR.

Retained variance on the test set for the deflated power iteration with
t_max in {5, 10, 20, 30, 40, 50} (delta = 1e-3, the paper's setting),
compared to the centralized eigendecomposition, plus the beyond-paper
blocked orthogonal iteration at the same budgets.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, folds, row, timed
from repro.core.pca import DistributedPCA, retained_variance


def run(iters=(5, 10, 20, 30, 40, 50), q: int = 5) -> list[dict]:
    data = dataset()
    tr_idx, te_idx = folds(3)[0]
    train, test = data.measurements[tr_idx], data.measurements[te_idx]
    rows = []

    exact, us = timed(DistributedPCA(q=q, method="eigh").fit, train, repeat=1)
    f_exact = retained_variance(test, exact.components, exact.mean)
    rows.append(row("fig13/exact_qr", us, f"retained={f_exact:.4f}"))

    for t_max in iters:
        res, us = timed(
            DistributedPCA(q=q, method="power", t_max=t_max,
                           delta=1e-3).fit, train, repeat=1)
        kept = res.components[:, res.valid]
        frac = retained_variance(test, kept, res.mean)
        its = np.asarray(res.iterations).tolist()
        rows.append(row(f"fig13/power_tmax={t_max}", us,
                        f"retained={frac:.4f} iters={its}"))

    for t_max in iters:
        res, us = timed(
            DistributedPCA(q=q, method="ortho", t_max=t_max,
                           delta=1e-3).fit, train, repeat=1)
        frac = retained_variance(test, res.components[:, res.valid], res.mean)
        rows.append(row(f"fig13/ortho_tmax={t_max}", us,
                        f"retained={frac:.4f}"))
    return rows
