"""Paper Table 1: communication/computation/memory of the four schemes,
instantiated for the experimental network (p=52, T=1440 epochs, q=5) and
for the production configuration (p=1M, banded h=128) — the TPU mapping.
"""

from __future__ import annotations

from benchmarks.common import row, topo
from repro.core import costs


def run() -> list[dict]:
    t = topo(10.0)
    n_max = int(t.neighborhood_sizes().max())
    c_max = int(t.tree.children_counts().max())
    rows = []
    rep = costs.table1(p=52, T=1440, q=5, n_max=n_max, c_max=c_max, iters=20)
    for name, r in rep.items():
        rows.append(row(f"table1/52/{name}", 0.0,
                        f"comm={r.communication:.3g} comp={r.computation:.3g}"
                        f" mem={r.memory:.3g}"))
    # production scale: 1M virtual sensors, neighborhood = band 2h
    rep = costs.table1(p=1_048_576, T=14_400, q=32, n_max=256, c_max=2,
                       iters=20)
    for name, r in rep.items():
        rows.append(row(f"table1/1m/{name}", 0.0,
                        f"comm={r.communication:.3g} comp={r.computation:.3g}"
                        f" mem={r.memory:.3g}"))
    return rows
