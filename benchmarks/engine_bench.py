"""Sustained-load serving benchmark: the StreamingPCAEngine under churn.

Drives the fleet engine (DESIGN.md Sec. 17) through a sustained request
load — more streams than slots, staggered lengths so retirements and
admissions happen continuously, a liveness-schedule variant so the masked
staging path is measured too — and reports the serving headline numbers
per configuration:

* ``engine/{sync,pipe}_fleet{B}_chunk{K}_{churn}`` — requests/s, rounds/s,
  p99 step latency, measured staged-vs-compute overlap fraction, prestage
  hit rate; one row per (mode, fleet size, chunk, churn level)
* ``engine/speedup_fleet{B}_chunk{K}_{churn}`` — pipelined vs synchronous
  requests/s ratio for the matching row pair

Every row carries the machine-readable fields (``requests_per_s``,
``overlap``, ``slots``, ``mode``, ...) next to the human-readable
``derived`` string, so the benchmarks/run.py gates compare numbers, not
regexes.

Pipelining overlaps single-threaded host staging with the XLA fold, so its
wall-clock win needs somewhere for the overlap to GO: a second core or an
accelerator device.  On a 1-core CPU host both sides share the core and
the ratio is ~1.0 by Amdahl — the rows record ``pipeline_capable`` and
``cores`` so the run.py overlap gate arms only where overlap is physically
possible, and prints the capability verdict instead of silently passing.

Standalone: ``python benchmarks/engine_bench.py --smoke --json
BENCH_engine.json`` (benchmarks/run.py --engine-json does this inside the
CI smoke run).
"""

from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import row
from repro.serve.engine import StreamingPCAEngine, StreamRequest
from repro.streaming import StreamConfig

P, Q, H = 32, 3, 4
N_PER_ROUND = 8


def pipeline_capable() -> bool:
    """True when host staging can physically overlap device compute:
    an accelerator backend, or more than one CPU core."""
    if jax.default_backend() != "cpu":
        return True
    return (os.cpu_count() or 1) > 1


def _requests(rng, n_req: int, rounds_base: int, *, masked: bool,
              jitter: int) -> list[StreamRequest]:
    """Staggered stream lengths (so retirements spread across steps — the
    sustained-churn regime, not synchronized waves) and, when ``masked``,
    a liveness schedule on every other stream to exercise the masked
    staging path."""
    reqs = []
    for i in range(n_req):
        r = rounds_base + (i * 7) % max(1, jitter)
        rounds = rng.normal(size=(r, N_PER_ROUND, P)).astype(np.float32)
        liveness = None
        if masked and i % 2 == 0:
            liveness = (rng.uniform(size=(r, P)) > 0.1).astype(np.float32)
        reqs.append(StreamRequest(rounds=rounds, liveness=liveness))
    return reqs


def _drive(cfg, *, slots: int, chunk: int, pipeline: bool, reqs,
           warm_req) -> dict:
    """One sustained-load run: compile outside the timed window (one
    throwaway warm stream per step shape), then submit the full load and
    time until drained."""
    eng = StreamingPCAEngine(cfg, slots=slots, seed=0, chunk=chunk,
                             pipeline=pipeline, telemetry=True)
    eng.submit(warm_req)
    eng.run_until_done()                 # compiles step fns + retirement
    eng.telemetry.reset()                # measure the loaded window only
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    wall = time.perf_counter() - t0
    done = sum(1 for r in reqs if r.done)
    if done != len(reqs):
        raise RuntimeError(f"{len(reqs) - done} requests not drained")
    summ = eng.telemetry.summary()
    assert eng.pulls["hot"] == 0, \
        f"hot-path device pulls: {eng.pulls['hot']}"   # contract, re-checked
    return dict(wall_s=wall,
                requests_per_s=done / wall,
                rounds_per_s=sum(r.rounds.shape[0] for r in reqs) / wall,
                p99_ms=summ["p99_step_s"] * 1e3,
                overlap=summ["overlap_fraction"],
                prestage_hit_rate=summ["prestage_hit_rate"])


def run(smoke: bool = False):
    """Sweep fleet size x churn rate x chunk x mode.  ``smoke`` shrinks
    the load to a seconds-scale pass (the CI setting) but keeps the
    32-slot chunk=8 acceptance row."""
    out = []
    rng = np.random.default_rng(0)
    capable = pipeline_capable()
    cores = os.cpu_count() or 1
    cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                       drift_threshold=0.1, warmup_rounds=5)
    # churn level -> (stream length base, +jitter): short streams retire
    # slots every few steps (heavy churn), long streams mostly fold
    churn_levels = {"hichurn": (16, 7), "lochurn": (48, 17)}
    sweeps: list[tuple[int, int, str, bool]] = []
    for slots in ((8, 32) if smoke else (8, 32, 64)):
        for k in ((8,) if smoke else (1, 8)):
            for churn in churn_levels:
                # the masked variant only at the acceptance point, to keep
                # smoke in seconds
                for masked in ((False,) if (smoke or slots != 32)
                               else (False, True)):
                    sweeps.append((slots, k, churn, masked))
    repeat = 2 if smoke else 3
    for slots, k, churn, masked in sweeps:
        base, jitter = churn_levels[churn]
        n_req = slots * (2 if smoke else 3)
        reqs_by_mode = {}
        for pipeline in (False, True):
            m = None
            for _ in range(repeat):      # best-of: shed scheduler noise
                # fresh identical request objects per run (the engine
                # mutates them); same seed -> same data
                r = np.random.default_rng(hash((slots, k, churn, masked))
                                          % 2**32)
                reqs = _requests(r, n_req, base, masked=masked,
                                 jitter=jitter)
                warm = StreamRequest(rounds=r.normal(
                    size=(2 * k, N_PER_ROUND, P)).astype(np.float32))
                mi = _drive(cfg, slots=slots, chunk=k, pipeline=pipeline,
                            reqs=reqs, warm_req=warm)
                if m is None or mi["requests_per_s"] > m["requests_per_s"]:
                    m = mi
            mode = "pipe" if pipeline else "sync"
            reqs_by_mode[mode] = m
            tag = f"fleet{slots}_chunk{k}_{churn}" + \
                ("_masked" if masked else "")
            rr = row(f"engine/{mode}_{tag}", m["wall_s"] * 1e6,
                     f"{m['requests_per_s']:.1f} req/s|"
                     f"{m['rounds_per_s']:.0f} rounds/s|"
                     f"p99 {m['p99_ms']:.1f}ms|"
                     f"overlap {m['overlap']:.3f}")
            rr.update(mode=mode, slots=slots, chunk=k, churn=churn,
                      masked=masked, cores=cores, pipeline_capable=capable,
                      **{kk: vv for kk, vv in m.items() if kk != "wall_s"})
            out.append(rr)
        ratio = (reqs_by_mode["pipe"]["requests_per_s"]
                 / reqs_by_mode["sync"]["requests_per_s"])
        tag = f"fleet{slots}_chunk{k}_{churn}" + ("_masked" if masked else "")
        rr = row(f"engine/speedup_{tag}", 0.0,
                 f"{ratio:.2f}x pipe vs sync|"
                 f"{'overlap-capable' if capable else 'single-core host'}")
        rr.update(mode="speedup", slots=slots, chunk=k, churn=churn,
                  masked=masked, cores=cores, pipeline_capable=capable,
                  speedup=ratio)
        out.append(rr)
    return out


def main() -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sweep (the CI setting)")
    ap.add_argument("--json",
                    help="write the gathered rows to this path "
                         "(the BENCH_engine.json artifact)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    if args.json:
        if not rows:
            print(f"ERROR: no rows gathered, refusing to write {args.json}")
            return 1
        with open(args.json, "w") as fh:
            json.dump(rows, fh, indent=2)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
