"""Weak-scaling benchmark: hierarchical fleets at growing region counts.

Times the two-level driver (:func:`repro.streaming.hierarchy.
hierarchical_stream_run` — per-region streaming + one cross-host energy
merge per refresh boundary, DESIGN.md Sec. 13) while the fleet grows and
the per-region work stays FIXED: p_region sensors, the same round count,
the same refresh schedule.  Perfect weak scaling would hold rounds/s
constant per region as regions are added; the measured curve charts what
the merge collectives and the region-axis sharding actually cost.

* ``scale/regions{R}`` — "rounds/s|fleet_rho|merge_packets|p_total" at R
  regions on a ``make_fleet_mesh`` whose region axis spans the largest
  divisor of R that fits the local devices
* ``scale/wsn_1m_smoke`` — the CI-sized wsn-1m replica
  (:meth:`repro.configs.wsn_1m.WSNConfig.smoke`) streamed END TO END
  through the hierarchy: the acceptance row that the production config's
  two-level shape actually runs, not just lowers

Standalone: ``python benchmarks/scale_bench.py --smoke --json
BENCH_scale.json`` (benchmarks/run.py --scale-json does this inside the CI
smoke run).  Multi-device weak scaling: force host devices first, e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.configs.wsn_1m import CONFIG as WSN
from repro.launch.mesh import make_fleet_mesh
from repro.streaming import StreamConfig
from repro.streaming.hierarchy import (hierarchical_stream_init,
                                       hierarchical_stream_run)

P_REGION, Q, H = 64, 4, 4
N_PER_ROUND = 8


def _region_axis(n_regions: int) -> int:
    """Largest divisor of ``n_regions`` spannable by the local devices."""
    return max(d for d in range(1, jax.device_count() + 1)
               if n_regions % d == 0)


def _fleet_data(key, cfg: StreamConfig, n_regions: int, n_rounds: int):
    """Per-region low-rank structure with distinct energy scales, so the
    level-2 merge has a real selection to make."""
    x = jax.random.normal(key, (n_regions, n_rounds, N_PER_ROUND, cfg.p))
    scale = jnp.linspace(4.0, 1.0, cfg.p)[None, None, None, :]
    region_gain = (1.0 + jnp.arange(n_regions, dtype=jnp.float32)
                   / max(n_regions, 1))[:, None, None, None]
    return x * scale * region_gain


def _one_scale_point(cfg: StreamConfig, n_regions: int, n_rounds: int,
                     repeat: int = 3):
    mesh = make_fleet_mesh(region=_region_axis(n_regions))
    key = jax.random.PRNGKey(7)
    states = hierarchical_stream_init(cfg, key, n_regions)
    xs = _fleet_data(jax.random.PRNGKey(3), cfg, n_regions, n_rounds)

    def _run():
        res = hierarchical_stream_run(cfg, mesh, states, xs)
        jax.block_until_ready(res[2].basis.rho)
        return res

    _run()                                           # compile outside timing
    (fin, metrics, fleet), us = timed(_run, repeat=repeat)
    rps = n_regions * n_rounds / (us / 1e6)
    return row(
        f"scale/regions{n_regions}", us,
        f"{rps:.0f} rounds/s|rho {float(fleet.basis.rho):.3f}|"
        f"{float(fleet.merge_packets):.0f} merge packets|"
        f"p_total {n_regions * cfg.p}")


def wsn_smoke_row(n_rounds: int = 4, repeat: int = 3):
    """Stream the wsn-1m smoke replica end to end through the hierarchy."""
    wsn = WSN.smoke()
    cfg = StreamConfig(p=wsn.region_p, q=wsn.q, halfwidth=wsn.halfwidth,
                       forgetting=0.95, drift_threshold=0.1,
                       warmup_rounds=1)
    mesh = make_fleet_mesh(region=_region_axis(wsn.n_regions))
    states = hierarchical_stream_init(cfg, jax.random.PRNGKey(11),
                                      wsn.n_regions)
    xs = _fleet_data(jax.random.PRNGKey(13), cfg, wsn.n_regions, n_rounds)

    def _run():
        res = hierarchical_stream_run(cfg, mesh, states, xs)
        jax.block_until_ready(res[2].basis.rho)
        return res

    _run()                                           # compile outside timing
    (fin, metrics, fleet), us = timed(_run, repeat=repeat)
    rps = wsn.n_regions * n_rounds / (us / 1e6)
    return row(
        "scale/wsn_1m_smoke", us,
        f"{rps:.0f} rounds/s|rho {float(fleet.basis.rho):.3f}|"
        f"{float(fleet.merge_packets):.0f} merge packets|"
        f"p_total {wsn.p}")


def run(smoke: bool = False, regions: tuple[int, ...] | None = None):
    """``smoke`` keeps the sweep seconds-scale; the region counts still
    cover >= 3 points so the weak-scaling curve exists in CI."""
    out = []
    regions = regions or ((1, 2, 4) if smoke else (1, 2, 4, 8, 16))
    n_rounds = 8 if smoke else 32
    cfg = StreamConfig(p=P_REGION, q=Q, halfwidth=H, forgetting=0.9,
                       drift_threshold=0.1, warmup_rounds=2)
    for n_regions in regions:
        out.append(_one_scale_point(cfg, n_regions, n_rounds))
    out.append(wsn_smoke_row(n_rounds=4 if smoke else 16))
    return out


def main() -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sweep (the CI setting)")
    ap.add_argument("--regions",
                    help="comma-separated region counts to sweep "
                         "(default: 1,2,4 smoke / 1,2,4,8,16 full)")
    ap.add_argument("--json",
                    help="write the gathered rows to this path "
                         "(the BENCH_scale.json artifact)")
    args = ap.parse_args()
    regions = tuple(int(c) for c in args.regions.split(",")) \
        if args.regions else None
    rows = run(smoke=args.smoke, regions=regions)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    if args.json:
        if not rows:
            print(f"ERROR: no rows gathered, refusing to write {args.json}")
            return 1
        with open(args.json, "w") as fh:
            json.dump(rows, fh, indent=2)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
