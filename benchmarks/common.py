"""Shared benchmark utilities: dataset cache, timing, CSV row format."""

from __future__ import annotations

import time
from functools import lru_cache

import numpy as np

from repro.core.topology import build_topology
from repro.sensors.dataset import berkeley_surrogate, kfold_blocks


@lru_cache(maxsize=1)
def dataset(n_epochs: int = 7200):
    return berkeley_surrogate(p=52, n_epochs=n_epochs, seed=0)


@lru_cache(maxsize=8)
def topo(radio_range: float):
    return build_topology(dataset().positions, radio_range=radio_range)


def folds(k: int = 3):
    return kfold_blocks(dataset().n_epochs, k=k)


def timed(fn, *args, repeat: int = 3, **kw):
    """Run fn repeatedly; returns (result, best microseconds)."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def row(name: str, us: float, derived) -> dict:
    return {"name": name, "us_per_call": round(us, 1), "derived": derived}
