"""Paper Fig. 11: accuracy of the local covariance hypothesis vs radio range.

Retained variance (q=5) on held-out data with the masked covariance at
several radio ranges, against the full-covariance upper curve and a random
orthonormal basis (the paper's lower reference).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, folds, row, timed, topo
from repro.core.pca import DistributedPCA, retained_variance


def run(ranges=(6.5, 8.0, 10.0, 15.0, 20.0, 30.0, 40.0), q: int = 5) -> list[dict]:
    data = dataset()
    tr_idx, te_idx = folds(3)[0]
    train, test = data.measurements[tr_idx], data.measurements[te_idx]
    rows = []

    res_full, us = timed(DistributedPCA(q=q, method="eigh").fit, train,
                         repeat=1)
    full = retained_variance(test, res_full.components, res_full.mean)
    rows.append(row("fig11/full_cov", us, f"retained={full:.4f}"))

    for r in ranges:
        try:
            t = topo(r)
        except ValueError:
            rows.append(row(f"fig11/range={r:g}", 0.0, "disconnected"))
            continue
        pca = DistributedPCA(q=q, method="eigh", cov_mode="masked",
                             mask=np.asarray(t.covariance_mask()))
        res, us = timed(pca.fit, train, repeat=1)
        kept = res.components[:, res.valid]
        frac = retained_variance(test, kept, res.mean)
        rows.append(row(f"fig11/range={r:g}", us,
                        f"retained={frac:.4f} kept={kept.shape[1]}"))

    rng = np.random.default_rng(0)
    w_rand = np.linalg.qr(rng.normal(size=(52, q)))[0]
    rand = retained_variance(test, w_rand, train.mean(axis=0))
    rows.append(row("fig11/random_basis", 0.0, f"retained={rand:.4f}"))
    return rows
