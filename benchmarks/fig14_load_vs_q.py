"""Paper Fig. 10 + 14: network load vs number of components extracted.

Fig. 10: per-epoch PCAg load for q in {1, 5, 15} against the default scheme
(crossover when q(C*+1) > 2p-1).  Fig. 14: total PIM extraction load,
quadratic in q (radio range 10 m, 20 iterations per component).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed, topo
from repro.core import costs


def run(qs=(1, 2, 5, 10, 15, 20), radio_range: float = 10.0) -> list[dict]:
    t = topo(radio_range)
    p = t.p
    c_max = int(t.tree.children_counts().max())
    rows = []

    d_max = costs.default_epoch_load(p)
    rows.append(row("fig10/default", 0.0, f"max={d_max}"))
    for q in qs:
        load = costs.pcag_epoch_load(q, c_max)
        rows.append(row(f"fig10/pcag_q={q}", 0.0,
                        f"max={load} beats_default="
                        f"{costs.pcag_beats_default(q, c_max, p)}"))

    for q in qs:
        (load, us) = timed(t.load_pim_total, q, [20] * q, repeat=3)
        rows.append(row(f"fig14/pim_q={q}", us,
                        f"max={int(load.max())} mean={load.mean():.0f}"))
    return rows
