"""Compression-tier benchmark: ε and bit-width sweeps on the serving path.

Three row families, the device-tier analogue of the paper's Sec.-5
compression experiment:

* ``compress/fused@{eps}`` — the fused Pallas ε-supervised kernel
  (project + reconstruct + flag in one pass) on a fleet batch, vs. ε:
  derived column ``maxerr|extras`` shows the guarantee holding while the
  notification count falls;
* ``compress/oracle`` — the host-side NumPy oracle on the same block
  (the path the tier replaced), for the speedup denominator;
* ``compress/stream@{bits}b`` — the full streaming fleet (cov fold +
  scheduler + compression stage) at each score bit width:
  ``maxerr|extras|bits`` charts the accuracy-vs-bits tradeoff.

Run standalone to emit a JSON artifact for the perf trajectory:

    PYTHONPATH=src:. python benchmarks/compression_bench.py \
        --smoke --json BENCH_compression.json
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, timed

EPSILONS = (0.1, 0.5, 2.0)
BIT_WIDTHS = (0, 8, 4, 2)
B, N, P, Q, H = 8, 8, 32, 3, 4
EPS_FOR_BITS = 0.5


def _fleet_block(rng):
    scale = np.concatenate([[4.0, 3.4, 2.8], np.linspace(1.2, 0.8, P - 3)])
    x = (rng.normal(size=(B, N, P)) * scale).astype(np.float32)
    W = np.linalg.qr(rng.normal(size=(P, Q)))[0].astype(np.float32)
    mean = (x.mean(axis=(0, 1))).astype(np.float32)
    return x, W, mean


def _fused_sweep(n_repeat: int):
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    out = []
    rng = np.random.default_rng(0)
    x, W, mean = _fleet_block(rng)
    xj, Wj, mj = jnp.asarray(x), jnp.asarray(W), jnp.asarray(mean)
    for eps in EPSILONS:
        def call(e=eps):
            z, xh, fl = ops.supervised_compress_batched(xj, Wj, mj, epsilon=e)
            jax.block_until_ready(z)
            return z, xh, fl
        call()                                   # compile outside timing
        (z, xh, fl), us = timed(call, repeat=n_repeat)
        x_sink = np.where(np.asarray(fl), x, np.asarray(xh))
        maxerr = np.abs(x_sink - x).max()
        extras = int(np.asarray(fl).sum())
        out.append(row(f"compress/fused@{eps}", us,
                       f"maxerr {maxerr:.3f}|{extras} extras"))

    # host-side NumPy oracle on the same block (fp32, same convention)
    from repro.core.compression import SupervisedCompressor
    comp = SupervisedCompressor(W, mean, epsilon=EPS_FOR_BITS,
                                dtype=np.float32)
    flat = x.reshape(-1, P)
    _, us = timed(lambda: comp.run(flat), repeat=n_repeat)
    out.append(row("compress/oracle", us, f"numpy fp32 eps={EPS_FOR_BITS}"))
    return out


def _stream_sweep(n_rounds: int, n_repeat: int):
    import jax
    import jax.numpy as jnp

    from repro.streaming import (CompressionConfig, StreamConfig,
                                 batched_stream_run, stream_init)

    out = []
    rng = np.random.default_rng(1)
    scale = np.concatenate([[4.0, 3.4, 2.8], np.linspace(1.2, 0.8, P - 3)])
    xs = jnp.asarray((rng.normal(size=(B, n_rounds, N, P)) * scale)
                     .astype(np.float32))
    keys = jax.random.split(jax.random.PRNGKey(1), B)
    for bits in BIT_WIDTHS:
        cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.95,
                           drift_threshold=0.08, warmup_rounds=5,
                           compression=CompressionConfig(
                               epsilon=EPS_FOR_BITS, score_bits=bits))
        states = jax.vmap(lambda k: stream_init(cfg, k))(keys)

        def _run(c=cfg, s=states):
            res = batched_stream_run(c, s, xs)
            jax.block_until_ready(res[1].rho)
            return res
        _run()                                   # compile outside timing
        (fin, met), us = timed(_run, repeat=n_repeat)
        comp = met.compression
        maxerr = float(np.asarray(comp.max_err).max())
        extras = float(np.asarray(comp.extra_packets).sum())
        bits_air = float(np.asarray(comp.bits_on_air).sum())
        out.append(row(f"compress/stream@{bits}b", us,
                       f"maxerr {maxerr:.3f}|{extras:.0f} extras"
                       f"|{bits_air:.0f} bits"))
    return out


def run(smoke: bool = False):
    n_repeat = 2 if smoke else 5
    n_rounds = 10 if smoke else 40
    return _fused_sweep(n_repeat) + _stream_sweep(n_rounds, n_repeat)


def main() -> int:
    import argparse
    import json
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", help="write rows to this JSON artifact path")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(rows, fh, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
