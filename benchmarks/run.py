# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; --full widens the CV folds and range sweeps to paper scale;
# --smoke shrinks every sweep to a seconds-scale pass AND makes any
# benchmark error fatal (exit 1) — the CI bit-rot guard for entrypoints.
from __future__ import annotations

import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="substring filter on benchmark module")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (10-fold CV, all ranges)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal settings, errors are fatal (CI mode)")
    ap.add_argument("--compression-json",
                    help="also write the compression rows gathered during "
                         "this run to a JSON artifact (avoids re-running "
                         "the sweep just for the CI artifact)")
    ap.add_argument("--events-json",
                    help="also write the event-detection rows gathered "
                         "during this run to a JSON artifact")
    ap.add_argument("--streaming-json",
                    help="also write the streaming-fleet rows (throughput, "
                         "chunk sweep) gathered during this run to a JSON "
                         "artifact")
    ap.add_argument("--scale-json",
                    help="also write the hierarchical weak-scaling rows "
                         "(regions sweep + wsn-1m smoke replica) gathered "
                         "during this run to a JSON artifact")
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")

    from benchmarks import (compression_bench, event_bench, fault_bench,
                            fig7_retained_variance, fig9_comm_costs,
                            fig11_local_cov, fig13_pim_convergence,
                            fig14_load_vs_q, kernels_bench, scale_bench,
                            streaming_bench, table1_complexity)

    modules = {
        "fig7": lambda: fig7_retained_variance.run(
            k_folds=10 if args.full else (2 if args.smoke else 3)),
        "fig9": fig9_comm_costs.run,
        "fig11": fig11_local_cov.run,
        "fig13": fig13_pim_convergence.run,
        "fig14": fig14_load_vs_q.run,
        "table1": table1_complexity.run,
        "kernels": lambda: kernels_bench.run(smoke=args.smoke),
        "streaming": lambda: streaming_bench.run(smoke=args.smoke),
        "fault": lambda: fault_bench.run(smoke=args.smoke),
        "compression": lambda: compression_bench.run(smoke=args.smoke),
        "events": lambda: event_bench.run(smoke=args.smoke),
        "scale": lambda: scale_bench.run(smoke=args.smoke),
    }

    failed = 0
    gathered: dict[str, list] = {"compression": [], "events": [],
                                 "streaming": [], "scale": []}
    print("name,us_per_call,derived")
    for name, fn in modules.items():
        if args.only and args.only not in name:
            continue
        try:
            for r in fn():
                print(f"{r['name']},{r['us_per_call']},{r['derived']}")
                if name in gathered:
                    gathered[name].append(r)
        except Exception as e:  # noqa: BLE001 — report and continue
            failed += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", file=sys.stdout)
    # a requested JSON artifact with NO gathered rows means the benchmark
    # silently never ran (filtered out, or it errored above): fail loudly —
    # an empty BENCH_* trajectory is indistinguishable from a healthy one
    for name, path, rows in (
            ("compression", args.compression_json, gathered["compression"]),
            ("events", args.events_json, gathered["events"]),
            ("streaming", args.streaming_json, gathered["streaming"]),
            ("scale", args.scale_json, gathered["scale"])):
        if not path:
            continue
        if not rows:
            failed += 1
            print(f"{name}/ERROR,0,requested JSON artifact {path} but the "
                  f"benchmark emitted no rows (never ran?)", file=sys.stdout)
            continue
        import json
        with open(path, "w") as fh:
            json.dump(rows, fh, indent=2)
    sys.stdout.flush()
    return 1 if (args.smoke and failed) else 0


if __name__ == "__main__":
    sys.exit(main())
