# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; --full widens the CV folds and range sweeps to paper scale.
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="substring filter on benchmark module")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (10-fold CV, all ranges)")
    args = ap.parse_args()

    from benchmarks import (fig7_retained_variance, fig9_comm_costs,
                            fig11_local_cov, fig13_pim_convergence,
                            fig14_load_vs_q, kernels_bench, streaming_bench,
                            table1_complexity)

    modules = {
        "fig7": lambda: fig7_retained_variance.run(
            k_folds=10 if args.full else 3),
        "fig9": fig9_comm_costs.run,
        "fig11": fig11_local_cov.run,
        "fig13": fig13_pim_convergence.run,
        "fig14": fig14_load_vs_q.run,
        "table1": table1_complexity.run,
        "kernels": kernels_bench.run,
        "streaming": streaming_bench.run,
    }

    print("name,us_per_call,derived")
    for name, fn in modules.items():
        if args.only and args.only not in name:
            continue
        try:
            for r in fn():
                print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        except Exception as e:  # noqa: BLE001 — report and continue
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}", file=sys.stdout)
    sys.stdout.flush()


if __name__ == "__main__":
    main()
