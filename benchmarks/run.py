# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows; --full widens the CV folds and range sweeps to paper scale;
# --smoke shrinks every sweep to a seconds-scale pass AND makes any
# benchmark error fatal (exit 1) — the CI bit-rot guard for entrypoints.
from __future__ import annotations

import argparse
import os
import re
import sys

# committed streaming throughput baseline (smoke settings); regenerate with
#   python benchmarks/run.py --only streaming --smoke \
#       --streaming-json benchmarks/baselines/BENCH_streaming.json
_STREAMING_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baselines",
    "BENCH_streaming.json")

# committed serving-engine throughput baseline (smoke settings); regenerate
#   python benchmarks/run.py --only engine --smoke \
#       --engine-json benchmarks/baselines/BENCH_engine.json
_ENGINE_BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baselines",
    "BENCH_engine.json")

# a measured rounds/s below this fraction of the committed baseline fails
# the run — the fail-loud guard against silently shipping a slow hot loop
_REGRESSION_FLOOR = 0.8

# pipelined rows on fleets at least this large must show at least this
# staged-vs-compute overlap — the pipeline must actually pipeline (gate
# arms only on hosts where overlap is physically possible)
_OVERLAP_MIN_SLOTS = 16
_OVERLAP_FLOOR = 0.10


def _rounds_per_sec(derived: str) -> float | None:
    m = re.match(r"^(\d+(?:\.\d+)?) rounds/s", str(derived))
    return float(m.group(1)) if m else None


def check_streaming_regression(rows: list,
                               baseline_path: str) -> list[tuple[str, str]]:
    """Compare this run's rounds/s rows against the committed baseline.

    Returns one ``(rule, detail)`` failure per row whose throughput fell
    below ``_REGRESSION_FLOOR`` x baseline — ``rule`` names the offending
    row (``regression:<row-name>``), ``detail`` carries measured vs.
    baseline in one line.  Rows without a rounds/s figure (the
    threshold-frontier rows) and names absent from the baseline (new
    sweeps, different fleet sizes) are skipped — the gate only ever
    compares like with like.
    """
    import json
    with open(baseline_path) as fh:
        base = {r["name"]: _rounds_per_sec(r["derived"])
                for r in json.load(fh)}
    failures = []
    for r in rows:
        rps = _rounds_per_sec(r["derived"])
        ref = base.get(r["name"])
        if rps is None or ref is None or ref <= 0:
            continue
        if rps < _REGRESSION_FLOOR * ref:
            failures.append((
                f"regression:{r['name']}",
                f"measured {rps:.0f} rounds/s vs baseline {ref:.0f} rounds/s "
                f"({rps / ref:.2f}x < {_REGRESSION_FLOOR:.2f}x floor)"))
    return failures


def check_engine_regression(rows: list,
                            baseline_path: str) -> list[tuple[str, str]]:
    """Compare this run's engine requests/s against the committed baseline.

    Engine rows carry machine-readable fields (``requests_per_s``), so the
    gate reads numbers instead of parsing the derived string.  Speedup
    rows and names absent from the baseline are skipped — the gate only
    compares like with like.
    """
    import json
    with open(baseline_path) as fh:
        base = {r["name"]: r.get("requests_per_s") for r in json.load(fh)}
    failures = []
    for r in rows:
        rps = r.get("requests_per_s")
        ref = base.get(r["name"])
        if rps is None or ref is None or ref <= 0:
            continue
        if rps < _REGRESSION_FLOOR * ref:
            failures.append((
                f"regression:{r['name']}",
                f"measured {rps:.0f} req/s vs baseline {ref:.0f} req/s "
                f"({rps / ref:.2f}x < {_REGRESSION_FLOOR:.2f}x floor)"))
    return failures


def check_engine_overlap(rows: list) -> list[tuple[str, str]]:
    """Pipelined rows on fleets >= 16 slots must measure >= 10% overlap
    — parity alone doesn't prove the pipeline pipelines.  The gate arms
    only where overlap is physically possible (``pipeline_capable``: an
    accelerator backend or a multi-core host); on a single-core CPU host
    staging and compute share the core, so the gate prints its verdict as
    informational instead of silently passing."""
    failures = []
    for r in rows:
        if r.get("mode") != "pipe" or r.get("slots", 0) < _OVERLAP_MIN_SLOTS:
            continue
        overlap = r.get("overlap")
        if overlap is None:
            continue
        if not r.get("pipeline_capable", False):
            print(f"run.py/INFO,overlap:{r['name']},single-core host "
                  f"(cores={r.get('cores')}): overlap gate vacuous, "
                  f"measured {overlap:.3f}")
            continue
        if overlap < _OVERLAP_FLOOR:
            failures.append((
                f"overlap:{r['name']}",
                f"measured overlap {overlap:.3f} < {_OVERLAP_FLOOR:.2f} "
                f"floor on a {r.get('slots')}-slot fleet "
                f"(the pipeline isn't pipelining)"))
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", help="substring filter on benchmark module")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (10-fold CV, all ranges)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal settings, errors are fatal (CI mode)")
    ap.add_argument("--compression-json",
                    help="also write the compression rows gathered during "
                         "this run to a JSON artifact (avoids re-running "
                         "the sweep just for the CI artifact)")
    ap.add_argument("--events-json",
                    help="also write the event-detection rows gathered "
                         "during this run to a JSON artifact")
    ap.add_argument("--streaming-json",
                    help="also write the streaming-fleet rows (throughput, "
                         "chunk sweep) gathered during this run to a JSON "
                         "artifact")
    ap.add_argument("--scale-json",
                    help="also write the hierarchical weak-scaling rows "
                         "(regions sweep + wsn-1m smoke replica) gathered "
                         "during this run to a JSON artifact")
    ap.add_argument("--engine-json",
                    help="also write the serving-engine sustained-load "
                         "rows (requests/s, p99, overlap fraction) "
                         "gathered during this run to a JSON artifact")
    ap.add_argument("--streaming-baseline", default=_STREAMING_BASELINE,
                    help="committed rounds/s baseline to gate against "
                         "(>20%% regression fails the run); pass an empty "
                         "string to skip the gate")
    ap.add_argument("--engine-baseline", default=_ENGINE_BASELINE,
                    help="committed requests/s baseline to gate against "
                         "(>20%% regression fails the run); pass an empty "
                         "string to skip the gate")
    args = ap.parse_args()
    if args.full and args.smoke:
        ap.error("--full and --smoke are mutually exclusive")

    from benchmarks import (compression_bench, engine_bench, event_bench,
                            fault_bench, fig7_retained_variance,
                            fig9_comm_costs, fig11_local_cov,
                            fig13_pim_convergence, fig14_load_vs_q,
                            kernels_bench, scale_bench, streaming_bench,
                            table1_complexity)

    modules = {
        "fig7": lambda: fig7_retained_variance.run(
            k_folds=10 if args.full else (2 if args.smoke else 3)),
        "fig9": fig9_comm_costs.run,
        "fig11": fig11_local_cov.run,
        "fig13": fig13_pim_convergence.run,
        "fig14": fig14_load_vs_q.run,
        "table1": table1_complexity.run,
        "kernels": lambda: kernels_bench.run(smoke=args.smoke),
        "streaming": lambda: streaming_bench.run(smoke=args.smoke),
        "fault": lambda: fault_bench.run(smoke=args.smoke),
        "compression": lambda: compression_bench.run(smoke=args.smoke),
        "events": lambda: event_bench.run(smoke=args.smoke),
        "scale": lambda: scale_bench.run(smoke=args.smoke),
        "engine": lambda: engine_bench.run(smoke=args.smoke),
    }

    # every gate failure is a named (rule, detail) pair so the final verdict
    # can say exactly which rule/row failed and why, in one line each
    bench_errors: list[tuple[str, str]] = []
    artifact_errors: list[tuple[str, str]] = []
    regressions: list[tuple[str, str]] = []
    gathered: dict[str, list] = {"compression": [], "events": [],
                                 "streaming": [], "scale": [], "engine": []}
    print("name,us_per_call,derived")
    for name, fn in modules.items():
        if args.only and args.only not in name:
            continue
        try:
            for r in fn():
                print(f"{r['name']},{r['us_per_call']},{r['derived']}")
                if name in gathered:
                    gathered[name].append(r)
        except Exception as e:  # noqa: BLE001 — report and continue
            bench_errors.append((f"bench-error:{name}",
                                 f"{type(e).__name__}: {e}"))
    # a requested JSON artifact with NO gathered rows means the benchmark
    # silently never ran (filtered out, or it errored above): fail loudly —
    # an empty BENCH_* trajectory is indistinguishable from a healthy one
    for name, path, rows in (
            ("compression", args.compression_json, gathered["compression"]),
            ("events", args.events_json, gathered["events"]),
            ("streaming", args.streaming_json, gathered["streaming"]),
            ("scale", args.scale_json, gathered["scale"]),
            ("engine", args.engine_json, gathered["engine"])):
        if not path:
            continue
        if not rows:
            artifact_errors.append((
                f"empty-artifact:{name}",
                f"requested JSON artifact {path} but the benchmark emitted "
                f"no rows (never ran?)"))
            continue
        import json
        with open(path, "w") as fh:
            json.dump(rows, fh, indent=2)
    # rounds/s regression gate: ANY streaming row more than 20% below the
    # committed baseline fails the run outright (not just under --smoke) —
    # a quiet throughput cliff on the hot loop must never merge silently
    if (gathered["streaming"] and args.streaming_baseline
            and os.path.exists(args.streaming_baseline)):
        regressions = check_streaming_regression(gathered["streaming"],
                                                 args.streaming_baseline)
    # serving-engine gates: requests/s regression vs the committed baseline
    # (structured fields, no regex) and the overlap floor on big pipelined
    # fleets — both always fatal, like the streaming gate
    if (gathered["engine"] and args.engine_baseline
            and os.path.exists(args.engine_baseline)):
        regressions += check_engine_regression(gathered["engine"],
                                               args.engine_baseline)
    if gathered["engine"]:
        regressions += check_engine_overlap(gathered["engine"])
    # static resource certifier (repro.analysis.resources): under --smoke
    # the derived VMEM/HBM/wire bills must still match the committed
    # analysis/baselines/resources.json — a perf run whose traced resource
    # bill drifted from the blessed one is reporting numbers for a
    # different program, so the drift is as fatal as a bench error
    resource_errors: list[tuple[str, str]] = []
    if args.smoke:
        try:
            from repro.analysis.check import resource_failures
            resource_errors = resource_failures()
        except Exception as e:  # noqa: BLE001 — certifier crash is a finding
            resource_errors = [("resources:driver",
                                f"{type(e).__name__}: {e}")]
    # bench/artifact/resource errors are fatal only under --smoke (CI
    # mode); a throughput regression is fatal on every run
    fatal = regressions + (bench_errors + artifact_errors + resource_errors
                           if args.smoke else [])
    warn_only = [] if args.smoke else bench_errors + artifact_errors
    for rule, detail in fatal + warn_only:
        print(f"run.py/FAIL,{rule},{detail}", file=sys.stdout)
    if fatal:
        print("run.py verdict: FAILED — "
              + "; ".join(rule for rule, _ in fatal), file=sys.stdout)
    sys.stdout.flush()
    return 1 if fatal else 0


if __name__ == "__main__":
    sys.exit(main())
