"""Streaming fleet benchmark: rounds/s and the accuracy-vs-comm frontier.

Times the jitted vmap+scan fleet driver at a few fleet sizes (the serving
hot path) and sweeps the drift threshold to chart the scheduler's
communication-vs-retained-variance tradeoff — the streaming analogue of the
paper's Fig. 9/14 load curves.  CSV derived column:

* ``stream/fleet{B}`` — network-rounds per second at fleet size B
* ``stream/threshold{t}`` — "retained@end|refreshes|packets" per network
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.streaming import StreamConfig, batched_stream_run, stream_init

P, Q, H = 32, 3, 4
N_PER_ROUND = 8


def _fleet(key, n_networks: int, n_rounds: int, shift_at: int) -> jnp.ndarray:
    base = jnp.linspace(4.0, 1.0, P)
    x = jax.random.normal(key, (n_networks, n_rounds, N_PER_ROUND, P))
    rounds = jnp.arange(n_rounds)[None, :, None, None]
    scale = jnp.where(rounds >= shift_at, base[::-1][None, None, None, :],
                      base[None, None, None, :])
    return x * scale


def _states(cfg, n_networks: int):
    keys = jax.random.split(jax.random.PRNGKey(1), n_networks)
    return jax.vmap(lambda k: stream_init(cfg, k))(keys)


def run(smoke: bool = False):
    """``smoke`` shrinks the fleets and round counts to a seconds-scale
    pass over the same code paths (the CI entrypoint guard)."""
    out = []
    n_rounds = 10 if smoke else 40

    # -- throughput vs fleet size ------------------------------------------
    cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                       drift_threshold=0.1, warmup_rounds=5)
    for B in (4, 8) if smoke else (8, 32, 64):
        xs = _fleet(jax.random.PRNGKey(0), B, n_rounds, shift_at=n_rounds // 2)
        states = _states(cfg, B)
        batched_stream_run(cfg, states, xs)          # compile outside timing
        _, us = timed(
            lambda s=states, x=xs: jax.block_until_ready(
                batched_stream_run(cfg, s, x)[1].rho))
        rps = B * n_rounds / (us / 1e6)
        out.append(row(f"stream/fleet{B}", us, f"{rps:.0f} rounds/s"))

    # -- accuracy vs communication frontier --------------------------------
    B = 4 if smoke else 16
    xs = _fleet(jax.random.PRNGKey(0), B, n_rounds, shift_at=n_rounds // 2)
    def _run(c, s):
        res = batched_stream_run(c, s, xs)
        jax.block_until_ready(res[1].rho)
        return res

    for thr in ((0.1,) if smoke else (0.02, 0.1, 0.3)):
        cfg_t = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                             drift_threshold=thr, warmup_rounds=5)
        states = _states(cfg_t, B)
        _run(cfg_t, states)                          # compile outside timing
        (final, m), us = timed(_run, cfg_t, states)
        rho_end = float(np.asarray(m.rho)[:, -1].mean())
        refreshes = float(np.asarray(final.sched.refreshes).mean())
        packets = float(np.asarray(final.sched.comm_packets).mean())
        out.append(row(
            f"stream/threshold{thr}", us,
            f"retained {rho_end:.3f}|{refreshes:.1f} refreshes|"
            f"{packets:.0f} packets"))
    return out
