"""Streaming fleet benchmark: rounds/s, chunking, and the accuracy frontier.

Times the jitted vmap+scan fleet driver at a few fleet sizes (the serving
hot path), sweeps the drift threshold to chart the scheduler's
communication-vs-retained-variance tradeoff — the streaming analogue of the
paper's Fig. 9/14 load curves — and sweeps the chunk size K of the
chunk-granular driver (DESIGN.md Sec. 12) against the per-round path.
CSV derived column:

* ``stream/fleet{B}`` — network-rounds per second at fleet size B
* ``stream/threshold{t}`` — "retained@end|refreshes|packets" per network
* ``stream/perround_fleet{B}`` — the chunk sweep's per-round baseline
* ``stream/chunk{K}_fleet{B}`` — "rounds/s|speedup|launches/round|selects/round"
  where launches/round counts the cov-update Pallas launches per streamed
  round and selects/round the refresh cond→selects, both read off the
  traced chunk body's jaxpr (1/K each — the structural amortization claim)
* ``stream/{split_fp32,fused_fp32,fused_bf16}_fleet{B}`` — the mega-kernel
  sweep (DESIGN.md Sec. 14): same data and chunk size with compression AND
  detection enabled, "rounds/s|speedup vs split|launches/chunk" (3 split →
  1 fused, read off the traced jaxpr); ``--fused`` runs only this sweep

Standalone: ``python benchmarks/streaming_bench.py --smoke --chunk 2,8
--json BENCH_streaming.json`` emits the same rows as a JSON artifact
(benchmarks/run.py --streaming-json does this inside the CI smoke run).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.streaming import (CompressionConfig, DetectionConfig,
                             StreamConfig, batched_stream_run, stream_init)

P, Q, H = 32, 3, 4
N_PER_ROUND = 8


def _fleet(key, n_networks: int, n_rounds: int, shift_at: int) -> jnp.ndarray:
    base = jnp.linspace(4.0, 1.0, P)
    x = jax.random.normal(key, (n_networks, n_rounds, N_PER_ROUND, P))
    rounds = jnp.arange(n_rounds)[None, :, None, None]
    scale = jnp.where(rounds >= shift_at, base[::-1][None, None, None, :],
                      base[None, None, None, :])
    return x * scale


def _states(cfg, n_networks: int):
    keys = jax.random.split(jax.random.PRNGKey(1), n_networks)
    return jax.vmap(lambda k: stream_init(cfg, k))(keys)


def _count_prims(jaxpr, names, acc=None):
    """Recursively count primitive occurrences in a jaxpr (sub-jaxprs
    included) — the structural launch accounting of the chunk sweep."""
    acc = acc if acc is not None else {}
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in names:
            acc[eqn.primitive.name] = acc.get(eqn.primitive.name, 0) + 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if hasattr(sub, "jaxpr"):
                    _count_prims(sub.jaxpr, names, acc)
    return acc


def _chunk_body_counts(cfg, chunk: int) -> tuple[float, float]:
    """(cov launches, refresh selects) per ROUND of the chunk body."""
    from repro.streaming import stream_init as s_init
    from repro.streaming.driver import chunk_stream_step

    st = s_init(cfg, jax.random.PRNGKey(0))
    jx = jax.make_jaxpr(lambda s, x: chunk_stream_step(cfg, s, x))(
        st, jnp.zeros((chunk, N_PER_ROUND, P)))
    counts = _count_prims(jx.jaxpr, {"pallas_call", "eigh"})
    return (counts.get("pallas_call", 0) / chunk,
            counts.get("eigh", 0) / chunk)


def chunk_sweep(smoke: bool = False, chunks: tuple[int, ...] | None = None):
    """Per-round vs chunk-granular fleet driver at a few chunk sizes.

    Same data, same config: only the dispatch granularity changes.  The
    derived column records rounds/s, the speedup over the per-round
    baseline, and the structural cov-launch / refresh-select counts per
    round (1/K — the per-chunk launch verified on the jaxpr).
    """
    out = []
    chunks = chunks or ((2, 8) if smoke else (2, 4, 8, 16))
    B = 4 if smoke else 16
    # the scan must be long enough that steady-state body cost dominates
    # scheduler-noise/dispatch jitter — 32 rounds keeps smoke in seconds
    # while making the best-of-5 ratio stable on a loaded CI box
    n_rounds = 32 if smoke else 64
    repeat = 5
    cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                       drift_threshold=0.1, warmup_rounds=5)
    xs = _fleet(jax.random.PRNGKey(0), B, n_rounds, shift_at=n_rounds // 2)
    states = _states(cfg, B)

    def _run(**kw):
        res = batched_stream_run(cfg, states, xs, **kw)
        jax.block_until_ready(res[1].rho)
        return res

    _run()                                           # compile outside timing
    _, us0 = timed(_run, repeat=repeat)
    rps0 = B * n_rounds / (us0 / 1e6)
    out.append(row(f"stream/perround_fleet{B}", us0, f"{rps0:.0f} rounds/s"))
    for k in chunks:
        _run(chunk=k)                                # compile outside timing
        _, us = timed(_run, chunk=k, repeat=repeat)
        rps = B * n_rounds / (us / 1e6)
        launches, selects = _chunk_body_counts(cfg, k)
        out.append(row(
            f"stream/chunk{k}_fleet{B}", us,
            f"{rps:.0f} rounds/s|{us0 / us:.2f}x vs per-round|"
            f"{launches:.3f} launches/round|{selects:.3f} selects/round"))
    return out


def fused_sweep(smoke: bool = False):
    """Split vs fused chunk body, fp32 vs bf16 tiles (DESIGN.md Sec. 14).

    Same data, same chunk size, compression AND detection enabled (the
    configuration where the split body pays 3 stage launches per chunk):
    only the launch fusion and the tile-load dtype change.  The derived
    column records rounds/s, the speedup over the split body, and the
    structural pallas-launch count per chunk read off the traced jaxpr
    (3 split → 1 fused — the amortization claim of the mega-kernel).
    """
    out = []
    B = 4 if smoke else 16
    n_rounds = 32 if smoke else 64
    K = 8
    repeat = 5
    xs = _fleet(jax.random.PRNGKey(0), B, n_rounds, shift_at=n_rounds // 2)
    base = dict(p=P, q=Q, halfwidth=H, forgetting=0.9, drift_threshold=0.1,
                warmup_rounds=5,
                compression=CompressionConfig(epsilon=0.5,
                                              emit_reconstruction=False),
                detection=DetectionConfig(alpha=1e-3, calib_rounds=5))
    us_split = None
    for name, kw in (("split_fp32", dict(fused=False)),
                     ("fused_fp32", dict(fused=True)),
                     ("fused_bf16", dict(fused=True, precision="bf16"))):
        cfg = StreamConfig(**base, **kw)
        states = _states(cfg, B)

        def _run(c=cfg, s=states):
            res = batched_stream_run(c, s, xs, chunk=K)
            jax.block_until_ready(res[1].rho)
            return res

        _run()                                       # compile outside timing
        _, us = timed(_run, repeat=repeat)
        us_split = us_split or us
        rps = B * n_rounds / (us / 1e6)
        launches = _chunk_body_counts(cfg, K)[0] * K
        out.append(row(
            f"stream/{name}_fleet{B}", us,
            f"{rps:.0f} rounds/s|{us_split / us:.2f}x vs split|"
            f"{launches:.0f} launches/chunk"))
    return out


def run(smoke: bool = False, chunks: tuple[int, ...] | None = None):
    """``smoke`` shrinks the fleets and round counts to a seconds-scale
    pass over the same code paths (the CI entrypoint guard)."""
    out = []
    n_rounds = 10 if smoke else 40

    # -- throughput vs fleet size ------------------------------------------
    cfg = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                       drift_threshold=0.1, warmup_rounds=5)
    for B in (4, 8) if smoke else (8, 32, 64):
        xs = _fleet(jax.random.PRNGKey(0), B, n_rounds, shift_at=n_rounds // 2)
        states = _states(cfg, B)
        batched_stream_run(cfg, states, xs)          # compile outside timing
        _, us = timed(
            lambda s=states, x=xs: jax.block_until_ready(
                batched_stream_run(cfg, s, x)[1].rho))
        rps = B * n_rounds / (us / 1e6)
        out.append(row(f"stream/fleet{B}", us, f"{rps:.0f} rounds/s"))

    # -- accuracy vs communication frontier --------------------------------
    B = 4 if smoke else 16
    xs = _fleet(jax.random.PRNGKey(0), B, n_rounds, shift_at=n_rounds // 2)
    def _run(c, s):
        res = batched_stream_run(c, s, xs)
        jax.block_until_ready(res[1].rho)
        return res

    for thr in ((0.1,) if smoke else (0.02, 0.1, 0.3)):
        cfg_t = StreamConfig(p=P, q=Q, halfwidth=H, forgetting=0.9,
                             drift_threshold=thr, warmup_rounds=5)
        states = _states(cfg_t, B)
        _run(cfg_t, states)                          # compile outside timing
        (final, m), us = timed(_run, cfg_t, states)
        rho_end = float(np.asarray(m.rho)[:, -1].mean())
        refreshes = float(np.asarray(final.sched.refreshes).mean())
        packets = float(np.asarray(final.sched.comm_packets).mean())
        out.append(row(
            f"stream/threshold{thr}", us,
            f"retained {rho_end:.3f}|{refreshes:.1f} refreshes|"
            f"{packets:.0f} packets"))

    # -- chunk-granular dispatch sweep -------------------------------------
    out.extend(chunk_sweep(smoke=smoke, chunks=chunks))

    # -- fused mega-kernel sweep (split vs fused x fp32 vs bf16) -----------
    out.extend(fused_sweep(smoke=smoke))
    return out


def main() -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale sweep (the CI setting)")
    ap.add_argument("--chunk",
                    help="comma-separated chunk sizes to sweep "
                         "(default: 2,8 smoke / 2,4,8,16 full)")
    ap.add_argument("--fused", action="store_true",
                    help="run only the fused-vs-split x fp32-vs-bf16 sweep")
    ap.add_argument("--json",
                    help="write the gathered rows to this path "
                         "(the BENCH_streaming.json artifact)")
    args = ap.parse_args()
    chunks = tuple(int(c) for c in args.chunk.split(",")) \
        if args.chunk else None
    rows = fused_sweep(smoke=args.smoke) if args.fused \
        else run(smoke=args.smoke, chunks=chunks)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    if args.json:
        if not rows:
            print(f"ERROR: no rows gathered, refusing to write {args.json}")
            return 1
        with open(args.json, "w") as fh:
            json.dump(rows, fh, indent=2)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
