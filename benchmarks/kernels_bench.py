"""Kernel benchmarks: jnp reference wall time on CPU (the operational
number in this container) + analytic TPU roofline estimate per kernel.

The Pallas kernels themselves run in interpret mode here (Python — not a
meaningful timing), so we time the jitted jnp reference, verify the kernel
against it, and report the arithmetic-intensity-derived TPU v5e time bound
(compute vs HBM, whichever dominates) as 'derived'.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timed
from repro.kernels import ops, ref
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS


def _tpu_bound_us(flops: float, bytes_moved: float) -> float:
    return max(flops / PEAK_FLOPS, bytes_moved / HBM_BW) * 1e6


def run(smoke: bool = False) -> list[dict]:
    """``smoke`` shrinks every shape ~8-16x: same code paths, seconds-scale
    (the CI entrypoint guard; timings are not comparable to the full run)."""
    rows = []
    key = jax.random.PRNGKey(0)

    # banded matvec: p=64k local shard, h=128
    p, h = (8192, 32) if smoke else (65_536, 128)
    nb = 2 * h + 1
    band = jax.random.normal(key, (nb, p), jnp.float32)
    v = jax.random.normal(key, (p,), jnp.float32)
    fn = jax.jit(ref.banded_matvec)
    fn(band, v).block_until_ready()
    _, us = timed(lambda: fn(band, v).block_until_ready(), repeat=5)
    flops = 2.0 * nb * p
    byts = (nb * p + 2 * p) * 4
    rows.append(row(f"kernel/banded_matvec/p{p // 1024}k_h{h}", us,
                    f"tpu_bound_us={_tpu_bound_us(flops, byts):.1f}"))
    out_k = ops.banded_matvec(band[:, :4096], v[:4096], interpret=True)
    ok = np.allclose(np.asarray(out_k),
                     np.asarray(ref.banded_matvec(band[:, :4096], v[:4096])),
                     atol=1e-3)
    rows.append(row("kernel/banded_matvec/validated", 0.0, ok))

    # cov update: n=256 epochs, p=16k shard, h=128
    n, p2, h2 = (64, 2048, 32) if smoke else (256, 16_384, 128)
    x = jax.random.normal(key, (n, p2), jnp.float32)
    fn2 = jax.jit(lambda xx: ref.cov_band_update(xx, h2))
    fn2(x).block_until_ready()
    _, us = timed(lambda: fn2(x).block_until_ready(), repeat=3)
    nb2 = 2 * h2 + 1
    flops = 2.0 * n * nb2 * p2
    byts = (n * p2 + nb2 * p2) * 4
    rows.append(row(f"kernel/cov_update/n{n}_p{p2 // 1024}k_h{h2}", us,
                    f"tpu_bound_us={_tpu_bound_us(flops, byts):.1f}"))

    # pca project: n=4096 rows, p=16k, q=32
    n3, p3, q3 = (512, 2048, 32) if smoke else (4096, 16_384, 32)
    x3 = jax.random.normal(key, (n3, p3), jnp.float32)
    w3 = jax.random.normal(key, (p3, q3), jnp.float32)
    fn3 = jax.jit(ref.pca_project)
    fn3(x3, w3).block_until_ready()
    _, us = timed(lambda: fn3(x3, w3).block_until_ready(), repeat=3)
    flops = 2.0 * n3 * p3 * q3
    byts = (n3 * p3 + p3 * q3 + n3 * q3) * 4
    rows.append(row(f"kernel/pca_project/n{n3}_p{p3 // 1024}k_q{q3}", us,
                    f"tpu_bound_us={_tpu_bound_us(flops, byts):.1f}"))
    return rows
