"""Elastic rescale planning: choose a mesh for whatever devices survive.

When hosts die mid-run, the launcher restarts with fewer (or, after repair,
more) chips.  The planner picks the new (data, model) mesh factorization
under the constraints that (a) the model axis still fits TP divisibility for
the arch, (b) the global batch stays divisible, and the restore path
(repro.train.checkpoint.restore with new shardings) re-slices every array.
"""

from __future__ import annotations

import dataclasses

__all__ = ["plan_mesh", "RescalePlan"]


@dataclasses.dataclass(frozen=True)
class RescalePlan:
    data: int
    model: int
    global_batch: int

    @property
    def n_devices(self) -> int:
        return self.data * self.model


def plan_mesh(n_devices: int, *, prefer_model: int, global_batch: int,
              max_model: int | None = None) -> RescalePlan:
    """Largest model axis <= prefer_model that divides n_devices, batch kept
    divisible by the data axis (batch is trimmed down if needed)."""
    max_model = max_model or prefer_model
    model = 1
    for m in range(min(prefer_model, max_model, n_devices), 0, -1):
        if n_devices % m == 0:
            model = m
            break
    data = n_devices // model
    gb = (global_batch // data) * data
    if gb == 0:
        gb = data
    return RescalePlan(data=data, model=model, global_batch=gb)
