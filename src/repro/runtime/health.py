"""Runtime health: heartbeats, straggler detection, failure policy.

At 1000+ nodes the failure model is: slow hosts (stragglers), dead hosts,
and flaky steps.  The monitor consumes per-step heartbeats and produces
actions:

* ``straggler``  — step time above ``straggler_factor`` x rolling median:
  log + (policy) drop the host from the next data allocation / trigger
  checkpoint-and-reshard.
* ``stall``      — no heartbeat for ``stall_timeout``: the launcher should
  restart from the latest checkpoint (the Trainer's atomic checkpoints make
  this always safe).

The monitor is deliberately dependency-free and synchronous so it can run
inside the train loop of every host and in the external watchdog.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

__all__ = ["HealthMonitor", "StragglerPolicy"]


@dataclasses.dataclass
class StragglerPolicy:
    straggler_factor: float = 2.0      # x median step time
    window: int = 32                   # rolling window (steps)
    stall_timeout: float = 300.0       # seconds without heartbeat
    min_samples: int = 8


class HealthMonitor:
    def __init__(self, policy: StragglerPolicy | None = None,
                 on_straggler: Callable[[dict], None] | None = None,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy or StragglerPolicy()
        self.on_straggler = on_straggler
        self.clock = clock
        self.durations: deque[float] = deque(maxlen=self.policy.window)
        self.last_beat: float | None = None
        self.events: list[dict] = []

    def heartbeat(self, *, step: int, duration: float) -> None:
        self.last_beat = self.clock()
        if len(self.durations) >= self.policy.min_samples:
            med = sorted(self.durations)[len(self.durations) // 2]
            if duration > self.policy.straggler_factor * med:
                ev = {"kind": "straggler", "step": step,
                      "duration": duration, "median": med}
                self.events.append(ev)
                if self.on_straggler:
                    self.on_straggler(ev)
        self.durations.append(duration)

    def stalled(self) -> bool:
        if self.last_beat is None:
            return False
        return (self.clock() - self.last_beat) > self.policy.stall_timeout

    def straggler_count(self) -> int:
        return sum(1 for e in self.events if e["kind"] == "straggler")
