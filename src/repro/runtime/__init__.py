"""Runtime: health monitoring, straggler policy, elastic rescale planning."""
