"""Online banded covariance with exponential forgetting (DESIGN.md Sec. 8.1).

The batch estimator (:mod:`repro.core.covariance`) keeps the plain sums of
Eq. (9)-(10); here the sufficient statistics decay by a forgetting factor
``beta`` each round so the estimate tracks a drifting distribution:

    t    <- beta * t    + n
    S_i  <- beta * S_i  + sum_tau x_i[tau]
    S_ij <- beta * S_ij + sum_tau x_i[tau] x_j[tau]     (band entries only)

``beta = 1`` recovers the batch statistics exactly (the equivalence test in
tests/test_streaming.py); ``beta < 1`` gives an effective window of
``n / (1 - beta)`` epochs.  The rank-n band update is the hot path and runs
through the :func:`repro.kernels.ops.cov_band_update` Pallas kernel; the decay
and mean terms are elementwise VPU work.

All functions are jit/vmap/scan-compatible: the state carries only arrays
(the band half-width is recovered from the band's leading dimension), so the
same code serves the single-network ``lax.scan`` driver and the batched
multi-network path (driver.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import covariance as cov
from repro.kernels import ops

__all__ = ["OnlineCovariance", "online_init", "online_update",
           "online_estimate", "online_total_variance", "stream_covariance"]


class OnlineCovariance(NamedTuple):
    """Decayed banded sufficient statistics (all-array pytree)."""

    t: jnp.ndarray          # () effective epoch count sum_r beta^(R-r) n_r
    s: jnp.ndarray          # (p,) decayed per-sensor sums
    band: jnp.ndarray       # (2h+1, p) decayed products, band[k,i] ~ S_{i,i+k-h}

    @property
    def halfwidth(self) -> int:
        return (self.band.shape[0] - 1) // 2

    @property
    def p(self) -> int:
        return self.s.shape[0]


def online_init(p: int, halfwidth: int, dtype=jnp.float32) -> OnlineCovariance:
    return OnlineCovariance(
        t=jnp.zeros((), dtype=dtype),
        s=jnp.zeros((p,), dtype=dtype),
        band=jnp.zeros((2 * halfwidth + 1, p), dtype=dtype),
    )


def online_update(state: OnlineCovariance, x: jnp.ndarray,
                  forgetting: float = 1.0,
                  mask: jnp.ndarray | None = None,
                  interpret: bool | None = None) -> OnlineCovariance:
    """Fold one round ``x`` of shape (n, p) into the decayed statistics.

    The decay is applied per *round* (not per row): every row of the round
    carries the same weight, matching the paper's epoch-synchronous model
    where a round is one aggregation epoch of the network.

    ``mask`` is an optional 0/1 validity array — (p,) sensor liveness (dead
    motes) or (n, p) measurement dropout.  Masked entries are absent: they
    join no outer product (the masked Pallas kernel) and no mean sum, so a
    dead sensor's statistics simply decay toward zero instead of being
    poisoned by phantom readings.  ``mask=None`` takes the unmasked kernel
    path and is bit-identical to the pre-fault-model behavior.
    """
    x = jnp.asarray(x, dtype=state.s.dtype)
    n = x.shape[0]
    h = state.halfwidth
    beta = jnp.asarray(forgetting, dtype=state.s.dtype)
    if mask is None:
        delta_band = ops.cov_band_update(x, h, interpret=interpret)
        delta_s = x.sum(axis=0)
    else:
        mask = jnp.asarray(mask, dtype=state.s.dtype)
        delta_band = ops.cov_band_update_masked(x, mask, h,
                                                interpret=interpret)
        xm = x * (mask[None, :] if mask.ndim == 1 else mask)
        delta_s = xm.sum(axis=0)
    return OnlineCovariance(
        t=beta * state.t + n,
        s=beta * state.s + delta_s,
        band=beta * state.band + delta_band.astype(state.band.dtype),
    )


def online_estimate(state: OnlineCovariance) -> jnp.ndarray:
    """Banded covariance diagonals c_band[k,i] = C[i, i+k-h] (Eq. 9, decayed).

    Normalizing the decayed sums by the decayed count makes ``beta`` cancel
    out of the weights: the estimate is the exponentially weighted sample
    covariance over the effective window.
    """
    return cov.banded_estimate(
        cov.BandedCovState(t=state.t, s=state.s, band=state.band,
                           halfwidth=state.halfwidth))


def online_total_variance(state: OnlineCovariance) -> jnp.ndarray:
    """trace(C) of the live estimate — the denominator of retained variance.

    The center row of the band holds the per-sensor variances, so the trace
    needs no reconstruction (one A op of a scalar in the WSN reading).
    """
    h = state.halfwidth
    t = jnp.maximum(state.t, 1.0)
    variances = state.band[h] / t - (state.s / t) ** 2
    return jnp.sum(variances)


def stream_covariance(state: OnlineCovariance, xs: jnp.ndarray,
                      forgetting: float = 1.0,
                      interpret: bool | None = None,
                      ) -> tuple[OnlineCovariance, jnp.ndarray]:
    """Jittable ``lax.scan`` driver: fold ``xs`` of shape (rounds, n, p).

    Returns the final state and the per-round total-variance trace (a cheap
    scalar probe of distribution drift, used by the Fig.-style streaming
    benchmark).
    """

    def step(carry, x_round):
        nxt = online_update(carry, x_round, forgetting=forgetting,
                            interpret=interpret)
        return nxt, online_total_variance(nxt)

    return jax.lax.scan(step, state, xs)
