"""Online banded covariance with exponential forgetting (DESIGN.md Sec. 8.1).

The batch estimator (:mod:`repro.core.covariance`) keeps the plain sums of
Eq. (9)-(10); here the sufficient statistics decay by a forgetting factor
``beta`` each round so the estimate tracks a drifting distribution:

    t    <- beta * t    + n
    S_i  <- beta * S_i  + sum_tau x_i[tau]
    S_ij <- beta * S_ij + sum_tau x_i[tau] x_j[tau]     (band entries only)

``beta = 1`` recovers the batch statistics exactly (the equivalence test in
tests/test_streaming.py); ``beta < 1`` gives an effective window of
``n / (1 - beta)`` epochs.  The rank-n band update is the hot path and runs
through the :func:`repro.kernels.ops.cov_band_update` Pallas kernel; the decay
and mean terms are elementwise VPU work.

All functions are jit/vmap/scan-compatible: the state carries only arrays
(the band half-width is recovered from the band's leading dimension), so the
same code serves the single-network ``lax.scan`` driver and the batched
multi-network path (driver.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import covariance as cov
from repro.kernels import ops

__all__ = ["OnlineCovariance", "online_init", "online_update",
           "online_update_chunk", "online_chunk_stats", "online_apply_chunk",
           "online_estimate", "online_total_variance", "stream_covariance"]


class OnlineCovariance(NamedTuple):
    """Decayed banded sufficient statistics (all-array pytree).

    ``t`` is the round-level effective epoch count; ``t_band`` holds the
    *pairwise* effective counts in the same diagonal layout as ``band``:
    ``t_band[k, i] = sum_r beta^(R-r) (rows where sensors i AND i+k-h were
    both present)``.  All entries coincide with ``t`` while every sensor is
    alive; under measurement dropout or node death they diverge, and
    normalizing by ``t`` would bias every statistic of a partially-present
    sensor toward zero (the masked-statistics bugfix — a product sum is
    only ever normalized by the rows that actually contributed to it, for
    ANY masking pattern, nested or not).  The center row is the per-sensor
    count, exposed as ``t_i``.
    """

    t: jnp.ndarray          # () effective epoch count sum_r beta^(R-r) n_r
    s: jnp.ndarray          # (p,) decayed per-sensor sums
    band: jnp.ndarray       # (2h+1, p) decayed products, band[k,i] ~ S_{i,i+k-h}
    t_band: jnp.ndarray     # (2h+1, p) pairwise effective counts

    @property
    def halfwidth(self) -> int:
        return (self.band.shape[0] - 1) // 2

    @property
    def p(self) -> int:
        return self.s.shape[0]

    @property
    def t_i(self) -> jnp.ndarray:
        """(p,) per-sensor effective counts (the pairwise count of a sensor
        with itself — the center diagonal of ``t_band``)."""
        return self.t_band[self.halfwidth]


def _band_valid(p: int, halfwidth: int) -> jnp.ndarray:
    """0/1 in-range indicator of the diagonal layout: entry (k, i) covers
    the pair (i, i + k - h), which exists iff the column index is in
    [0, p)."""
    h = halfwidth
    j = jnp.arange(p)[None, :]
    k = jnp.arange(2 * h + 1)[:, None]
    return ((j + k - h >= 0) & (j + k - h < p)).astype(jnp.float32)


def online_init(p: int, halfwidth: int, dtype=jnp.float32) -> OnlineCovariance:
    return OnlineCovariance(
        t=jnp.zeros((), dtype=dtype),
        s=jnp.zeros((p,), dtype=dtype),
        band=jnp.zeros((2 * halfwidth + 1, p), dtype=dtype),
        t_band=jnp.zeros((2 * halfwidth + 1, p), dtype=dtype),
    )


def online_update(state: OnlineCovariance, x: jnp.ndarray,
                  forgetting: float = 1.0,
                  mask: jnp.ndarray | None = None,
                  interpret: bool | None = None) -> OnlineCovariance:
    """Fold one round ``x`` of shape (n, p) into the decayed statistics.

    The decay is applied per *round* (not per row): every row of the round
    carries the same weight, matching the paper's epoch-synchronous model
    where a round is one aggregation epoch of the network.

    ``mask`` is an optional 0/1 validity array — (p,) sensor liveness (dead
    motes) or (n, p) measurement dropout.  Masked entries are absent: they
    join no outer product (the masked Pallas kernel), no mean sum, and no
    effective count — a product sum is only ever normalized by the rows
    that contributed to it, so a dead sensor's statistics simply decay
    toward zero instead of being poisoned by phantom readings or dragged
    toward zero by rows it never saw.  The pairwise counts are the band
    update of the mask with itself, ``sum_t m_i m_j``: for a (p,) liveness
    mask that is analytically ``n * m_i * m_j`` (elementwise, no kernel);
    only a genuine (n, p) dropout mask pays one extra kernel pass.
    ``mask=None`` takes the unmasked kernel path, updates the counts
    analytically, and is bit-identical to an all-ones mask (the regression
    pin in tests/test_streaming.py).
    """
    x = jnp.asarray(x, dtype=state.s.dtype)
    n = x.shape[0]
    h = state.halfwidth
    beta = jnp.asarray(forgetting, dtype=state.s.dtype)
    valid = _band_valid(state.p, h).astype(state.t_band.dtype)
    if mask is None:
        delta_band = ops.cov_band_update(x, h, interpret=interpret)
        delta_s = x.sum(axis=0)
        delta_tb = n * valid
    else:
        mask = jnp.asarray(mask, dtype=state.s.dtype)
        delta_band = ops.cov_band_update_masked(x, mask, h,
                                                interpret=interpret)
        if mask.ndim == 1:
            delta_s = (x * mask[None, :]).sum(axis=0)
            mj = jnp.stack([cov._shifted(mask[None, :], k - h)[0]
                            for k in range(2 * h + 1)], axis=0)
            delta_tb = (n * mask[None, :] * mj).astype(state.t_band.dtype)
        else:
            delta_s = (x * mask).sum(axis=0)
            delta_tb = ops.cov_band_update(mask, h, interpret=interpret) \
                .astype(state.t_band.dtype)
    return OnlineCovariance(
        t=beta * state.t + n,
        s=beta * state.s + delta_s,
        band=beta * state.band + delta_band.astype(state.band.dtype),
        t_band=beta * state.t_band + delta_tb,
    )


def online_update_chunk(state: OnlineCovariance, xs: jnp.ndarray,
                        forgetting: float = 1.0,
                        masks: jnp.ndarray | None = None,
                        round_valid: jnp.ndarray | None = None,
                        interpret: bool | None = None) -> OnlineCovariance:
    """Fold a (K, n, p) chunk of rounds in ONE fused kernel launch.

    Mathematically identical to K sequential :func:`online_update` calls:
    the per-round forgetting weights ``beta^(K-1-t)`` are fused into the
    chunk kernel's tile loads (each round's products enter the band already
    carrying the decay they would have accumulated by the end of the
    chunk), and the carried statistics decay once by ``beta^K``.  The decay
    powers come from a host-side table (no traced ``pow``), so at K=1 the
    fold is bit-identical to the per-round update — the probe_every=1
    differential guarantee.

    ``masks`` is (K, p) per-round liveness or (K, n, p) per-reading
    dropout.  ``round_valid`` (K,) flags which rounds of the chunk are
    real: a 0 round contributes nothing anywhere (weight 0) and does not
    advance the decay — this is how a tail chunk shorter than K rides the
    same traced program (the driver pads the stream and marks the pad
    invalid) and how the serving engine folds slots whose streams end
    mid-chunk.
    """
    xs = jnp.asarray(xs, state.s.dtype)
    h = state.halfwidth
    w, beta_eff, delta_s, delta_tb = online_chunk_stats(
        state, xs, forgetting=forgetting, masks=masks,
        round_valid=round_valid)
    if masks is None:
        delta_band = ops.cov_band_update_chunk(xs, w, h, interpret=interpret)
    else:
        masks = jnp.asarray(masks, state.s.dtype)
        delta_band = ops.cov_band_update_chunk(xs, w, h, mask=masks,
                                               interpret=interpret)
        if delta_tb is None:
            # (K, n, p) per-reading dropout: the pairwise counts are the
            # band update of the mask with itself — one extra kernel pass
            delta_tb = ops.cov_band_update_chunk(masks, w, h,
                                                 interpret=interpret) \
                .astype(state.t_band.dtype)
    return online_apply_chunk(state, delta_band, w, beta_eff,
                              delta_s, delta_tb, xs.shape[1])


def online_chunk_stats(state: OnlineCovariance, xs: jnp.ndarray,
                       forgetting: float = 1.0,
                       masks: jnp.ndarray | None = None,
                       round_valid: jnp.ndarray | None = None,
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                  jnp.ndarray | None]:
    """The analytic (kernel-free) half of :func:`online_update_chunk`:
    per-round forgetting weights, the chunk's effective decay, and the
    mean-sum / pairwise-count deltas.

    Split out so the fused driver path
    (:func:`repro.streaming.driver.chunk_stream_step`) can form the live
    mean estimate ``(beta_eff s + delta_s) / (beta_eff t_i + delta_tb[h])``
    BEFORE launching the mega-kernel that needs it as a stage operand —
    the band delta is the only part that needs a kernel.

    Returns ``(w, beta_eff, delta_s, delta_tb)``; ``delta_tb`` is None for
    a (K, n, p) dropout mask (its pairwise counts need a kernel pass of
    their own — :func:`online_update_chunk` pays it; the fused driver path
    routes such chunks to the split path instead).
    """
    xs = jnp.asarray(xs, state.s.dtype)
    K, n, p = xs.shape
    h = state.halfwidth
    beta = float(forgetting)
    # beta^j for j in [0, K]: host-computed constants, gathered on device —
    # pow(traced, traced) would lower to exp/log and break the K=1
    # bit-identity (pow_table[1] is beta itself, exactly)
    pow_table = jnp.asarray([beta ** j for j in range(K + 1)],
                            dtype=state.s.dtype)
    if round_valid is None:
        w = pow_table[jnp.arange(K - 1, -1, -1)]
        beta_eff = pow_table[K]
    else:
        rv = jnp.asarray(round_valid, state.s.dtype)
        # each valid round decays once per valid round AFTER it in the chunk
        after = (jnp.cumsum(rv[::-1])[::-1] - rv).astype(jnp.int32)
        w = pow_table[after] * rv
        beta_eff = pow_table[jnp.sum(rv).astype(jnp.int32)]
    valid = _band_valid(p, h).astype(state.t_band.dtype)
    if masks is None:
        delta_s = jnp.einsum("t,tp->p", w, xs.sum(axis=1))
        delta_tb = (jnp.sum(w) * n) * valid
    else:
        masks = jnp.asarray(masks, state.s.dtype)
        if masks.ndim == 2:
            delta_s = jnp.einsum("t,tp->p", w,
                                 (xs * masks[:, None, :]).sum(axis=1))
            # pairwise counts stay analytic: n * m_i * m_j per round,
            # chunk-weighted (no extra kernel pass for a liveness mask)
            mj = jnp.stack([cov._shifted(masks, k - h)
                            for k in range(2 * h + 1)], axis=0)  # (nb, K, p)
            delta_tb = jnp.einsum("t,tp,ktp->kp", w * n, masks, mj) \
                .astype(state.t_band.dtype)
        else:
            delta_s = jnp.einsum("t,tp->p", w, (xs * masks).sum(axis=1))
            delta_tb = None
    return w, beta_eff, delta_s, delta_tb


def online_apply_chunk(state: OnlineCovariance, delta_band: jnp.ndarray,
                       w: jnp.ndarray, beta_eff: jnp.ndarray,
                       delta_s: jnp.ndarray, delta_tb: jnp.ndarray,
                       n: int) -> OnlineCovariance:
    """Apply a chunk's deltas (:func:`online_chunk_stats` + a band kernel)
    to the carried statistics — the other half of
    :func:`online_update_chunk`, shared verbatim by the fused driver path
    so both paths produce the same bits."""
    return OnlineCovariance(
        t=beta_eff * state.t + jnp.sum(w) * n,
        s=beta_eff * state.s + delta_s,
        band=beta_eff * state.band + delta_band.astype(state.band.dtype),
        t_band=beta_eff * state.t_band + delta_tb,
    )


def online_estimate(state: OnlineCovariance) -> jnp.ndarray:
    """Banded covariance diagonals c_band[k,i] = C[i, i+k-h] (Eq. 9, decayed).

    Normalizing the decayed sums by the decayed counts makes ``beta`` cancel
    out of the weights: the estimate is the exponentially weighted sample
    covariance over the effective window.

    Every sum is normalized by its OWN effective count: means are
    ``s_i / t_i`` and the band entry (i, j) by the pairwise count
    ``t_band[k, i]`` — exact for ANY masking pattern (nested death waves,
    independent per-reading dropout, anything in between) and equal to the
    old scalar ``t`` on the all-alive path.  The pre-fix code divided
    everything by the round count ``t``, biasing every partially-present
    sensor's mean, variance, and cross-covariances toward zero.
    """
    h = state.halfwidth
    ti = jnp.maximum(state.t_i, 1.0)
    mean = state.s / ti
    p = state.s.shape[0]
    t_pair = jnp.maximum(state.t_band, 1.0)
    rows = []
    for k in range(2 * h + 1):
        mean_j = cov._shifted(mean[None, :], k - h)[0]
        rows.append(state.band[k] / t_pair[k] - mean * mean_j)
    band = jnp.stack(rows, axis=0)
    # zero out-of-range entries explicitly (same convention as
    # core.covariance.banded_estimate)
    return jnp.where(_band_valid(p, h) > 0, band, 0.0)


def online_total_variance(state: OnlineCovariance) -> jnp.ndarray:
    """trace(C) of the live estimate — the denominator of retained variance.

    The center row of the band holds the per-sensor variances, so the trace
    needs no reconstruction (one A op of a scalar in the WSN reading).
    Per-sensor normalization (see :func:`online_estimate`).
    """
    h = state.halfwidth
    ti = jnp.maximum(state.t_i, 1.0)
    variances = state.band[h] / ti - (state.s / ti) ** 2
    return jnp.sum(variances)


def stream_covariance(state: OnlineCovariance, xs: jnp.ndarray,
                      forgetting: float = 1.0,
                      interpret: bool | None = None,
                      ) -> tuple[OnlineCovariance, jnp.ndarray]:
    """Jittable ``lax.scan`` driver: fold ``xs`` of shape (rounds, n, p).

    Returns the final state and the per-round total-variance trace (a cheap
    scalar probe of distribution drift, used by the Fig.-style streaming
    benchmark).
    """

    def step(carry, x_round):
        nxt = online_update(carry, x_round, forgetting=forgetting,
                            interpret=interpret)
        return nxt, online_total_variance(nxt)

    return jax.lax.scan(step, state, xs)
