"""Hierarchical two-level streaming decomposition (DESIGN.md Sec. 13).

The paper distributes power iteration *inside* one sensor network; at the
million-sensor scale of ``configs/wsn_1m.py`` the fleet itself needs a second
level.  Following Wiesel & Hero's decomposable PCA (the global basis can be
computed per-region and merged) and Elgamal & Hefeeda's observation that
synchronization rounds — not FLOPs — bound distributed PCA at scale
(PAPERS.md), the decomposition is:

* **Level 1 (intra-region, no cross-host traffic):** every region streams its
  own online banded covariance and drift-triggered orthogonal-iteration
  refreshes through the existing chunked drivers
  (:func:`repro.streaming.driver.batched_stream_run` — one fused cov-update
  kernel launch per chunk, PR 5; with compression/detection stages
  configured the launch is the Sec.-14 mega-kernel, and
  ``StreamConfig.fused`` / ``StreamConfig.precision`` thread through each
  region's chunk body unchanged — the hierarchy adds no split/fused logic
  of its own).  Under the banded/local-covariance
  hypothesis a region boundary cuts only the ±h cross terms, so per-region
  bases span the global top-q subspace up to the boundary coupling.
* **Level 2 (cross-host, ONE collective per refresh):** the fleet basis is
  the block-diagonal embedding of per-region components, globally *selected*
  by subspace energy.  Each region contributes its (q_local + 1)-element
  record — the live Rayleigh energies ``diag(W^T C W)`` plus its trace
  partial — via ``all_gather``/``psum`` over the ``region`` mesh axis
  (:func:`repro.distributed.sharding.region_axis_spec`); the top
  ``q_fleet`` components by energy form the fleet basis, and the fleet
  retained fraction is ``sum(selected energies) / psum(trace partials)``.

The merge's packet bill is booked against the Table-1 accounting exactly
like intra-network rounds: one region-level aggregation epoch of a
(q_local + 1)-record per merge (:func:`repro.core.costs.merge_round_cost`),
lossy-scaled by the same ARQ expectation as every other packet.  One merge
is booked per decision boundary at which ANY region refreshed its basis
(a fleet whose regions never refresh pays for exactly one merge — the final
one that produced the returned basis).

With ``regions=1`` the hierarchy is the flat driver bit-exactly (one region
IS the whole fleet; the merge selects the identity) — the differential
anchor in tests/test_hierarchy.py.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.covariance import banded_matmul_ref
from repro.streaming.driver import (RoundMetrics, StreamConfig, StreamState,
                                    _metrics_template, batched_stream_init,
                                    batched_stream_run)
from repro.streaming.online_cov import online_estimate, online_total_variance

__all__ = ["FleetBasis", "FleetMerge", "region_energies", "merge_fleet",
           "fleet_basis_dense", "hierarchical_stream_init",
           "hierarchical_stream_run"]


class FleetBasis(NamedTuple):
    """The fleet-level basis in compact (region, column) form.

    Component ``j`` of the fleet basis is column ``col[j]`` of region
    ``region[j]``'s local basis, embedded at that region's sensor offset —
    the block-diagonal structure of the decomposable merge means the dense
    (p_fleet, q_fleet) form (:func:`fleet_basis_dense`) is orthonormal by
    construction (disjoint supports, orthonormal within each region) and
    never needs to exist on any single host.
    """

    region: jnp.ndarray          # (q_fleet,) int32 owning region per component
    col: jnp.ndarray             # (q_fleet,) int32 column within that region
    lam: jnp.ndarray             # (q_fleet,) subspace energies, descending
    rho: jnp.ndarray             # () fleet retained fraction of the selection
    lam_table: jnp.ndarray       # (regions, q_local) gathered energy records
    total_variance: jnp.ndarray  # () psum of per-region trace partials


class FleetMerge(NamedTuple):
    """Level-2 output of a hierarchical run: basis + merge accounting."""

    basis: FleetBasis
    merge_epochs: jnp.ndarray    # () int32 cross-host merges performed
    merge_packets: jnp.ndarray   # () region-head Table-1 bill, lossy-scaled


def region_energies(state: StreamState) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The (q_local,) live subspace energies ``diag(W^T C W)`` of a region's
    basis against its online covariance estimate, plus the region's trace
    partial — the exact record one region head sends up the region tree
    (the per-component split of the drift probe's ``(q+1)``-record).
    """
    band = online_estimate(state.cov)
    W = state.sched.W
    lam = jnp.sum(W * banded_matmul_ref(band, W), axis=0)
    return lam, online_total_variance(state.cov)


def merge_fleet(lam_table: jnp.ndarray, total_variance: jnp.ndarray,
                q_fleet: int) -> FleetBasis:
    """Select the global top-``q_fleet`` components by subspace energy.

    ``lam_table`` is the (regions, q_local) gathered energy records;
    ``total_variance`` the psum of trace partials.  Pure jnp and replicated:
    after the all_gather every shard computes the identical selection, so
    the F flood one level down is a single scalar (the energy threshold).
    """
    n_regions, q_local = lam_table.shape
    if q_fleet > n_regions * q_local:
        raise ValueError(
            f"q_fleet={q_fleet} > regions*q_local={n_regions * q_local}")
    flat = lam_table.reshape(-1)
    order = jnp.argsort(-flat)[:q_fleet]
    lam = flat[order]
    return FleetBasis(
        region=(order // q_local).astype(jnp.int32),
        col=(order % q_local).astype(jnp.int32),
        lam=lam,
        rho=jnp.sum(lam) / jnp.maximum(total_variance, 1e-30),
        lam_table=lam_table,
        total_variance=total_variance,
    )


def fleet_basis_dense(basis: FleetBasis,
                      W_regions: jnp.ndarray) -> jnp.ndarray:
    """Materialize the (p_fleet, q_fleet) block-embedded fleet basis.

    ``W_regions`` is the (regions, p_region, q_local) stack of local bases.
    Test/small-fleet utility: at p=1M the compact form is the deployment
    artifact and this dense embed never leaves the differential suite.
    """
    n_regions, p_region, _ = W_regions.shape
    q_fleet = basis.region.shape[0]
    cols = W_regions[basis.region, :, basis.col]          # (q_fleet, p_region)
    dense = jnp.zeros((q_fleet, n_regions * p_region), cols.dtype)
    idx = (basis.region * p_region)[:, None] + jnp.arange(p_region)[None, :]
    dense = dense.at[jnp.arange(q_fleet)[:, None], idx].set(cols)
    return dense.T


def hierarchical_stream_init(cfg: StreamConfig, key: jax.Array,
                             n_regions: int,
                             dtype=jnp.float32) -> StreamState:
    """Per-region states stacked on a leading regions axis (``cfg.p`` is the
    per-REGION sensor count; the fleet has ``n_regions * cfg.p`` sensors)."""
    return batched_stream_init(cfg, key, n_regions, dtype=dtype)


@functools.partial(jax.jit, static_argnums=(0, 1),
                   static_argnames=("q_fleet", "c_regions", "axis", "chunk",
                                    "probe_every"))
def hierarchical_stream_run(cfg: StreamConfig, mesh, states: StreamState,
                            xs: jnp.ndarray,
                            masks: jnp.ndarray | None = None, *,
                            q_fleet: int | None = None,
                            c_regions: int | None = None,
                            axis: str = "region",
                            chunk: int | None = None,
                            probe_every: int | None = None,
                            ) -> tuple[StreamState, RoundMetrics, FleetMerge]:
    """Two-level run: per-region streaming + one cross-host fleet merge.

    ``xs`` is (regions, rounds, n, p_region); ``masks`` the optional
    (regions, rounds, p_region) liveness schedule.  The regions axis is
    sharded over mesh axis ``axis`` (:func:`region_axis_spec`); each shard
    streams its local regions through :func:`batched_stream_run` (with the
    PR 5 chunk/probe_every knobs threaded through) with NO cross-shard
    traffic, then the merge runs as the run's only collectives: one tiled
    ``all_gather`` of the (q_local,) energy records and ONE multi-operand
    ``psum`` carrying both the trace partials and the refresh-boundary
    flags (contract ``hierarchy.refresh`` pins this budget at the jaxpr
    level — see the registration at the bottom of this module).

    Returns ``(final_states, metrics, fleet)`` where states/metrics are the
    per-region leaves of the flat driver (regions-leading) and ``fleet``
    carries the merged basis plus the merge's Table-1 bill: one
    (q_local + 1)-record region-tree epoch per decision boundary at which
    any region refreshed (min. one — the final merge), at fan-out
    ``c_regions`` (default ``cfg.c_max``), ARQ-scaled like every
    intra-network packet.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from repro.distributed.sharding import region_axis_spec

    n_regions = xs.shape[0]
    qf = cfg.q if q_fleet is None else q_fleet
    cr = cfg.c_max if c_regions is None else c_regions
    if qf > n_regions * cfg.q:
        raise ValueError(f"q_fleet={qf} > regions*q_local="
                         f"{n_regions * cfg.q}")
    spec = region_axis_spec(mesh, axis)
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    if n_regions % axis_size != 0:
        raise ValueError(f"{n_regions} regions not divisible by axis "
                         f"{axis!r} of size {axis_size}")
    merge_price = costs.lossy_merge_cost(
        cfg.q, cr, cfg.link_loss, cfg.max_retries).communication

    def local_run(states_l, xs_l, masks_l=None):
        fin, metrics = batched_stream_run(cfg, states_l, xs_l, masks_l,
                                          chunk=chunk,
                                          probe_every=probe_every)
        # level-2 records: per-region energies + trace partials
        lam_l, den_l = jax.vmap(region_energies)(fin)
        lam_table = jax.lax.all_gather(lam_l, axis, tiled=True)
        # ONE multi-operand psum (a single collective on the wire) carries
        # both the trace partials and the per-boundary refresh flags; the
        # flags' fleet-wide sum books one merge per decision boundary at
        # which ANY region refreshed, plus the final merge when none fired
        total_var, fired = jax.lax.psum(
            (jnp.sum(den_l),
             jnp.sum(metrics.did_refresh.astype(jnp.float32), axis=0)),
            axis)
        basis = merge_fleet(lam_table, total_var, qf)
        merges = jnp.maximum(jnp.sum(fired > 0), 1).astype(jnp.int32)
        fleet = FleetMerge(basis=basis, merge_epochs=merges,
                           merge_packets=merges * jnp.asarray(merge_price,
                                                              jnp.float32))
        return fin, metrics, fleet

    state_specs = jax.tree.map(lambda _: spec, states)
    metric_specs = jax.tree.map(lambda _: spec, _metrics_template(cfg))
    rep = PartitionSpec()
    fleet_specs = FleetMerge(
        basis=FleetBasis(region=rep, col=rep, lam=rep, rho=rep,
                         lam_table=rep, total_variance=rep),
        merge_epochs=rep, merge_packets=rep)
    out_specs = (state_specs, metric_specs, fleet_specs)
    if masks is None:
        fm = shard_map(local_run, mesh=mesh, in_specs=(state_specs, spec),
                       out_specs=out_specs, check_rep=False)
        return fm(states, xs)
    fm = shard_map(local_run, mesh=mesh,
                   in_specs=(state_specs, spec, spec),
                   out_specs=out_specs, check_rep=False)
    return fm(states, xs, masks)


# ===========================================================================
# Program contract (repro.analysis; DESIGN.md Sec. 15): the PR 6 headline —
# "ONE collective merge per refresh" — pinned at the jaxpr level.
# ===========================================================================
from repro.analysis import contracts as _contracts  # noqa: E402
from repro.analysis import jaxpr_lint as _jl        # noqa: E402
from repro.analysis import resources as _res        # noqa: E402

_CONTRACT_Q = 2                  # q_local of the traced contract config


def _trace_hierarchy_refresh():
    """Trace the two-level run on a 1-device ``region`` mesh (sub-jaxpr
    structure is mesh-size independent; the collectives appear either way)."""
    from repro.launch.mesh import make_fleet_mesh

    cfg = StreamConfig(p=8, q=_CONTRACT_Q, halfwidth=1, warmup_rounds=2)
    mesh = make_fleet_mesh(region=1, data=1)
    states = hierarchical_stream_init(cfg, jax.random.PRNGKey(0), 2)
    xs = jnp.zeros((2, 4, 4, cfg.p), jnp.float32)
    jx = jax.make_jaxpr(
        lambda s, x: hierarchical_stream_run(cfg, mesh, s, x,
                                             chunk=2))(states, xs)
    return {"regions=2": jx}


_contracts.register(_contracts.Contract(
    id="hierarchy.refresh",
    where="repro.streaming.hierarchy.hierarchical_stream_run",
    claim="exactly one all_gather and one (multi-operand) psum on the "
          "'region' axis per run, none inside loop bodies, no other "
          "cross-host collectives anywhere (PR 6)",
    trace=_trace_hierarchy_refresh,
    rules=(_jl.CollectiveBudget(axis="region",
                                budgets=(("all_gather", 1), ("psum", 1))),
           _jl.ForbidInLoops(),
           _jl.NoF64(),
           _res.VmemBudget(),
           # booked == traced: the merge collectives must put exactly the
           # (q+1)-element record merge_round_cost bills on the wire —
           # q gathered energies + the psum'd trace partial (the fired
           # flags ride the same psum as declared bookkeeping)
           _res.WireBytesBudget(
               axis="region",
               record_elems=costs.merge_record_elems(_CONTRACT_Q))),
))
