"""Stream drivers: single network, vmap-batched fleet, shard_map-sharded fleet.

Layering (DESIGN.md Sec. 8.3):

* :func:`stream_step` — one round for ONE network: fold the round's
  measurements into the online covariance (Pallas cov-update kernel), then
  one scheduler decision (drift probe + possible basis refresh).
* :func:`stream_run` — ``lax.scan`` of the step over a (rounds, n, p) stream;
  this is the jittable single-network driver.
* :func:`chunk_stream_step` / :func:`chunked_stream_run` — the
  chunk-granular forms (DESIGN.md Sec. 12): K rounds per dispatch through
  the fused multi-round cov-update kernel, one scheduler decision per
  ``probe_every`` rounds, per-epoch cost booking kept exact.
  ``probe_every=1`` is bit-identical to the per-round driver.
* :func:`batched_stream_run` — ``jax.vmap`` of the run over a leading
  networks axis: hundreds of independent sensor networks stream concurrently
  in one program — the serving shape.  The scheduler's ``lax.cond`` lowers to
  a select, so each round costs one (masked) refresh for the whole batch
  while the *booked* WSN cost stays per-network exact.
* :func:`sharded_stream_run` — the batched run inside ``shard_map`` with the
  networks axis split over the mesh data axis
  (:func:`repro.distributed.sharding.network_axis_spec`); per-network state
  never crosses devices, so the fleet scales linearly with chips.
* :func:`repro.streaming.hierarchy.hierarchical_stream_run` — the two-level
  fleet form (DESIGN.md Sec. 13): the batched run per *region* shard over a
  cross-host ``region`` mesh axis, topped by one ``all_gather``/``psum``
  merge of region bases per refresh — the million-sensor shape where every
  band fold stays a local problem and only (q+1)-element energy records
  ever cross hosts.

With ``StreamConfig.compression`` set, every round additionally runs the
ε-supervised compression stage (:mod:`repro.streaming.compressor`) against
the slot's current basis: the fused Pallas kernel emits the scores the
sink decodes, the ε-true sink view, and the notification mask, and the
Sec.-2.4.1 packet bill (scores A + feedback F + flagged raws, lossy-scaled)
is booked into the same per-network communication account as the
scheduler's Table-1 costs.

With ``StreamConfig.detection`` set, every round also runs the T²/SPE
event-detection stage (:mod:`repro.streaming.detector`) against the same
live basis and the scheduler's per-component variance estimates λ̂: the
fused Pallas monitoring pass emits the two per-epoch statistics, the
detector thresholds (recalibrated over a healthy window after every
refresh) turn them into alarms, and the Sec.-2.4.3 bill — one extra
scalar on the per-round drift record plus one F alarm flood per alarmed
epoch, lossy-scaled — is booked into the same account.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.faults import expected_transmissions
from repro.kernels import ops
from repro.streaming.compressor import (CompressionConfig, RoundCompression,
                                        compress_round, compression_books,
                                        compression_round_cost,
                                        epoch_packet_split)
from repro.streaming.detector import (DetectionConfig, DetectorState,
                                      RoundDetection, detect_apply,
                                      detect_round, detection_packet_split,
                                      detector_init, inv_lambda,
                                      row_liveness)
from repro.streaming.online_cov import (OnlineCovariance, online_apply_chunk,
                                        online_chunk_stats, online_init,
                                        online_update, online_update_chunk)
from repro.streaming.scheduler import RecomputeScheduler, SchedulerState

__all__ = ["StreamConfig", "StreamState", "RoundMetrics", "stream_init",
           "stream_step", "chunk_stream_step", "engine_chunk_step_fn",
           "stream_run", "chunked_stream_run", "batched_stream_run",
           "sharded_stream_run"]


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static configuration shared by every network of a fleet."""

    p: int                          # sensors per network
    q: int                          # principal components maintained
    halfwidth: int                  # covariance band half-width
    forgetting: float = 1.0         # per-round exponential forgetting factor
    drift_threshold: float = 0.02   # refresh trigger (retained-variance drop)
    refresh_iters: int = 8          # orthogonal-iteration length per refresh
    warmup_rounds: int = 10         # rounds before the first refresh
    n_max: int = 8                  # |N_i*| for the cost model
    c_max: int = 4                  # C_i* for the cost model
    link_loss: float = 0.0          # per-hop packet loss (cost booking)
    max_retries: int = 3            # ARQ retransmission budget per packet
    interpret: bool | None = None   # Pallas interpret override (None = auto)
    compression: CompressionConfig | None = None  # ε-supervised stage
    detection: DetectionConfig | None = None      # T²/SPE monitoring stage
    fused: bool = True              # one-pass mega-kernel on the chunk path
    precision: str = "fp32"         # fused tile-load dtype: "fp32" | "bf16"

    def __post_init__(self):
        if self.precision not in ("fp32", "bf16"):
            raise ValueError(
                f"precision must be 'fp32' or 'bf16', got {self.precision!r}")

    def scheduler(self) -> RecomputeScheduler:
        return RecomputeScheduler(
            q=self.q, drift_threshold=self.drift_threshold,
            refresh_iters=self.refresh_iters,
            warmup_rounds=self.warmup_rounds,
            n_max=self.n_max, c_max=self.c_max,
            link_loss=self.link_loss, max_retries=self.max_retries)


class StreamState(NamedTuple):
    cov: OnlineCovariance
    sched: SchedulerState
    rounds: jnp.ndarray             # () int32 rounds streamed so far
    alive: jnp.ndarray              # (p,) 0/1 liveness seen last round
    det: DetectorState | None = None  # T²/SPE thresholds + healthy window


class RoundMetrics(NamedTuple):
    """Per-round observability record (stacked by scan over time).

    ``compression``/``detection`` are ``None`` when the config carries no
    such stage (None is an empty pytree node, so every variant
    scan/vmap/shards cleanly — the pytree structure is fixed per
    StreamConfig).
    """

    rho: jnp.ndarray                # retained fraction before any refresh
    did_refresh: jnp.ndarray        # bool — scheduler fired this round
    refreshes: jnp.ndarray          # cumulative refresh count
    comm_packets: jnp.ndarray       # cumulative communication (packets)
    compression: RoundCompression | None = None  # ε-supervised output
    detection: RoundDetection | None = None      # T²/SPE monitoring output


def _metrics_template(cfg: "StreamConfig") -> RoundMetrics:
    """A structure-only RoundMetrics matching cfg (for shard_map out_specs)."""
    comp = None
    if cfg.compression is not None:
        emit = cfg.compression.emit_reconstruction
        comp = RoundCompression(
            z=0, x_sink=0 if emit else None, flagged=0 if emit else None,
            max_err=0, extra_packets=0, score_packets=0,
            feedback_packets=0, bits_on_air=0)
    det = None
    if cfg.detection is not None:
        emit = cfg.detection.emit_statistics
        det = RoundDetection(
            t2=0 if emit else None, spe=0 if emit else None,
            events=0 if emit else None, alarms=0,
            t2_threshold=0, spe_threshold=0, calibrating=0)
    return RoundMetrics(rho=0, did_refresh=0, refreshes=0, comm_packets=0,
                        compression=comp, detection=det)


def stream_init(cfg: StreamConfig, key: jax.Array,
                dtype=jnp.float32) -> StreamState:
    return StreamState(
        cov=online_init(cfg.p, cfg.halfwidth, dtype=dtype),
        sched=cfg.scheduler().init(cfg.p, key, dtype=dtype),
        rounds=jnp.zeros((), jnp.int32),
        alive=jnp.ones((cfg.p,), dtype=dtype),
        det=detector_init(dtype) if cfg.detection is not None else None,
    )


def stream_step(cfg: StreamConfig, state: StreamState, x_round: jnp.ndarray,
                mask: jnp.ndarray | None = None,
                ) -> tuple[StreamState, RoundMetrics]:
    """One round for one network: covariance fold + scheduling decision.

    ``mask`` is the round's (p,) sensor-liveness vector (1 = alive).  Dead
    sensors contribute no outer products and no mean sums (the masked Pallas
    path in :func:`repro.streaming.online_cov.online_update`), and a change
    of liveness between consecutive rounds — a death or a revival, i.e.
    topology churn — is reported to the scheduler as an unconditional drift
    trigger.  ``mask=None`` is the fault-free path, bit-identical to the
    pre-fault behavior.
    """
    if mask is None:
        cov = online_update(state.cov, x_round, forgetting=cfg.forgetting,
                            interpret=cfg.interpret)
        churn = jnp.zeros((), bool)
        alive = state.alive
    else:
        mask = jnp.asarray(mask, dtype=state.alive.dtype)
        cov = online_update(state.cov, x_round, forgetting=cfg.forgetting,
                            mask=mask, interpret=cfg.interpret)
        churn = jnp.any(mask != state.alive)
        alive = mask
    sched, rho, fired = cfg.scheduler().step(state.sched, cov, state.rounds,
                                             churn=churn)
    # live per-sensor mean estimate of the online covariance — normalized
    # by each sensor's OWN effective count (the masked-statistics bugfix:
    # dividing by the round count biased dropout-ridden sensors to zero)
    mean_est = cov.s / jnp.maximum(cov.t_i, 1.0)
    factor = expected_transmissions(cfg.link_loss, cfg.max_retries)
    compression = None
    if cfg.compression is not None:
        # compress this round against the slot's CURRENT basis (post-step W)
        # and the live mean estimate — the same quantities the deployment
        # would have flooded to the nodes
        compression = compress_round(
            sched.W, mean_est, x_round, cfg.compression, cfg.c_max,
            mask=mask, interpret=cfg.interpret)
        # book the Sec.-2.4.1 epoch: scores A + feedback F (with the scale
        # flood at the quantized budget), plus the flagged raws — every
        # packet paying the same expected ARQ retransmissions as the
        # scheduler's bill
        flagfree = compression_round_cost(cfg.q, cfg.c_max, cfg.compression)
        bill = (flagfree + compression.extra_packets) * factor
        sched = sched._replace(comm_packets=sched.comm_packets + bill)
    det_state, detection = state.det, None
    if cfg.detection is not None:
        # monitor this round against the same post-step basis and the
        # scheduler's λ̂; a refresh this round opens a fresh healthy window
        det_state, detection = detect_round(
            sched.W, mean_est, sched.lam, x_round, state.det, cfg.detection,
            refreshed=fired, mask=mask, interpret=cfg.interpret)
        # book the Sec.-2.4.3 epoch: one extra scalar on the per-round
        # (q+1) drift record plus one F alarm flood per alarmed epoch,
        # lossy-scaled like every other packet of the round
        flagfree, per_alarm = detection_packet_split(cfg.q, cfg.c_max)
        bill = (flagfree + detection.alarms * per_alarm) * factor
        sched = sched._replace(comm_packets=sched.comm_packets + bill)
    new = StreamState(cov=cov, sched=sched, rounds=state.rounds + 1,
                      alive=alive, det=det_state)
    metrics = RoundMetrics(rho=rho, did_refresh=fired,
                           refreshes=sched.refreshes,
                           comm_packets=sched.comm_packets,
                           compression=compression,
                           detection=detection)
    return new, metrics


def chunk_stream_step(cfg: StreamConfig, state: StreamState,
                      x_chunk: jnp.ndarray,
                      masks: jnp.ndarray | None = None,
                      round_valid: jnp.ndarray | None = None,
                      ) -> tuple[StreamState, RoundMetrics]:
    """K rounds for one network in ONE dispatch: fused covariance fold of
    the whole (K, n, p) chunk (:func:`online_update_chunk` — one kernel
    launch, one HBM band writeback), then ONE scheduler decision at the
    chunk boundary, then one compression/detection pass over the chunk's
    (K·n, p) epoch view.

    The Table-1 bill stays per-EPOCH exact: the chunk books K per-round
    drift records (and K flag-free compression/monitoring epochs) even
    though only one decision is evaluated — the WSN would still aggregate
    every epoch; only the eigenvector-phase *decisions* are amortized.

    ``masks`` is the chunk's (K, p) per-round liveness schedule; any
    liveness change across the chunk (vs. the state's last-seen liveness)
    raises the scheduler's churn trigger at the boundary.  ``round_valid``
    (K,) flags which rounds are real — 0 rounds (stream tail padding, or
    an engine slot whose stream ends mid-chunk) contribute nothing to the
    fold, the stages, the books, or the round counter.

    At K=1 this is bit-identical to :func:`stream_step` (the chunk kernel
    with weight 1 is the per-round kernel, and every booking term reduces
    to the per-round expression exactly) — the differential guarantee
    behind ``chunked_stream_run(..., probe_every=1)``.

    With a compression and/or detection stage configured, the chunk body
    takes the FUSED path by default (``cfg.fused``; DESIGN.md Sec. 14):
    one mega-kernel (:func:`repro.kernels.ops.fused_stream_update`) loads
    each chunk tile into VMEM once and emits the band delta AND the stage
    outputs — 1 ``pallas_call`` per chunk body instead of 3.  The stages
    are speculated against the pre-decision basis (bit-identical to the
    post-decision basis whenever the scheduler does not fire — the
    refresh is a select); on the refresh rounds a pure-jnp twin
    (:func:`repro.kernels.ops.fused_stream_stages_blocked`, bitwise equal
    to the kernel's stage arithmetic) recomputes them against the rotated
    basis under ``lax.cond``.  At fp32 the fused path is bit-identical to
    the split path; ``cfg.precision="bf16"`` halves the kernel's tile
    traffic (fp32 accumulation) at tolerance-level divergence — note the
    ε flag decision then happens in tile precision, so the fp32-measured
    sink error can overshoot ε by the bf16 rounding (~1e-3 relative);
    deployments that need the bound exact in fp32 keep the default
    precision.  Quantized
    compression (``score_bits > 0`` — the quantizer needs the whole
    round's scores between projection and reconstruction) and (K, n, p)
    per-reading dropout masks (their pairwise counts need a second kernel
    pass anyway) keep the split path.
    """
    K, n, p = x_chunk.shape
    if masks is not None:
        masks = jnp.asarray(masks, state.alive.dtype)
    has_stage = cfg.compression is not None or cfg.detection is not None
    use_fused = (cfg.fused and has_stage
                 and (cfg.compression is None
                      or cfg.compression.score_bits == 0)
                 and (masks is None or masks.ndim == 2))
    if round_valid is None:
        rv = None
        live = K                            # static: folds into constants
        live_i = K
    else:
        rv = jnp.asarray(round_valid, jnp.float32)
        live = jnp.sum(rv)
        live_i = live.astype(jnp.int32)
    if masks is None:
        churn = jnp.zeros((), bool)
        alive = state.alive
    else:
        churn = jnp.zeros((), bool)
        alive = state.alive
        for t in range(K):                  # static unroll, K is small
            changed = jnp.any(masks[t] != alive)
            if rv is None:
                churn = churn | changed
                alive = masks[t]
            else:
                v_t = rv[t] > 0
                churn = churn | (v_t & changed)
                alive = jnp.where(v_t, masks[t], alive)
    # the stages already vectorize over epochs: they see the (K·n, p)
    # chunk view, with pad/idle rounds masked out (a padded epoch is a
    # dead epoch: no record, no flag)
    x_view = x_chunk.reshape(K * n, p)
    mask_view = None
    if has_stage and (masks is not None or rv is not None):
        m3 = jnp.ones((K, n, p), x_view.dtype) if masks is None \
            else jnp.broadcast_to(masks[:, None, :], (K, n, p))
        if rv is not None:
            m3 = m3 * rv[:, None, None].astype(m3.dtype)
        mask_view = m3.reshape(K * n, p)

    z = x_hat = flags = t2 = spe = None
    if use_fused:
        with_c = cfg.compression is not None
        with_m = cfg.detection is not None
        # analytic half of the fold first: the kernel needs the POST-fold
        # mean estimate as a stage operand, and s/t_band never touch a
        # kernel (online_apply_chunk shares the arithmetic, so the split
        # path produces the same bits)
        w, beta_eff, delta_s, delta_tb = online_chunk_stats(
            state.cov, x_chunk, forgetting=cfg.forgetting, masks=masks,
            round_valid=round_valid)
        s_new = beta_eff * state.cov.s + delta_s
        t_i_new = (beta_eff * state.cov.t_band + delta_tb)[cfg.halfwidth]
        mean_est = s_new / jnp.maximum(t_i_new, 1.0)
        il = inv_lambda(state.sched.lam, cfg.detection) if with_m \
            else jnp.ones((cfg.q,), jnp.float32)
        eps = cfg.compression.epsilon if with_c else 0.0
        # ONE kernel launch: band fold + stages against the pre-decision
        # basis (== post-decision whenever the scheduler does not fire)
        band_delta, z, x_hat, flags, t2, spe = ops.fused_stream_update(
            x_view, jnp.repeat(w, n), state.sched.W, mean_est, il,
            halfwidth=cfg.halfwidth, epsilon=eps, with_compress=with_c,
            with_monitor=with_m, mask=mask_view, precision=cfg.precision,
            interpret=cfg.interpret)
        cov = online_apply_chunk(state.cov, band_delta, w, beta_eff,
                                 delta_s, delta_tb, n)
    else:
        cov = online_update_chunk(state.cov, x_chunk,
                                  forgetting=cfg.forgetting, masks=masks,
                                  round_valid=round_valid,
                                  interpret=cfg.interpret)
        mean_est = cov.s / jnp.maximum(cov.t_i, 1.0)
    # one decision at the boundary, indexed at the LAST folded round (the
    # same warmup arithmetic the per-round path would apply at that round)
    sched, rho, fired = cfg.scheduler().step(state.sched, cov,
                                             state.rounds + (live_i - 1),
                                             churn=churn)
    # step() booked one per-round record; book the chunk's remaining live
    # rounds (static no-op at K=1)
    extra = live - 1
    if not (isinstance(extra, int) and extra == 0):
        sched = sched._replace(
            comm_packets=sched.comm_packets
            + extra * cfg.scheduler().round_cost())
    factor = expected_transmissions(cfg.link_loss, cfg.max_retries)
    if use_fused:
        # the decision fired: the stages must reflect the rotated basis
        # (and its λ̂) — the pure-jnp twin recomputes them bit-identically
        # to what the kernel would produce, without a second pallas_call
        # in the traced body (lax.cond branches both count)
        def _pack(z_, xh_, fl_, t2_, spe_):
            out = [z_]
            if with_c:
                out += [xh_, fl_]
            if with_m:
                out += [t2_, spe_]
            return tuple(out)

        def _recompute(_):
            il2 = inv_lambda(sched.lam, cfg.detection) if with_m else il
            return _pack(*ops.fused_stream_stages_blocked(
                x_view, sched.W, mean_est, il2, epsilon=eps,
                with_compress=with_c, with_monitor=with_m, mask=mask_view,
                precision=cfg.precision))

        staged = jax.lax.cond(fired, _recompute,
                              lambda _: _pack(z, x_hat, flags, t2, spe),
                              operand=None)
        z = staged[0]
        k_out = 1
        if with_c:
            x_hat, flags = staged[k_out], staged[k_out + 1]
            k_out += 2
        if with_m:
            t2, spe = staged[k_out], staged[k_out + 1]
    compression = None
    if cfg.compression is not None:
        if use_fused:
            mask2d = mask_view if mask_view is not None \
                else jnp.ones((K * n, p), jnp.float32)
            compression = compression_books(
                jnp.asarray(x_view, jnp.float32), z, x_hat, flags, mask2d,
                cfg.compression, cfg.q, cfg.c_max)
        else:
            compression = compress_round(
                sched.W, mean_est, x_view, cfg.compression, cfg.c_max,
                mask=mask_view, interpret=cfg.interpret)
        flagfree = compression_round_cost(cfg.q, cfg.c_max, cfg.compression)
        bill = (flagfree * live + compression.extra_packets) * factor
        sched = sched._replace(comm_packets=sched.comm_packets + bill)
        # compress_round's fixed A/F record (and its bits) covers ONE
        # epoch round; this metrics row covers the chunk's live rounds —
        # scale the per-round constants so the books a consumer sums from
        # metrics (the engine's bits_on_air account) stay per-epoch exact
        # like comm_packets above (static no-op at K=1)
        if not (isinstance(live, int) and live == 1):
            a_pk, f_pk = epoch_packet_split(cfg.q, cfg.c_max,
                                            cfg.compression)
            compression = compression._replace(
                score_packets=compression.score_packets * live,
                feedback_packets=compression.feedback_packets * live,
                bits_on_air=compression.bits_on_air
                + (live - 1) * (a_pk + f_pk) * cfg.compression.word_bits)
    det_state, detection = state.det, None
    if cfg.detection is not None:
        if use_fused:
            det_state, detection = detect_apply(
                t2, spe, row_liveness(mask_view, K * n, t2.dtype), cfg.q,
                state.det, cfg.detection, refreshed=fired)
        else:
            det_state, detection = detect_round(
                sched.W, mean_est, sched.lam, x_view, state.det,
                cfg.detection, refreshed=fired, mask=mask_view,
                interpret=cfg.interpret)
        flagfree, per_alarm = detection_packet_split(cfg.q, cfg.c_max)
        bill = (flagfree * live + detection.alarms * per_alarm) * factor
        sched = sched._replace(comm_packets=sched.comm_packets + bill)
    new = StreamState(cov=cov, sched=sched, rounds=state.rounds + live_i,
                      alive=alive, det=det_state)
    metrics = RoundMetrics(rho=rho, did_refresh=fired,
                           refreshes=sched.refreshes,
                           comm_packets=sched.comm_packets,
                           compression=compression,
                           detection=detection)
    return new, metrics


@functools.lru_cache(maxsize=None)
def engine_chunk_step_fn(cfg: StreamConfig, *, masked: bool = False):
    """The serving engine's jitted chunk body (DESIGN.md Sec. 17): the
    vmapped :func:`chunk_stream_step` with the stacked fleet state DONATED.

    Memoized per (cfg, masked): every engine instance with the same config
    shares ONE jitted callable — and therefore one compilation cache —
    instead of re-tracing per engine (a benchmark sweeping modes would
    otherwise spend most of its wall time compiling identical programs).

    This is the donation-safe consumer of the engine's double-buffered
    staging path: argument 0 (the per-slot state pytree) is donated so XLA
    updates the fleet in place every step, while the staged chunk batch
    (argument 1) and mask batch are deliberately NOT donated — they are
    engine-owned uploads that the staging fence may still be waiting on
    when the next chunk is dispatched, so the engine must keep the right
    to hold references to them.  Built here (not in ``serve/engine.py``)
    so the engine and the ``engine.step*`` analysis contracts trace the
    exact same callable.
    """
    if masked:
        def body(s, x, m, rv):
            return chunk_stream_step(cfg, s, x, m, rv)
    else:
        def body(s, x, rv):
            return chunk_stream_step(cfg, s, x, round_valid=rv)
    return jax.jit(jax.vmap(body), donate_argnums=(0,))


@functools.partial(jax.jit, static_argnums=0)
def stream_run(cfg: StreamConfig, state: StreamState, xs: jnp.ndarray,
               masks: jnp.ndarray | None = None,
               ) -> tuple[StreamState, RoundMetrics]:
    """Jittable scan driver: stream ``xs`` of shape (rounds, n, p).

    ``masks`` (rounds, p), if given, carries the per-round sensor-liveness
    schedule (e.g. from :meth:`repro.core.faults.NodeChurn.liveness`).
    """
    if masks is None:
        def step(carry, x_round):
            return stream_step(cfg, carry, x_round)
        return jax.lax.scan(step, state, xs)

    def step(carry, xm):
        x_round, mask = xm
        return stream_step(cfg, carry, x_round, mask)

    return jax.lax.scan(step, state, (xs, masks))


@functools.partial(jax.jit, static_argnums=0,
                   static_argnames=("chunk", "probe_every"))
def chunked_stream_run(cfg: StreamConfig, state: StreamState,
                       xs: jnp.ndarray,
                       masks: jnp.ndarray | None = None, *,
                       chunk: int = 8,
                       probe_every: int | None = None,
                       ) -> tuple[StreamState, RoundMetrics]:
    """Chunk-granular scan driver: K rounds per dispatch.

    ``xs`` is (rounds, n, p) like :func:`stream_run`; the scan advances
    ``probe_every`` rounds per step (default: the whole ``chunk``), each
    step one fused covariance fold + one scheduler decision + one
    compression/detection pass (:func:`chunk_stream_step`).  A decision
    needs the covariance at its own boundary, so the fold granularity IS
    the decision granularity: with ``probe_every`` set below ``chunk``
    (it must divide it) every dispatch fuses ``probe_every`` rounds —
    ``chunk`` then only names the K the caller is A/B-ing against.
    Metrics come back with one entry per DECISION, i.e.
    ``ceil(rounds / probe_every)`` rows; ``comm_packets`` still accounts
    every epoch (per-round booking is exact, only decisions are
    amortized).

    ``probe_every=1`` reproduces today's per-round trajectory bit-exactly
    (states and metrics identical to :func:`stream_run` — the differential
    suite in tests/test_chunked_streaming.py pins this), so the decision
    cadence is a pure perf/accuracy knob, not a semantic fork.  A stream
    whose length is not divisible by the step is padded with invalid
    rounds that contribute nothing (the tail chunk folds and books only
    its real rounds).
    """
    R = xs.shape[0]
    step_rounds = chunk if probe_every is None else probe_every
    if chunk < 1 or step_rounds < 1:
        raise ValueError(f"chunk/probe_every must be >= 1, got "
                         f"{chunk}/{probe_every}")
    if chunk % step_rounds != 0:
        raise ValueError(
            f"probe_every ({step_rounds}) must divide chunk ({chunk})")
    S = step_rounds
    n_steps = -(-R // S)
    pad = n_steps * S - R
    if pad:
        xs = jnp.concatenate(
            [xs, jnp.zeros((pad,) + xs.shape[1:], xs.dtype)], axis=0)
        if masks is not None:
            masks = jnp.concatenate(
                [masks, jnp.zeros((pad,) + masks.shape[1:], masks.dtype)],
                axis=0)
        rv = jnp.concatenate([jnp.ones((R,), jnp.float32),
                              jnp.zeros((pad,), jnp.float32)])
        rv = rv.reshape(n_steps, S)
    xs_c = xs.reshape(n_steps, S, *xs.shape[1:])
    masks_c = None if masks is None \
        else masks.reshape(n_steps, S, *masks.shape[1:])
    if not pad and masks is None:
        def step(carry, xc):
            return chunk_stream_step(cfg, carry, xc)
        return jax.lax.scan(step, state, xs_c)
    if not pad:
        def step(carry, xm):
            xc, mc = xm
            return chunk_stream_step(cfg, carry, xc, mc)
        return jax.lax.scan(step, state, (xs_c, masks_c))
    if masks is None:
        def step(carry, xm):
            xc, rc = xm
            return chunk_stream_step(cfg, carry, xc, round_valid=rc)
        return jax.lax.scan(step, state, (xs_c, rv))

    def step(carry, xm):
        xc, mc, rc = xm
        return chunk_stream_step(cfg, carry, xc, mc, rc)

    return jax.lax.scan(step, state, (xs_c, masks_c, rv))


def batched_stream_init(cfg: StreamConfig, key: jax.Array, n_networks: int,
                        dtype=jnp.float32) -> StreamState:
    """Per-network states stacked on a leading networks axis."""
    keys = jax.random.split(key, n_networks)
    return jax.vmap(lambda k: stream_init(cfg, k, dtype=dtype))(keys)


@functools.partial(jax.jit, static_argnums=0,
                   static_argnames=("chunk", "probe_every"))
def batched_stream_run(cfg: StreamConfig, states: StreamState,
                       xs: jnp.ndarray,
                       masks: jnp.ndarray | None = None, *,
                       chunk: int | None = None,
                       probe_every: int | None = None,
                       ) -> tuple[StreamState, RoundMetrics]:
    """vmap the scan over a fleet: ``xs`` is (networks, rounds, n, p).

    ``masks`` (networks, rounds, p), if given, is the per-network liveness
    schedule.  Metrics come back as (networks, rounds) leaves.

    ``chunk``, if set, switches every network to the chunk-granular driver
    (:func:`chunked_stream_run` under the same vmap): one fused cov launch
    and ONE refresh select per chunk for the whole fleet — the per-round
    path pays the ``lax.cond``→select refresh for every round of every
    network — with metrics at decision granularity.  ``chunk=None`` is the
    per-round path, unchanged (``probe_every`` requires it).
    """
    if chunk is None:
        if probe_every is not None:
            raise ValueError("probe_every requires chunk (the per-round "
                             "path has no dispatch granularity to probe)")
        if masks is None:
            return jax.vmap(lambda s, x: stream_run(cfg, s, x))(states, xs)
        return jax.vmap(lambda s, x, m: stream_run(cfg, s, x, m))(
            states, xs, masks)
    if masks is None:
        return jax.vmap(lambda s, x: chunked_stream_run(
            cfg, s, x, chunk=chunk, probe_every=probe_every))(states, xs)
    return jax.vmap(lambda s, x, m: chunked_stream_run(
        cfg, s, x, m, chunk=chunk, probe_every=probe_every))(
        states, xs, masks)


def sharded_stream_run(cfg: StreamConfig, mesh, states: StreamState,
                       xs: jnp.ndarray, axis: str = "data", *,
                       chunk: int | None = None,
                       probe_every: int | None = None,
                       ) -> tuple[StreamState, RoundMetrics]:
    """The batched run with the networks axis sharded over ``axis``.

    Each device streams its local slice of the fleet; no collective touches
    per-network state (checked with ``check_rep=False`` because the body is
    collective-free by construction).  Requires the number of networks to be
    divisible by the axis size.  ``chunk``/``probe_every`` thread through to
    :func:`batched_stream_run` per shard (the chunked body is just as
    collective-free).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from repro.distributed.sharding import network_axis_spec

    spec = network_axis_spec(mesh, axis)
    n_networks = xs.shape[0]
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    if n_networks % axis_size != 0:
        raise ValueError(
            f"{n_networks} networks not divisible by axis {axis!r} "
            f"of size {axis_size}")

    def local_run(states_l, xs_l):
        return batched_stream_run(cfg, states_l, xs_l, chunk=chunk,
                                  probe_every=probe_every)

    fm = shard_map(
        local_run, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: spec, states),
                  spec),
        out_specs=(jax.tree.map(lambda _: spec, states),
                   jax.tree.map(lambda _: spec, _metrics_template(cfg))),
        check_rep=False,
    )
    return fm(states, xs)


# ===========================================================================
# Program contracts (repro.analysis; DESIGN.md Sec. 15).
#
# Every structural claim the docs/benchmarks make about the chunk body is
# declared here, next to the code it describes, and machine-checked by
# ``python -m repro.analysis.check`` (a dedicated CI job) — the Table-1
# discipline of the paper, applied to the traced program instead of the
# WSN packet ledger.
# ===========================================================================
from repro.analysis import contracts as _contracts  # noqa: E402
from repro.analysis import jaxpr_lint as _jl        # noqa: E402
from repro.analysis import resources as _res        # noqa: E402

_CONTRACT_P, _CONTRACT_Q, _CONTRACT_H, _CONTRACT_N = 12, 3, 2, 4


def _contract_cfg(*, fused: bool = True, stages: bool = True,
                  precision: str = "fp32") -> StreamConfig:
    comp = CompressionConfig(epsilon=0.5) if stages else None
    det = DetectionConfig(alpha=1e-3, calib_rounds=3) if stages else None
    return StreamConfig(p=_CONTRACT_P, q=_CONTRACT_Q,
                        halfwidth=_CONTRACT_H, warmup_rounds=4,
                        compression=comp, detection=det,
                        fused=fused, precision=precision)


def _trace_chunk_body(cfg: StreamConfig, ks=(1, 4, 8)):
    st = stream_init(cfg, jax.random.PRNGKey(0))
    out = {}
    for k in ks:
        xc = jnp.zeros((k, _CONTRACT_N, cfg.p), jnp.float32)
        out[f"K={k}"] = jax.make_jaxpr(
            lambda s, x: chunk_stream_step(cfg, s, x))(st, xc)
    return out


def _trace_chunked_run():
    cfg = _contract_cfg(stages=False)
    st = stream_init(cfg, jax.random.PRNGKey(0))
    xs = jnp.zeros((8, _CONTRACT_N, cfg.p), jnp.float32)
    return {"R=8,chunk=4": jax.make_jaxpr(
        lambda s, x: chunked_stream_run(cfg, s, x, chunk=4))(st, xs)}


def _trace_dtype_policy():
    st32 = stream_init(_contract_cfg(stages=False), jax.random.PRNGKey(0))
    xs = jnp.zeros((4, _CONTRACT_N, _CONTRACT_P), jnp.float32)
    cfg32 = _contract_cfg(stages=False)
    cfg_f = _contract_cfg()
    cfg_bf = _contract_cfg(precision="bf16")
    st_f = stream_init(cfg_f, jax.random.PRNGKey(0))
    return {
        "stream_run": jax.make_jaxpr(
            lambda s, x: stream_run(cfg32, s, x))(st32, xs),
        "chunked-fp32": jax.make_jaxpr(
            lambda s, x: chunked_stream_run(cfg_f, s, x, chunk=4))(st_f, xs),
        "chunked-bf16": jax.make_jaxpr(
            lambda s, x: chunked_stream_run(cfg_bf, s, x, chunk=4))(
            stream_init(cfg_bf, jax.random.PRNGKey(0)), xs),
    }


_contracts.register(_contracts.Contract(
    id="chunk.body",
    where="repro.streaming.driver.chunk_stream_step",
    claim="one cov pallas launch and at most one eigh per chunk body, "
          "independent of K (PR 5)",
    trace=lambda: _trace_chunk_body(_contract_cfg(stages=False)),
    rules=(_jl.PrimitiveBudget("pallas_call", exact=1),
           _jl.PrimitiveBudget("eigh", max=1),
           _jl.ForbidInLoops(everywhere=True),
           _jl.NoF64(),
           _res.VmemBudget(),
           _res.HbmTrafficBudget(max_passes=1.0)),
))

_contracts.register(_contracts.Contract(
    id="chunk.fused.fp32",
    where="repro.streaming.driver.chunk_stream_step",
    claim="1 pallas_call per fused chunk body with both stages configured "
          "(was 3 on the split path; PR 7) — lax.cond branches included",
    trace=lambda: _trace_chunk_body(_contract_cfg()),
    rules=(_jl.PrimitiveBudget("pallas_call", exact=1),
           _jl.PrimitiveBudget("eigh", max=1),
           _jl.ForbidInLoops(everywhere=True),
           _jl.NoF64(),
           _res.VmemBudget(),
           # one tile-load per chunk: the whole entry moves exactly one
           # pass of HBM traffic, and the chunk data/mask tiles in
           # particular are never re-fetched across feature blocks
           _res.HbmTrafficBudget(max_passes=1.0,
                                 single_pass=("x_ref", "m_ref"))),
))

_contracts.register(_contracts.Contract(
    id="chunk.fused.bf16",
    where="repro.streaming.driver.chunk_stream_step",
    claim="the bf16 fused body still launches once and keeps every "
          "accumulator fp32 (bf16 is a tile format only; PR 7)",
    trace=lambda: _trace_chunk_body(_contract_cfg(precision="bf16")),
    rules=(_jl.PrimitiveBudget("pallas_call", exact=1),
           _jl.Fp32Accumulators(),
           _jl.NoF64(),
           _res.VmemBudget(),
           _res.HbmTrafficBudget(max_passes=1.0,
                                 single_pass=("x_ref", "m_ref"))),
))

_contracts.register(_contracts.Contract(
    id="chunk.body.split",
    where="repro.streaming.driver.chunk_stream_step",
    claim="the split (fused=False) chunk body pays exactly the three "
          "launches the mega-kernel collapsed (the fused path's oracle)",
    trace=lambda: _trace_chunk_body(_contract_cfg(fused=False), ks=(4,)),
    rules=(_jl.PrimitiveBudget("pallas_call", exact=3),
           _jl.PrimitiveBudget("eigh", max=1),
           _res.VmemBudget(),
           # each of the three split launches is itself one-pass; the
           # fused win is fewer launches, not fewer passes per launch
           _res.HbmTrafficBudget(max_passes=1.0)),
))

_contracts.register(_contracts.Contract(
    id="driver.hot-loop",
    where="repro.streaming.driver.chunked_stream_run",
    claim="the streamed scan is host-sync-free (no device_put/callbacks in "
          "the loop body) and launches scan-length x 1 pallas kernels",
    trace=_trace_chunked_run,
    rules=(_jl.ForbidInLoops(),
           # loop-weighted: 8 rounds / chunk 4 = 2 scan trips x 1 launch
           _jl.PrimitiveBudget("pallas_call", exact=2, loop_weighted=True),
           _jl.NoF64(),
           _res.VmemBudget(),
           _res.HbmTrafficBudget(max_passes=1.0)),
))

_contracts.register(_contracts.Contract(
    id="dtype.policy",
    where="repro.streaming.driver",
    claim="no f64 anywhere on the streaming paths; bf16 never escapes the "
          "tile loads (pallas outputs and scan carries stay fp32)",
    trace=_trace_dtype_policy,
    rules=(_jl.NoF64(), _jl.Fp32Accumulators()),
))
