"""Stream drivers: single network, vmap-batched fleet, shard_map-sharded fleet.

Layering (DESIGN.md Sec. 8.3):

* :func:`stream_step` — one round for ONE network: fold the round's
  measurements into the online covariance (Pallas cov-update kernel), then
  one scheduler decision (drift probe + possible basis refresh).
* :func:`stream_run` — ``lax.scan`` of the step over a (rounds, n, p) stream;
  this is the jittable single-network driver.
* :func:`batched_stream_run` — ``jax.vmap`` of the run over a leading
  networks axis: hundreds of independent sensor networks stream concurrently
  in one program — the serving shape.  The scheduler's ``lax.cond`` lowers to
  a select, so each round costs one (masked) refresh for the whole batch
  while the *booked* WSN cost stays per-network exact.
* :func:`sharded_stream_run` — the batched run inside ``shard_map`` with the
  networks axis split over the mesh data axis
  (:func:`repro.distributed.sharding.network_axis_spec`); per-network state
  never crosses devices, so the fleet scales linearly with chips.

With ``StreamConfig.compression`` set, every round additionally runs the
ε-supervised compression stage (:mod:`repro.streaming.compressor`) against
the slot's current basis: the fused Pallas kernel emits the scores the
sink decodes, the ε-true sink view, and the notification mask, and the
Sec.-2.4.1 packet bill (scores A + feedback F + flagged raws, lossy-scaled)
is booked into the same per-network communication account as the
scheduler's Table-1 costs.

With ``StreamConfig.detection`` set, every round also runs the T²/SPE
event-detection stage (:mod:`repro.streaming.detector`) against the same
live basis and the scheduler's per-component variance estimates λ̂: the
fused Pallas monitoring pass emits the two per-epoch statistics, the
detector thresholds (recalibrated over a healthy window after every
refresh) turn them into alarms, and the Sec.-2.4.3 bill — one extra
scalar on the per-round drift record plus one F alarm flood per alarmed
epoch, lossy-scaled — is booked into the same account.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.faults import expected_transmissions
from repro.streaming.compressor import (CompressionConfig, RoundCompression,
                                        compress_round,
                                        compression_round_cost)
from repro.streaming.detector import (DetectionConfig, DetectorState,
                                      RoundDetection, detect_round,
                                      detection_packet_split, detector_init)
from repro.streaming.online_cov import (OnlineCovariance, online_init,
                                        online_update)
from repro.streaming.scheduler import RecomputeScheduler, SchedulerState

__all__ = ["StreamConfig", "StreamState", "RoundMetrics", "stream_init",
           "stream_step", "stream_run", "batched_stream_run",
           "sharded_stream_run"]


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Static configuration shared by every network of a fleet."""

    p: int                          # sensors per network
    q: int                          # principal components maintained
    halfwidth: int                  # covariance band half-width
    forgetting: float = 1.0         # per-round exponential forgetting factor
    drift_threshold: float = 0.02   # refresh trigger (retained-variance drop)
    refresh_iters: int = 8          # orthogonal-iteration length per refresh
    warmup_rounds: int = 10         # rounds before the first refresh
    n_max: int = 8                  # |N_i*| for the cost model
    c_max: int = 4                  # C_i* for the cost model
    link_loss: float = 0.0          # per-hop packet loss (cost booking)
    max_retries: int = 3            # ARQ retransmission budget per packet
    interpret: bool | None = None   # Pallas interpret override (None = auto)
    compression: CompressionConfig | None = None  # ε-supervised stage
    detection: DetectionConfig | None = None      # T²/SPE monitoring stage

    def scheduler(self) -> RecomputeScheduler:
        return RecomputeScheduler(
            q=self.q, drift_threshold=self.drift_threshold,
            refresh_iters=self.refresh_iters,
            warmup_rounds=self.warmup_rounds,
            n_max=self.n_max, c_max=self.c_max,
            link_loss=self.link_loss, max_retries=self.max_retries)


class StreamState(NamedTuple):
    cov: OnlineCovariance
    sched: SchedulerState
    rounds: jnp.ndarray             # () int32 rounds streamed so far
    alive: jnp.ndarray              # (p,) 0/1 liveness seen last round
    det: DetectorState | None = None  # T²/SPE thresholds + healthy window


class RoundMetrics(NamedTuple):
    """Per-round observability record (stacked by scan over time).

    ``compression``/``detection`` are ``None`` when the config carries no
    such stage (None is an empty pytree node, so every variant
    scan/vmap/shards cleanly — the pytree structure is fixed per
    StreamConfig).
    """

    rho: jnp.ndarray                # retained fraction before any refresh
    did_refresh: jnp.ndarray        # bool — scheduler fired this round
    refreshes: jnp.ndarray          # cumulative refresh count
    comm_packets: jnp.ndarray       # cumulative communication (packets)
    compression: RoundCompression | None = None  # ε-supervised output
    detection: RoundDetection | None = None      # T²/SPE monitoring output


def _metrics_template(cfg: "StreamConfig") -> RoundMetrics:
    """A structure-only RoundMetrics matching cfg (for shard_map out_specs)."""
    comp = None
    if cfg.compression is not None:
        emit = cfg.compression.emit_reconstruction
        comp = RoundCompression(
            z=0, x_sink=0 if emit else None, flagged=0 if emit else None,
            max_err=0, extra_packets=0, score_packets=0,
            feedback_packets=0, bits_on_air=0)
    det = None
    if cfg.detection is not None:
        emit = cfg.detection.emit_statistics
        det = RoundDetection(
            t2=0 if emit else None, spe=0 if emit else None,
            events=0 if emit else None, alarms=0,
            t2_threshold=0, spe_threshold=0, calibrating=0)
    return RoundMetrics(rho=0, did_refresh=0, refreshes=0, comm_packets=0,
                        compression=comp, detection=det)


def stream_init(cfg: StreamConfig, key: jax.Array,
                dtype=jnp.float32) -> StreamState:
    return StreamState(
        cov=online_init(cfg.p, cfg.halfwidth, dtype=dtype),
        sched=cfg.scheduler().init(cfg.p, key, dtype=dtype),
        rounds=jnp.zeros((), jnp.int32),
        alive=jnp.ones((cfg.p,), dtype=dtype),
        det=detector_init(dtype) if cfg.detection is not None else None,
    )


def stream_step(cfg: StreamConfig, state: StreamState, x_round: jnp.ndarray,
                mask: jnp.ndarray | None = None,
                ) -> tuple[StreamState, RoundMetrics]:
    """One round for one network: covariance fold + scheduling decision.

    ``mask`` is the round's (p,) sensor-liveness vector (1 = alive).  Dead
    sensors contribute no outer products and no mean sums (the masked Pallas
    path in :func:`repro.streaming.online_cov.online_update`), and a change
    of liveness between consecutive rounds — a death or a revival, i.e.
    topology churn — is reported to the scheduler as an unconditional drift
    trigger.  ``mask=None`` is the fault-free path, bit-identical to the
    pre-fault behavior.
    """
    if mask is None:
        cov = online_update(state.cov, x_round, forgetting=cfg.forgetting,
                            interpret=cfg.interpret)
        churn = jnp.zeros((), bool)
        alive = state.alive
    else:
        mask = jnp.asarray(mask, dtype=state.alive.dtype)
        cov = online_update(state.cov, x_round, forgetting=cfg.forgetting,
                            mask=mask, interpret=cfg.interpret)
        churn = jnp.any(mask != state.alive)
        alive = mask
    sched, rho, fired = cfg.scheduler().step(state.sched, cov, state.rounds,
                                             churn=churn)
    # live per-sensor mean estimate of the online covariance — normalized
    # by each sensor's OWN effective count (the masked-statistics bugfix:
    # dividing by the round count biased dropout-ridden sensors to zero)
    mean_est = cov.s / jnp.maximum(cov.t_i, 1.0)
    factor = expected_transmissions(cfg.link_loss, cfg.max_retries)
    compression = None
    if cfg.compression is not None:
        # compress this round against the slot's CURRENT basis (post-step W)
        # and the live mean estimate — the same quantities the deployment
        # would have flooded to the nodes
        compression = compress_round(
            sched.W, mean_est, x_round, cfg.compression, cfg.c_max,
            mask=mask, interpret=cfg.interpret)
        # book the Sec.-2.4.1 epoch: scores A + feedback F (with the scale
        # flood at the quantized budget), plus the flagged raws — every
        # packet paying the same expected ARQ retransmissions as the
        # scheduler's bill
        flagfree = compression_round_cost(cfg.q, cfg.c_max, cfg.compression)
        bill = (flagfree + compression.extra_packets) * factor
        sched = sched._replace(comm_packets=sched.comm_packets + bill)
    det_state, detection = state.det, None
    if cfg.detection is not None:
        # monitor this round against the same post-step basis and the
        # scheduler's λ̂; a refresh this round opens a fresh healthy window
        det_state, detection = detect_round(
            sched.W, mean_est, sched.lam, x_round, state.det, cfg.detection,
            refreshed=fired, mask=mask, interpret=cfg.interpret)
        # book the Sec.-2.4.3 epoch: one extra scalar on the per-round
        # (q+1) drift record plus one F alarm flood per alarmed epoch,
        # lossy-scaled like every other packet of the round
        flagfree, per_alarm = detection_packet_split(cfg.q, cfg.c_max)
        bill = (flagfree + detection.alarms * per_alarm) * factor
        sched = sched._replace(comm_packets=sched.comm_packets + bill)
    new = StreamState(cov=cov, sched=sched, rounds=state.rounds + 1,
                      alive=alive, det=det_state)
    metrics = RoundMetrics(rho=rho, did_refresh=fired,
                           refreshes=sched.refreshes,
                           comm_packets=sched.comm_packets,
                           compression=compression,
                           detection=detection)
    return new, metrics


@functools.partial(jax.jit, static_argnums=0)
def stream_run(cfg: StreamConfig, state: StreamState, xs: jnp.ndarray,
               masks: jnp.ndarray | None = None,
               ) -> tuple[StreamState, RoundMetrics]:
    """Jittable scan driver: stream ``xs`` of shape (rounds, n, p).

    ``masks`` (rounds, p), if given, carries the per-round sensor-liveness
    schedule (e.g. from :meth:`repro.core.faults.NodeChurn.liveness`).
    """
    if masks is None:
        def step(carry, x_round):
            return stream_step(cfg, carry, x_round)
        return jax.lax.scan(step, state, xs)

    def step(carry, xm):
        x_round, mask = xm
        return stream_step(cfg, carry, x_round, mask)

    return jax.lax.scan(step, state, (xs, masks))


def batched_stream_init(cfg: StreamConfig, key: jax.Array, n_networks: int,
                        dtype=jnp.float32) -> StreamState:
    """Per-network states stacked on a leading networks axis."""
    keys = jax.random.split(key, n_networks)
    return jax.vmap(lambda k: stream_init(cfg, k, dtype=dtype))(keys)


@functools.partial(jax.jit, static_argnums=0)
def batched_stream_run(cfg: StreamConfig, states: StreamState,
                       xs: jnp.ndarray,
                       masks: jnp.ndarray | None = None,
                       ) -> tuple[StreamState, RoundMetrics]:
    """vmap the scan over a fleet: ``xs`` is (networks, rounds, n, p).

    ``masks`` (networks, rounds, p), if given, is the per-network liveness
    schedule.  Metrics come back as (networks, rounds) leaves.
    """
    if masks is None:
        return jax.vmap(lambda s, x: stream_run(cfg, s, x))(states, xs)
    return jax.vmap(lambda s, x, m: stream_run(cfg, s, x, m))(
        states, xs, masks)


def sharded_stream_run(cfg: StreamConfig, mesh, states: StreamState,
                       xs: jnp.ndarray, axis: str = "data",
                       ) -> tuple[StreamState, RoundMetrics]:
    """The batched run with the networks axis sharded over ``axis``.

    Each device streams its local slice of the fleet; no collective touches
    per-network state (checked with ``check_rep=False`` because the body is
    collective-free by construction).  Requires the number of networks to be
    divisible by the axis size.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from repro.distributed.sharding import network_axis_spec

    spec = network_axis_spec(mesh, axis)
    n_networks = xs.shape[0]
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    if n_networks % axis_size != 0:
        raise ValueError(
            f"{n_networks} networks not divisible by axis {axis!r} "
            f"of size {axis_size}")

    def local_run(states_l, xs_l):
        return batched_stream_run(cfg, states_l, xs_l)

    fm = shard_map(
        local_run, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: spec, states),
                  spec),
        out_specs=(jax.tree.map(lambda _: spec, states),
                   jax.tree.map(lambda _: spec, _metrics_template(cfg))),
        check_rep=False,
    )
    return fm(states, xs)
