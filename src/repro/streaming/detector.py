"""Streaming event-detection stage: T²/SPE monitoring on the hot loop.

The paper's third application (Sec. 2.4.3) is *event detection*: a
network-scale anomaly that is invisible at any single node shows up as a
significant coordinate on components the healthy distribution does not
excite, and the evaluator is a chi-square test on the standardized scores.
:mod:`repro.core.events` is that evaluator host-side against a frozen
basis; this module is its device-resident continuation against the *live*
basis the streaming scheduler maintains — the Gupchup et al. "model-based
event detection" loop (PAPERS.md) run continuously, with the model itself
drifting underneath (Johard et al.'s self-adaptive encodings).

Two statistics per measurement epoch, both emitted by the fused Pallas
monitoring pass (:func:`repro.kernels.ops.pca_monitor` — the ε-supervised
kernel with the error test swapped for two VPU reductions; the
reconstruction never reaches HBM):

* **T²** ``= Σ_k z_k² / λ̂_k`` — energy moving *within* the tracked top-q
  subspace, standardized by the per-component variance estimates λ̂ the
  scheduler's refresh already computes (Rayleigh quotients of the ordering
  step, previously discarded);
* **SPE** (the Q statistic) ``= ‖(x − μ̂) − Z Wᵀ‖²`` over live sensors —
  network-coherent energy the basis does *not* span, the streaming
  analogue of the paper's low-variance evaluator (the trailing components
  of a frozen full basis ARE the complement of the live top-q subspace).

Thresholds are state, not constants: a chi-square quantile calibrated
against a stale basis is a false-alarm machine the moment the scheduler
rotates W, so after EVERY refresh (drift- or churn-triggered) the detector
opens a fresh healthy window — alarms are suppressed for
``calib_rounds`` rounds while it accumulates the moments of both
statistics, then re-arms with moment-matched ``g·χ²_h`` thresholds
(Nomikos-MacGregor / Box approximation) evaluated by the Wilson-Hilferty
device-side quantile.  T² additionally floors at the nominal ``χ²_q``
quantile (under a correct λ̂ the two agree; the floor guards against a
lucky ultra-quiet window).  The Sec.-2.4.3 packet bill — one extra scalar
on the per-round (q+1) drift record, plus one F alarm flood per alarmed
epoch — is booked by the driver through
:func:`repro.core.costs.detection_round_cost`.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.events import _norm_quantile
from repro.kernels import ops

__all__ = ["DetectionConfig", "DetectorState", "RoundDetection",
           "detector_init", "detect_round", "detect_apply", "inv_lambda",
           "row_liveness", "wilson_hilferty", "detection_packet_split"]


@dataclasses.dataclass(frozen=True)
class DetectionConfig:
    """Static per-deployment detection policy (hashable: rides the jitted
    StreamConfig as a compile-time constant).

    Parameters
    ----------
    alpha: per-epoch false-alarm rate under H0 — must lie in the open
        interval (0, 1) (the same validation the host-side
        :class:`repro.core.events.LowVarianceDetector` applies).
    calib_rounds: healthy-window length (rounds) after every basis
        refresh; alarms are suppressed while the window is open and the
        thresholds re-arm when it closes.
    min_lambda: clamp floor for the per-component variance estimates
        before inversion (a near-zero Rayleigh quotient would turn T²
        into an alarm siren).
    emit_statistics: carry the per-epoch (n,) T²/SPE/event arrays in the
        per-round output.  Costs rounds × n floats through a scan — right
        for examples/tests; disable at scale to keep only the scalar
        alarm counts and thresholds.
    """

    alpha: float = 1e-3
    calib_rounds: int = 8
    min_lambda: float = 1e-9
    emit_statistics: bool = True

    def __post_init__(self):
        if not 0.0 < self.alpha < 1.0:
            raise ValueError(
                f"alpha must be in the open interval (0, 1), got {self.alpha}")
        if self.calib_rounds < 1:
            raise ValueError(
                f"calib_rounds must be >= 1, got {self.calib_rounds}")
        if self.min_lambda <= 0.0:
            raise ValueError(
                f"min_lambda must be > 0, got {self.min_lambda}")

    @property
    def z_alpha(self) -> float:
        """Normal (1 - alpha) quantile, resolved host-side (alpha is
        static); the device evaluates only the Wilson-Hilferty cube."""
        return float(_norm_quantile(1.0 - self.alpha))


class DetectorState(NamedTuple):
    """Per-network detector state (all-array pytree; scan/vmap carry)."""

    t2_threshold: jnp.ndarray    # () — +inf until the first window closes
    spe_threshold: jnp.ndarray   # () — +inf until the first window closes
    calib_left: jnp.ndarray      # () int32 rounds left in the healthy window
    t2_sum: jnp.ndarray          # () window moments of the T² statistic
    t2_sumsq: jnp.ndarray        # ()
    spe_sum: jnp.ndarray         # () window moments of the SPE statistic
    spe_sumsq: jnp.ndarray       # ()
    count: jnp.ndarray           # () epochs folded into the open window


class RoundDetection(NamedTuple):
    """Per-round detection output (scan-stackable pytree).

    ``t2``/``spe``/``events`` are ``None`` when the config disables
    statistics emission (None is an empty pytree node, so both variants
    scan/vmap/shard cleanly — the structure is fixed per StreamConfig).
    """

    t2: jnp.ndarray | None       # (n,) per-epoch T² statistic
    spe: jnp.ndarray | None      # (n,) per-epoch SPE statistic
    events: jnp.ndarray | None   # (n,) 0/1 alarms (0 while calibrating)
    alarms: jnp.ndarray          # () alarmed epochs this round
    t2_threshold: jnp.ndarray    # () threshold in effect this round
    spe_threshold: jnp.ndarray   # () threshold in effect this round
    calibrating: jnp.ndarray     # () bool — healthy window open this round


def wilson_hilferty(df: jnp.ndarray, z: float) -> jnp.ndarray:
    """Chi-square quantile by the Wilson-Hilferty cube, traced ``df``.

    The same approximation as :func:`repro.core.events._chi2_quantile`, but
    with the normal quantile ``z`` pre-resolved host-side (alpha is static)
    and ``df`` a traced — possibly fractional — scalar, so the moment-
    matched ``g·χ²_h`` thresholds evaluate on device with no host sync.
    """
    a = 2.0 / (9.0 * jnp.maximum(df, 1e-12))
    return df * (1.0 - a + z * jnp.sqrt(a)) ** 3


def detector_init(dtype=jnp.float32) -> DetectorState:
    zero = jnp.zeros((), dtype)
    return DetectorState(
        t2_threshold=jnp.asarray(jnp.inf, dtype),
        spe_threshold=jnp.asarray(jnp.inf, dtype),
        calib_left=jnp.zeros((), jnp.int32),
        t2_sum=zero, t2_sumsq=zero, spe_sum=zero, spe_sumsq=zero,
        count=zero,
    )


def _moment_threshold(s: jnp.ndarray, ss: jnp.ndarray, cnt: jnp.ndarray,
                      z: float) -> jnp.ndarray:
    """Moment-matched g·χ²_h (1-alpha) quantile from window sums.

    Box's approximation: a positive statistic with healthy-window mean m
    and variance v is treated as g·χ²_h with ``g = v / 2m``,
    ``h = 2m² / v`` — for a true χ²_q with a correct λ̂ this recovers
    (m, v) = (q, 2q), i.e. the nominal threshold; a drifted or mis-scaled
    statistic gets a threshold matched to what healthy data actually does.
    """
    cnt = jnp.maximum(cnt, 1.0)
    m = jnp.maximum(s / cnt, 1e-12)
    v = jnp.maximum(ss / cnt - m * m, 1e-12)
    g = v / (2.0 * m)
    h = 2.0 * m * m / v
    return g * wilson_hilferty(h, z)


@jax.custom_batching.custom_vmap
def _stat_barrier(stats):
    """``optimization_barrier`` with a vmap rule (the stock primitive has
    none): each batching level peels off by re-entering the wrapper, so
    the barrier composes with the batched/sharded fleet drivers."""
    return jax.lax.optimization_barrier(stats)


@_stat_barrier.def_vmap
def _stat_barrier_vmap(axis_size, in_batched, stats):
    return _stat_barrier(stats), in_batched[0]


def _ordered_sum(v: jnp.ndarray) -> jnp.ndarray:
    """Sum over the last axis with a FIXED pairwise association.

    ``jnp.sum`` lowers to a ``reduce`` whose accumulation order is an
    implementation choice — XLA picks a vectorization per fusion context,
    so the same fp32 inputs can sum to different bits in the split and
    fused driver programs (observed: the T² window moment drifting ~1 ulp
    between the two batched runs).  A static halving tree spells every add
    out as its own elementwise HLO op; fp addition is non-associative, so
    the compiler must preserve the written order — the bits are pinned by
    construction in ANY surrounding program.  Zero-padding to a power of
    two is exact (x + 0 == x).
    """
    n = v.shape[-1]
    m = 1 << max(n - 1, 0).bit_length()
    if m != n:
        v = jnp.concatenate(
            [v, jnp.zeros(v.shape[:-1] + (m - n,), v.dtype)], axis=-1)
    while m > 1:
        m //= 2
        v = v[..., :m] + v[..., m:]
    return v[..., 0]


def inv_lambda(lam: jnp.ndarray, cfg: DetectionConfig) -> jnp.ndarray:
    """Clamped inverse of the per-component variance estimates — the T²
    standardization weights.  One expression shared by the split path
    (:func:`detect_round`) and the fused driver path, so both feed the
    monitoring kernel bit-identical operands."""
    return 1.0 / jnp.maximum(jnp.asarray(lam, jnp.float32), cfg.min_lambda)


def row_liveness(mask: jnp.ndarray | None, n: int,
                 dtype=jnp.float32) -> jnp.ndarray:
    """(n,) 0/1 weight of each epoch in the healthy-window moments: an
    epoch with NO live sensor carries no statistic — folding its zeros
    into the window would drag both thresholds toward (or below) zero and
    arm an alarm siren."""
    if mask is None:
        return jnp.ones((n,), dtype)
    m = jnp.asarray(mask, dtype)
    return (jnp.max(m) > 0) * jnp.ones((n,), dtype) \
        if m.ndim == 1 else (jnp.max(m, axis=1) > 0).astype(dtype)


def detect_round(W: jnp.ndarray, mean: jnp.ndarray, lam: jnp.ndarray,
                 x: jnp.ndarray, state: DetectorState, cfg: DetectionConfig,
                 refreshed: jnp.ndarray,
                 mask: jnp.ndarray | None = None,
                 interpret: bool | None = None,
                 ) -> tuple[DetectorState, RoundDetection]:
    """Monitor one (n, p) measurement round against basis W (p, q).

    ``lam`` (q,) are the scheduler's per-component variance estimates
    (clamped here before inversion); ``refreshed`` flags that the basis
    was recomputed THIS round — the detector opens a fresh healthy window
    before folding the round's statistics, so post-rotation epochs
    calibrate the new thresholds instead of tripping the old ones.
    ``mask`` is the round's (p,) or (n, p) liveness/validity array: dead
    sensors contribute no score record and no residual energy.
    """
    n = x.shape[0]
    _, t2, spe = ops.pca_monitor(jnp.asarray(x, jnp.float32), W, mean,
                                 inv_lambda(lam, cfg), mask=mask,
                                 interpret=interpret)
    return detect_apply(t2, spe, row_liveness(mask, n, t2.dtype),
                        W.shape[1], state, cfg, refreshed)


def detect_apply(t2: jnp.ndarray, spe: jnp.ndarray, row_live: jnp.ndarray,
                 q: int, state: DetectorState, cfg: DetectionConfig,
                 refreshed: jnp.ndarray,
                 ) -> tuple[DetectorState, RoundDetection]:
    """The detector state machine on already-computed statistics: healthy
    window fold, threshold re-arm, alarm evaluation.

    Split out of :func:`detect_round` so the fused driver path
    (:func:`repro.streaming.driver.chunk_stream_step`) can feed it the
    mega-kernel's T²/SPE reductions without re-running the monitoring
    kernel — the state machine is pure VPU-scalar work either way.

    The statistics pass an ``optimization_barrier`` before the healthy-
    window moment sums: those sums are order-sensitive fp32 reductions,
    and XLA picks their vectorization from the producer they fuse with —
    the split and fused paths produce ``spe`` through different producers
    (stage kernel vs mega-kernel vs the cond'd twin), so without the cut
    the same bit-identical statistics could fold into different moment
    bits.  The barrier pins the reduction to a materialized input in
    every path (bit-parity is structural, not just mathematical).
    """
    t2, spe = _stat_barrier((t2, spe))
    # a refresh rotates the basis: reset the healthy window FIRST so this
    # round's statistics (computed against the new W) seed the new window
    refreshed = jnp.asarray(refreshed, bool)
    zero = jnp.zeros((), state.t2_sum.dtype)
    calib_left = jnp.where(refreshed,
                           jnp.asarray(cfg.calib_rounds, jnp.int32),
                           state.calib_left)
    t2_sum = jnp.where(refreshed, zero, state.t2_sum)
    t2_sumsq = jnp.where(refreshed, zero, state.t2_sumsq)
    spe_sum = jnp.where(refreshed, zero, state.spe_sum)
    spe_sumsq = jnp.where(refreshed, zero, state.spe_sumsq)
    count = jnp.where(refreshed, zero, state.count)

    calibrating = calib_left > 0
    cal_f = calibrating.astype(t2.dtype)
    n_live = jnp.sum(row_live)
    # window moments fold through the fixed-order tree: these are the only
    # order-sensitive fp reductions shared by the split and fused paths
    t2_sum = t2_sum + cal_f * _ordered_sum(t2 * row_live)
    t2_sumsq = t2_sumsq + cal_f * _ordered_sum(t2 * t2 * row_live)
    spe_sum = spe_sum + cal_f * _ordered_sum(spe * row_live)
    spe_sumsq = spe_sumsq + cal_f * _ordered_sum(spe * spe * row_live)
    count = count + cal_f * n_live
    # a fully-dead round contributes nothing: the window does not advance,
    # so a blacked-out network stays suppressed instead of arming on zeros
    calib_left = calib_left - (calibrating & (n_live > 0)).astype(jnp.int32)
    closing = calibrating & (calib_left == 0)

    z = cfg.z_alpha
    t2_thr_new = jnp.maximum(_moment_threshold(t2_sum, t2_sumsq, count, z),
                             wilson_hilferty(jnp.asarray(float(q)), z))
    # SPE has no nominal scale to floor at, but a degenerate window must
    # never arm a non-positive threshold (0 > 0 is false, so fully-dead
    # epochs — statistic exactly 0 — can still never alarm)
    spe_thr_new = jnp.maximum(
        _moment_threshold(spe_sum, spe_sumsq, count, z), 0.0)
    t2_threshold = jnp.where(closing, t2_thr_new, state.t2_threshold)
    spe_threshold = jnp.where(closing, spe_thr_new, state.spe_threshold)

    # alarms fire only outside the healthy window (this round's epochs are
    # window members when calibrating — including the closing round), and
    # against the thresholds in effect BEFORE any re-arm this round
    armed = ~calibrating
    events = armed & ((t2 > state.t2_threshold)
                      | (spe > state.spe_threshold))
    events_f = events.astype(t2.dtype)
    alarms = jnp.sum(events_f)

    new_state = DetectorState(
        t2_threshold=t2_threshold, spe_threshold=spe_threshold,
        calib_left=calib_left,
        t2_sum=t2_sum, t2_sumsq=t2_sumsq,
        spe_sum=spe_sum, spe_sumsq=spe_sumsq, count=count,
    )
    emit = cfg.emit_statistics
    detection = RoundDetection(
        t2=t2 if emit else None,
        spe=spe if emit else None,
        events=events_f if emit else None,
        alarms=alarms,
        t2_threshold=state.t2_threshold,
        spe_threshold=state.spe_threshold,
        calibrating=calibrating,
    )
    return new_state, detection


def detection_packet_split(q: int, c_max: int) -> tuple[float, float]:
    """(flag-free packets per round, packets per alarmed epoch) of one
    Sec.-2.4.3 monitoring epoch at the highest-loaded node.

    The cost model owns both numbers (the driver books through
    :func:`detection_round_cost`, which delegates to it): the flag-free
    part is the one extra record element riding the per-round drift
    aggregation, the per-alarm part is the scalar F alarm flood.
    """
    base = costs.detection_round_cost(q, c_max).communication
    per_alarm = (costs.detection_round_cost(q, c_max, 1.0).communication
                 - base)
    return float(base), float(per_alarm)
