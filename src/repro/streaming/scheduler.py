"""Recompute scheduler: drift-triggered basis refreshes (DESIGN.md Sec. 8.2).

The paper refreshes principal components by rerunning the whole PIM pipeline;
on a live stream that is the single most expensive decision the system makes
(Table 1: the eigenvector phase dominates communication).  The scheduler
amortizes it: every round it evaluates the *retained-variance drift* of the
current basis against the live covariance estimate,

    rho(W, C) = trace(W^T C W) / trace(C)            (Eq. 4 on the live C)
    drift     = rho_at_last_refresh - rho(W, C_now)

and only past a configurable threshold recomputes the basis — a fixed-length
blocked orthogonal iteration (EXPERIMENTS.md Sec. Beyond-paper) warm-started
from the stale basis.  Each refresh books its paper-style communication cost
through :func:`repro.core.costs.streaming_refresh_cost` so benchmarks can
report accuracy-vs-communication exactly like Fig. 9/14.

Everything is branch-free jittable: the refresh is a ``lax.cond`` whose
batched (vmap) lowering evaluates both branches and selects per network —
the cost model, not XLA, is the source of truth for what a WSN would pay.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.covariance import banded_matmul_ref
from repro.streaming.online_cov import (OnlineCovariance, online_estimate,
                                        online_total_variance)

__all__ = ["RecomputeScheduler", "SchedulerState", "retained_fraction",
           "ortho_refresh", "ortho_refresh_evals"]


def retained_fraction(band_est: jnp.ndarray, W: jnp.ndarray,
                      total_variance: jnp.ndarray) -> jnp.ndarray:
    """rho = trace(W^T C W) / trace(C) for an orthonormal basis W.

    In the WSN reading this is one aggregation of a (q+1)-element record
    (per-node partial trace + partial variance); the cost is booked by
    :func:`repro.core.costs.streaming_round_cost`.
    """
    cw = banded_matmul_ref(band_est, W)
    num = jnp.sum(W * cw)
    return num / jnp.maximum(total_variance, 1e-30)


def ortho_refresh_evals(band_est: jnp.ndarray, W0: jnp.ndarray,
                        iters: int, eps: float = 1e-8,
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fixed-length blocked orthogonal iteration, warm-started from W0.

    A ``fori_loop`` (static trip count) rather than the convergence
    ``while_loop`` of :func:`repro.core.power_iteration.orthogonal_iteration`:
    the scheduler's refresh must be vmappable across networks with a
    deterministic per-refresh cost, and the warm start means a handful of
    iterations track a slowly rotating subspace (EXPERIMENTS.md Sec.
    Streaming).  Orthonormalization is the replicated-Cholesky ``inv(L)^T``
    form (EXPERIMENTS.md Sec. Perf hillclimb 1).

    Returns ``(W, evals)``: the ordered orthonormal basis AND the Rayleigh
    quotients (descending) of its columns against the live band.  The
    ordering step computes these eigenvalue estimates anyway; keeping them
    (instead of discarding them, as the pre-detection code did) is what
    feeds the event tier's per-component variance estimates λ̂ — in the WSN
    reading they are the q scalars the refresh flood already carries.
    """
    q = W0.shape[1]
    eye = eps * jnp.eye(q, dtype=W0.dtype)

    def orthonormalize(V):
        G = V.T @ V
        L = jnp.linalg.cholesky(G + eye)
        return V @ jnp.linalg.inv(L).T

    def body(_, V):
        return orthonormalize(banded_matmul_ref(band_est, V))

    V = jax.lax.fori_loop(0, iters, body, orthonormalize(W0))
    # order by Rayleigh quotient (replicated q x q solve)
    H = V.T @ banded_matmul_ref(band_est, V)
    evals, U = jnp.linalg.eigh(H)
    order = jnp.argsort(-evals)
    return V @ U[:, order], evals[order]


def ortho_refresh(band_est: jnp.ndarray, W0: jnp.ndarray,
                  iters: int, eps: float = 1e-8) -> jnp.ndarray:
    """Basis-only form of :func:`ortho_refresh_evals` (kept as the public
    refresh entrypoint for callers that do not track eigenvalues)."""
    return ortho_refresh_evals(band_est, W0, iters, eps)[0]


class SchedulerState(NamedTuple):
    W: jnp.ndarray            # (p, q) current orthonormal basis
    rho_ref: jnp.ndarray      # () retained fraction measured at last refresh
    refreshes: jnp.ndarray    # () int32 — number of refreshes triggered
    comm_packets: jnp.ndarray  # () accumulated communication (packets)
    lam: jnp.ndarray          # (q,) per-component variance estimates λ̂
    #                           (Rayleigh quotients at the last refresh;
    #                           ones before the first — consumers clamp)


@dataclasses.dataclass(frozen=True)
class RecomputeScheduler:
    """Policy + cost parameters (static; the state is the pytree above).

    Parameters
    ----------
    q: number of principal components maintained.
    drift_threshold: refresh when retained variance has dropped this much
        (absolute fraction) since the last refresh.
    refresh_iters: orthogonal-iteration length per refresh (fixed).
    warmup_rounds: no refresh before this many rounds (the covariance needs
        an effective window before the estimate is meaningful); the FIRST
        refresh after warmup is unconditional (the initial basis is random).
    n_max, c_max: WSN topology constants for the Table-1 cost model.
    link_loss, max_retries: per-hop packet-loss model — every booked packet
        is scaled by the expected ARQ transmissions
        (:func:`repro.core.costs.lossy_round_cost`); zero loss books the
        reliable Table-1 figures exactly.
    """

    q: int
    drift_threshold: float = 0.02
    refresh_iters: int = 8
    warmup_rounds: int = 10
    n_max: int = 8
    c_max: int = 4
    link_loss: float = 0.0
    max_retries: int = 3

    def init(self, p: int, key: jax.Array, dtype=jnp.float32) -> SchedulerState:
        W0 = jnp.linalg.qr(jax.random.normal(key, (p, self.q), dtype))[0]
        return SchedulerState(
            W=W0,
            rho_ref=jnp.zeros((), dtype),
            refreshes=jnp.zeros((), jnp.int32),
            comm_packets=jnp.zeros((), dtype),
            lam=jnp.ones((self.q,), dtype),
        )

    def round_cost(self) -> float:
        return costs.lossy_round_cost(
            self.n_max, self.q, self.c_max,
            self.link_loss, self.max_retries).communication

    def refresh_cost(self, p: int) -> float:
        return costs.lossy_refresh_cost(
            p, self.q, self.n_max, self.c_max, self.refresh_iters,
            self.link_loss, self.max_retries).communication

    def step(self, state: SchedulerState, cov_state: OnlineCovariance,
             round_index: jnp.ndarray,
             churn: jnp.ndarray | bool = False,
             ) -> tuple[SchedulerState, jnp.ndarray, jnp.ndarray]:
        """One scheduling decision against the live covariance.

        Returns ``(new_state, rho, did_refresh)`` where ``rho`` is the
        retained fraction of the basis in effect *before* any refresh (the
        quantity the trigger saw).

        ``churn`` flags a topology change this round (node death/revival,
        see DESIGN.md Sec. 9): the live covariance's support just moved, so
        drift is certain — the scheduler treats churn as an unconditional
        trigger (after warmup) instead of waiting for the retained-variance
        estimate to catch up over the forgetting window.
        """
        p = state.W.shape[0]
        band_est = online_estimate(cov_state)
        total_var = online_total_variance(cov_state)
        rho = retained_fraction(band_est, state.W, total_var)

        past_warmup = round_index >= self.warmup_rounds
        never_fit = state.refreshes == 0
        drifted = (state.rho_ref - rho) > self.drift_threshold
        trigger = past_warmup & (never_fit | drifted | jnp.asarray(churn))

        def do_refresh(_):
            W_new, lam_new = ortho_refresh_evals(band_est, state.W,
                                                 self.refresh_iters)
            rho_new = retained_fraction(band_est, W_new, total_var)
            return SchedulerState(
                W=W_new,
                rho_ref=rho_new,
                refreshes=state.refreshes + 1,
                comm_packets=state.comm_packets + self.refresh_cost(p),
                lam=lam_new,
            )

        def keep(_):
            return state

        new_state = jax.lax.cond(trigger, do_refresh, keep, operand=None)
        new_state = new_state._replace(
            comm_packets=new_state.comm_packets + self.round_cost())
        return new_state, rho, trigger
