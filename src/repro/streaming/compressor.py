"""Streaming compression stage: ε-supervised PCAg scores on the hot loop.

The paper's validating application (Sec. 2.3-2.4, Sec. 5) is *compression*:
project each round of sensor readings on the current principal components,
feed the scores back, and let every node compare its local reconstruction
against the truth — nodes whose error strictly exceeds ε ship the raw
measurement, so the sink is always within the closed bound ``|x - x̂| <= ε``.

This module is the device-resident tier of that protocol, threaded through
:func:`repro.streaming.driver.stream_step`: every streaming round is
compressed against the slot's *current* basis (the scheduler's W) and the
live mean estimate of the online covariance, through the fused Pallas kernel
(:func:`repro.kernels.ops.supervised_compress`).  The host-side NumPy path
(:mod:`repro.core.compression`) remains the differential oracle.

Quantized scores: a uniform per-component quantizer (configurable bit
width) models the bit-budget tradeoff of "Self-adaptive node-based PCA
encodings" (PAPERS.md).  The ε guarantee is *independent* of quantization:
nodes flag against the same dequantized reconstruction the sink computes
(the F flood carries the quantized scores), so coarser scores only raise
the notification rate, never break the bound.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp

from repro.core import costs
from repro.kernels import ops

__all__ = ["CompressionConfig", "RoundCompression", "quantize_scores",
           "compress_round", "compression_books", "compression_round_cost",
           "epoch_packet_split"]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Static per-deployment compression policy (hashable: rides the jitted
    StreamConfig as a compile-time constant).

    Parameters
    ----------
    epsilon: the Sec.-2.4.1 accuracy bound; the sink is guaranteed within
        ``<= epsilon`` of the truth for every live sensor.
    score_bits: uniform-quantizer width for the score records; 0 disables
        quantization (full-precision scores).  Must be 0 or >= 2 (one sign
        bit plus at least one magnitude bit).
    word_bits: radio word size — what one Table-1 "packet" carries; the
        bit-budget booking expresses quantized scores as packet fractions.
    emit_reconstruction: carry the (n, p) sink view and flag mask in the
        per-round output.  Costs rounds x n x p floats through a scan —
        right for examples/tests and modest fleets; disable at scale to
        keep only the scores and the scalar books.
    """

    epsilon: float
    score_bits: int = 0
    word_bits: int = 32
    emit_reconstruction: bool = True

    def __post_init__(self):
        if self.epsilon < 0.0:
            raise ValueError(f"epsilon must be >= 0, got {self.epsilon}")
        if self.score_bits == 1 or self.score_bits < 0:
            raise ValueError(
                f"score_bits must be 0 (off) or >= 2, got {self.score_bits}")
        if self.word_bits <= 0:
            raise ValueError(f"word_bits must be > 0, got {self.word_bits}")
        if self.score_bits > self.word_bits:
            raise ValueError(
                f"score_bits ({self.score_bits}) cannot exceed word_bits "
                f"({self.word_bits}) — a score never outgrows a packet word")


class RoundCompression(NamedTuple):
    """Per-round compression output (all-array pytree; scan-stackable).

    ``x_sink``/``flagged`` are ``None`` when the config disables
    reconstruction emission (None is an empty pytree node, so the scan and
    shard_map drivers stay shape-consistent per config).
    """

    z: jnp.ndarray                   # (n, q) scores as the sink decodes them
    x_sink: jnp.ndarray | None       # (n, p) ε-true sink view
    flagged: jnp.ndarray | None      # (n, p) 0/1 notification mask
    max_err: jnp.ndarray             # () max |x - x_sink| over live sensors
    extra_packets: jnp.ndarray       # () flagged raw measurements this round
    score_packets: jnp.ndarray       # () booked A packets (highest node)
    feedback_packets: jnp.ndarray    # () booked F packets (highest node)
    bits_on_air: jnp.ndarray         # () score+extra bits at the highest node


def quantize_scores(z: jnp.ndarray, bits: int,
                    ) -> tuple[jnp.ndarray, jnp.ndarray | None]:
    """Uniform symmetric per-component quantizer.

    ``scale[k] = max_t |z[t, k]| / (2^(bits-1) - 1)``; codes are
    ``round(z / scale)`` clipped to the signed range; returns the
    *dequantized* scores ``codes * scale`` (what both the node and the sink
    reconstruct from) and the per-component scales.  ``bits == 0`` is the
    identity (no quantization, scale ``None``).
    """
    if bits == 0:
        return z, None
    if bits == 1 or bits < 0:
        raise ValueError(f"bits must be 0 or >= 2, got {bits}")
    levels = (1 << (bits - 1)) - 1
    scale = jnp.max(jnp.abs(z), axis=0) / levels
    scale = jnp.maximum(scale, jnp.finfo(z.dtype).tiny)
    codes = jnp.clip(jnp.round(z / scale), -levels, levels)
    return codes * scale, scale


def epoch_packet_split(q: int, c_max: int, cfg: CompressionConfig,
                       ) -> tuple[float, float]:
    """(A packets up, F packets down) of one flag-free compressed epoch at
    the highest-loaded node.

    A carries the q score records at the quantized width; F carries the
    scores back down PLUS — when quantizing — the q full-precision
    per-component scales the nodes need to dequantize (re-derived from
    every round's scores, so they travel every round).  The two halves sum
    exactly to :func:`repro.core.costs.quantized_supervised_round_cost`'s
    flag-free communication — the cost model owns the total (the driver
    books through :func:`compression_round_cost`, which delegates to it);
    this split exists only for the metrics' A/F fields, and the sum
    equality is pinned in tests/test_compression_tier.py.
    """
    unit = q * (c_max + 1)                      # Eq. 7: one q-record A or F
    if cfg.score_bits == 0:
        return float(unit), float(unit)
    frac = cfg.score_bits / cfg.word_bits
    return float(unit * frac), float(unit * frac + unit)


def compression_round_cost(q: int, c_max: int, cfg: CompressionConfig,
                           ) -> float:
    """Flag-free packet bill of one compressed epoch at the highest node
    (the cost model is the source of truth; see epoch_packet_split)."""
    return costs.quantized_supervised_round_cost(
        q, c_max, cfg.score_bits, cfg.word_bits).communication


def compress_round(W: jnp.ndarray, mean: jnp.ndarray | None,
                   x: jnp.ndarray, cfg: CompressionConfig,
                   c_max: int,
                   mask: jnp.ndarray | None = None,
                   interpret: bool | None = None) -> RoundCompression:
    """Compress one (n, p) measurement round against basis W (p, q).

    Unquantized (``score_bits == 0``): the fused Pallas kernel emits
    scores, reconstruction and flags in one pass.  Quantized: the kernel
    composition project → quantize → reconstruct → flag (the quantizer
    needs the whole round's scores to set the per-component scales, so the
    single-pass fusion doesn't apply — see EXPERIMENTS.md).

    ``mask`` is the round's (p,) or (n, p) liveness/validity array: dead
    sensors contribute no score record, raise no notification, and are
    excluded from ``max_err`` (no guarantee is owed for a sensor that sent
    nothing).  Books the Sec.-2.4.1 packet bill via
    :func:`repro.core.costs.quantized_supervised_round_cost`.
    """
    n, p = x.shape
    q = W.shape[1]
    eps = cfg.epsilon
    x = jnp.asarray(x, jnp.float32)
    if mask is None:
        mask2d = jnp.ones((n, p), jnp.float32)
    else:
        mask2d = jnp.asarray(mask, jnp.float32)
        if mask2d.ndim == 1:
            mask2d = jnp.broadcast_to(mask2d[None, :], (n, p))

    if cfg.score_bits == 0:
        z, x_hat, flagged = ops.supervised_compress(
            x, W, mean, epsilon=eps, mask=mask2d, interpret=interpret)
    else:
        mean_row = (jnp.zeros((p,), jnp.float32) if mean is None
                    else jnp.asarray(mean, jnp.float32))
        z_full = ops.pca_project((x - mean_row[None, :]) * mask2d, W,
                                 interpret=interpret)
        z, _ = quantize_scores(z_full, cfg.score_bits)
        x_hat = ops.pca_reconstruct(z, W, interpret=interpret) \
            + mean_row[None, :]
        flagged = (jnp.abs(x - x_hat) > eps) & (mask2d > 0.0)

    return compression_books(x, z, x_hat, flagged, mask2d, cfg, q, c_max)


def compression_books(x: jnp.ndarray, z: jnp.ndarray, x_hat: jnp.ndarray,
                      flagged: jnp.ndarray, mask2d: jnp.ndarray,
                      cfg: CompressionConfig, q: int, c_max: int,
                      ) -> RoundCompression:
    """Turn one round's stage outputs (scores, reconstruction, flag mask)
    into the :class:`RoundCompression` record — sink view, max error over
    live sensors, and the Sec.-2.4.1 packet books.

    The tail of :func:`compress_round`, split out so the fused driver path
    (:func:`repro.streaming.driver.chunk_stream_step`) can build identical
    books from the mega-kernel's outputs without re-running a stage kernel.
    """
    fl = flagged.astype(jnp.float32)
    x_sink = jnp.where(flagged, x, x_hat)
    err = jnp.abs(x - x_sink) * mask2d          # dead sensors owe no bound
    n_flagged = jnp.sum(fl)
    a_pk, f_pk = epoch_packet_split(q, c_max, cfg)
    return RoundCompression(
        z=z,
        x_sink=x_sink if cfg.emit_reconstruction else None,
        flagged=fl if cfg.emit_reconstruction else None,
        max_err=jnp.max(err),
        extra_packets=n_flagged,
        score_packets=jnp.asarray(a_pk),
        feedback_packets=jnp.asarray(f_pk),
        bits_on_air=(a_pk + f_pk) * cfg.word_bits
        + n_flagged * cfg.word_bits,
    )
