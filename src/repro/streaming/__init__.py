"""Streaming distributed PCA: online covariance + drift-triggered refreshes.

The paper computes principal components from a covariance snapshot; this
package is the *online* continuation (DESIGN.md Sec. 8): sensor rounds arrive
continuously, the banded sufficient statistics are folded in place with an
exponential forgetting factor, and the basis is recomputed only when the
retained variance of the current components drifts past a threshold — the
accuracy-vs-communication tradeoff of the paper's Secs. 3-4 replayed in time.

Submodules
----------
online_cov   OnlineCovariance state + forgetting-factor updates (Pallas
             cov-update kernel on the hot path) and the ``lax.scan`` driver
scheduler    RecomputeScheduler: retained-variance drift monitor +
             orthogonal-iteration basis refresh with Table-1 cost accounting
compressor   ε-supervised compression stage (Sec. 2.4.1 on device): fused
             Pallas project/reconstruct/flag pass + uniform score quantizer
detector     T²/SPE event-detection stage (Sec. 2.4.3 on device): fused
             Pallas monitoring pass + Wilson-Hilferty thresholds with
             healthy-window recalibration after every basis refresh
driver       single-network stream loop, ``jax.vmap`` batched multi-network
             driver and the ``shard_map`` sharded runner
hierarchy    two-level million-sensor fleets (DESIGN.md Sec. 13): per-region
             streaming + cross-host energy-merge collectives over the
             ``region`` mesh axis, Table-1 merge billing
"""

from repro.streaming.online_cov import (
    OnlineCovariance, online_init, online_update, online_update_chunk,
    online_estimate, stream_covariance,
)
from repro.streaming.scheduler import (
    RecomputeScheduler, SchedulerState, retained_fraction, ortho_refresh,
    ortho_refresh_evals,
)
from repro.streaming.compressor import (
    CompressionConfig, RoundCompression, quantize_scores, compress_round,
)
from repro.streaming.detector import (
    DetectionConfig, DetectorState, RoundDetection, detect_round,
    detector_init, wilson_hilferty,
)
from repro.streaming.driver import (
    StreamConfig, StreamState, RoundMetrics, stream_init, stream_step,
    chunk_stream_step, stream_run, chunked_stream_run, batched_stream_run,
    sharded_stream_run,
)
from repro.streaming.hierarchy import (
    FleetBasis, FleetMerge, region_energies, merge_fleet, fleet_basis_dense,
    hierarchical_stream_init, hierarchical_stream_run,
)

__all__ = [
    "OnlineCovariance", "online_init", "online_update",
    "online_update_chunk", "online_estimate", "stream_covariance",
    "RecomputeScheduler", "SchedulerState", "retained_fraction",
    "ortho_refresh", "ortho_refresh_evals",
    "CompressionConfig", "RoundCompression", "quantize_scores",
    "compress_round",
    "DetectionConfig", "DetectorState", "RoundDetection", "detect_round",
    "detector_init", "wilson_hilferty",
    "StreamConfig", "StreamState", "RoundMetrics", "stream_init",
    "stream_step", "chunk_stream_step", "stream_run", "chunked_stream_run",
    "batched_stream_run", "sharded_stream_run",
    "FleetBasis", "FleetMerge", "region_energies", "merge_fleet",
    "fleet_basis_dense", "hierarchical_stream_init",
    "hierarchical_stream_run",
]
