import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Perf-debugging tool: list the largest collectives of a dry-run cell with
their enclosing computation, trip-count multiplier and wire bytes.

    python -m repro.launch.inspect_collectives --arch llama3-405b \
        --shape train_4k [--multipod] [--top 15]
"""

import argparse
import re

import jax
import numpy as np

from repro.launch import hlo_analysis as H


def dump_largest(hlo_text: str, n_devices: int, top: int = 15):
    comps = H._split_computations(hlo_text)
    body_trip = {}
    children = {name: [] for name in comps}
    for name, lines in comps.items():
        for ln in lines:
            m = H._WHILE_RE.search(ln)
            if m:
                cond, body = m.group(1), m.group(2)
                body_trip[body] = H._trip_count(comps.get(cond, []))
                children[name].append(body)
    mult = {name: 1.0 for name in comps}

    def visit(name, factor):
        mult[name] = max(mult.get(name, 1.0), factor)
        for child in children.get(name, []):
            # unknown trip (None): display-only tool — floor at x1, the
            # printed "trip" column still shows the floored multiplier
            visit(child, factor * (body_trip.get(child) or 1))

    for name in comps:
        if name not in body_trip:
            visit(name, 1.0)

    rows = []
    for name, lines in comps.items():
        for ln in lines:
            for kind in H._COLLECTIVES:
                if f" {kind}(" in ln or f" {kind}-start(" in ln:
                    lhs = ln.split(f" {kind}")[0]
                    nbytes = H._result_bytes(lhs)
                    g = H._group_size(ln, n_devices)
                    wire = (2 * nbytes * (g - 1) / g if kind == "all-reduce"
                            else nbytes if kind == "collective-permute"
                            else nbytes * (g - 1) / g)
                    meta = re.search(r'op_name="([^"]*)"', ln)
                    rows.append({
                        "kind": kind, "comp": name, "trip": mult.get(name, 1),
                        "bytes": nbytes, "wire_total": wire * mult.get(name, 1),
                        "group": g,
                        "op": meta.group(1)[-90:] if meta else "?",
                    })
                    break
    rows.sort(key=lambda r: -r["wire_total"])
    total = sum(r["wire_total"] for r in rows)
    print(f"total wire/dev: {total/2**30:.1f} GiB")
    for r in rows[:top]:
        print(f"{r['wire_total']/2**30:9.2f} GiB  {r['kind']:<18} x{r['trip']:<6.0f}"
              f" g={r['group']:<4} {r['bytes']/2**20:8.1f} MiB/op  {r['op']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--opt-level", type=int, default=0)
    args = ap.parse_args()

    from repro.launch.dryrun import build_lm_cell, build_wsn_cell
    from repro.launch.mesh import make_production_mesh
    from repro.distributed.sharding import activation_sharding, act_rules

    mesh = make_production_mesh(multi_pod=args.multipod)
    n_dev = int(np.prod(mesh.devices.shape))
    if args.arch == "wsn-1m":
        fn, cell_args, extra = build_wsn_cell(args.shape, mesh)
    else:
        fn, cell_args, extra = build_lm_cell(args.arch, args.shape, mesh,
                                             opt_level=args.opt_level)
    donate = extra.pop("donate", ())
    with mesh, activation_sharding(mesh, act_rules(args.multipod)):
        compiled = jax.jit(fn, donate_argnums=donate).lower(*cell_args).compile()
    dump_largest(compiled.as_text(), n_dev, args.top)


if __name__ == "__main__":
    main()
