"""Analytic roofline terms (per device) for every dry-run cell.

Why this exists: XLA's ``cost_analysis()`` on the CPU backend counts each
``while``/``scan`` body ONCE, not times its trip count (verified empirically
— see EXPERIMENTS.md Sec. Dry-run caveats).  Our models are scan-over-layers
inside scan-over-microbatches with further inner scans (SSD chunks, chunked
attention), so HLO-reported FLOPs/bytes undercount by 1-3 orders of
magnitude, inconsistently across cells.  The roofline table therefore uses:

* **compute term**: exact analytic FLOPs (standard MFU accounting:
  6·N_active·D for training, 2·N_active·D + attention quadratic terms for
  inference, family-specific SSD/MoE corrections),
* **memory term**: an explicit per-step HBM traffic model (documented per
  term below),
* **collective term**: the loop-aware HLO-parsed wire bytes
  (repro.launch.hlo_analysis multiplies each while-body's collectives by its
  statically parsed trip count, nesting included).

``peak memory`` always comes from ``compiled.memory_analysis()`` which uses
buffer assignment and is loop-correct.
"""

from __future__ import annotations

import dataclasses

from repro import configs
from repro.configs import SHAPES
from repro.configs.wsn_1m import CONFIG as WSN
from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS

BF16 = 2
F32 = 4


@dataclasses.dataclass(frozen=True)
class CellModel:
    flops_global: float          # whole-job FLOPs per step
    hbm_bytes_global: float      # whole-job HBM traffic per step
    collective_scale: float      # multiply parsed HLO wire bytes by this

    def terms(self, chips: int, parsed_wire_bytes_per_dev: float) -> dict:
        compute_s = self.flops_global / chips / PEAK_FLOPS
        memory_s = self.hbm_bytes_global / chips / HBM_BW
        collective_s = (parsed_wire_bytes_per_dev * self.collective_scale
                        / ICI_BW)
        dom = max(("compute", compute_s), ("memory", memory_s),
                  ("collective", collective_s), key=lambda kv: kv[1])
        return {"compute_s": compute_s, "memory_s": memory_s,
                "collective_s": collective_s, "dominant": dom[0],
                "bound_s": dom[1]}


def _attn_fwd_flops(cfg, B, S_q, S_kv_avg) -> float:
    """scores + PV for all layers: 4 * B * S_q * S_kv * H * hd."""
    if cfg.family == "ssm":
        return 0.0
    L = cfg.n_layers
    win = [w if w > 0 else None for w in _windows(cfg)]
    total = 0.0
    for w in win:
        kv = S_kv_avg if w is None else min(w, S_kv_avg)
        total += 4.0 * B * S_q * kv * cfg.n_heads * cfg.head_dim
    if cfg.family == "encdec":
        # encoder self (bidir, full) + decoder cross
        total += cfg.enc_layers * 4.0 * B * S_q * 2 * S_q \
            * cfg.n_heads * cfg.head_dim
    return total


def _windows(cfg):
    import numpy as np
    from repro.models.transformer import layer_windows
    return layer_windows(cfg).tolist()


def _ssd_core_flops(cfg, B, S, chunk=128) -> float:
    """Intra-chunk quadratic + state terms per token, all layers."""
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    nh = cfg.n_ssm_heads
    hd = cfg.ssm_headdim
    n = cfg.d_state
    q = min(chunk, S)
    per_token = nh * (2.0 * q * (n + hd) + 4.0 * hd * n)
    return cfg.n_layers * B * S * per_token


def _param_bytes(cfg, dtype_bytes=BF16) -> float:
    return float(cfg.param_count()) * dtype_bytes


def lm_cell_model(arch: str, shape: str, chips: int,
                  microbatches: int = 1) -> CellModel:
    cfg = configs.get(arch)
    shp = SHAPES[shape]
    B, S = shp.global_batch, shp.seq_len
    n_act = float(cfg.active_param_count())
    P = _param_bytes(cfg)

    if shp.kind == "train":
        D = B * S
        flops = 6.0 * n_act * D + 3.0 * _attn_fwd_flops(cfg, B, S, S / 2) \
            + 3.0 * _ssd_core_flops(cfg, B, S)
        # HBM model: params fwd+bwd reads (2P), grad write+read (2P),
        # opt: param rw + 2 moments rw (params fp32 master absent: bf16) —
        # ~ (2+2+2+4)*P; activations: remat stash w+r + recompute w ~ 3
        # passes of (B,S,d) per layer per microbatch; logits w+r fp32.
        act = 3.0 * microbatches * cfg.n_layers \
            * (B / microbatches) * S * cfg.d_model * BF16
        logits = 2.0 * B * S * cfg.vocab_size * F32
        hbm = 10.0 * P + act + logits
        return CellModel(flops, hbm, 1.0)

    if shp.kind == "prefill":
        D = B * S
        flops = 2.0 * n_act * D + _attn_fwd_flops(cfg, B, S, S / 2) \
            + _ssd_core_flops(cfg, B, S)
        # params once; activations ~2 passes/layer; KV cache write;
        # chunked attention re-reads K,V per query chunk (nq times)
        act = 2.0 * cfg.n_layers * B * S * cfg.d_model * BF16
        kv_bytes = (2.0 * cfg.n_layers * B * S
                    * cfg.n_kv_heads * cfg.head_dim * BF16)
        nq = max(S // 1024, 1)
        hbm = P + act + kv_bytes * (1.0 + 0.5 * nq)
        return CellModel(flops, hbm, 1.0)

    # decode: one token per sequence
    cache_len = S
    flops = 2.0 * n_act * B + _attn_fwd_flops(cfg, B, 1, cache_len)
    win = [w if w > 0 else cache_len for w in _windows(cfg)] or [cache_len]
    kv_read = sum(2.0 * B * min(w, cache_len) * cfg.n_kv_heads
                  * cfg.head_dim * BF16 for w in win)
    if cfg.family in ("ssm", "hybrid"):
        nh = cfg.n_ssm_heads
        kv_read += 2.0 * cfg.n_layers * B * nh * cfg.ssm_headdim \
            * cfg.d_state * F32            # state read+write
    hbm = P + kv_read + 2.0 * B * cfg.vocab_size * F32
    return CellModel(flops, hbm, 1.0)


def wsn_cell_model(shape: str, chips: int) -> CellModel:
    p, h, q, n = WSN.p, WSN.halfwidth, WSN.q, WSN.batch_epochs
    nb = 2 * h + 1
    if shape == "cov_update":
        flops = 2.0 * n * nb * p
        hbm = (2.0 * nb * p + n * p * (nb / 64.0 + 1)) * F32
        # band r+w; x read (re-read per diagonal block, ~nb/64 effective)
        return CellModel(flops, hbm, 1.0)
    if shape == "pim_block":
        flops = 2.0 * nb * p * q + 4.0 * p * q * q
        hbm = (nb * p + 3.0 * p * q) * F32
        return CellModel(flops, hbm, 1.0)
    if shape == "pim_deflated":
        flops = 2.0 * nb * p + 4.0 * p * (q - 1)
        hbm = (nb * p + p * q + 2.0 * p) * F32
        return CellModel(flops, hbm, 1.0)
    if shape == "transform":
        flops = 2.0 * n * p * q
        hbm = (n * p + p * q + n * q) * F32
        return CellModel(flops, hbm, 1.0)
    raise KeyError(shape)


def cell_model(arch: str, shape: str, chips: int,
               microbatches: int = 1) -> CellModel:
    if arch == "wsn-1m":
        return wsn_cell_model(shape, chips)
    return lm_cell_model(arch, shape, chips, microbatches)
