"""Roofline-informed kernel tile targets (per backend, per dtype).

The kernel wrappers in :mod:`repro.kernels.ops` used to hard-code their
block-size targets (rows 128, features 512 — the shapes the kernels were
first tuned at).  This module derives the targets instead, from the same
machine model :mod:`repro.launch.hlo_analysis` uses for the dry-run
roofline (:data:`PEAK_FLOPS` / :data:`HBM_BW` of a v5e core) plus the VMEM
capacity, so a backend with different balance points picks different tiles
without touching kernel code.

Derivation (TPU branch):

* the minimum profitable tile is the register-file native shape — (8, 128)
  sublanes × lanes at fp32, (16, 128) at bf16 (packed sublanes);
* the row-block target is sized so a double-buffered working set of the
  fused kernel (x + halo slab + stage outputs, ~4 (block_n, p)-sized tiles
  in flight) stays under half of VMEM at the largest supported feature
  width — rounded down to a power of two;
* the feature target keeps the arithmetic intensity of the band fold above
  the HBM ridge point (FLOPs/byte = PEAK_FLOPS / HBM_BW): each band
  product reads 8 bytes/feature and does 2·(2h+1) FLOPs, so wider feature
  tiles only help until the slab exceeds VMEM — the cap lands at the
  historical 512 for fp32 and doubles for bf16 (half the bytes per lane).

Non-TPU backends (the CPU CI container runs every kernel in interpret
mode) return the historical targets unchanged, so every existing result is
bit-identical: tiling is part of the accumulation order, and the
differential suites pin bits, not just values.
"""

from __future__ import annotations

import jax

from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS

__all__ = ["VMEM_BYTES", "MIN_TILE", "RIDGE_FLOPS_PER_BYTE",
           "block_targets"]

# v5e per-core VMEM (the budget the fused kernel's working set must fit)
VMEM_BYTES = 16 * 2 ** 20

# the HBM ridge point of the machine model: an op under this arithmetic
# intensity is bandwidth-bound regardless of tile shape — which the band
# fold (2·(2h+1) FLOPs per 8 bytes) always is, hence width-greedy slabs
RIDGE_FLOPS_PER_BYTE = PEAK_FLOPS / HBM_BW

# native register tile (sublanes, lanes) per dtype byte-width
MIN_TILE = {4: (8, 128), 2: (16, 128)}

# the shapes the kernels were tuned at before this module existed — every
# non-TPU backend keeps them so interpret-mode results stay bit-identical
_HISTORICAL = {"rows": 128, "features": 512}


def _dtype_bytes(dtype: str) -> int:
    return {"fp32": 4, "float32": 4, "bf16": 2, "bfloat16": 2}[dtype]


def block_targets(kind: str, dtype: str = "fp32",
                  backend: str | None = None) -> dict[str, int]:
    """Tile-size targets ``{"rows": ..., "features": ...}`` for a kernel
    family.

    ``kind`` names the wrapper family (``"cov"``, ``"stage"``,
    ``"fused"``, ``"banded"`` — they share the row/feature split);
    ``dtype`` the tile-load dtype (``"fp32"``/``"bf16"``); ``backend``
    overrides the detected JAX backend (tests pass ``"tpu"`` explicitly —
    the CI container is CPU-only).

    The returned numbers are *targets*: the wrappers still clamp to exact
    divisors where that preserves historical bit-exactness, and pad
    otherwise (:func:`repro.kernels.ops._pick_block_padded`).
    """
    if kind not in ("cov", "stage", "fused", "banded"):
        raise ValueError(f"unknown kernel family {kind!r}")
    be = backend or jax.default_backend()
    if be != "tpu":
        return dict(_HISTORICAL)
    nbytes = _dtype_bytes(dtype)
    sub, lanes = MIN_TILE[nbytes]
    # feature target: the band fold reads 8 bytes/feature for 2·(2h+1)
    # FLOPs, far under the ridge point (PEAK_FLOPS / HBM_BW), so the fold
    # is bandwidth-bound at any width — the slab goes as wide as the byte
    # budget allows: the historical 512 lanes at fp32, double at bf16
    # (half the bytes per lane buys double the features per slab)
    features = 512 * (4 // nbytes)
    # row target: largest power of two whose double-buffered working set
    # (~4 (rows, features) tiles in flight: x + halo slab + stage outputs)
    # still fits half of VMEM
    rows = sub
    while (4 * 2 * (2 * rows) * features * nbytes <= VMEM_BYTES // 2
           and rows < 1024):
        rows *= 2
    return {"rows": max(rows, _HISTORICAL["rows"]),
            "features": max(features, lanes)}
