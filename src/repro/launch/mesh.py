"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
does not touch jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization and then calls these.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_fleet_mesh",
           "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips for two pods.

    Axes: 'data' carries DP/FSDP, 'model' carries TP/EP; 'pod' (multi-pod)
    carries the cross-pod data-parallel / FSDP dimension over DCI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(region: int | None = None, data: int = 1):
    """Two-level fleet mesh: 'region' carries the cross-host hierarchy axis
    (one shard per group of regions, the only axis the per-refresh merge
    collectives cross — DESIGN.md Sec. 13), 'data' the intra-shard networks
    axis.  ``region=None`` spreads the region axis over every local device
    (the multi-host simulation shape: ``XLA_FLAGS
    --xla_force_host_platform_device_count=N`` forced before jax init).
    """
    n = (jax.device_count() // data) if region is None else region
    return jax.make_mesh((n, data), ("region", "data"))


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
