import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh).

For each cell this driver builds the jitted step with full production
shardings, calls ``.lower(**ShapeDtypeStruct inputs).compile()`` (no device
allocation), and records:

* ``compiled.memory_analysis()``  — per-device argument/temp/output bytes,
* ``compiled.cost_analysis()``    — per-device FLOPs + bytes accessed,
* parsed collective wire bytes    — from the post-SPMD optimized HLO,
* the three roofline terms        — repro.launch.hlo_analysis.

Cells: the 10 assigned LM architectures x their shape sets (train_4k /
prefill_32k / decode_32k / long_500k where applicable) plus the paper's own
system at production scale (wsn-1m: cov / pim / pim_faithful / transform).

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k \
        --mesh pod --out results.jsonl
    python -m repro.launch.dryrun --list          # enumerate all cells
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro import configs
from repro.configs import SHAPES, applicable_shapes
from repro.configs.wsn_1m import CONFIG as WSN
from repro.core import covariance as cov
from repro.core import production as wsn_prod
from repro.distributed.sharding import (activation_sharding, act_rules,
                                        param_rules)
from repro.launch import hlo_analysis as H
from repro.launch.mesh import (make_local_mesh, make_production_mesh,
                               mesh_axis_sizes)
from repro.models import transformer as T
from repro.models.params import param_pspecs
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainConfig, make_train_step

WSN_SHAPES = ["cov_update", "pim_block", "pim_deflated", "transform",
              "hier_merge"]


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------
def _spec(mesh, dims, axes):
    """PartitionSpec from mesh-axis names with divisibility fallback."""
    sizes = mesh_axis_sizes(mesh)
    entries = []
    for dim, ax in zip(dims, axes):
        if ax is None:
            entries.append(None)
            continue
        ax_tuple = (ax,) if isinstance(ax, str) else tuple(ax)
        total = int(np.prod([sizes[a] for a in ax_tuple]))
        if dim % total != 0:
            entries.append(None)
        else:
            entries.append(ax_tuple if len(ax_tuple) > 1 else ax_tuple[0])
    return PartitionSpec(*entries)


def _sds(shape, dtype, mesh, axes):
    return jax.ShapeDtypeStruct(
        shape, dtype,
        sharding=NamedSharding(mesh, _spec(mesh, shape, axes)))


def _dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _shard_tree(tree_shapes, specs_tree, mesh):
    """Attach NamedShardings to an eval_shape pytree."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        tree_shapes, specs_tree)


def _params_specs(cfg, mesh):
    schema = T.model_schema(cfg)
    rules = param_rules(multi_pod="pod" in mesh.axis_names)
    return param_pspecs(schema, rules, mesh_axis_sizes(mesh))


def _params_sds(cfg, mesh, dtype=jnp.bfloat16):
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))
    return _shard_tree(shapes, _params_specs(cfg, mesh), mesh)


def _decode_state_sds(cfg, mesh, batch, cache_len, enc_len=0):
    dp = _dp_axes(mesh)
    shapes = jax.eval_shape(
        lambda: T.init_decode_state(cfg, batch, cache_len,
                                    dtype=jnp.bfloat16, enc_len=enc_len))

    def spec_for(path, sds):
        name = "/".join(str(getattr(p, "name", getattr(p, "key", p)))
                        for p in path)
        dims = sds.shape
        if "attn/pos" in name:
            axes = (None, dp, "model")
        elif "attn/" in name:                      # k, v: (L,B,Cl,K,Dh)
            axes = (None, dp, "model", None, None)
        elif "ssm/h" in name:                      # (L,B,nh,hd,N)
            axes = (None, dp, "model", None, None)
        elif "ssm/conv" in name:                   # (L,B,dc-1,conv_dim)
            axes = (None, dp, None, "model")
        elif "cross" in name:                      # (L,B,Se,K,Dh)
            axes = (None, dp, "model", None, None)
        else:
            axes = tuple(None for _ in dims)
        return jax.ShapeDtypeStruct(
            dims, sds.dtype,
            sharding=NamedSharding(mesh, _spec(mesh, dims, axes)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    leaves = [spec_for(path, sds) for path, sds in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _opt_sds(params_sds, moment_dtype):
    """AdamW state ShapeDtypeStructs mirroring param shardings."""
    mdt = jnp.dtype(moment_dtype)
    moments = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, mdt, sharding=p.sharding),
        params_sds)
    from repro.train.optimizer import AdamWState
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      mu=moments, nu=jax.tree.map(lambda x: x, moments))


def pick_microbatches(cfg, B, S, dp: int, budget_bytes=192 * 2**20) -> int:
    """Smallest power-of-two microbatch count keeping the per-device layer
    activation (B/m, S, d) bf16 — plus, for MoE, the per-data-shard
    dispatch buffer (T_loc * k * cf * d) — under budget."""
    m = 1
    while m < B:
        per_dev = (B // m) * S * cfg.d_model * 2 / dp
        if cfg.n_experts and cfg.top_k:
            per_dev += ((B // m) * S / dp) * cfg.top_k \
                * cfg.capacity_factor * cfg.d_model * 2
        if per_dev <= budget_bytes:
            break
        m *= 2
    return m


# ---------------------------------------------------------------------------
# Cell builders: return (fn, args tuple of ShapeDtypeStructs)
# ---------------------------------------------------------------------------
def build_lm_cell(arch: str, shape_name: str, mesh,
                  opt_level: int = 0):
    """opt_level 0 = paper-faithful baseline shardings; 1+ = Sec.-Perf
    optimizations (grad reduce-scatter constraints, ...)."""
    cfg = configs.get(arch)
    shp = SHAPES[shape_name]
    dp = _dp_axes(mesh)
    dp_size = int(np.prod([mesh_axis_sizes(mesh)[a] for a in dp]))
    big = cfg.param_count() > 5e10
    params = _params_sds(cfg, mesh)

    if shp.kind == "train":
        B, S = shp.global_batch, shp.seq_len
        remat_groups = 0
        budget = 192 * 2 ** 20
        if opt_level >= 2:
            # nested remat stashes only ~sqrt(L) boundaries, buying a 4x
            # larger microbatch (4x fewer re-gathers/re-reductions)
            L = cfg.n_layers
            remat_groups = next((g for g in range(int(L ** 0.5), 1, -1)
                                 if L % g == 0), 0)
            budget = 768 * 2 ** 20
        m = pick_microbatches(cfg, B, S, dp_size, budget_bytes=budget)
        tcfg = TrainConfig(
            optimizer=AdamWConfig(
                moment_dtype="bfloat16" if big else "float32"),
            microbatches=m,
            accum_dtype="bfloat16" if big else "float32",
            remat=True, remat_groups=remat_groups)
        grad_shardings = None
        if opt_level >= 1:
            grad_shardings = jax.tree.map(lambda s: s.sharding, params)
        step = make_train_step(cfg, tcfg, grad_shardings=grad_shardings)
        opt = _opt_sds(params, tcfg.optimizer.moment_dtype)
        if cfg.family == "encdec":
            Se = Sd = S // 2
            batch = {"tokens": _sds((B, Sd), jnp.int32, mesh, (dp, None)),
                     "enc_input": _sds((B, Se, cfg.d_model), jnp.bfloat16,
                                       mesh, (dp, None, None))}
        else:
            batch = {"tokens": _sds((B, S), jnp.int32, mesh, (dp, None))}
        fn = lambda p, o, b, s: step(p, o, None, b, s)
        args = (params, opt, batch, jax.ShapeDtypeStruct((), jnp.int32))
        return fn, args, {"microbatches": m, "donate": (0, 1)}

    if shp.kind == "prefill":
        B, S = shp.global_batch, shp.seq_len
        if cfg.family == "encdec":
            Se = Sd = S // 2
            state = _decode_state_sds(cfg, mesh, B, Sd, enc_len=Se)
            tokens = _sds((B, Sd), jnp.int32, mesh, (dp, None))
            enc = _sds((B, Se, cfg.d_model), jnp.bfloat16,
                       mesh, (dp, None, None))
            fn = lambda p, tok, st, e: T.prefill(p, cfg, tok, st, enc_input=e)
            return fn, (params, tokens, state, enc), {"donate": (2,)}
        state = _decode_state_sds(cfg, mesh, B, S)
        tokens = _sds((B, S), jnp.int32, mesh, (dp, None))
        fn = lambda p, tok, st: T.prefill(p, cfg, tok, st)
        return fn, (params, tokens, state), {"donate": (2,)}

    # decode
    B, S = shp.global_batch, shp.seq_len
    if cfg.family == "encdec":
        Se = Sd = S // 2
        state = _decode_state_sds(cfg, mesh, B, Sd, enc_len=Se)
    else:
        state = _decode_state_sds(cfg, mesh, B, S)
    tokens = _sds((B, 1), jnp.int32, mesh, (dp, None))
    t = jax.ShapeDtypeStruct((), jnp.int32)
    fn = lambda p, tok, st, tt: T.decode_step(p, cfg, tok, st, tt)
    return fn, (params, tokens, state, t), {"donate": (2,)}


def build_wsn_cell(shape_name: str, mesh, wsn=WSN):
    """The paper's production system; feature axis over every mesh axis."""
    all_axes = tuple(mesh.axis_names)
    p, h, q, n = wsn.p, wsn.halfwidth, wsn.q, wsn.batch_epochs
    nb = 2 * h + 1
    band = _sds((nb, p), jnp.float32, mesh, (None, all_axes))

    if shape_name == "cov_update":
        t_s = jax.ShapeDtypeStruct((), jnp.float32)
        s_s = _sds((p,), jnp.float32, mesh, (all_axes,))
        x = _sds((n, p), jnp.float32, mesh, (None, all_axes))

        def fn(t, s, b, xx):
            # halfwidth stays static (python int), not a traced leaf
            st = cov.BandedCovState(t=t, s=s, band=b, halfwidth=h)
            new = wsn_prod.cov_update_step(st, xx)
            return new.t, new.s, new.band

        return fn, (t_s, s_s, band, x), {"donate": (0, 1, 2)}
    if shape_name == "pim_block":
        v = _sds((p, q), jnp.float32, mesh, (all_axes, None))
        fn = lambda b, vv: wsn_prod.pim_block_step(b, vv)
        return fn, (band, v), {}
    if shape_name == "pim_deflated":
        v = _sds((p,), jnp.float32, mesh, (all_axes,))
        w_prev = _sds((p, q - 1), jnp.float32, mesh, (all_axes, None))
        fn = lambda b, vv, w: wsn_prod.pim_deflated_step(b, vv, w)
        return fn, (band, v, w_prev), {}
    if shape_name == "transform":
        w = _sds((p, q), jnp.float32, mesh, (all_axes, None))
        mean = _sds((p,), jnp.float32, mesh, (all_axes,))
        x = _sds((n, p), jnp.float32, mesh, (None, all_axes))
        fn = lambda ww, mm, xx: wsn_prod.transform_step(ww, mm, xx)
        return fn, (w, mean, x), {}
    if shape_name == "hier_merge":
        # level-2 fleet merge (DESIGN.md Sec. 13): global top-q selection
        # over the gathered (regions, q_local) energy table
        from repro.streaming.hierarchy import merge_fleet
        lam = _sds((wsn.n_regions, q), jnp.float32, mesh, (all_axes, None))
        tv = jax.ShapeDtypeStruct((), jnp.float32)
        fn = lambda ll, dd: merge_fleet(ll, dd, q)
        return fn, (lam, tv), {}
    raise KeyError(shape_name)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------
def all_cells() -> list[tuple[str, str]]:
    cells = []
    for arch in configs.ASSIGNED:
        for shp in applicable_shapes(configs.get(arch)):
            cells.append((arch, shp))
    for shp in WSN_SHAPES:
        cells.append(("wsn-1m", shp))
    return cells


def skipped_cells() -> list[tuple[str, str, str]]:
    out = []
    for arch in configs.ASSIGNED:
        cfg = configs.get(arch)
        if not cfg.supports_long_context:
            out.append((arch, "long_500k",
                        "full-attention family: long_500k requires "
                        "sub-quadratic sequence mixing (DESIGN.md Sec. 4)"))
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_level: int = 0, smoke: bool = False) -> dict:
    if smoke:
        # CI-sized end-to-end check: the same cells at the smoke config's
        # scaled-down shapes, on a mesh over whatever local devices exist
        mesh = make_local_mesh(data=jax.device_count(), model=1)
        mesh_name = f"local{jax.device_count()}"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = int(np.prod(mesh.devices.shape))
    rec = {"arch": arch, "shape": shape_name, "opt_level": opt_level,
           "mesh": mesh_name, "ok": False}
    t0 = time.time()
    try:
        if arch == "wsn-1m":
            wsn = WSN.smoke() if smoke else WSN
            fn, args, extra = build_wsn_cell(shape_name, mesh, wsn=wsn)
        else:
            fn, args, extra = build_lm_cell(arch, shape_name, mesh,
                                            opt_level=opt_level)
        donate = extra.pop("donate", ())
        rec.update(extra)
        rules = act_rules(multi_pod=multi_pod)
        with mesh, activation_sharding(mesh, rules):
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        # per-device peak ~ args + temps (aliased buffers counted once)
        rec["memory"]["peak_per_device"] = int(
            ma.argument_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes)
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # CPU backend wraps in a list
            ca = ca[0] if ca else {}
        rec["cost"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes accessed": float(ca.get("bytes accessed", 0.0))}
        coll = H.parse_collectives(compiled.as_text(), n_devices=n_dev)
        rec["collectives"] = {
            "counts": coll.counts,
            "wire_bytes": {k: float(v) for k, v in coll.wire_bytes.items()},
            "total_wire_bytes": float(coll.total_wire_bytes),
            "unknown_trips": list(coll.unknown_trips),
        }
        # an unparseable while bound makes roofline_terms raise (the wire
        # bytes would be under-counted) — that marks the cell failed, the
        # fail-loud half of the unknown-trip policy

        terms = H.roofline_terms(rec["cost"], coll)
        rec["roofline"] = {
            "compute_s": terms.compute_s,
            "memory_s": terms.memory_s,
            "collective_s": terms.collective_s,
            "dominant": terms.dominant,
        }
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — cell failures are data
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--opt-level", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="WSNConfig.smoke() shapes on a local-device mesh "
                         "(CI end-to-end check; wsn-1m cells only)")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a, s in all_cells():
            print(f"{a}\t{s}")
        for a, s, why in skipped_cells():
            print(f"{a}\t{s}\tSKIP: {why}")
        return

    cells = all_cells()
    if args.smoke:
        cells = [(a, s) for a, s in cells if a == "wsn-1m"]
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    if args.smoke:
        meshes = [False]            # one local mesh — run_cell builds it

    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, opt_level=args.opt_level,
                           smoke=args.smoke)
            line = json.dumps(rec)
            with open(args.out, "a") as f:
                f.write(line + "\n")
            status = "OK " if rec["ok"] else "FAIL"
            print(f"[{status}] {arch} {shape} {rec['mesh']} "
                  f"({rec['total_s']}s)"
                  + ("" if rec["ok"] else f"  {rec.get('error')}"))


if __name__ == "__main__":
    main()
