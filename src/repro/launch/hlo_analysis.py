"""Post-compile HLO analysis: collective traffic + roofline terms.

``cost_analysis()`` gives per-device FLOPs and bytes accessed but no
collective traffic, so we parse the optimized (post-SPMD-partitioning) HLO
text and sum the wire bytes of every collective op.

Wire-byte model (per device, ring algorithms — the XLA default on ICI):
    all-reduce          2 * N * (g-1)/g      (reduce-scatter + all-gather)
    all-gather          N * (g-1)/g          (N = full result bytes)
    reduce-scatter      N * (g-1)/g          (N = full input bytes)
    all-to-all          N * (g-1)/g
    collective-permute  N

Hardware constants (TPU v5e target):
    peak bf16 compute   197 TFLOP/s per chip
    HBM bandwidth       819 GB/s per chip
    ICI link bandwidth  ~50 GB/s per link
"""

from __future__ import annotations

import dataclasses
import math
import re

__all__ = ["CollectiveStats", "parse_collectives", "RooflineTerms",
           "roofline_terms", "fallback_trip", "ring_wire_bytes",
           "PEAK_FLOPS", "HBM_BW", "ICI_BW"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one shape token like  bf16[256,4096]{1,0}  or  f32[]
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
# iota-style replica groups:  [32,16]<=[512]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
# explicit groups:  {{0,1,2,3},{4,5,6,7}}
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype)
    if bpe is None:
        return 0                      # token types, opaque
    if not dims:
        return bpe
    return bpe * math.prod(int(d) for d in dims.split(",") if d)


def _result_bytes(lhs: str) -> int:
    """Sum all shape tokens on the result side (handles tuple results)."""
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def ring_wire_bytes(kind: str, nbytes: float, group: int) -> float:
    """Per-device wire bytes of one collective under the ring model in the
    module docstring.  ``nbytes`` is the full result (all-gather) / full
    input (everything else) size; shared by the HLO parser below and the
    jaxpr-level certifier (:mod:`repro.analysis.resources`), so both sides
    price a collective identically."""
    g = max(int(group), 1)
    if kind == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if kind == "collective-permute":
        return float(nbytes)
    return float(nbytes) * (g - 1) / g


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    result_bytes: dict        # sum of result sizes per kind
    wire_bytes: dict          # modeled per-device wire traffic per kind
    loop_corrected: bool = False
    unknown_trips: tuple = ()  # while bodies whose trip could not be parsed

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def trips_known(self) -> bool:
        return not self.unknown_trips


# -- loop-aware HLO structure -------------------------------------------------
# computation header: `%name (params...) -> result {` (ENTRY optional);
# params may contain nested parens, so match greedily up to `) ->`.
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->\s*"
                             r"\S.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+),"
                       r"\s*body=%?([\w\.\-]+)", re.DOTALL)
_CONST_RE = re.compile(r"%?([\w\.\-]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(([^)]*)\)")


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    current = None
    for line in text.splitlines():
        m = _COMP_HEADER_RE.match(line.strip())
        if m and ("{" in line):
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
                continue
            comps[current].append(line.strip())
    return comps


def fallback_trip(values) -> int | None:
    """Loop-trip fallback shared by the HLO and jaxpr walkers
    (:mod:`repro.analysis.jaxpr_lint`): a loop condition is tiny — the
    induction limit plus occasional 0/1 constants — so the largest scalar
    integer constant observed in it is the trip count, with a floor of 1.

    A condition with NO integer constants (a data-dependent bound) returns
    ``None`` — the trip is *unknown*.  It used to silently default to 1,
    which under-counted every collective and launch inside such a loop;
    callers must now either propagate the unknown (and fail loudly in
    whatever rule depends on the count) or supply an explicit bound."""
    ints = [int(v) for v in values]
    return max(max(ints), 1) if ints else None


def _trip_count(cond_lines: list[str]) -> int | None:
    """Trip count from a while condition: the constant compared against the
    induction variable.  The compare is frequently wrapped in a fusion, so
    after trying a direct compare we fall back to the largest scalar int
    constant in the condition computation (:func:`fallback_trip`); a
    condition with no constants at all yields ``None`` (unknown trip)."""
    consts = {}
    for ln in cond_lines:
        for name, val in _CONST_RE.findall(ln):
            consts[name] = int(val)
    for ln in cond_lines:
        if " compare(" in ln and "ROOT" in ln:
            m = _COMPARE_RE.search(ln)
            if m:
                for op in m.group(1).split(","):
                    op = op.strip().lstrip("%")
                    op = op.split()[-1].lstrip("%")
                    if op in consts:
                        return max(consts[op], 1)
    return fallback_trip(consts.values())


def _collective_bytes_in(lines: list[str], n_devices: int):
    counts = {k: 0 for k in _COLLECTIVES}
    rbytes = {k: 0.0 for k in _COLLECTIVES}
    wbytes = {k: 0.0 for k in _COLLECTIVES}
    for stripped in lines:
        for kind in _COLLECTIVES:
            # match the op use, not metadata; async pairs: count starts only
            if f" {kind}(" in stripped or f" {kind}-start(" in stripped:
                lhs = stripped.split(f" {kind}")[0]
                n = _result_bytes(lhs)
                g = _group_size(stripped, n_devices)
                counts[kind] += 1
                rbytes[kind] += n
                wbytes[kind] += ring_wire_bytes(kind, n, g)
                break
    return counts, rbytes, wbytes


def parse_collectives(hlo_text: str, n_devices: int = 512,
                      loop_aware: bool = True,
                      unknown_trip: int | None = None) -> CollectiveStats:
    """Sum collective traffic; with ``loop_aware`` every while-body's
    contribution is multiplied by its (statically parsed) trip count,
    including nesting — XLA prints each loop body once.

    A while-loop whose trip count cannot be parsed (data-dependent bound)
    uses the explicit ``unknown_trip`` bound if one is given; otherwise the
    body contributes x1 AND is recorded in ``CollectiveStats.unknown_trips``
    so downstream consumers (:func:`roofline_terms`) fail loudly instead of
    silently under-counting."""
    comps = _split_computations(hlo_text)
    if not comps or not loop_aware:
        counts, rbytes, wbytes = _collective_bytes_in(
            [l.strip() for l in hlo_text.splitlines()], n_devices)
        return CollectiveStats(counts, rbytes, wbytes, loop_corrected=False)

    # map body computation -> trip count, and parent -> child bodies
    body_trip: dict[str, int] = {}
    unknown: list[str] = []
    children: dict[str, list[str]] = {name: [] for name in comps}
    for name, lines in comps.items():
        for ln in lines:
            m = _WHILE_RE.search(ln)
            if m:
                cond, body = m.group(1), m.group(2)
                trip = _trip_count(comps.get(cond, []))
                if trip is None:
                    if unknown_trip is not None:
                        trip = int(unknown_trip)
                    else:
                        unknown.append(body)
                        trip = 1
                body_trip[body] = trip
                children[name].append(body)

    # multiplier for each computation = product of trip counts on the path
    # from the entry; computations not reached from a while get x1
    mult: dict[str, float] = {name: 1.0 for name in comps}

    def visit(name: str, factor: float):
        mult[name] = max(mult.get(name, 1.0), factor)
        for child in children.get(name, []):
            visit(child, factor * body_trip.get(child, 1))

    for name in comps:
        if name not in body_trip:          # roots: entry + non-loop comps
            visit(name, 1.0)

    counts = {k: 0 for k in _COLLECTIVES}
    rbytes = {k: 0.0 for k in _COLLECTIVES}
    wbytes = {k: 0.0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        c, r, w = _collective_bytes_in(lines, n_devices)
        f = mult.get(name, 1.0)
        for k in _COLLECTIVES:
            counts[k] += c[k]
            rbytes[k] += r[k] * f
            wbytes[k] += w[k] * f
    return CollectiveStats(counts, rbytes, wbytes, loop_corrected=True,
                           unknown_trips=tuple(unknown))


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float          # per-device FLOPs / peak
    memory_s: float           # per-device bytes accessed / HBM bw
    collective_s: float       # per-device wire bytes / ICI link bw
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Lower-bound step time if the three terms fully overlapped."""
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(cost: dict, coll: CollectiveStats, *,
                   allow_unknown_trips: bool = False) -> RooflineTerms:
    """Roofline terms from ``cost_analysis()`` numbers + collective stats.

    Refuses stats carrying unparsed while-loop trips — those wire bytes are
    under-counted by an unknown factor, and a roofline built on them would
    quietly report a too-fast bound.  Re-run :func:`parse_collectives` with
    an explicit ``unknown_trip=<bound>`` (or pass ``allow_unknown_trips=True``
    to accept the x1 floor knowingly)."""
    if coll.unknown_trips and not allow_unknown_trips:
        raise ValueError(
            "while-loop trip count unknown for HLO bodies "
            f"{list(coll.unknown_trips)} — collective wire bytes are "
            "under-counted; pass unknown_trip=<bound> to parse_collectives "
            "or allow_unknown_trips=True to accept the x1 floor")
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    wire = float(coll.total_wire_bytes)
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=wire / ICI_BW,
        flops_per_device=flops,
        bytes_per_device=byts,
        wire_bytes_per_device=wire,
    )
