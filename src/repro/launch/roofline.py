"""Roofline report: aggregate dry-run JSONL records into the Sec.-Roofline
table (EXPERIMENTS.md).

Per (arch x shape x mesh):
  compute / memory / collective terms (seconds), dominant term,
  MODEL_FLOPS = 6 N D (train) or 2 N_active D (inference) vs compiled
  HLO FLOPs (useful-compute ratio), peak bytes/device vs v5e HBM.

Usage:  python -m repro.launch.roofline results/*.jsonl [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import sys

from repro import configs
from repro.configs import SHAPES
from repro.configs.wsn_1m import CONFIG as WSN

V5E_HBM = 16 * 2 ** 30


def model_flops(arch: str, shape: str) -> float:
    """Analytic 'useful' FLOPs per step, whole job (all chips)."""
    if arch == "wsn-1m":
        p, h, q, n = WSN.p, WSN.halfwidth, WSN.q, WSN.batch_epochs
        nb = 2 * h + 1
        return {
            "cov_update": 2.0 * n * nb * p,
            "pim_block": 2.0 * nb * p * q + 4.0 * p * q * q,
            "pim_deflated": 2.0 * nb * p + 4.0 * p * (q - 1),
            "transform": 2.0 * n * p * q,
        }[shape]
    cfg = configs.get(arch)
    shp = SHAPES[shape]
    n_active = cfg.active_param_count()
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n_active * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shp.global_batch


def n_chips(mesh: str) -> int:
    return 512 if mesh == "2x16x16" else 256


def load(paths) -> list[dict]:
    recs = []
    for path in paths:
        with open(path) as f:
            for line in f:
                if line.strip():
                    recs.append(json.loads(line))
    # dedup on (arch, shape, mesh): keep the last record
    uniq = {}
    for r in recs:
        uniq[(r["arch"], r["shape"], r["mesh"])] = r
    return sorted(uniq.values(),
                  key=lambda r: (r["arch"], r["shape"], r["mesh"]))


def analyze(rec: dict) -> dict:
    from repro.launch.analytic import cell_model
    chips = n_chips(rec["mesh"])
    rl = rec.get("roofline", {})
    cost = rec.get("cost", {})
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = cost.get("flops", 0.0) * chips

    model = cell_model(rec["arch"], rec["shape"], chips,
                       microbatches=rec.get("microbatches", 1))
    wire_per_dev = rec.get("collectives", {}).get("total_wire_bytes", 0.0)
    # CPU-backend adjustment: XLA CPU upcasts bf16 dots to f32, so the
    # all-reduces of dot outputs ride fp32 shapes; the TPU lowering keeps
    # them bf16 — halve the AR component for the TPU estimate.
    ar = rec.get("collectives", {}).get("wire_bytes", {}).get("all-reduce", 0.0)
    wire_per_dev = wire_per_dev - 0.5 * ar
    terms = model.terms(chips, wire_per_dev)
    useful = mf / model.flops_global if model.flops_global else float("nan")
    frac = terms["compute_s"] / terms["bound_s"] if terms["bound_s"] \
        else float("nan")
    peak = rec.get("memory", {}).get("peak_per_device", 0)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "ok": rec["ok"],
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": terms["dominant"],
        "hlo_compute_s": rl.get("compute_s"),
        "hlo_memory_s": rl.get("memory_s"),
        "hlo_collective_s": rl.get("collective_s"),
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "peak_gb": peak / 2 ** 30,
        "fits_v5e": peak <= V5E_HBM,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*", default=["results/dryrun_*.jsonl"])
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    paths = []
    for p in args.paths:
        paths.extend(glob.glob(p))
    if not paths:
        sys.exit("no dry-run result files found")
    rows = [analyze(r) for r in load(paths) if r["ok"]]
    if args.mesh:
        rows = [r for r in rows if r["mesh"] == args.mesh]

    if args.md:
        print("| arch | shape | mesh | compute s | memory s | coll s |"
              " dominant | MODEL/HLO | comp/bound | peak GB | fits v5e |")
        print("|---|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                  f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
                  f"| {r['collective_s']:.3e} | {r['dominant']} "
                  f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
                  f"| {r['peak_gb']:.1f} | {'y' if r['fits_v5e'] else 'N'} |")
    else:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
