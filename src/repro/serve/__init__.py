"""Serving substrate: KV-cache LM engine + streaming-PCA fleet engine,
both with continuous batching over a fixed device batch."""
