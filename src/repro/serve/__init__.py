"""Serving substrate: KV-cache engine with continuous batching."""
