"""Serving engines: LM decode + streaming-PCA fleets, continuous batching.

Two slot-based engines in the vLLM style share the pattern *fixed device
batch, host-side slot management, jitted steps*:

* :class:`Engine` — the LM path: **prefill** runs per-request and writes the
  slot's region of the decode state; **decode** advances all active slots one
  token per call; finished slots (EOS or max_tokens) are refilled from the
  queue.
* :class:`StreamingPCAEngine` — the sensor path (DESIGN.md Sec. 8.4): each
  slot holds one live sensor network; every engine step folds one measurement
  round per slot through the jitted batched streaming step
  (:func:`repro.streaming.driver.stream_step` under ``vmap``), drift-triggered
  basis refreshes happen inside the step, and exhausted streams retire with
  their final basis + Table-1 communication bill.

The decode state is the stacked pytree from repro.models.transformer; slot
management is pure Python (host side), the steps are jitted.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.streaming.driver import (StreamConfig, StreamState, stream_init,
                                    stream_step)

__all__ = ["Request", "ServeConfig", "Engine",
           "StreamRequest", "StreamResult", "StreamingPCAEngine"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stops early
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8
    max_len: int = 512
    dtype: str = "float32"


class Engine:
    def __init__(self, cfg, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        dt = jnp.dtype(scfg.dtype)
        self.state = T.init_decode_state(cfg, scfg.slots, scfg.max_len,
                                         dtype=dt)
        self.pos = np.zeros(scfg.slots, np.int32)       # next content position
        self.active: list[Request | None] = [None] * scfg.slots
        self.queue: list[Request] = []

        self._decode = jax.jit(
            lambda p, tok, st, t: T.decode_step(p, cfg, tok, st, t))
        self._prefill = jax.jit(
            lambda p, tok, st: T.prefill(p, cfg, tok, st))
        self._last_tok = np.zeros((scfg.slots, 1), np.int32)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill empty slots from the queue (continuous batching)."""
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Run prefill for one request and splice its state into the slot.

        Implementation note: prefill is batched over a single row; the
        resulting caches are written into slot ``slot`` of the engine state.
        """
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        single = T.init_decode_state(self.cfg, 1, self.scfg.max_len,
                                     dtype=jnp.dtype(self.scfg.dtype))
        logits, single = self._prefill(self.params, prompt, single)

        def splice(full, one):
            # every stacked cache leaf has layout (L, B, ...): batch = axis 1
            return full.at[:, slot:slot + 1].set(one)

        self.state = jax.tree.map(splice, self.state, single)
        tok = int(np.asarray(jnp.argmax(logits, -1))[0])
        req.output.append(tok)
        self._last_tok[slot, 0] = tok
        self.pos[slot] = len(req.prompt)
        self.active[slot] = req

    # -- main loop ------------------------------------------------------------
    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        self._admit()
        live = [s for s in range(self.scfg.slots) if self.active[s]]
        if not live:
            return 0
        # per-slot positions: unaligned requests decode together (the
        # PosCache mask is derived from stored positions per row)
        batch_tok = jnp.asarray(self._last_tok)
        t_vec = jnp.asarray(self.pos, jnp.int32)
        logits, self.state = self._decode(self.params, batch_tok, self.state,
                                          t_vec)
        next_tok = np.asarray(jnp.argmax(logits, -1))
        for s in live:
            req = self.active[s]
            tok = int(next_tok[s])
            req.output.append(tok)
            self._last_tok[s, 0] = tok
            self.pos[s] += 1
            if (tok == req.eos_id
                    or len(req.output) >= req.max_new_tokens
                    or self.pos[s] >= self.scfg.max_len - 1):
                req.done = True
                self.active[s] = None
        return len(live)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return


# ===========================================================================
# Streaming-PCA fleet engine
# ===========================================================================
@dataclasses.dataclass
class StreamRequest:
    """One live sensor network: a finite stream of measurement rounds."""

    rounds: np.ndarray               # (R, n, p) float32 measurement rounds
    # filled by the engine:
    result: "StreamResult | None" = None
    done: bool = False


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Final per-network summary returned when a stream retires."""

    components: np.ndarray           # (p, q) final basis
    retained: float                  # rho of the final basis on the live cov
    refreshes: int                   # scheduled basis recomputations
    comm_packets: float              # Table-1 communication bill (packets)
    rounds: int                      # rounds streamed


class StreamingPCAEngine:
    """Continuous batching over sensor-network streams.

    Parameters
    ----------
    cfg: the per-network :class:`~repro.streaming.driver.StreamConfig`
        (every slot shares p, n, band half-width and scheduler policy —
        the fleet is shape-homogeneous like a decode batch).
    slots: device batch size (networks streamed concurrently).
    """

    def __init__(self, cfg: StreamConfig, slots: int = 8, seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        key = jax.random.PRNGKey(seed)
        self._slot_keys = jax.random.split(key, slots)
        self.states: StreamState = jax.vmap(
            lambda k: stream_init(cfg, k))(self._slot_keys)
        self.active: list[StreamRequest | None] = [None] * slots
        self.cursor = np.zeros(slots, np.int64)     # next round per slot
        self.queue: list[StreamRequest] = []
        self._step_fn = jax.jit(jax.vmap(lambda s, x: stream_step(cfg, s, x)))
        self._n: int | None = None       # epochs/round, fixed fleet-wide

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: StreamRequest) -> None:
        r, n, p = req.rounds.shape
        if p != self.cfg.p:
            raise ValueError(f"stream p={p} != engine p={self.cfg.p}")
        if r == 0:
            raise ValueError("stream has no rounds")
        # the device batch is shape-homogeneous: every stream must share the
        # epochs-per-round of the first submitted stream
        if self._n is None:
            self._n = n
        elif n != self._n:
            raise ValueError(f"stream n={n} != engine n={self._n}")
        self.queue.append(req)

    def _splice_reset(self, slot: int) -> None:
        """Re-init slot ``slot`` of the stacked state (fresh network)."""
        fresh = stream_init(self.cfg, self._slot_keys[slot])

        def splice(full, one):
            return full.at[slot].set(one)

        self.states = jax.tree.map(splice, self.states, fresh)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                self.active[slot] = self.queue.pop(0)
                self.cursor[slot] = 0
                self._splice_reset(slot)

    def _retire(self, slot: int) -> None:
        req = self.active[slot]
        state_i = jax.tree.map(lambda a: a[slot], self.states)
        from repro.streaming.online_cov import (online_estimate,
                                                online_total_variance)
        from repro.streaming.scheduler import retained_fraction
        rho = retained_fraction(online_estimate(state_i.cov),
                                state_i.sched.W,
                                online_total_variance(state_i.cov))
        req.result = StreamResult(
            components=np.asarray(state_i.sched.W),
            retained=float(rho),
            refreshes=int(state_i.sched.refreshes),
            comm_packets=float(state_i.sched.comm_packets),
            rounds=int(state_i.rounds),
        )
        req.done = True
        self.active[slot] = None

    # -- main loop ------------------------------------------------------------
    def step(self) -> int:
        """Fold one measurement round for every active slot; returns #active.

        Idle slots process a zero round (masked out at retirement — their
        state is re-initialized on admission), keeping the device batch
        static like the decode path.
        """
        self._admit()
        live = [s for s in range(self.slots) if self.active[s]]
        if not live:
            return 0
        zeros_round = np.zeros((self._n, self.cfg.p), np.float32)
        batch = np.stack([
            np.asarray(self.active[s].rounds[self.cursor[s]], np.float32)
            if self.active[s] is not None else zeros_round
            for s in range(self.slots)])
        self.states, _ = self._step_fn(self.states, jnp.asarray(batch))
        for s in live:
            self.cursor[s] += 1
            if self.cursor[s] >= self.active[s].rounds.shape[0]:
                self._retire(s)
        return len(live)

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
