"""Serving engines: LM decode + streaming-PCA fleets, continuous batching.

Two slot-based engines in the vLLM style share the pattern *fixed device
batch, host-side slot management, jitted steps*:

* :class:`Engine` — the LM path: **prefill** runs per-request and writes the
  slot's region of the decode state; **decode** advances all active slots one
  token per call; finished slots (EOS or max_tokens) are refilled from the
  queue.
* :class:`StreamingPCAEngine` — the sensor path (DESIGN.md Sec. 8.4/12):
  each slot holds one live sensor network; every engine step pre-stages and
  folds the next K-round chunk per slot through the jitted batched chunk
  step (:func:`repro.streaming.driver.chunk_stream_step` under ``vmap``,
  fleet state donated so XLA updates it in place), drift-triggered basis
  refreshes happen at chunk boundaries inside the step, and exhausted
  streams retire with their final basis + Table-1 communication bill.
  ``StreamConfig.fused``/``precision`` flow straight through the vmapped
  step: with stages configured each slot's chunk body is the one-launch
  mega-kernel (DESIGN.md Sec. 14), and ``precision="bf16"`` stages the
  chunk tiles in bf16 while all engine-visible state stays fp32.

The streaming engine is fault-aware (DESIGN.md Sec. 9): each slot carries a
:class:`repro.runtime.health.HealthMonitor` driven by a *logical* clock (one
tick per engine step, so verdicts are deterministic).  A slot whose network
reports too few alive sensors stops heartbeating; once the monitor rules the
slot stalled, the network is **retired dead** — and if its liveness schedule
shows a later revival, a continuation request is re-queued from the revival
round.  Whenever the live-network count changes, the engine re-plans its
device mesh through :func:`repro.runtime.elastic.plan_mesh` (the WSN-fleet
analogue of elastic rescale after host death).

The decode state is the stacked pytree from repro.models.transformer; slot
management is pure Python (host side), the steps are jitted.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.runtime.elastic import RescalePlan, plan_mesh
from repro.runtime.health import HealthMonitor, StragglerPolicy
from repro.streaming.driver import (StreamConfig, StreamState,
                                    chunk_stream_step, stream_init)

__all__ = ["Request", "ServeConfig", "Engine",
           "StreamRequest", "StreamResult", "FleetSummary",
           "StreamingPCAEngine"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stops early
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8
    max_len: int = 512
    dtype: str = "float32"


class Engine:
    def __init__(self, cfg, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        dt = jnp.dtype(scfg.dtype)
        self.state = T.init_decode_state(cfg, scfg.slots, scfg.max_len,
                                         dtype=dt)
        self.pos = np.zeros(scfg.slots, np.int32)       # next content position
        self.active: list[Request | None] = [None] * scfg.slots
        self.queue: list[Request] = []

        self._decode = jax.jit(
            lambda p, tok, st, t: T.decode_step(p, cfg, tok, st, t))
        self._prefill = jax.jit(
            lambda p, tok, st, vl: T.prefill(p, cfg, tok, st, valid_len=vl))
        self._last_tok = np.zeros((scfg.slots, 1), np.int32)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill empty slots from the queue (continuous batching)."""
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(slot, req)

    def _bucket_len(self, s_len: int) -> int:
        """Power-of-two prompt bucket: one compiled prefill per bucket
        instead of one re-trace per distinct prompt length — compile count
        O(log max_len).  Dense attention only: its caches are
        position-indexed, so the pad suffix is masked out exactly (pos -1).
        An SSM scan state would absorb the pad tokens, and MoE expert
        routing counts them against expert capacity (pad top-1 slots can
        evict real tokens' lower choices, shifting logits), so those
        families keep exact lengths.
        """
        if self.cfg.family != "dense":
            return s_len
        bucket = 1 << (max(s_len, 8) - 1).bit_length()
        return max(s_len, min(bucket, self.scfg.max_len))

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Run prefill for one request and splice its state into the slot.

        Implementation note: prefill is batched over a single row, padded
        to the power-of-two length bucket (masked via ``valid_len``); the
        resulting caches are written into slot ``slot`` of the engine state.
        """
        s_len = len(req.prompt)
        padded = np.zeros(self._bucket_len(s_len), np.int32)
        padded[:s_len] = req.prompt
        prompt = jnp.asarray(padded[None, :])
        single = T.init_decode_state(self.cfg, 1, self.scfg.max_len,
                                     dtype=jnp.dtype(self.scfg.dtype))
        logits, single = self._prefill(self.params, prompt, single,
                                       jnp.asarray(s_len, jnp.int32))

        def splice(full, one):
            # every stacked cache leaf has layout (L, B, ...): batch = axis 1
            return full.at[:, slot:slot + 1].set(one)

        self.state = jax.tree.map(splice, self.state, single)
        tok = int(np.asarray(jnp.argmax(logits, -1))[0])
        req.output.append(tok)
        self._last_tok[slot, 0] = tok
        self.pos[slot] = len(req.prompt)
        self.active[slot] = req

    # -- main loop ------------------------------------------------------------
    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        self._admit()
        live = [s for s in range(self.scfg.slots) if self.active[s]]
        if not live:
            return 0
        # per-slot positions: unaligned requests decode together (the
        # PosCache mask is derived from stored positions per row)
        batch_tok = jnp.asarray(self._last_tok)
        t_vec = jnp.asarray(self.pos, jnp.int32)
        logits, self.state = self._decode(self.params, batch_tok, self.state,
                                          t_vec)
        next_tok = np.asarray(jnp.argmax(logits, -1))
        for s in live:
            req = self.active[s]
            tok = int(next_tok[s])
            req.output.append(tok)
            self._last_tok[s, 0] = tok
            self.pos[s] += 1
            if (tok == req.eos_id
                    or len(req.output) >= req.max_new_tokens
                    or self.pos[s] >= self.scfg.max_len - 1):
                req.done = True
                self.active[s] = None
        return len(live)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return


# ===========================================================================
# Streaming-PCA fleet engine
# ===========================================================================
@dataclasses.dataclass(eq=False)       # identity equality: requests hold arrays
class StreamRequest:
    """One live sensor network: a finite stream of measurement rounds.

    ``liveness`` is an optional (R, p) per-round sensor-liveness schedule
    (1 = alive), e.g. from :meth:`repro.core.faults.NodeChurn.liveness`;
    ``None`` means every sensor is alive for the whole stream.

    ``region`` tags the network with its region id in a two-level fleet
    (DESIGN.md Sec. 13): slots are region-aware — the engine tracks which
    region each slot is streaming, and :meth:`StreamingPCAEngine.fleet_summary`
    merges the retired regions' bases into the fleet-level basis with the
    merge's Table-1 bill.  The default region 0 keeps flat fleets unchanged.
    """

    rounds: np.ndarray               # (R, n, p) float32 measurement rounds
    liveness: np.ndarray | None = None   # (R, p) per-round sensor liveness
    region: int = 0                  # region id in the two-level fleet
    # filled by the engine:
    result: "StreamResult | None" = None
    done: bool = False
    # early (dead-network) retirements collected before the final result;
    # each entry covers the rounds streamed up to that retirement
    retirements: list = dataclasses.field(default_factory=list)
    # engine-internal: round to resume from after a revival re-admission
    resume_at: int = 0


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Final per-network summary returned when a stream retires.

    The ``compression_*`` fields are populated only when the engine's
    StreamConfig carries a compression stage: the worst sink error over
    every round streamed in this segment (the ε guarantee holds iff
    ``compression_max_err <= ε``), the flagged-raw extras sent, and the
    score bits put on air at the quantized budget.

    The ``detection_*`` fields are populated only when the StreamConfig
    carries a detection stage: the alarmed-epoch count of this segment,
    the Sec.-2.4.3 alarm-flood packets those alarms billed (lossy-scaled,
    on top of the per-round monitoring scalar already inside
    ``comm_packets``), and the T²/SPE thresholds in effect at retirement.
    """

    components: np.ndarray           # (p, q) final basis
    retained: float                  # rho of the final basis on the live cov
    refreshes: int                   # scheduled basis recomputations
    comm_packets: float              # Table-1 communication bill (packets)
    rounds: int                      # rounds streamed
    reason: str = "completed"        # "completed" | "dead"
    # the region head's level-2 merge record (DESIGN.md Sec. 13): live
    # per-component subspace energies diag(W^T C W) and the trace partial —
    # exactly what fleet_summary aggregates across regions
    energies: np.ndarray | None = None    # (q,) subspace energies
    total_variance: float | None = None   # trace(C) partial
    compression_max_err: float | None = None
    compression_extra_packets: float | None = None
    compression_bits_on_air: float | None = None
    detection_events: float | None = None
    detection_alarm_packets: float | None = None
    detection_t2_threshold: float | None = None
    detection_spe_threshold: float | None = None


@dataclasses.dataclass(frozen=True)
class FleetSummary:
    """The two-level fleet basis merged from retired region results.

    ``basis`` is the dense block-embedded (p_fleet, q_fleet) fleet basis
    (orthonormal by construction — disjoint region supports); ``region``/
    ``col``/``lam`` the compact selection; ``merge_packets`` the Table-1
    bill of the merge epoch that produced it (one (q+1)-record region-tree
    aggregation, ARQ-scaled — :func:`repro.core.costs.lossy_merge_cost`).
    """

    basis: np.ndarray                # (p_fleet, q_fleet)
    region: np.ndarray               # (q_fleet,) owning region per component
    col: np.ndarray                  # (q_fleet,) column within that region
    lam: np.ndarray                  # (q_fleet,) energies, descending
    rho: float                       # fleet retained fraction
    regions: tuple                   # region ids merged, ascending
    merge_packets: float             # region-head bill of this merge epoch


class StreamingPCAEngine:
    """Continuous batching over sensor-network streams, fault-aware.

    Parameters
    ----------
    cfg: the per-network :class:`~repro.streaming.driver.StreamConfig`
        (every slot shares p, n, band half-width and scheduler policy —
        the fleet is shape-homogeneous like a decode batch).
    slots: device batch size (networks streamed concurrently).
    health_policy: per-slot :class:`~repro.runtime.health.StragglerPolicy`;
        ``stall_timeout`` is measured in *engine steps* (the logical clock
        ticks once per step, keeping verdicts deterministic).
    min_alive_fraction: a slot heartbeats only while at least this fraction
        of its sensors is alive; below it the network is considered
        unresponsive and the monitor's stall verdict retires it.
    chunk: rounds folded per engine step (K).  Each step pre-stages every
        slot's next K rounds device-side in ONE upload, folds them through
        the fused chunk kernel, and evaluates ONE scheduler decision per
        slot — the per-dispatch overhead (launches, refresh selects,
        host→device transfers, slot bookkeeping) is amortized over K
        measurement epochs while the Table-1 bill stays per-epoch exact.
        Admission and retirement happen at chunk boundaries; a stream
        whose tail is shorter than K folds only its real rounds (the
        chunk step's per-round validity).  ``chunk=1`` reproduces the
        per-round engine bit-exactly.
    """

    def __init__(self, cfg: StreamConfig, slots: int = 8, seed: int = 0,
                 health_policy: StragglerPolicy | None = None,
                 min_alive_fraction: float = 0.25, chunk: int = 1):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.cfg = cfg
        self.slots = slots
        self.chunk = chunk
        self.min_alive_fraction = min_alive_fraction
        self.health_policy = health_policy or StragglerPolicy(
            stall_timeout=2.5)          # logical steps, not seconds
        key = jax.random.PRNGKey(seed)
        self._slot_keys = jax.random.split(key, slots)
        self.states: StreamState = jax.vmap(
            lambda k: stream_init(cfg, k))(self._slot_keys)
        self.active: list[StreamRequest | None] = [None] * slots
        self.cursor = np.zeros(slots, np.int64)     # next round per slot
        self.queue: list[StreamRequest] = []
        # region-aware slots (DESIGN.md Sec. 13): which region each slot is
        # streaming right now (-1 = idle), and the latest final result per
        # region — the merge inputs of fleet_summary()
        self.slot_region = np.full(slots, -1, np.int64)
        self.region_results: dict[int, StreamResult] = {}
        # two jitted chunk steps: the masked one only runs when some active
        # request actually carries a liveness schedule — fault-free fleets
        # never build or upload a mask batch at all (and stay on the
        # unmasked kernel); the two are bit-identical under an all-ones
        # mask, so the switch is invisible to results.  The fleet state is
        # DONATED: XLA updates the slot pytree in place instead of
        # allocating a fresh copy every step (the states are never read
        # after the call — the returned buffers replace them).
        self._step_fn = jax.jit(
            jax.vmap(lambda s, x, rv: chunk_stream_step(
                cfg, s, x, round_valid=rv)),
            donate_argnums=(0,))
        self._step_fn_masked = jax.jit(
            jax.vmap(lambda s, x, m, rv: chunk_stream_step(cfg, s, x, m, rv)),
            donate_argnums=(0,))
        self._n: int | None = None       # epochs/round, fixed fleet-wide
        # persistent zero/ones templates, allocated once on the first step
        # (need _n).  The staging batch itself is a FRESH array per chunk
        # — device_put may alias aligned host memory on CPU, so a reused
        # fill buffer could be mutated under an in-flight upload; one
        # slots×K×n×p allocation per K rounds is the amortized, safe form
        # of the old per-round np.stack
        self._zeros_chunk: np.ndarray | None = None
        self._ones_chunk_mask: np.ndarray | None = None
        # ε-supervised compression accounting (cfg.compression only):
        # per-slot running worst sink error / flagged-raw extras / bits on
        # air for the current segment.  Accumulated ON DEVICE (jnp ops, no
        # per-step host sync — the step stays async-dispatchable like the
        # decode path); the scalars are pulled to host only at retirement.
        # last_compression keeps the most recent round's full device output
        # (scores, sink view, flags) for observability — one round's
        # arrays, bounded.
        self._comp_max_err = jnp.zeros(slots, jnp.float32)
        self._comp_extras = jnp.zeros(slots, jnp.float32)
        self._comp_bits = jnp.zeros(slots, jnp.float32)
        self.last_compression = None
        # T²/SPE detection accounting (cfg.detection only): per-slot running
        # alarmed-epoch count and alarm-flood bill for the current segment,
        # accumulated on device like the compression books; last_detection
        # keeps the most recent round's device output for observability.
        # The per-alarm packet price and ARQ factor are engine-lifetime
        # constants (cfg is fixed), resolved once here.
        self._det_events = jnp.zeros(slots, jnp.float32)
        self._det_alarm_packets = jnp.zeros(slots, jnp.float32)
        self.last_detection = None
        if cfg.detection is not None:
            from repro.core.faults import expected_transmissions
            from repro.streaming.detector import detection_packet_split
            _, per_alarm = detection_packet_split(cfg.q, cfg.c_max)
            self._det_alarm_price = per_alarm * expected_transmissions(
                cfg.link_loss, cfg.max_retries)
        # fault machinery: logical clock, per-slot monitors, retirement log
        self._clock = 0
        self.health: list[HealthMonitor | None] = [None] * slots
        self.retired_log: list[tuple[StreamRequest, str]] = []
        # elastic fleet mesh: one virtual device per live network; re-planned
        # through runtime.elastic whenever the live count changes (the
        # initial plan assumes a full fleet, so a first step at full
        # occupancy appends nothing)
        self._last_live = slots
        self.plan: RescalePlan = plan_mesh(max(1, slots), prefer_model=1,
                                           global_batch=max(1, slots))
        self.plan_history: list[RescalePlan] = [self.plan]

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: StreamRequest) -> None:
        r, n, p = req.rounds.shape
        if p != self.cfg.p:
            raise ValueError(f"stream p={p} != engine p={self.cfg.p}")
        if r == 0:
            raise ValueError("stream has no rounds")
        if req.liveness is not None and req.liveness.shape != (r, p):
            raise ValueError(
                f"liveness shape {req.liveness.shape} != {(r, p)}")
        # the device batch is shape-homogeneous: every stream must share the
        # epochs-per-round of the first submitted stream
        if self._n is None:
            self._n = n
        elif n != self._n:
            raise ValueError(f"stream n={n} != engine n={self._n}")
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill empty slots from the queue, then reset every admitted
        slot's device state in ONE batched splice (one scatter per state
        leaf and per accounting vector, however many slots were admitted —
        the per-slot ``.at[slot].set`` loop re-dispatched a scatter per
        slot per leaf)."""
        newly: list[int] = []
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                self.cursor[slot] = req.resume_at
                self.slot_region[slot] = req.region
                newly.append(slot)
                monitor = HealthMonitor(self.health_policy,
                                        clock=lambda: float(self._clock))
                monitor.heartbeat(step=self._clock, duration=1.0)
                self.health[slot] = monitor
        if not newly:
            return
        idx_np = np.asarray(newly, np.int32)
        idx = jnp.asarray(idx_np)
        fresh = jax.vmap(lambda k: stream_init(self.cfg, k))(
            self._slot_keys[idx_np])
        self.states = jax.tree.map(lambda full, f: full.at[idx].set(f),
                                   self.states, fresh)
        if self.cfg.compression is not None:
            self._comp_max_err = self._comp_max_err.at[idx].set(0.0)
            self._comp_extras = self._comp_extras.at[idx].set(0.0)
            self._comp_bits = self._comp_bits.at[idx].set(0.0)
        if self.cfg.detection is not None:
            self._det_events = self._det_events.at[idx].set(0.0)
            self._det_alarm_packets = self._det_alarm_packets.at[idx].set(0.0)

    def _result(self, slot: int, reason: str) -> StreamResult:
        state_i = jax.tree.map(lambda a: a[slot], self.states)
        from repro.streaming.online_cov import (online_estimate,
                                                online_total_variance)
        from repro.streaming.scheduler import retained_fraction
        rho = retained_fraction(online_estimate(state_i.cov),
                                state_i.sched.W,
                                online_total_variance(state_i.cov))
        from repro.streaming.hierarchy import region_energies
        lam, total_var = region_energies(state_i)
        comp: dict = {}
        if self.cfg.compression is not None:
            comp = dict(
                compression_max_err=float(self._comp_max_err[slot]),
                compression_extra_packets=float(self._comp_extras[slot]),
                compression_bits_on_air=float(self._comp_bits[slot]),
            )
        if self.cfg.detection is not None:
            comp.update(
                detection_events=float(self._det_events[slot]),
                detection_alarm_packets=float(
                    self._det_alarm_packets[slot]),
                detection_t2_threshold=float(state_i.det.t2_threshold),
                detection_spe_threshold=float(state_i.det.spe_threshold),
            )
        return StreamResult(
            components=np.asarray(state_i.sched.W),
            retained=float(rho),
            refreshes=int(state_i.sched.refreshes),
            comm_packets=float(state_i.sched.comm_packets),
            rounds=int(state_i.rounds),
            reason=reason,
            energies=np.asarray(lam),
            total_variance=float(total_var),
            **comp,
        )

    def _retire(self, slot: int) -> None:
        req = self.active[slot]
        req.result = self._result(slot, "completed")
        req.done = True
        self.retired_log.append((req, "completed"))
        self.region_results[req.region] = req.result
        self.active[slot] = None
        self.slot_region[slot] = -1
        self.health[slot] = None

    def _retire_dead(self, slot: int) -> None:
        """Stall verdict: retire the network; re-queue it if it revives.

        The partial result (basis, bill, rounds streamed before death) is
        appended to ``req.retirements``.  If the liveness schedule shows the
        network healthy again at a later round, a continuation resumes from
        there with fresh per-slot state — the covariance re-warms over the
        forgetting window, exactly like a rebooted deployment.
        """
        req = self.active[slot]
        partial = self._result(slot, "dead")
        self.retired_log.append((req, "dead"))
        self.active[slot] = None
        self.slot_region[slot] = -1
        self.health[slot] = None
        revive = None
        if req.liveness is not None:
            frac = req.liveness[int(self.cursor[slot]):].mean(axis=1)
            ahead = np.nonzero(frac >= self.min_alive_fraction)[0]
            if ahead.size:
                revive = int(self.cursor[slot]) + int(ahead[0])
        if revive is not None:
            # a continuation will follow: this segment is an early retirement
            req.retirements.append(partial)
            req.resume_at = revive
            self.queue.append(req)
        else:
            # no revival ahead: the partial IS the final result (kept out of
            # retirements so segment bills sum without double-counting)
            req.result = partial
            req.done = True
            self.region_results[req.region] = partial

    def _replan(self, n_live: int) -> None:
        """Elastic fleet mesh: one virtual device per live network."""
        if n_live != self._last_live and n_live > 0:
            self.plan = plan_mesh(n_live, prefer_model=1,
                                  global_batch=n_live)
            self.plan_history.append(self.plan)
        self._last_live = n_live

    # -- main loop ------------------------------------------------------------
    def step(self) -> int:
        """Fold the next K-round chunk for every active slot; returns #active.

        Idle slots carry a zero chunk with zero round-validity (they fold
        nothing and book nothing; their state is re-initialized on
        admission), keeping the device batch static like the decode path.
        A live slot whose stream ends mid-chunk folds only its real tail
        rounds.  The hot loop is host-sync-free: one staging-buffer fill +
        one upload per chunk, the jitted step updates the donated fleet
        state in place, and the accounting stays on device — scalars are
        pulled to host only at retirement.  Per step, each live slot
        heartbeats its HealthMonitor iff enough of its sensors were alive
        over the chunk's rounds; slots ruled stalled afterwards are
        retired dead (and re-queued from their revival round, if any).
        """
        self._admit()
        self._clock += 1
        live = [s for s in range(self.slots) if self.active[s]]
        self._replan(len(live))
        if not live:
            return 0
        K, p = self.chunk, self.cfg.p
        if self._zeros_chunk is None:       # one-time template allocations
            self._zeros_chunk = np.zeros((K, self._n, p), np.float32)
            self._ones_chunk_mask = np.ones((K, p), np.float32)
        batch = np.empty((self.slots, K, self._n, p), np.float32)
        rv = np.zeros((self.slots, K), np.float32)
        consumed = np.zeros(self.slots, np.int64)
        start = self.cursor.copy()
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                batch[s] = self._zeros_chunk
                continue
            c = int(start[s])
            take = min(K, req.rounds.shape[0] - c)
            batch[s, :take] = req.rounds[c:c + take]
            if take < K:
                batch[s, take:] = 0.0
            rv[s, :take] = 1.0
            consumed[s] = take
        # fast path: when no active request carries a liveness schedule the
        # mask batch is neither built nor uploaded (the masked and unmasked
        # steps are bit-identical under all-ones masks, so the switch is
        # invisible to results)
        any_schedule = any(self.active[s] is not None
                           and self.active[s].liveness is not None
                           for s in live)
        if any_schedule:
            masks = np.empty((self.slots, K, p), np.float32)
            for s in range(self.slots):
                req = self.active[s]
                if req is None or req.liveness is None:
                    masks[s] = self._ones_chunk_mask
                    continue
                c, take = int(start[s]), int(consumed[s])
                masks[s, :take] = req.liveness[c:c + take]
                if take < K:
                    masks[s, take:] = 1.0
            self.states, metrics = self._step_fn_masked(
                self.states, jnp.asarray(batch), jnp.asarray(masks),
                jnp.asarray(rv))
        else:
            self.states, metrics = self._step_fn(
                self.states, jnp.asarray(batch), jnp.asarray(rv))
        # idle slots fold zero rounds: mask them out of the books
        # (where, not multiply — robust to any NaN in an idle slot)
        lm = np.zeros(self.slots, np.float32)
        lm[live] = 1.0
        lmj = jnp.asarray(lm)
        if self.cfg.compression is not None:
            comp = metrics.compression
            self.last_compression = comp      # (slots, ...) device arrays
            self._comp_max_err = jnp.maximum(
                self._comp_max_err, jnp.where(lmj > 0, comp.max_err, 0.0))
            self._comp_extras = self._comp_extras + jnp.where(
                lmj > 0, comp.extra_packets, 0.0)
            self._comp_bits = self._comp_bits + jnp.where(
                lmj > 0, comp.bits_on_air, 0.0)
        if self.cfg.detection is not None:
            det = metrics.detection
            self.last_detection = det         # (slots, ...) device arrays
            alarms = jnp.where(lmj > 0, det.alarms, 0.0)
            self._det_events = self._det_events + alarms
            self._det_alarm_packets = (self._det_alarm_packets
                                       + alarms * self._det_alarm_price)
        for s in live:
            req = self.active[s]
            c, take = int(start[s]), int(consumed[s])
            frac = 1.0 if req.liveness is None \
                else float(req.liveness[c:c + take].mean())
            if frac >= self.min_alive_fraction:
                self.health[s].heartbeat(step=self._clock, duration=1.0)
            self.cursor[s] += take
            if self.cursor[s] >= req.rounds.shape[0]:
                self._retire(s)
            elif self.health[s].stalled():
                self._retire_dead(s)
        return len(live)

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return

    # -- two-level fleet merge (DESIGN.md Sec. 13) ---------------------------
    def fleet_summary(self, q_fleet: int | None = None,
                      c_regions: int | None = None) -> FleetSummary:
        """Merge the retired regions' bases into the fleet-level basis.

        One level-2 merge epoch over the region results collected so far
        (latest final result per region id): global top-``q_fleet``
        selection by subspace energy (:func:`repro.streaming.hierarchy.
        merge_fleet` — the same jittable core the cross-host driver runs
        after its ``all_gather``), dense block embedding, and the merge's
        Table-1 bill at region-tree fan-out ``c_regions`` (default
        ``cfg.c_max``), ARQ-scaled like every intra-network packet.
        """
        from repro.core import costs
        from repro.streaming.hierarchy import fleet_basis_dense, merge_fleet
        if not self.region_results:
            raise ValueError("no retired region results to merge")
        regions = sorted(self.region_results)
        results = [self.region_results[r] for r in regions]
        lam_table = jnp.asarray(np.stack([r.energies for r in results]))
        total = jnp.asarray(sum(r.total_variance for r in results),
                            jnp.float32)
        qf = self.cfg.q if q_fleet is None else q_fleet
        basis = merge_fleet(lam_table, total, qf)
        W_regions = jnp.asarray(np.stack([r.components for r in results]))
        cr = self.cfg.c_max if c_regions is None else c_regions
        bill = costs.lossy_merge_cost(self.cfg.q, cr, self.cfg.link_loss,
                                      self.cfg.max_retries).communication
        return FleetSummary(
            basis=np.asarray(fleet_basis_dense(basis, W_regions)),
            region=np.asarray(basis.region),
            col=np.asarray(basis.col),
            lam=np.asarray(basis.lam),
            rho=float(basis.rho),
            regions=tuple(regions),
            merge_packets=float(bill),
        )


# ===========================================================================
# Program contract (repro.analysis; DESIGN.md Sec. 15): the engine hot loop.
# Static rules pin the vmapped chunk body (one launch per step); the runtime
# check needs the lowered/compiled artifact — buffer donation is a lowering
# property, retraces a jit-cache property — so it runs a tiny interpret-mode
# fleet for a few steps.
# ===========================================================================
from repro.analysis import contracts as _contracts  # noqa: E402
from repro.analysis import jaxpr_lint as _jl        # noqa: E402
from repro.analysis import resources as _res        # noqa: E402

_CONTRACT_SLOTS, _CONTRACT_K, _CONTRACT_N = 2, 2, 4


def _contract_engine() -> StreamingPCAEngine:
    cfg = StreamConfig(p=8, q=2, halfwidth=1, warmup_rounds=2,
                       interpret=True)
    eng = StreamingPCAEngine(cfg, slots=_CONTRACT_SLOTS, seed=0,
                             chunk=_CONTRACT_K)
    rng = np.random.default_rng(0)
    for _ in range(_CONTRACT_SLOTS):
        eng.submit(StreamRequest(rounds=rng.normal(
            size=(6, _CONTRACT_N, cfg.p)).astype(np.float32)))
    return eng


def _contract_engine_batch(eng: StreamingPCAEngine):
    batch = jnp.zeros((eng.slots, eng.chunk, _CONTRACT_N, eng.cfg.p),
                      jnp.float32)
    rv = jnp.ones((eng.slots, eng.chunk), jnp.float32)
    return batch, rv


def _trace_engine_step():
    eng = _contract_engine()
    batch, rv = _contract_engine_batch(eng)
    jx = jax.make_jaxpr(lambda s, x, r: eng._step_fn(s, x, r))(
        eng.states, batch, rv)
    return {f"slots={eng.slots},K={eng.chunk}": jx}


def _engine_runtime_checks():
    eng = _contract_engine()
    batch, rv = _contract_engine_batch(eng)
    results = [_contracts.donation_report(eng._step_fn, eng.states, batch,
                                          rv, argnum=0,
                                          contract="engine.step")]
    for _ in range(3):               # 6 rounds / chunk 2 = 3 same-shape steps
        eng.step()
    results.append(_contracts.retrace_report(eng._step_fn, 3,
                                             contract="engine.step"))
    return results


_contracts.register(_contracts.Contract(
    id="engine.step",
    where="repro.serve.engine.StreamingPCAEngine.step",
    claim="the vmapped chunk step launches one pallas kernel per engine "
          "step, the fleet state is donated (in-place update), and "
          "same-shape steps never retrace",
    trace=_trace_engine_step,
    rules=(_jl.PrimitiveBudget("pallas_call", exact=1),
           _jl.PrimitiveBudget("eigh", max=1),
           _jl.ForbidInLoops(everywhere=True),
           _jl.NoF64(),
           _res.VmemBudget(),
           _res.HbmTrafficBudget(max_passes=1.0)),
    runtime=_engine_runtime_checks,
))
