"""Serving engine: prefill/decode split with continuous batching.

A slot-based engine in the vLLM style, sized for the decode shapes of the
assigned pool:

* fixed number of **slots** (the decode batch); each slot holds one request;
* **prefill** runs per-request (padded to the slot's prompt) and writes the
  slot's region of the decode state;
* **decode** advances all active slots one token per call (the jitted
  ``decode_step``), greedy or temperature sampling;
* finished slots (EOS or max_tokens) are refilled from the queue —
  continuous batching.

The decode state is the stacked pytree from repro.models.transformer; slot
management is pure Python (host side), the steps are jitted.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T

__all__ = ["Request", "ServeConfig", "Engine"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stops early
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8
    max_len: int = 512
    dtype: str = "float32"


class Engine:
    def __init__(self, cfg, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        dt = jnp.dtype(scfg.dtype)
        self.state = T.init_decode_state(cfg, scfg.slots, scfg.max_len,
                                         dtype=dt)
        self.pos = np.zeros(scfg.slots, np.int32)       # next content position
        self.active: list[Request | None] = [None] * scfg.slots
        self.queue: list[Request] = []

        self._decode = jax.jit(
            lambda p, tok, st, t: T.decode_step(p, cfg, tok, st, t))
        self._prefill = jax.jit(
            lambda p, tok, st: T.prefill(p, cfg, tok, st))
        self._last_tok = np.zeros((scfg.slots, 1), np.int32)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill empty slots from the queue (continuous batching)."""
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(slot, req)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Run prefill for one request and splice its state into the slot.

        Implementation note: prefill is batched over a single row; the
        resulting caches are written into slot ``slot`` of the engine state.
        """
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        single = T.init_decode_state(self.cfg, 1, self.scfg.max_len,
                                     dtype=jnp.dtype(self.scfg.dtype))
        logits, single = self._prefill(self.params, prompt, single)

        def splice(full, one):
            # every stacked cache leaf has layout (L, B, ...): batch = axis 1
            return full.at[:, slot:slot + 1].set(one)

        self.state = jax.tree.map(splice, self.state, single)
        tok = int(np.asarray(jnp.argmax(logits, -1))[0])
        req.output.append(tok)
        self._last_tok[slot, 0] = tok
        self.pos[slot] = len(req.prompt)
        self.active[slot] = req

    # -- main loop ------------------------------------------------------------
    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        self._admit()
        live = [s for s in range(self.scfg.slots) if self.active[s]]
        if not live:
            return 0
        # per-slot positions: unaligned requests decode together (the
        # PosCache mask is derived from stored positions per row)
        batch_tok = jnp.asarray(self._last_tok)
        t_vec = jnp.asarray(self.pos, jnp.int32)
        logits, self.state = self._decode(self.params, batch_tok, self.state,
                                          t_vec)
        next_tok = np.asarray(jnp.argmax(logits, -1))
        for s in live:
            req = self.active[s]
            tok = int(next_tok[s])
            req.output.append(tok)
            self._last_tok[s, 0] = tok
            self.pos[s] += 1
            if (tok == req.eos_id
                    or len(req.output) >= req.max_new_tokens
                    or self.pos[s] >= self.scfg.max_len - 1):
                req.done = True
                self.active[s] = None
        return len(live)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
