"""Serving engines: LM decode + streaming-PCA fleets, continuous batching.

Two slot-based engines in the vLLM style share the pattern *fixed device
batch, host-side slot management, jitted steps*:

* :class:`Engine` — the LM path: **prefill** runs per-request and writes the
  slot's region of the decode state; **decode** advances all active slots one
  token per call; finished slots (EOS or max_tokens) are refilled from the
  queue.
* :class:`StreamingPCAEngine` — the sensor path (DESIGN.md Sec. 8.4/12/17):
  each slot holds one live sensor network; every engine step stages the
  next K-round chunk per slot and folds it through the jitted batched chunk
  step (:func:`repro.streaming.driver.engine_chunk_step_fn` — the vmapped
  :func:`~repro.streaming.driver.chunk_stream_step` with the fleet state
  donated so XLA updates it in place), drift-triggered basis refreshes
  happen at chunk boundaries inside the step, and exhausted streams retire
  with their final basis + Table-1 communication bill.
  ``StreamConfig.fused``/``precision`` flow straight through the vmapped
  step: with stages configured each slot's chunk body is the one-launch
  mega-kernel (DESIGN.md Sec. 14), and ``precision="bf16"`` stages the
  chunk tiles in bf16 while all engine-visible state stays fp32.

With ``pipeline=True`` the hot loop is fully pipelined (DESIGN.md Sec. 17):
staging runs through two pinned, engine-owned host buffers whose uploads
are explicit owned copies (the device batch never aliases staging memory),
and chunk t+1 is filled and uploaded WHILE the donated jitted step folds
chunk t — the only waits in the loop are the transfer fence on a buffer's
previous upload (never on the compute) and the per-slot result pull at
retirement.  Overlap only reorders host work, never device math, so the
pipelined engine is bit-identical to the synchronous one — pinned by the
differential suite in tests/test_engine_async.py.

Admission runs through a priority queue front end
(:class:`repro.serve.queue.AdmissionQueue`): higher priority admits first,
oldest-first within a priority, per-tenant concurrent-slot quotas, and a
bounded queue that rejects (backpressures) external submits when full.
Structured telemetry (:class:`repro.serve.telemetry.TelemetryRecorder`)
records per-step wall time, staged-vs-compute overlap, queue depth,
admissions/retirements and per-slot bills into a ring buffer with an
optional JSONL sink.

The streaming engine is fault-aware (DESIGN.md Sec. 9): each slot carries a
:class:`repro.runtime.health.HealthMonitor` driven by a *logical* clock (one
tick per engine step, so verdicts are deterministic).  A slot whose network
reports too few alive sensors stops heartbeating; once the monitor rules the
slot stalled, the network is **retired dead** — and if its liveness schedule
shows a later revival, a continuation request is re-queued from the revival
round.  Whenever the live-network count changes, the engine re-plans its
device mesh through :func:`repro.runtime.elastic.plan_mesh` (the WSN-fleet
analogue of elastic rescale after host death).

The decode state is the stacked pytree from repro.models.transformer; slot
management is pure Python (host side), the steps are jitted.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.runtime.elastic import RescalePlan, plan_mesh
from repro.runtime.health import HealthMonitor, StragglerPolicy
from repro.serve.queue import AdmissionQueue, QueuePolicy
from repro.serve.telemetry import StepRecord, TelemetryRecorder
from repro.streaming.driver import (StreamConfig, StreamState,
                                    engine_chunk_step_fn, stream_init)

__all__ = ["Request", "ServeConfig", "Engine",
           "StreamRequest", "StreamResult", "FleetSummary",
           "StreamingPCAEngine"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stops early
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 8
    max_len: int = 512
    dtype: str = "float32"


class Engine:
    def __init__(self, cfg, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        dt = jnp.dtype(scfg.dtype)
        self.state = T.init_decode_state(cfg, scfg.slots, scfg.max_len,
                                         dtype=dt)
        self.pos = np.zeros(scfg.slots, np.int32)       # next content position
        self.active: list[Request | None] = [None] * scfg.slots
        self.queue: list[Request] = []

        self._decode = jax.jit(
            lambda p, tok, st, t: T.decode_step(p, cfg, tok, st, t))
        self._prefill = jax.jit(
            lambda p, tok, st, vl: T.prefill(p, cfg, tok, st, valid_len=vl))
        self._last_tok = np.zeros((scfg.slots, 1), np.int32)

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill empty slots from the queue (continuous batching)."""
        for slot in range(self.scfg.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_slot(slot, req)

    def _bucket_len(self, s_len: int) -> int:
        """Power-of-two prompt bucket: one compiled prefill per bucket
        instead of one re-trace per distinct prompt length — compile count
        O(log max_len).  Dense attention only: its caches are
        position-indexed, so the pad suffix is masked out exactly (pos -1).
        An SSM scan state would absorb the pad tokens, and MoE expert
        routing counts them against expert capacity (pad top-1 slots can
        evict real tokens' lower choices, shifting logits), so those
        families keep exact lengths.
        """
        if self.cfg.family != "dense":
            return s_len
        bucket = 1 << (max(s_len, 8) - 1).bit_length()
        return max(s_len, min(bucket, self.scfg.max_len))

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Run prefill for one request and splice its state into the slot.

        Implementation note: prefill is batched over a single row, padded
        to the power-of-two length bucket (masked via ``valid_len``); the
        resulting caches are written into slot ``slot`` of the engine state.
        """
        s_len = len(req.prompt)
        padded = np.zeros(self._bucket_len(s_len), np.int32)
        padded[:s_len] = req.prompt
        prompt = jnp.asarray(padded[None, :])
        single = T.init_decode_state(self.cfg, 1, self.scfg.max_len,
                                     dtype=jnp.dtype(self.scfg.dtype))
        logits, single = self._prefill(self.params, prompt, single,
                                       jnp.asarray(s_len, jnp.int32))

        def splice(full, one):
            # every stacked cache leaf has layout (L, B, ...): batch = axis 1
            return full.at[:, slot:slot + 1].set(one)

        self.state = jax.tree.map(splice, self.state, single)
        tok = int(np.asarray(jnp.argmax(logits, -1))[0])
        req.output.append(tok)
        self._last_tok[slot, 0] = tok
        self.pos[slot] = len(req.prompt)
        self.active[slot] = req

    # -- main loop ------------------------------------------------------------
    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        self._admit()
        live = [s for s in range(self.scfg.slots) if self.active[s]]
        if not live:
            return 0
        # per-slot positions: unaligned requests decode together (the
        # PosCache mask is derived from stored positions per row)
        batch_tok = jnp.asarray(self._last_tok)
        t_vec = jnp.asarray(self.pos, jnp.int32)
        logits, self.state = self._decode(self.params, batch_tok, self.state,
                                          t_vec)
        next_tok = np.asarray(jnp.argmax(logits, -1))
        for s in live:
            req = self.active[s]
            tok = int(next_tok[s])
            req.output.append(tok)
            self._last_tok[s, 0] = tok
            self.pos[s] += 1
            if (tok == req.eos_id
                    or len(req.output) >= req.max_new_tokens
                    or self.pos[s] >= self.scfg.max_len - 1):
                req.done = True
                self.active[s] = None
        return len(live)

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return


# ===========================================================================
# Streaming-PCA fleet engine
# ===========================================================================
@dataclasses.dataclass(eq=False)       # identity equality: requests hold arrays
class StreamRequest:
    """One live sensor network: a finite stream of measurement rounds.

    ``liveness`` is an optional (R, p) per-round sensor-liveness schedule
    (1 = alive), e.g. from :meth:`repro.core.faults.NodeChurn.liveness`;
    ``None`` means every sensor is alive for the whole stream.

    ``region`` tags the network with its region id in a two-level fleet
    (DESIGN.md Sec. 13): slots are region-aware — the engine tracks which
    region each slot is streaming, and :meth:`StreamingPCAEngine.fleet_summary`
    merges the retired regions' bases into the fleet-level basis with the
    merge's Table-1 bill.  The default region 0 keeps flat fleets unchanged.

    ``priority``/``tenant`` feed the admission queue (DESIGN.md Sec. 17):
    higher priority admits first (oldest-first within a class), and a
    tenant never holds more concurrent slots than the engine's
    ``QueuePolicy.max_slots_per_tenant``.  The defaults reproduce plain
    FIFO admission.
    """

    rounds: np.ndarray               # (R, n, p) float32 measurement rounds
    liveness: np.ndarray | None = None   # (R, p) per-round sensor liveness
    region: int = 0                  # region id in the two-level fleet
    priority: int = 0                # admission priority (higher first)
    tenant: str | None = None        # quota bucket (None: unmetered)
    # filled by the engine:
    result: "StreamResult | None" = None
    done: bool = False
    # early (dead-network) retirements collected before the final result;
    # each entry covers the rounds streamed up to that retirement
    retirements: list = dataclasses.field(default_factory=list)
    # engine-internal: round to resume from after a revival re-admission
    resume_at: int = 0


@dataclasses.dataclass(frozen=True)
class StreamResult:
    """Final per-network summary returned when a stream retires.

    The ``compression_*`` fields are populated only when the engine's
    StreamConfig carries a compression stage: the worst sink error over
    every round streamed in this segment (the ε guarantee holds iff
    ``compression_max_err <= ε``), the flagged-raw extras sent, and the
    score bits put on air at the quantized budget.

    The ``detection_*`` fields are populated only when the StreamConfig
    carries a detection stage: the alarmed-epoch count of this segment,
    the Sec.-2.4.3 alarm-flood packets those alarms billed (lossy-scaled,
    on top of the per-round monitoring scalar already inside
    ``comm_packets``), and the T²/SPE thresholds in effect at retirement.
    """

    components: np.ndarray           # (p, q) final basis
    retained: float                  # rho of the final basis on the live cov
    refreshes: int                   # scheduled basis recomputations
    comm_packets: float              # Table-1 communication bill (packets)
    rounds: int                      # rounds streamed
    reason: str = "completed"        # "completed" | "dead"
    # the region head's level-2 merge record (DESIGN.md Sec. 13): live
    # per-component subspace energies diag(W^T C W) and the trace partial —
    # exactly what fleet_summary aggregates across regions
    energies: np.ndarray | None = None    # (q,) subspace energies
    total_variance: float | None = None   # trace(C) partial
    compression_max_err: float | None = None
    compression_extra_packets: float | None = None
    compression_bits_on_air: float | None = None
    detection_events: float | None = None
    detection_alarm_packets: float | None = None
    detection_t2_threshold: float | None = None
    detection_spe_threshold: float | None = None


@dataclasses.dataclass(frozen=True)
class FleetSummary:
    """The two-level fleet basis merged from retired region results.

    ``basis`` is the dense block-embedded (p_fleet, q_fleet) fleet basis
    (orthonormal by construction — disjoint region supports); ``region``/
    ``col``/``lam`` the compact selection; ``merge_packets`` the Table-1
    bill of the merge epoch that produced it (one (q+1)-record region-tree
    aggregation, ARQ-scaled — :func:`repro.core.costs.lossy_merge_cost`).
    """

    basis: np.ndarray                # (p_fleet, q_fleet)
    region: np.ndarray               # (q_fleet,) owning region per component
    col: np.ndarray                  # (q_fleet,) column within that region
    lam: np.ndarray                  # (q_fleet,) energies, descending
    rho: float                       # fleet retained fraction
    regions: tuple                   # region ids merged, ascending
    merge_packets: float             # region-head bill of this merge epoch


@functools.lru_cache(maxsize=None)
def _slot_summary_fn(cfg: StreamConfig):
    """One jitted per-slot retirement summary per StreamConfig (the slot
    index is a traced argument, so every retirement of every engine with
    this config reuses a single compilation).  The eager alternative — a
    dozen small host-dispatched ops per retirement — costs ~25 ms per
    retired slot at serving time, which under churn dwarfs the chunk fold
    itself."""
    from repro.streaming.hierarchy import region_energies
    from repro.streaming.online_cov import (online_estimate,
                                            online_total_variance)
    from repro.streaming.scheduler import retained_fraction

    def summarize(states, comp, det, i):
        st = jax.tree.map(lambda a: a[i], states)
        out = dict(
            W=st.sched.W,
            rho=retained_fraction(online_estimate(st.cov), st.sched.W,
                                  online_total_variance(st.cov)),
            refreshes=st.sched.refreshes,
            comm_packets=st.sched.comm_packets,
            rounds=st.rounds)
        out["lam"], out["total"] = region_energies(st)
        if cfg.compression is not None:
            out.update(comp_max=comp[0][i], comp_extra=comp[1][i],
                       comp_bits=comp[2][i])
        if cfg.detection is not None:
            out.update(det_events=det[0][i], det_alarms=det[1][i],
                       det_t2=st.det.t2_threshold,
                       det_spe=st.det.spe_threshold)
        return out

    return jax.jit(summarize)


@dataclasses.dataclass
class _StagedChunk:
    """One staged chunk upload: device batches plus the host-side plan
    they were built from.  ``signature`` pins the slot plan (per-slot
    request identity + cursor) so a prestaged chunk is consumed only if
    admissions/retirements/submissions did not move the plan under it."""

    batch: jax.Array                 # (slots, K, n, p) owned device copy
    masks: jax.Array | None          # (slots, K, p) or None (no schedules)
    rv: jax.Array                    # (slots, K) round validity
    start: np.ndarray                # cursor snapshot at staging time
    consumed: np.ndarray             # rounds each slot will fold
    signature: tuple                 # plan token (see _plan_signature)


class StreamingPCAEngine:
    """Continuous batching over sensor-network streams, fault-aware.

    Parameters
    ----------
    cfg: the per-network :class:`~repro.streaming.driver.StreamConfig`
        (every slot shares p, n, band half-width and scheduler policy —
        the fleet is shape-homogeneous like a decode batch).
    slots: device batch size (networks streamed concurrently).
    health_policy: per-slot :class:`~repro.runtime.health.StragglerPolicy`;
        ``stall_timeout`` is measured in *engine steps* (the logical clock
        ticks once per step, keeping verdicts deterministic).
    min_alive_fraction: a slot heartbeats only while at least this fraction
        of its sensors is alive; below it the network is considered
        unresponsive and the monitor's stall verdict retires it.
    chunk: rounds folded per engine step (K).  Each step stages every
        slot's next K rounds device-side in ONE upload, folds them through
        the fused chunk kernel, and evaluates ONE scheduler decision per
        slot — the per-dispatch overhead (launches, refresh selects,
        host→device transfers, slot bookkeeping) is amortized over K
        measurement epochs while the Table-1 bill stays per-epoch exact.
        Admission and retirement happen at chunk boundaries; a stream
        whose tail is shorter than K folds only its real rounds (the
        chunk step's per-round validity).  ``chunk=1`` reproduces the
        per-round engine bit-exactly.
    pipeline: pipelined double-buffered staging (DESIGN.md Sec. 17) —
        chunk t+1 is filled and uploaded while the jitted step folds
        chunk t.  Overlap reorders host work only; results are
        bit-identical to ``pipeline=False``.
    queue: a :class:`~repro.serve.queue.QueuePolicy` (or a prebuilt
        :class:`~repro.serve.queue.AdmissionQueue`) for the admission
        front end; None is an unbounded FIFO, bit-compatible with the
        pre-queue engine.
    telemetry: a :class:`~repro.serve.telemetry.TelemetryRecorder`, or
        ``True`` for a default ring recorder; None disables recording.
    """

    def __init__(self, cfg: StreamConfig, slots: int = 8, seed: int = 0,
                 health_policy: StragglerPolicy | None = None,
                 min_alive_fraction: float = 0.25, chunk: int = 1,
                 pipeline: bool = False,
                 queue: QueuePolicy | AdmissionQueue | None = None,
                 telemetry: TelemetryRecorder | bool | None = None):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.cfg = cfg
        self.slots = slots
        self.chunk = chunk
        self.pipeline = pipeline
        self.min_alive_fraction = min_alive_fraction
        self.health_policy = health_policy or StragglerPolicy(
            stall_timeout=2.5)          # logical steps, not seconds
        key = jax.random.PRNGKey(seed)
        self._slot_keys = jax.random.split(key, slots)
        self.states: StreamState = jax.vmap(
            lambda k: stream_init(cfg, k))(self._slot_keys)
        # per-slot re-admission template: slot s always re-initializes
        # from key s, so the fresh fleet is computed once and cached
        self._fresh_states: StreamState | None = None
        self.active: list[StreamRequest | None] = [None] * slots
        self.cursor = np.zeros(slots, np.int64)     # next round per slot
        self.queue: AdmissionQueue = (
            queue if isinstance(queue, AdmissionQueue)
            else AdmissionQueue(queue))
        self.telemetry: TelemetryRecorder | None = (
            TelemetryRecorder() if telemetry is True else telemetry or None)
        # region-aware slots (DESIGN.md Sec. 13): which region each slot is
        # streaming right now (-1 = idle), and the latest final result per
        # region — the merge inputs of fleet_summary()
        self.slot_region = np.full(slots, -1, np.int64)
        self.region_results: dict[int, StreamResult] = {}
        # two jitted chunk steps (repro.streaming.driver.engine_chunk_step_fn
        # — shared with the engine.step* analysis contracts): the masked one
        # only runs when some active request actually carries a liveness
        # schedule — fault-free fleets never build or upload a mask batch at
        # all (and stay on the unmasked kernel); the two are bit-identical
        # under an all-ones mask, so the switch is invisible to results.
        # The fleet state is DONATED: XLA updates the slot pytree in place
        # instead of allocating a fresh copy every step (the states are
        # never read after the call — the returned buffers replace them).
        self._step_fn = engine_chunk_step_fn(cfg)
        self._step_fn_masked = engine_chunk_step_fn(cfg, masked=True)
        self._n: int | None = None       # epochs/round, fixed fleet-wide
        # double-buffered staging (DESIGN.md Sec. 17): two pinned,
        # engine-owned host buffers filled alternately; every upload is an
        # EXPLICIT OWNED COPY (jnp.asarray(copy=True)), so the device batch
        # never aliases staging memory — refilling a buffer two chunks
        # later cannot corrupt an in-flight batch (the CPU device_put
        # aliasing hazard, pinned by the poisoning regression test).  The
        # per-buffer transfer fence (_uploads) is waited on before a
        # REFILL — a wait on the copy-out, never on the chunk fold.
        self._host_bufs: list[np.ndarray | None] = [None, None]
        self._mask_bufs: list[np.ndarray | None] = [None, None]
        self._uploads: list[tuple | None] = [None, None]
        self._parity = 0
        self._staged: _StagedChunk | None = None
        # hot-loop hygiene counters (checked by the engine.step.pipelined
        # contract): every device→host conversion in the engine goes
        # through _pull with a ledger key — "hot" must stay 0 forever
        self.pulls = {"hot": 0, "retire": 0}
        self._transfer_fences = 0
        self._prestage_hits = 0
        self._prestage_misses = 0
        # ε-supervised compression accounting (cfg.compression only):
        # per-slot running worst sink error / flagged-raw extras / bits on
        # air for the current segment.  Accumulated ON DEVICE (jnp ops, no
        # per-step host sync — the step stays async-dispatchable like the
        # decode path); the scalars are pulled to host only at retirement.
        # last_compression keeps the most recent round's full device output
        # (scores, sink view, flags) for observability — one round's
        # arrays, bounded.
        self._comp_max_err = jnp.zeros(slots, jnp.float32)
        self._comp_extras = jnp.zeros(slots, jnp.float32)
        self._comp_bits = jnp.zeros(slots, jnp.float32)
        self.last_compression = None
        # T²/SPE detection accounting (cfg.detection only): per-slot running
        # alarmed-epoch count and alarm-flood bill for the current segment,
        # accumulated on device like the compression books; last_detection
        # keeps the most recent round's device output for observability.
        # The per-alarm packet price and ARQ factor are engine-lifetime
        # constants (cfg is fixed), resolved once here.
        self._det_events = jnp.zeros(slots, jnp.float32)
        self._det_alarm_packets = jnp.zeros(slots, jnp.float32)
        self.last_detection = None
        if cfg.detection is not None:
            from repro.core.faults import expected_transmissions
            from repro.streaming.detector import detection_packet_split
            _, per_alarm = detection_packet_split(cfg.q, cfg.c_max)
            self._det_alarm_price = per_alarm * expected_transmissions(
                cfg.link_loss, cfg.max_retries)
        # fault machinery: logical clock, per-slot monitors, retirement log
        self._clock = 0
        self.health: list[HealthMonitor | None] = [None] * slots
        self.retired_log: list[tuple[StreamRequest, str]] = []
        # elastic fleet mesh: one virtual device per live network; re-planned
        # through runtime.elastic whenever the live count changes (the
        # initial plan assumes a full fleet, so a first step at full
        # occupancy appends nothing)
        self._last_live = slots
        self.plan: RescalePlan = plan_mesh(max(1, slots), prefer_model=1,
                                           global_batch=max(1, slots))
        self.plan_history: list[RescalePlan] = [self.plan]

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: StreamRequest) -> bool:
        """Enqueue a stream for admission; returns False when the bounded
        queue rejected it (backpressure — the caller owns the retry)."""
        r, n, p = req.rounds.shape
        if p != self.cfg.p:
            raise ValueError(f"stream p={p} != engine p={self.cfg.p}")
        if r == 0:
            raise ValueError("stream has no rounds")
        if req.liveness is not None and req.liveness.shape != (r, p):
            raise ValueError(
                f"liveness shape {req.liveness.shape} != {(r, p)}")
        # the device batch is shape-homogeneous: every stream must share the
        # epochs-per-round of the first submitted stream
        if self._n is None:
            self._n = n
        elif n != self._n:
            raise ValueError(f"stream n={n} != engine n={self._n}")
        ok = self.queue.submit(req, priority=req.priority, tenant=req.tenant)
        if not ok and self.telemetry is not None:
            self.telemetry.record_event("rejected", step=self._clock,
                                        priority=req.priority,
                                        tenant=req.tenant,
                                        queue_depth=len(self.queue))
        return ok

    def _tenant_load(self) -> dict:
        load: dict = {}
        for req in self.active:
            if req is not None and req.tenant is not None:
                load[req.tenant] = load.get(req.tenant, 0) + 1
        return load

    def _admit(self) -> int:
        """Fill empty slots from the queue front end (priority order,
        oldest-first within a priority, per-tenant quotas respected), then
        reset every admitted slot's device state in ONE batched splice
        (one scatter per state leaf and per accounting vector, however
        many slots were admitted).  Returns the number admitted."""
        newly: list[int] = []
        load = self._tenant_load()
        for slot in range(self.slots):
            if self.active[slot] is not None:
                continue
            entry = self.queue.pop_admissible(load)
            if entry is None:
                break
            req = entry.req
            self.active[slot] = req
            self.cursor[slot] = req.resume_at
            self.slot_region[slot] = req.region
            if req.tenant is not None:
                load[req.tenant] = load.get(req.tenant, 0) + 1
            newly.append(slot)
            monitor = HealthMonitor(self.health_policy,
                                    clock=lambda: float(self._clock))
            monitor.heartbeat(step=self._clock, duration=1.0)
            self.health[slot] = monitor
            if self.telemetry is not None:
                self.telemetry.record_event(
                    "admitted", step=self._clock, slot=slot,
                    priority=entry.priority, tenant=entry.tenant,
                    resume_at=int(req.resume_at))
        if not newly:
            return 0
        # fixed-shape masked splice: fresh states for the FULL fleet (one
        # compile, ever), selected per slot by a (slots,) mask.  A
        # variable-length at[idx].set would retrace once per distinct
        # admit count — serving-time compile spikes the sustained-load
        # benchmark would otherwise report as latency.  jnp.where writes
        # the identical values, so admission stays bit-identical.
        mask = np.zeros(self.slots, bool)
        mask[newly] = True
        mj = jnp.asarray(mask)
        if self._fresh_states is None:
            self._fresh_states = jax.vmap(
                lambda k: stream_init(self.cfg, k))(self._slot_keys)
        fresh = self._fresh_states

        def splice(full, f):
            sel = mj.reshape((self.slots,) + (1,) * (f.ndim - 1))
            return jnp.where(sel, f, full)

        self.states = jax.tree.map(splice, self.states, fresh)
        if self.cfg.compression is not None:
            self._comp_max_err = jnp.where(mj, 0.0, self._comp_max_err)
            self._comp_extras = jnp.where(mj, 0.0, self._comp_extras)
            self._comp_bits = jnp.where(mj, 0.0, self._comp_bits)
        if self.cfg.detection is not None:
            self._det_events = jnp.where(mj, 0.0, self._det_events)
            self._det_alarm_packets = jnp.where(mj, 0.0,
                                                self._det_alarm_packets)
        return len(newly)

    # -- retirement (classify host-side now, pull device scalars later) ------
    def _pull(self, x, where: str = "hot"):
        """The engine's ONLY device→host conversion point: every float()/
        np.asarray() of a device value routes through here with a ledger
        key, so the pipelined-hot-loop contract can assert pulls["hot"]==0
        (results are pulled at retirement, nowhere else)."""
        self.pulls[where] = self.pulls.get(where, 0) + 1
        return x

    def _result_slices(self, slot: int):
        """Dispatch the retiring slot's device-side summary, BEFORE any
        admission scatter can overwrite the slot.  Async dispatch only;
        nothing is pulled to host here."""
        comp = ((self._comp_max_err, self._comp_extras, self._comp_bits)
                if self.cfg.compression is not None else ())
        det = ((self._det_events, self._det_alarm_packets)
               if self.cfg.detection is not None else ())
        return _slot_summary_fn(self.cfg)(self.states, comp, det,
                                          np.int32(slot))

    def _finalize_result(self, slices, reason: str) -> StreamResult:
        """Pull a retiring slot's device scalars and build its
        StreamResult — the only blocking device→host sync of the loop.
        ONE device_get for the whole summary dict: pulling the ~17 fields
        individually pays ~0.3 ms dispatch latency each, which under
        churn would dominate the chunk fold itself."""
        out = self._pull(jax.device_get(slices), "retire")
        extra: dict = {}
        if self.cfg.compression is not None:
            extra = dict(
                compression_max_err=float(out["comp_max"]),
                compression_extra_packets=float(out["comp_extra"]),
                compression_bits_on_air=float(out["comp_bits"]),
            )
        if self.cfg.detection is not None:
            extra.update(
                detection_events=float(out["det_events"]),
                detection_alarm_packets=float(out["det_alarms"]),
                detection_t2_threshold=float(out["det_t2"]),
                detection_spe_threshold=float(out["det_spe"]),
            )
        return StreamResult(
            components=np.asarray(out["W"]),
            retained=float(out["rho"]),
            refreshes=int(out["refreshes"]),
            comm_packets=float(out["comm_packets"]),
            rounds=int(out["rounds"]),
            reason=reason,
            energies=np.asarray(out["lam"]),
            total_variance=float(out["total"]),
            **extra,
        )

    def _begin_retire(self, slot: int, reason: str) -> dict:
        """Host-side half of retirement: snapshot the slot's device slices
        (lazy), free the slot, and — for a dead retirement whose liveness
        schedule shows a revival — re-queue the continuation (an internal
        submit, exempt from the queue bound: the work was already
        admitted once).  The StreamResult pull happens in
        :meth:`_finish_retire`, AFTER the pipelined loop has staged the
        next chunk, so the pull never blocks the staging overlap."""
        req = self.active[slot]
        pending = dict(req=req, reason=reason, slot=slot,
                       region=int(self.slot_region[slot]),
                       slices=self._result_slices(slot), revive=None)
        self.active[slot] = None
        self.slot_region[slot] = -1
        self.health[slot] = None
        if reason == "dead":
            revive = None
            if req.liveness is not None:
                frac = req.liveness[int(self.cursor[slot]):].mean(axis=1)
                ahead = np.nonzero(frac >= self.min_alive_fraction)[0]
                if ahead.size:
                    revive = int(self.cursor[slot]) + int(ahead[0])
            pending["revive"] = revive
            if revive is not None:
                req.resume_at = revive
                self.queue.submit(req, priority=req.priority,
                                  tenant=req.tenant, internal=True)
        return pending

    def _finish_retire(self, pending: dict) -> None:
        req = pending["req"]
        reason = pending["reason"]
        result = self._finalize_result(pending["slices"], reason)
        self.retired_log.append((req, reason))
        if reason == "dead" and pending["revive"] is not None:
            # a continuation will follow: this segment is an early retirement
            req.retirements.append(result)
        else:
            # final result (dead retirements without a revival ahead stay
            # out of `retirements` so segment bills sum without
            # double-counting)
            req.result = result
            req.done = True
            self.region_results[pending["region"]] = result
        if self.telemetry is not None:
            self.telemetry.record_event(
                "retired", step=self._clock, slot=pending["slot"],
                reason=reason, tenant=req.tenant,
                rounds=result.rounds, comm_packets=result.comm_packets,
                refreshes=result.refreshes,
                revive=pending["revive"])

    def _replan(self, n_live: int) -> None:
        """Elastic fleet mesh: one virtual device per live network."""
        if n_live != self._last_live and n_live > 0:
            self.plan = plan_mesh(n_live, prefer_model=1,
                                  global_batch=n_live)
            self.plan_history.append(self.plan)
        self._last_live = n_live

    # -- staging (double-buffered) -------------------------------------------
    def _plan_signature(self) -> tuple:
        """The slot plan a staged chunk depends on: per-slot request
        identity + cursor.  Any admission, retirement or resumed
        continuation moves it, invalidating a prestaged chunk."""
        return tuple(
            (id(self.active[s]), int(self.cursor[s]))
            if self.active[s] is not None else None
            for s in range(self.slots))

    def _upload(self, host_buf: np.ndarray) -> jax.Array:
        """Owned-copy upload: the device buffer never aliases the pinned
        staging memory (``copy=True`` forces the copy the CPU backend
        would elide for aligned host arrays), so the buffer is free to be
        refilled once the copy-out fence clears."""
        return jnp.asarray(host_buf, copy=True)

    def _stage(self) -> _StagedChunk:
        """Fill the next pinned host buffer with every active slot's next
        K rounds and upload it as an owned device copy.  Idle slots carry
        a zero chunk with zero round-validity (they fold nothing and book
        nothing); a live slot whose stream ends mid-chunk stages only its
        real tail rounds.  The mask batch is neither built nor uploaded
        unless some active request actually carries a liveness schedule
        (the masked and unmasked steps are bit-identical under all-ones
        masks, so the switch is invisible to results)."""
        K, p = self.chunk, self.cfg.p
        i = self._parity
        self._parity ^= 1
        if self._host_bufs[i] is None:
            self._host_bufs[i] = np.zeros((self.slots, K, self._n, p),
                                          np.float32)
            self._mask_bufs[i] = np.ones((self.slots, K, p), np.float32)
        elif self._uploads[i] is not None:
            # transfer fence: wait for this buffer's PREVIOUS upload to
            # finish copying out of the host memory we are about to
            # overwrite.  This is the pipeline's only wait besides the
            # retirement pull — and it is on the device_put, never on the
            # chunk fold.
            self._transfer_fences += 1
            jax.block_until_ready(self._uploads[i])
        buf = self._host_bufs[i]
        rv = np.zeros((self.slots, K), np.float32)
        consumed = np.zeros(self.slots, np.int64)
        start = self.cursor.copy()
        any_schedule = False
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                buf[s] = 0.0
                continue
            c = int(start[s])
            take = min(K, req.rounds.shape[0] - c)
            buf[s, :take] = req.rounds[c:c + take]
            if take < K:
                buf[s, take:] = 0.0
            rv[s, :take] = 1.0
            consumed[s] = take
            any_schedule |= req.liveness is not None
        masks_dev = None
        if any_schedule:
            mbuf = self._mask_bufs[i]
            for s in range(self.slots):
                req = self.active[s]
                if req is None or req.liveness is None:
                    mbuf[s] = 1.0
                    continue
                c, take = int(start[s]), int(consumed[s])
                mbuf[s, :take] = req.liveness[c:c + take]
                if take < K:
                    mbuf[s, take:] = 1.0
            masks_dev = self._upload(mbuf)
        batch_dev = self._upload(buf)
        self._uploads[i] = (batch_dev,) if masks_dev is None \
            else (batch_dev, masks_dev)
        return _StagedChunk(batch=batch_dev, masks=masks_dev,
                            rv=jnp.asarray(rv), start=start,
                            consumed=consumed,
                            signature=self._plan_signature())

    def _accumulate_books(self, metrics, live: list[int]) -> None:
        """Fold the step's stage outputs into the per-slot device
        accounts.  Idle slots fold zero rounds: mask them out of the
        books (where, not multiply — robust to any NaN in an idle
        slot).  All jnp ops — async-dispatchable, no host sync."""
        lm = np.zeros(self.slots, np.float32)
        lm[live] = 1.0
        lmj = jnp.asarray(lm)
        if self.cfg.compression is not None:
            comp = metrics.compression
            self.last_compression = comp      # (slots, ...) device arrays
            self._comp_max_err = jnp.maximum(
                self._comp_max_err, jnp.where(lmj > 0, comp.max_err, 0.0))
            self._comp_extras = self._comp_extras + jnp.where(
                lmj > 0, comp.extra_packets, 0.0)
            self._comp_bits = self._comp_bits + jnp.where(
                lmj > 0, comp.bits_on_air, 0.0)
        if self.cfg.detection is not None:
            det = metrics.detection
            self.last_detection = det         # (slots, ...) device arrays
            alarms = jnp.where(lmj > 0, det.alarms, 0.0)
            self._det_events = self._det_events + alarms
            self._det_alarm_packets = (self._det_alarm_packets
                                       + alarms * self._det_alarm_price)

    # -- main loop ------------------------------------------------------------
    def step(self) -> int:
        """Fold the next K-round chunk for every active slot; returns
        #active.

        The loop is host-sync-free in steady state: the staged batch is
        an owned device copy, the jitted step updates the donated fleet
        state in place, and the accounting stays on device — scalars are
        pulled to host only at retirement.  With ``pipeline=True`` the
        chunk consumed by step t+1 was filled and uploaded DURING step t,
        while the device folded chunk t (staged-vs-compute overlap); a
        prestaged chunk is dropped and restaged inline if the slot plan
        moved under it (new admission, retirement, or a submission that
        fills a free slot).  Per step, each live slot heartbeats its
        HealthMonitor iff enough of its sensors were alive over the
        chunk's rounds; slots ruled stalled afterwards are retired dead
        (and re-queued from their revival round, if any).
        """
        t0 = time.perf_counter()
        admitted = self._admit()
        self._clock += 1
        live = [s for s in range(self.slots) if self.active[s]]
        self._replan(len(live))
        if not live:
            self._staged = None
            if self.telemetry is not None:
                self.telemetry.record_step(StepRecord(
                    step=self._clock, wall_s=time.perf_counter() - t0,
                    stage_s=0.0, overlap_s=0.0, prestaged=False, live=0,
                    rounds=0, queue_depth=len(self.queue),
                    admitted=admitted, retired=0))
            return 0
        # -- chunk t: consume the prestaged upload, or stage inline --------
        staged, self._staged = self._staged, None
        prestaged = (staged is not None
                     and staged.signature == self._plan_signature())
        stage_s = 0.0
        if prestaged:
            self._prestage_hits += 1
        else:
            self._prestage_misses += 1
            t_s = time.perf_counter()
            staged = self._stage()
            stage_s = time.perf_counter() - t_s
        # -- dispatch: nothing below blocks on the fold --------------------
        if staged.masks is not None:
            self.states, metrics = self._step_fn_masked(
                self.states, staged.batch, staged.masks, staged.rv)
        else:
            self.states, metrics = self._step_fn(
                self.states, staged.batch, staged.rv)
        self._accumulate_books(metrics, live)
        # -- host bookkeeping: heartbeats, cursors, retirement verdicts ----
        pendings: list[dict] = []
        for s in live:
            req = self.active[s]
            c, take = int(staged.start[s]), int(staged.consumed[s])
            frac = 1.0 if req.liveness is None \
                else float(req.liveness[c:c + take].mean())
            if frac >= self.min_alive_fraction:
                self.health[s].heartbeat(step=self._clock, duration=1.0)
            self.cursor[s] += take
            if self.cursor[s] >= req.rounds.shape[0]:
                pendings.append(self._begin_retire(s, "completed"))
            elif self.health[s].stalled():
                pendings.append(self._begin_retire(s, "dead"))
        # -- pipelined prestage: chunk t+1 overlaps the in-flight fold -----
        overlap_s = 0.0
        if self.pipeline:
            admitted += self._admit()
            if any(r is not None for r in self.active):
                t_s = time.perf_counter()
                self._staged = self._stage()
                overlap_s = time.perf_counter() - t_s
                stage_s += overlap_s
        # -- retirement results: the loop's only device→host pulls ---------
        for pending in pendings:
            self._finish_retire(pending)
        if self.telemetry is not None:
            self.telemetry.record_step(StepRecord(
                step=self._clock, wall_s=time.perf_counter() - t0,
                stage_s=stage_s, overlap_s=overlap_s, prestaged=prestaged,
                live=len(live), rounds=int(staged.consumed.sum()),
                queue_depth=len(self.queue), admitted=admitted,
                retired=len(pendings)))
        return len(live)

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return

    # -- two-level fleet merge (DESIGN.md Sec. 13) ---------------------------
    def fleet_summary(self, q_fleet: int | None = None,
                      c_regions: int | None = None) -> FleetSummary:
        """Merge the retired regions' bases into the fleet-level basis.

        One level-2 merge epoch over the region results collected so far
        (latest final result per region id): global top-``q_fleet``
        selection by subspace energy (:func:`repro.streaming.hierarchy.
        merge_fleet` — the same jittable core the cross-host driver runs
        after its ``all_gather``), dense block embedding, and the merge's
        Table-1 bill at region-tree fan-out ``c_regions`` (default
        ``cfg.c_max``), ARQ-scaled like every intra-network packet.
        """
        from repro.core import costs
        from repro.streaming.hierarchy import fleet_basis_dense, merge_fleet
        if not self.region_results:
            raise ValueError("no retired region results to merge")
        regions = sorted(self.region_results)
        results = [self.region_results[r] for r in regions]
        lam_table = jnp.asarray(np.stack([r.energies for r in results]))
        total = jnp.asarray(sum(r.total_variance for r in results),
                            jnp.float32)
        qf = self.cfg.q if q_fleet is None else q_fleet
        basis = merge_fleet(lam_table, total, qf)
        W_regions = jnp.asarray(np.stack([r.components for r in results]))
        cr = self.cfg.c_max if c_regions is None else c_regions
        bill = costs.lossy_merge_cost(self.cfg.q, cr, self.cfg.link_loss,
                                      self.cfg.max_retries).communication
        return FleetSummary(
            basis=np.asarray(fleet_basis_dense(basis, W_regions)),
            region=np.asarray(basis.region),
            col=np.asarray(basis.col),
            lam=np.asarray(basis.lam),
            rho=float(basis.rho),
            regions=tuple(regions),
            merge_packets=float(bill),
        )


# ===========================================================================
# Program contracts (repro.analysis; DESIGN.md Sec. 15/17): the engine hot
# loop, synchronous and pipelined.  Static rules pin the vmapped chunk body
# (one launch per step, no host-sync primitive anywhere in the traced
# program); the runtime checks need the lowered/compiled artifact — buffer
# donation is a lowering property, retraces a jit-cache property, and the
# pipelined loop's no-host-pull claim lives on the engine's pull ledger —
# so they run a tiny interpret-mode fleet for a few steps.
# ===========================================================================
from repro.analysis import contracts as _contracts  # noqa: E402
from repro.analysis import jaxpr_lint as _jl        # noqa: E402
from repro.analysis import resources as _res        # noqa: E402

_CONTRACT_SLOTS, _CONTRACT_K, _CONTRACT_N = 2, 2, 4


def _contract_engine(pipeline: bool = False) -> StreamingPCAEngine:
    cfg = StreamConfig(p=8, q=2, halfwidth=1, warmup_rounds=2,
                       interpret=True)
    eng = StreamingPCAEngine(cfg, slots=_CONTRACT_SLOTS, seed=0,
                             chunk=_CONTRACT_K, pipeline=pipeline)
    rng = np.random.default_rng(0)
    for _ in range(_CONTRACT_SLOTS):
        eng.submit(StreamRequest(rounds=rng.normal(
            size=(6, _CONTRACT_N, cfg.p)).astype(np.float32)))
    return eng


def _contract_engine_batch(eng: StreamingPCAEngine):
    batch = jnp.zeros((eng.slots, eng.chunk, _CONTRACT_N, eng.cfg.p),
                      jnp.float32)
    rv = jnp.ones((eng.slots, eng.chunk), jnp.float32)
    return batch, rv


def _trace_engine_step():
    eng = _contract_engine()
    batch, rv = _contract_engine_batch(eng)
    jx = jax.make_jaxpr(lambda s, x, r: eng._step_fn(s, x, r))(
        eng.states, batch, rv)
    return {f"slots={eng.slots},K={eng.chunk}": jx}


def _engine_runtime_checks():
    eng = _contract_engine()
    # a FRESH (un-memoized) jitted step: the factory cache shares one
    # callable per config across all engines, so the retrace check needs
    # its own instance to see an isolated jit cache
    eng._step_fn = engine_chunk_step_fn.__wrapped__(eng.cfg)
    batch, rv = _contract_engine_batch(eng)
    results = [_contracts.donation_report(eng._step_fn, eng.states, batch,
                                          rv, argnum=0,
                                          contract="engine.step")]
    for _ in range(3):               # 6 rounds / chunk 2 = 3 same-shape steps
        eng.step()
    results.append(_contracts.retrace_report(eng._step_fn, 3,
                                             contract="engine.step"))
    return results


_contracts.register(_contracts.Contract(
    id="engine.step",
    where="repro.serve.engine.StreamingPCAEngine.step",
    claim="the vmapped chunk step launches one pallas kernel per engine "
          "step, the fleet state is donated (in-place update), and "
          "same-shape steps never retrace",
    trace=_trace_engine_step,
    rules=(_jl.PrimitiveBudget("pallas_call", exact=1),
           _jl.PrimitiveBudget("eigh", max=1),
           _jl.ForbidInLoops(everywhere=True),
           _jl.NoF64(),
           _res.VmemBudget(),
           _res.HbmTrafficBudget(max_passes=1.0)),
    runtime=_engine_runtime_checks,
))


def _trace_engine_step_pipelined():
    eng = _contract_engine(pipeline=True)
    batch, rv = _contract_engine_batch(eng)
    jx = jax.make_jaxpr(lambda s, x, r: eng._step_fn(s, x, r))(
        eng.states, batch, rv)
    return {f"slots={eng.slots},K={eng.chunk}": jx}


def _pipelined_runtime_checks():
    """The async-loop half of the contract (DESIGN.md Sec. 17): donation
    and no-retrace as on the sync path, PLUS the pipeline hygiene only an
    actual run can show — zero device→host pulls in the hot path (the
    engine's pull ledger keys every conversion), retirement being the one
    place that pulls, and prestaged chunks actually being consumed in
    steady state (the overlap exists structurally, not just in timings)."""
    eng = _contract_engine(pipeline=True)
    eng._step_fn = engine_chunk_step_fn.__wrapped__(eng.cfg)   # isolated cache
    batch, rv = _contract_engine_batch(eng)
    results = [_contracts.donation_report(eng._step_fn, eng.states, batch,
                                          rv, argnum=0,
                                          contract="engine.step.pipelined")]
    eng.run_until_done()             # 6 rounds / chunk 2 = 3 steps + drain
    results.append(_contracts.retrace_report(eng._step_fn, 3,
                                             contract="engine.step.pipelined"))
    cid = "engine.step.pipelined"
    results.append(_contracts.RuleResult(
        cid, "hot-loop:no-host-pull", eng.pulls["hot"] == 0,
        f"{eng.pulls['hot']} device pulls in the pipelined hot path over "
        f"{eng._clock} steps (want 0; retirement pulled "
        f"{eng.pulls['retire']})"))
    results.append(_contracts.RuleResult(
        cid, "hot-loop:retire-pulls-only", eng.pulls["retire"] > 0,
        f"retirement pulled {eng.pulls['retire']} scalars — the loop's "
        f"only device→host sync point"))
    results.append(_contracts.RuleResult(
        cid, "hot-loop:prestage", eng._prestage_hits >= 1,
        f"{eng._prestage_hits} prestaged chunks consumed, "
        f"{eng._prestage_misses} inline stages (want >=1 hit: the "
        f"pipeline must actually pipeline)"))
    return results


_contracts.register(_contracts.Contract(
    id="engine.step.pipelined",
    where="repro.serve.engine.StreamingPCAEngine.step",
    claim="the pipelined loop's chunk body is the same single-launch "
          "donated step (no host-sync primitive anywhere in the traced "
          "program), and at runtime the hot path makes zero device->host "
          "pulls — results are pulled at retirement only, and prestaged "
          "chunks are consumed in steady state",
    trace=_trace_engine_step_pipelined,
    rules=(_jl.PrimitiveBudget("pallas_call", exact=1),
           _jl.PrimitiveBudget("eigh", max=1),
           _jl.ForbidInLoops(everywhere=True),
           _jl.NoF64(),
           _res.VmemBudget(),
           _res.HbmTrafficBudget(max_passes=1.0)),
    runtime=_pipelined_runtime_checks,
))
