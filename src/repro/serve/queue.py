"""Priority admission queue for the streaming-PCA engine (DESIGN.md Sec. 17).

The queue is the front end of :class:`repro.serve.engine.StreamingPCAEngine`:
every external :meth:`submit` lands here, and the engine's ``_admit`` drains
it into free device slots.  Three serving knobs live in the
:class:`QueuePolicy`:

* **priorities** — higher ``priority`` admits first; within a priority
  class the queue is strictly oldest-first (FIFO by arrival sequence), so
  admission order is a pure function of the arrival schedule.
* **per-tenant quotas** — ``max_slots_per_tenant`` caps how many device
  slots one tenant may hold concurrently; an over-quota tenant's requests
  are *skipped, not dropped* — they stay queued (in order) and admit as
  soon as one of the tenant's slots retires.  Johard et al.'s
  self-adaptive per-node encodings (PAPERS.md) motivate exactly this
  per-tenant admission dial.
* **backpressure** — ``capacity`` bounds the queue depth; a submit into a
  full queue is *rejected* (``submit`` returns ``False``, the
  ``rejected`` counter ticks) rather than buffered without bound.  The
  engine's own continuation re-queues (churn revivals) bypass the bound:
  they represent work already admitted once, so dropping them would lose
  accepted state.

Everything is host-side pure Python with no randomness: given the same
arrival schedule (submit calls interleaved with engine steps) the admission
sequence is bit-reproducible — the determinism-replay tests pin this.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Iterator, Mapping

__all__ = ["QueuePolicy", "QueuedRequest", "AdmissionQueue"]


@dataclasses.dataclass(frozen=True)
class QueuePolicy:
    """Admission-control knobs; the default is an unbounded plain FIFO
    (bit-compatible with the pre-queue engine's ``list`` semantics)."""

    capacity: int | None = None            # max queued entries; None = no bound
    max_slots_per_tenant: int | None = None  # concurrent-slot quota per tenant

    def __post_init__(self):
        if self.capacity is not None and self.capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {self.capacity}")
        if (self.max_slots_per_tenant is not None
                and self.max_slots_per_tenant < 1):
            raise ValueError("max_slots_per_tenant must be >= 1, got "
                             f"{self.max_slots_per_tenant}")


@dataclasses.dataclass(frozen=True, order=True)
class QueuedRequest:
    """One queue entry.  The sort key IS the admission order: higher
    priority first (negated), oldest arrival first within a priority."""

    sort_key: tuple[int, int] = dataclasses.field(repr=False)
    req: object = dataclasses.field(compare=False)
    priority: int = dataclasses.field(compare=False)
    tenant: object = dataclasses.field(compare=False)
    seq: int = dataclasses.field(compare=False)


class AdmissionQueue:
    """Bounded priority queue with per-tenant quota-aware draining."""

    def __init__(self, policy: QueuePolicy | None = None):
        self.policy = policy or QueuePolicy()
        self._entries: list[QueuedRequest] = []   # kept sorted by sort_key
        self._seq = 0                             # arrival counter (total order)
        self.rejected = 0                         # backpressure rejections
        self.submitted = 0                        # accepted submissions

    # -- producer side -------------------------------------------------------
    def submit(self, req, *, priority: int = 0, tenant=None,
               internal: bool = False) -> bool:
        """Enqueue ``req``; returns False (and counts a rejection) when the
        queue is at capacity.  ``internal`` marks engine-initiated
        continuation re-queues, which are exempt from the bound."""
        if (not internal and self.policy.capacity is not None
                and len(self._entries) >= self.policy.capacity):
            self.rejected += 1
            return False
        entry = QueuedRequest(sort_key=(-priority, self._seq), req=req,
                              priority=priority, tenant=tenant,
                              seq=self._seq)
        self._seq += 1
        bisect.insort(self._entries, entry)
        self.submitted += 1
        return True

    # -- consumer side (the engine's _admit) ---------------------------------
    def pop_admissible(self, tenant_load: Mapping | None = None
                       ) -> QueuedRequest | None:
        """Remove and return the highest-priority oldest entry whose tenant
        has spare quota under ``tenant_load`` (a ``{tenant: live-slot
        count}`` view of the engine's active slots).  Over-quota tenants'
        entries are skipped in place; returns None when nothing admits."""
        quota = self.policy.max_slots_per_tenant
        for i, entry in enumerate(self._entries):
            if (quota is not None and entry.tenant is not None
                    and tenant_load is not None
                    and tenant_load.get(entry.tenant, 0) >= quota):
                continue
            return self._entries.pop(i)
        return None

    # -- observability -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def __iter__(self) -> Iterator[QueuedRequest]:
        return iter(list(self._entries))

    def depth_by_priority(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for e in self._entries:
            out[e.priority] = out.get(e.priority, 0) + 1
        return out
