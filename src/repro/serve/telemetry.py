"""Structured serving telemetry: ring-buffer recorder + JSONL sink.

The engine's hot loop (DESIGN.md Sec. 17) emits one :class:`StepRecord`
per step and one event record per admission/retirement/rejection; the
:class:`TelemetryRecorder` keeps the most recent ``capacity`` of each in a
ring buffer (bounded memory for arbitrarily long serving runs) and can
mirror every record to a JSONL file as it arrives — the append-a-line-per-
step logging shape of the ``wandblog.py`` pattern the ROADMAP cites, with
the file as the sink instead of a tracking service.

Everything recorded is HOST-side (wall times from ``perf_counter``, host
counters, queue depths): recording never touches a device array, so the
recorder can sit inside the pipelined hot loop without adding a sync.  The
one exception is the per-slot Table-1 bill attached to retirement events —
the engine already pulls those scalars to host to build the
:class:`~repro.serve.engine.StreamResult`, so telemetry reuses the pulled
values rather than causing its own transfer.

``summary()`` folds the ring into the serving headline numbers: p50/p99
step latency, mean staged-vs-compute overlap fraction, prestage hit rate,
throughput, admission/retirement totals.
"""

from __future__ import annotations

import collections
import dataclasses
import json
from typing import IO

import numpy as np

__all__ = ["StepRecord", "TelemetryRecorder"]


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """One engine step, entirely host-observed.

    ``stage_s`` is the host staging work done during this step (buffer
    fill + owned-copy upload dispatch); ``overlap_s`` is the part of it
    that ran while the previous chunk's device compute was still in
    flight (the pipelined engine stages chunk t+1 after dispatching
    chunk t, so its whole staging cost overlaps; the synchronous engine
    stages before dispatch, so its overlap is 0 by construction).
    ``prestaged`` flags whether the chunk folded THIS step came from the
    previous step's staging (the steady-state pipelined case) or had to
    be staged inline (first step, or an admission/retirement changed the
    slot plan under the staged batch).
    """

    step: int                 # engine logical clock at this step
    wall_s: float             # whole-step wall time
    stage_s: float            # host staging work performed this step
    overlap_s: float          # staging time overlapped with device compute
    prestaged: bool           # chunk folded this step was staged last step
    live: int                 # active slots this step
    rounds: int               # measurement rounds folded this step
    queue_depth: int          # queue depth after admission
    admitted: int             # slots admitted this step
    retired: int              # slots retired this step

    @property
    def overlap_fraction(self) -> float:
        return self.overlap_s / self.wall_s if self.wall_s > 0 else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["kind"] = "step"
        d["overlap_fraction"] = self.overlap_fraction
        return d


class TelemetryRecorder:
    """Bounded ring of step/event records with an optional JSONL mirror."""

    def __init__(self, capacity: int = 4096,
                 jsonl_path: str | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.steps: collections.deque[StepRecord] = collections.deque(
            maxlen=capacity)
        self.events: collections.deque[dict] = collections.deque(
            maxlen=capacity)
        # lifetime totals survive ring eviction (the ring is a window,
        # the totals are the ledger)
        self.total_steps = 0
        self.total_rounds = 0
        self.total_admitted = 0
        self.total_retired = 0
        self.total_wall_s = 0.0
        self._sink: IO[str] | None = (
            open(jsonl_path, "a") if jsonl_path else None)

    # -- recording -----------------------------------------------------------
    def record_step(self, rec: StepRecord) -> None:
        self.steps.append(rec)
        self.total_steps += 1
        self.total_rounds += rec.rounds
        self.total_admitted += rec.admitted
        self.total_retired += rec.retired
        self.total_wall_s += rec.wall_s
        if self._sink is not None:
            json.dump(rec.to_json(), self._sink)
            self._sink.write("\n")

    def record_event(self, kind: str, **fields) -> None:
        """Admission / retirement / rejection events; retirement events
        carry the slot's pulled per-segment bill (``comm_packets`` etc.)."""
        rec = {"kind": kind, **fields}
        self.events.append(rec)
        if self._sink is not None:
            json.dump(rec, self._sink)
            self._sink.write("\n")

    # -- summaries -----------------------------------------------------------
    def step_latency_percentiles(self, qs=(50.0, 99.0)) -> dict[str, float]:
        """``{"p50": seconds, ...}`` over the ring window (empty → zeros)."""
        walls = np.asarray([r.wall_s for r in self.steps], np.float64)
        if walls.size == 0:
            return {f"p{q:g}": 0.0 for q in qs}
        return {f"p{q:g}": float(np.percentile(walls, q)) for q in qs}

    def mean_overlap_fraction(self) -> float:
        """Staged-vs-compute overlap over the ring, weighted by wall time
        (the fraction of serving time the host spent staging under an
        in-flight device chunk)."""
        wall = sum(r.wall_s for r in self.steps)
        if wall <= 0:
            return 0.0
        return sum(r.overlap_s for r in self.steps) / wall

    def prestage_hit_rate(self) -> float:
        """Fraction of non-idle steps that consumed a prestaged chunk."""
        folded = [r for r in self.steps if r.live > 0]
        if not folded:
            return 0.0
        return sum(1 for r in folded if r.prestaged) / len(folded)

    def summary(self) -> dict:
        pct = self.step_latency_percentiles()
        return {
            "steps": self.total_steps,
            "rounds": self.total_rounds,
            "admitted": self.total_admitted,
            "retired": self.total_retired,
            "wall_s": self.total_wall_s,
            "rounds_per_s": (self.total_rounds / self.total_wall_s
                             if self.total_wall_s > 0 else 0.0),
            "p50_step_s": pct["p50"],
            "p99_step_s": pct["p99"],
            "overlap_fraction": self.mean_overlap_fraction(),
            "prestage_hit_rate": self.prestage_hit_rate(),
        }

    def reset(self) -> None:
        """Clear the rings and lifetime totals (the JSONL sink, if any,
        keeps appending) — e.g. to drop warm-up/compile steps before a
        measured benchmark window."""
        self.steps.clear()
        self.events.clear()
        self.total_steps = 0
        self.total_rounds = 0
        self.total_admitted = 0
        self.total_retired = 0
        self.total_wall_s = 0.0

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None

    def __enter__(self) -> "TelemetryRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
