from repro.sensors.dataset import SensorDataset, berkeley_surrogate, kfold_blocks

__all__ = ["SensorDataset", "berkeley_surrogate", "kfold_blocks"]
