"""Synthetic surrogate of the Intel-Berkeley temperature trace (paper Sec. 4.1).

The original trace (54 Mica2Dot motes, 5 days, 31 s sampling, sensors 5 and 15
dead -> 52 usable) is not available offline.  This module generates a
statistically matched surrogate with the properties the paper's experiments
depend on:

* p = 52 sensors at a Berkeley-like 2-D layout (40 m x 30 m),
* N = 14 400 epochs of 30 s (5 days),
* temperatures within ~15-35 C,
* a shared diurnal cycle (dominant first principal component, ~80 % variance),
* spatially correlated residuals whose correlation decays with distance
  (the *local covariance hypothesis* substrate), least-correlated pair ~0.6,
* localized AC/occupancy events (the Fig.-8 'air conditioning near sensor 49'
  plateaus) contributing mid-rank components,
* i.i.d. sensor noise (the white-noise tail of Fig. 7).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.topology import berkeley_like_layout

__all__ = ["SensorDataset", "berkeley_surrogate", "kfold_blocks",
           "inject_ac_event"]


@dataclasses.dataclass(frozen=True)
class SensorDataset:
    """(N, p) measurement matrix plus sensor positions; rows are epochs."""

    measurements: np.ndarray     # (N, p) float64, degrees C
    positions: np.ndarray        # (p, 2) meters
    epoch_seconds: float = 30.0

    @property
    def n_epochs(self) -> int:
        return int(self.measurements.shape[0])

    @property
    def p(self) -> int:
        return int(self.measurements.shape[1])

    def centered(self, mean: np.ndarray | None = None) -> np.ndarray:
        mu = self.measurements.mean(axis=0) if mean is None else mean
        return self.measurements - mu


def berkeley_surrogate(p: int = 52, n_epochs: int = 14_400, seed: int = 0,
                       noise_std: float = 0.25) -> SensorDataset:
    """Generate the surrogate trace.  Deterministic for a given seed."""
    rng = np.random.default_rng(seed)
    positions = berkeley_like_layout(p=p, seed=seed + 7)

    t = np.arange(n_epochs) * 30.0 / 86_400.0  # time in days
    # --- shared diurnal component (global, dominates variance) -------------
    diurnal = 24.0 + 6.5 * np.sin(2 * np.pi * (t - 0.3))  # (N,)
    diurnal = diurnal + 1.2 * np.sin(4 * np.pi * (t - 0.1))
    # per-sensor coupling to the diurnal cycle: near-window sensors swing more
    gain = 0.75 + 0.5 * rng.beta(2.0, 2.0, size=p)          # (p,)
    offset = rng.normal(0.0, 1.0, size=p)                   # per-sensor bias

    # --- spatially correlated slow residual (GP over positions) ------------
    d = np.linalg.norm(positions[:, None, :] - positions[None, :, :], axis=-1)
    ell = 18.0                                  # spatial correlation length, m
    K = np.exp(-(d / ell) ** 2) + 1e-6 * np.eye(p)
    Lk = np.linalg.cholesky(K)
    # temporally smooth drivers: random walk smoothed by an EMA
    n_factors = p
    z = rng.normal(size=(n_epochs, n_factors))
    alpha = 0.015                               # ~30-min smoothing at 30 s
    for i in range(1, n_epochs):
        z[i] = (1 - alpha) * z[i - 1] + np.sqrt(alpha * (2 - alpha)) * z[i]
    spatial = 1.6 * (z @ Lk.T)                  # (N, p)

    # --- localized AC / occupancy events (plateaus near a random site) -----
    events = np.zeros((n_epochs, p))
    n_events = 10
    for _ in range(n_events):
        site = rng.integers(0, p)
        start = rng.integers(0, n_epochs - 1_200)
        dur = rng.integers(400, 1_200)
        amp = rng.uniform(-3.0, -1.0)           # cooling plateaus
        foot = np.exp(-(d[site] / 6.0) ** 2)    # ~6 m footprint
        window = np.zeros(n_epochs)
        window[start:start + dur] = 1.0
        # smooth the edges (~5 epochs)
        kernel = np.ones(11) / 11.0
        window = np.convolve(window, kernel, mode="same")
        events += amp * window[:, None] * foot[None, :]

    x = (offset[None, :] + gain[None, :] * diurnal[:, None]
         + spatial + events
         + rng.normal(0.0, noise_std, size=(n_epochs, p)))
    x = np.clip(x, 12.0, 38.0)
    return SensorDataset(measurements=x, positions=positions)


def inject_ac_event(measurements: np.ndarray, positions: np.ndarray, *,
                    site: int, start: int, duration: int,
                    amplitude: float, footprint_m: float = 6.0,
                    ramp_epochs: int = 11,
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Inject one localized AC/occupancy plateau into an (N, p) epoch block.

    The same event family :func:`berkeley_surrogate` seeds its traces with
    (the Fig.-8 'air conditioning near sensor 49' plateaus), exposed as a
    standalone generator so detection experiments can place *known* events:
    a spatial footprint ``exp(-(d / footprint_m)^2)`` around ``site``
    (network-coherent — every nearby sensor moves together — yet small
    against each sensor's own swing: exactly what the Sec.-2.4.3 evaluator
    exists to catch), a plateau of ``duration`` epochs whose first/last
    ``ramp_epochs`` ramp linearly INSIDE the window (no amplitude ever
    leaks outside it — an event epoch outside the truth mask would charge
    a correct detector with false positives), and ``amplitude`` degrees at
    the site (negative for cooling).

    Returns ``(x_event, window)``: a modified copy of ``measurements`` and
    the (N,) boolean truth mask — exactly the support of the injected
    envelope, the ground truth TPR/FPR sweeps score against.
    """
    x = np.array(measurements, dtype=measurements.dtype)
    n_epochs, p = x.shape
    if not 0 <= site < p:
        raise ValueError(f"site {site} outside [0, {p})")
    if start < 0 or start + duration > n_epochs:
        raise ValueError(
            f"event [{start}, {start + duration}) outside [0, {n_epochs})")
    d = np.linalg.norm(positions - positions[site], axis=-1)
    foot = np.exp(-(d / footprint_m) ** 2)
    plateau = np.ones(duration)
    r = min(ramp_epochs, duration // 2)
    if r > 1:
        up = np.linspace(1.0 / r, 1.0, r)
        plateau[:r] = up
        plateau[duration - r:] = up[::-1]
    window = np.zeros(n_epochs)
    window[start:start + duration] = plateau
    x += amplitude * window[:, None] * foot[None, :]
    return x, window > 0.0


def kfold_blocks(n_epochs: int, k: int = 10) -> list[tuple[np.ndarray, np.ndarray]]:
    """The paper's block K-fold CV (Sec. 4.3): K *consecutive* blocks; each
    block is the training set in turn, the remaining epochs are the test set.
    Returns a list of (train_idx, test_idx)."""
    edges = np.linspace(0, n_epochs, k + 1).astype(int)
    folds = []
    all_idx = np.arange(n_epochs)
    for i in range(k):
        tr = all_idx[edges[i]:edges[i + 1]]
        te = np.concatenate([all_idx[:edges[i]], all_idx[edges[i + 1]:]])
        folds.append((tr, te))
    return folds
