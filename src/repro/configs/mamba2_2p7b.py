"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060; unverified].

64L d_model=2560, attention-free, vocab=50280, ssm_state=128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50_280,
    d_state=128, expand=2, d_conv=4, ssm_headdim=64,
    source="arXiv:2405.21060",
)
