"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
128 meta tokens; SWA(1024) everywhere except 3 global full-attention layers
(first/middle/last), per the Hymba recipe.  SSM branch: expand=1 so the
mamba heads mirror the 25x64 attention geometry (DESIGN.md Sec. 4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32_001, d_state=16, expand=1, d_conv=4, ssm_headdim=64,
    swa_window=1024, n_global_layers=3, n_meta_tokens=128,
    rope_theta=10_000.0,
    source="arXiv:2411.13676",
)
