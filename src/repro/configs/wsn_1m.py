"""wsn-1m — the paper's own system at production scale.

1,048,576 virtual sensors (fleet telemetry channels) sharded over all chips,
banded covariance with half-width 128 after bandwidth reduction
(local covariance hypothesis), q=32 principal components, 256-epoch update
batches.  Not an LM architecture: consumed by the dry-run via
repro.core.production.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class WSNConfig:
    name: str = "wsn-1m"
    p: int = 1_048_576
    halfwidth: int = 128
    q: int = 32
    batch_epochs: int = 256
    dtype: str = "float32"


CONFIG = WSNConfig()
