"""wsn-1m — the paper's own system at production scale.

1,048,576 virtual sensors (fleet telemetry channels) sharded over all chips,
banded covariance with half-width 128 after bandwidth reduction
(local covariance hypothesis), q=32 principal components, 256-epoch update
batches.  Not an LM architecture: consumed by the dry-run via
repro.core.production.

The fleet is two-level (DESIGN.md Sec. 13): ``n_regions`` regions of
``region_p`` sensors each stream independently and merge per refresh over
the cross-host ``region`` mesh axis.  :meth:`WSNConfig.smoke` is the
CI-sized replica of the same two-level shape — every ratio (band fraction,
q per region, regions per device) scaled down so the full pipeline runs
end-to-end in seconds on forced host devices.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class WSNConfig:
    name: str = "wsn-1m"
    p: int = 1_048_576
    halfwidth: int = 128
    q: int = 32
    batch_epochs: int = 256
    n_regions: int = 1024
    dtype: str = "float32"

    @property
    def region_p(self) -> int:
        """Per-region sensor count of the two-level decomposition."""
        if self.p % self.n_regions != 0:
            raise ValueError(f"p={self.p} not divisible by "
                             f"n_regions={self.n_regions}")
        return self.p // self.n_regions

    def smoke(self) -> "WSNConfig":
        """CI-sized replica: same two-level shape, seconds not hours."""
        return dataclasses.replace(
            self, name="wsn-1m-smoke", p=4096, halfwidth=8, q=8,
            batch_epochs=8, n_regions=8)


CONFIG = WSNConfig()
