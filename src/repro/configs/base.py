"""Architecture + run configuration.

One :class:`ArchConfig` per assigned architecture lives in
``repro/configs/<id>.py``; reduced smoke variants are derived with
:meth:`ArchConfig.smoke`.  Shape sets (train_4k / prefill_32k / decode_32k /
long_500k) are defined here and gated per-family (``long_500k`` requires
sub-quadratic attention — DESIGN.md Sec. 4).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    swa_window: int = 0              # 0 = full attention
    n_global_layers: int = 0         # hybrid: layers with full attn
    n_meta_tokens: int = 0           # hybrid: learned prefix tokens
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm
    d_state: int = 0
    expand: int = 2
    d_conv: int = 4
    ssm_headdim: int = 64
    # enc-dec
    enc_layers: int = 0              # encoder layers (dec = n_layers)
    # numerics
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # provenance
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k eligibility: sub-quadratic sequence mixing."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d
        if self.family == "ssm":
            di, ns, hd = self.d_inner, self.d_state, self.ssm_headdim
            nh = di // hd
            per = (d * (2 * di + 2 * ns + nh)        # in_proj (z,x,B,C,dt)
                   + self.d_conv * (di + 2 * ns)     # conv
                   + di * d                          # out_proj
                   + 2 * nh + di)                    # A, D, norm
            return emb * 2 + L * per
        attn = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.head_dim * d
        if self.family == "moe":
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        else:
            ffn = 3 * d * self.d_ff
        per = attn + ffn + 2 * d
        layers = L + self.enc_layers
        if self.family == "encdec":
            per = per + attn                         # cross attention
        if self.family == "hybrid":
            di, ns, hd = self.d_inner, self.d_state, self.ssm_headdim
            nh = di // hd
            per = per + (d * (2 * di + 2 * ns + nh) + di * d
                         + self.d_conv * (di + 2 * ns) + 2 * nh + di)
        return emb * 2 + layers * per

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d
        attn = d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.head_dim * d
        ffn = self.top_k * 3 * d * self.d_ff + d * self.n_experts
        return emb * 2 + L * (attn + ffn + 2 * d)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            d_state=min(self.d_state, 16) if self.d_state else 0,
            ssm_headdim=16,
            enc_layers=2 if self.enc_layers else 0,
            n_meta_tokens=min(self.n_meta_tokens, 8),
            swa_window=min(self.swa_window, 16) if self.swa_window else 0,
            n_global_layers=min(self.n_global_layers, 1),
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The shape cells this architecture runs (DESIGN.md Sec. 4)."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        out.append("long_500k")
    return out
