"""chameleon-34b — early-fusion VLM, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.  Backbone only: the
VQ-VAE image tokenizer is a stub; image tokens share the 65536 vocab.
QK-norm enabled (Chameleon's logit-divergence fix).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22_016,
    vocab_size=65_536, qk_norm=True, rope_theta=10_000.0,
    source="arXiv:2405.09818",
)
