"""Architecture config registry: one module per assigned architecture."""

from __future__ import annotations

from repro.configs.base import (ArchConfig, ShapeConfig, SHAPES,
                                applicable_shapes)

from repro.configs.mamba2_2p7b import CONFIG as mamba2_2p7b
from repro.configs.chameleon_34b import CONFIG as chameleon_34b
from repro.configs.qwen2_7b import CONFIG as qwen2_7b
from repro.configs.llama3_405b import CONFIG as llama3_405b
from repro.configs.llama3p2_1b import CONFIG as llama3p2_1b
from repro.configs.phi3_medium_14b import CONFIG as phi3_medium_14b
from repro.configs.granite_moe_3b import CONFIG as granite_moe_3b
from repro.configs.moonshot_v1_16b import CONFIG as moonshot_v1_16b
from repro.configs.seamless_m4t_medium import CONFIG as seamless_m4t_medium
from repro.configs.hymba_1p5b import CONFIG as hymba_1p5b
from repro.configs.lm100m import CONFIG as lm100m

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in [
        mamba2_2p7b, chameleon_34b, qwen2_7b, llama3_405b, llama3p2_1b,
        phi3_medium_14b, granite_moe_3b, moonshot_v1_16b,
        seamless_m4t_medium, hymba_1p5b, lm100m,
    ]
}

ASSIGNED = [n for n in ARCHS if n != "lm100m"]

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "ARCHS", "ASSIGNED",
           "applicable_shapes"]


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
