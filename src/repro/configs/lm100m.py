"""lm100m — ~100M-parameter llama-style model for the end-to-end training
example (examples/train_lm.py).  Not part of the assigned pool.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="lm100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
    vocab_size=32_000, rope_theta=10_000.0,
    source="examples",
)
