"""seamless-m4t-medium — enc-dec, multimodal [arXiv:2308.11596; hf].

12L enc + 12L dec, d_model=1024 16H (kv=16 => MHA) d_ff=4096 vocab=256206.
Modality frontend is a STUB: input_specs() provides precomputed speech frame
embeddings for the encoder (DESIGN.md Sec. 4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256_206, rope_theta=10_000.0,
    source="arXiv:2308.11596",
)
