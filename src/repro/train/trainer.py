"""Training loop: step builder with microbatching, mixed precision,
PCA/power-iteration gradient compression, checkpoint/resume, health hooks.

The step is a pure function jitted once; the Trainer owns the impure parts
(data cursor, checkpoint IO, heartbeats).  On a mesh, pass shardings for
params/opt-state and the batch; on one device everything is unsharded.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import compression as GC
from repro.models import transformer as T
from repro.train import checkpoint as CKPT
from repro.train.optimizer import (AdamWConfig, AdamWState, adamw_init,
                                   adamw_update, warmup_cosine)

__all__ = ["TrainConfig", "TrainState", "make_train_step", "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1          # gradient accumulation
    accum_dtype: str = "float32"   # bf16 for memory-bound giants (405b)
    compress_rank: int = 0         # 0 = off; >0 enables PowerIter compression
    remat: bool = True
    remat_groups: int = 0          # >1: nested (two-level) remat
    checkpoint_dir: str | None = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3


class TrainState:
    """Mutable bundle: params, optimizer, compressor, step counter."""

    def __init__(self, params, opt_state: AdamWState, comp_state, step: int):
        self.params = params
        self.opt_state = opt_state
        self.comp_state = comp_state
        self.step = step

    @classmethod
    def create(cls, cfg, tcfg: TrainConfig, key: jax.Array, dtype=None):
        params = T.init_params(cfg, key, dtype=dtype)
        opt = adamw_init(params, tcfg.optimizer)
        comp = (GC.init_compressor(params, tcfg.compress_rank,
                                   jax.random.fold_in(key, 1))
                if tcfg.compress_rank else None)
        return cls(params, opt, comp, 0)


def make_train_step(cfg, tcfg: TrainConfig,
                    reduce_fn: Callable | None = None,
                    grad_shardings=None):
    """Returns step(params, opt_state, comp_state, batch, step) -> (...)

    ``reduce_fn`` is the data-parallel gradient reduction used *inside* the
    compressor (psum on a mesh axis under shard_map; identity under plain
    jit where GSPMD inserts the reduction itself).

    ``grad_shardings``: optional pytree of NamedSharding matching params.
    Constraining each microbatch gradient to the FSDP param sharding lets
    GSPMD emit reduce-scatters for the dW data-reduction instead of full
    all-reduces (2x wire for the dominant term of large-model training —
    EXPERIMENTS.md Sec. Perf hillclimb 2).
    """

    def constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def loss_fn(params, batch):
        return T.lm_loss(params, cfg, batch, remat=tcfg.remat,
                         remat_groups=tcfg.remat_groups)

    def step_fn(params, opt_state, comp_state, batch, step):
        if tcfg.microbatches > 1:
            tokens = batch["tokens"]
            B = tokens.shape[0]
            mb = B // tcfg.microbatches
            micro = {k: v.reshape(tcfg.microbatches, mb, *v.shape[1:])
                     for k, v in batch.items()}

            def acc_step(carry, mbatch):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                g = constrain_grads(g)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            accum_dt = jnp.dtype(tcfg.accum_dtype)
            zeros = jax.tree.map(lambda p: jnp.zeros_like(p, accum_dt),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(acc_step, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
            loss = lsum / tcfg.microbatches
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = constrain_grads(grads)

        if comp_state is not None:
            grads, comp_state = GC.compress_gradients(grads, comp_state,
                                                      reduce_fn)
        elif reduce_fn is not None:
            grads = jax.tree.map(reduce_fn, grads)

        lr = warmup_cosine(step, peak_lr=tcfg.optimizer.lr,
                           warmup=tcfg.warmup_steps, total=tcfg.total_steps)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, tcfg.optimizer, lr)
        out_metrics = {"loss": loss, "lr": lr, **opt_metrics,
                       **{k: v for k, v in (metrics or {}).items()}}
        return params, opt_state, comp_state, out_metrics

    return step_fn


class Trainer:
    """Drives the jitted step; owns checkpointing, resume and health hooks."""

    def __init__(self, cfg, tcfg: TrainConfig, pipeline, *,
                 key: jax.Array | None = None, dtype=None,
                 health_monitor=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.pipeline = pipeline
        self.health = health_monitor
        key = key if key is not None else jax.random.PRNGKey(0)
        self.state = TrainState.create(cfg, tcfg, key, dtype=dtype)
        self._step_fn = jax.jit(make_train_step(cfg, tcfg))
        self.history: list[dict] = []

    # -- fault tolerance ----------------------------------------------------
    def save(self, async_: bool = True) -> None:
        if not self.tcfg.checkpoint_dir:
            return
        tree = {"params": self.state.params,
                "opt": self.state.opt_state,
                "comp": self.state.comp_state}
        extra = {"step": self.state.step,
                 "data": self.pipeline.state_dict()}
        fn = CKPT.save_async if async_ else CKPT.save
        fn(self.tcfg.checkpoint_dir, self.state.step, tree, extra=extra,
           keep=self.tcfg.keep_checkpoints)

    def try_resume(self) -> bool:
        d = self.tcfg.checkpoint_dir
        if not d or CKPT.latest_step(d) is None:
            return False
        template = {"params": self.state.params,
                    "opt": self.state.opt_state,
                    "comp": self.state.comp_state}
        tree, extra = CKPT.restore(d, template)
        self.state.params = tree["params"]
        self.state.opt_state = tree["opt"]
        self.state.comp_state = tree["comp"]
        self.state.step = int(extra["step"])
        self.pipeline.load_state_dict(extra["data"])
        return True

    # -- loop ----------------------------------------------------------------
    def run(self, n_steps: int, log_every: int = 10) -> list[dict]:
        for _ in range(n_steps):
            t0 = time.perf_counter()
            tokens = next(self.pipeline)
            batch = {"tokens": jnp.asarray(tokens)}
            (self.state.params, self.state.opt_state, self.state.comp_state,
             metrics) = self._step_fn(self.state.params,
                                      self.state.opt_state,
                                      self.state.comp_state, batch,
                                      jnp.asarray(self.state.step))
            self.state.step += 1
            dt = time.perf_counter() - t0
            if self.health is not None:
                self.health.heartbeat(step=self.state.step, duration=dt)
            rec = {k: float(v) for k, v in metrics.items()}
            rec.update(step=self.state.step, seconds=dt)
            self.history.append(rec)
            if log_every and self.state.step % log_every == 0:
                print(f"step {self.state.step:5d} "
                      f"loss {rec['loss']:.4f} lr {rec['lr']:.2e} "
                      f"gnorm {rec['grad_norm']:.2f} {dt*1e3:.0f} ms")
            if (self.tcfg.checkpoint_dir
                    and self.state.step % self.tcfg.checkpoint_every == 0):
                self.save()
        CKPT.wait_pending()
        return self.history
