"""Fault-tolerant checkpointing: sharded, atomic, async, elastic.

Layout (one directory per step)::

    <dir>/step_000100.tmp/...      (being written)
    <dir>/step_000100/             (atomically renamed when complete)
        manifest.json              step, tree structure, leaf shapes/dtypes
        shard_00000.npz            this host's leaves (flat name -> array)

Guarantees
----------
* **Atomicity**: a checkpoint is visible only after os.replace of the tmp
  dir; a crash mid-write leaves the previous checkpoint intact.
* **Async**: ``save_async`` snapshots to host RAM synchronously (cheap) and
  writes to disk on a worker thread — the train loop is not blocked by IO.
* **Resume**: restores params/opt/data-cursor/rng; bitwise-identical
  continuation is covered by tests/test_train.py.
* **Elastic reshard**: leaves are stored unsharded per host slice with the
  global spec in the manifest; :func:`restore` re-slices for whatever mesh
  the restart uses (checkpoint written on N chips restores on M != N).
  On this single-process container, save gathers to host fully — the
  per-host slice path follows the same manifest format.
* **Retention**: ``keep`` most recent checkpoints are retained.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "CheckpointError"]


class CheckpointError(RuntimeError):
    pass


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((name, leaf))
    return out


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:08d}")


def save(directory: str, step: int, tree: Any, *, extra: dict | None = None,
         keep: int = 3) -> str:
    """Synchronous sharded save with atomic publish."""
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    named = _flatten_with_paths(tree)
    arrays = {}
    manifest_leaves = {}
    for name, leaf in named:
        arr = np.asarray(leaf)
        arrays[name] = arr
        manifest_leaves[name] = {"shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
    manifest = {"step": step, "leaves": manifest_leaves,
                "extra": extra or {}, "format": 1}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    _gc(directory, keep)
    return final


_PENDING: list[threading.Thread] = []


def save_async(directory: str, step: int, tree: Any, *,
               extra: dict | None = None, keep: int = 3) -> threading.Thread:
    """Snapshot to host memory now, write on a background thread."""
    snapshot = jax.tree.map(lambda x: np.array(x), tree)   # device -> host
    t = threading.Thread(target=save,
                         args=(directory, step, snapshot),
                         kwargs={"extra": extra, "keep": keep}, daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending() -> None:
    for t in list(_PENDING):
        t.join()
        _PENDING.remove(t)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name[5:]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(directory: str, template: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of NamedSharding matching template — the
    elastic-reshard path: arrays are placed with jax.device_put under the
    *current* mesh regardless of the mesh that wrote the checkpoint.
    Returns (tree, extra).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise CheckpointError(f"no checkpoint found in {directory}")
    final = _step_dir(directory, step)
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(final, "shard_00000.npz"))

    named = _flatten_with_paths(template)
    flat_shardings = None
    if shardings is not None:
        flat_shardings = [s for _, s in _flatten_with_paths(shardings)]

    leaves = []
    for i, (name, leaf) in enumerate(named):
        if name not in data:
            raise CheckpointError(f"missing leaf {name!r} in checkpoint")
        arr = data[name]
        expect = np.asarray(leaf)
        if tuple(arr.shape) != tuple(expect.shape):
            raise CheckpointError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs "
                f"template {expect.shape}")
        # np.savez stores extension dtypes (bfloat16 & friends) as raw void
        # bytes (|V2), which np.ndarray.astype cannot cast ("No cast
        # function available").  The manifest kept the true dtype — view
        # the bytes back before casting.
        want = manifest["leaves"].get(name, {}).get("dtype")
        if want and str(arr.dtype) != want and arr.dtype.kind == "V":
            try:
                arr = arr.view(np.dtype(want))
            except TypeError as e:
                raise CheckpointError(
                    f"cannot reinterpret leaf {name!r} stored as "
                    f"{arr.dtype} back to {want}: {e}") from e
        arr = arr.astype(expect.dtype)
        if flat_shardings is not None:
            leaves.append(jax.device_put(arr, flat_shardings[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    _, treedef = jax.tree_util.tree_flatten(template)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        int(n[5:]) for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)
