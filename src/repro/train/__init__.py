"""Training substrate: optimizer, trainer loop, fault-tolerant checkpoints."""
