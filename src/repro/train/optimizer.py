"""AdamW + LR schedules (self-contained; no optax dependency).

Optimizer state dtype is configurable: fp32 moments by default, bf16 moments
for the memory-constrained large-model configs (the llama3-405b fit story —
EXPERIMENTS.md Sec. Perf).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "warmup_cosine", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"      # or "bfloat16" for big models


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig,
                 lr: jnp.ndarray | float):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = AdamWState(step=step,
                           mu=jax.tree.unflatten(treedef, [o[1] for o in out]),
                           nu=jax.tree.unflatten(treedef, [o[2] for o in out]))
    return new_params, new_state, {"grad_norm": gnorm}


def warmup_cosine(step: jnp.ndarray, *, peak_lr: float, warmup: int,
                  total: int, floor: float = 0.1) -> jnp.ndarray:
    """Linear warmup then cosine decay to floor * peak."""
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
