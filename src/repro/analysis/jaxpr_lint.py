"""Recursive jaxpr walker + the program-contract rule vocabulary.

One walker replaces the ad-hoc ``_count_primitive``/``_count`` helpers the
test suites grew independently: it descends into every sub-jaxpr a
primitive can carry (``scan``/``while`` bodies, ``cond`` branches, ``pjit``
calls, ``shard_map`` regions, ``custom_vmap``/``custom_jvp`` rules) and
yields each equation with its *static execution multiplier* — scan bodies
multiplied by their ``length`` param, while bodies by the trip count parsed
from the condition (the same largest-int-constant fallback the HLO-side
loop correction uses: :func:`repro.launch.hlo_analysis.fallback_trip`).

``lax.cond`` branches are all visited at the same multiplier: the compiled
program contains both, and the repo's launch-count guarantees are claims
about the traced body ("both branches count" — see
``kernels/ops.py::fused_stream_stages_blocked``).

Rules are small frozen dataclasses with a ``check(jaxpr) -> RuleReport``
method; :mod:`repro.analysis.contracts` binds them to entry points.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Iterator, Mapping

import numpy as np
from jax.core import ClosedJaxpr, Jaxpr, Literal

from repro.launch.hlo_analysis import fallback_trip

__all__ = ["EqnSite", "iter_eqns", "count_primitive", "count_primitives",
           "collective_counts", "while_trip_count", "UnknownTripError",
           "COLLECTIVE_PRIMITIVES", "HOST_SYNC_PRIMITIVES", "RuleReport",
           "PrimitiveBudget", "CollectiveBudget", "ForbidInLoops", "NoF64",
           "Fp32Accumulators"]


class UnknownTripError(ValueError):
    """A loop-weighted count hit a ``while`` whose trip count could not be
    parsed from its condition (data-dependent bound).  Rules that price
    per-run work must fail loudly on it rather than under-count — declare
    an explicit bound (restructure to ``scan``/``fori_loop``) or drop
    ``loop_weighted``."""

# collectives as they appear in jaxprs (inside shard_map regions); the
# HLO-side names in launch/hlo_analysis.py are the post-SPMD spellings
COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "all_gather", "all_gather_invariant", "all_to_all", "ppermute",
    "pmax", "pmin", "psum_scatter", "reduce_scatter", "pbroadcast",
})

# host round-trips / staged transfers that must never appear inside a
# device-resident hot loop (the host-sync-free claim, DESIGN.md Sec. 12)
HOST_SYNC_PRIMITIVES = frozenset({
    "device_put", "pure_callback", "io_callback", "debug_callback",
    "callback",
})


@dataclasses.dataclass(frozen=True)
class EqnSite:
    """One equation as seen by the walker."""

    eqn: object                  # jax.core.JaxprEqn
    mult: float                  # static execution multiplier (loop trips);
    #                              NaN when an enclosing while trip is unknown
    loop_depth: int              # > 0 inside a scan/while body
    path: tuple[str, ...]        # sub-jaxpr labels from the entry

    @property
    def name(self) -> str:
        return self.eqn.primitive.name

    @property
    def trip_known(self) -> bool:
        return not math.isnan(self.mult)


def _as_jaxpr(target) -> Jaxpr:
    """Normalize any of (ClosedJaxpr, Jaxpr, make_jaxpr output) to a Jaxpr."""
    if isinstance(target, Jaxpr):
        return target
    inner = getattr(target, "jaxpr", None)
    if isinstance(inner, (Jaxpr, ClosedJaxpr)):
        return _as_jaxpr(inner)
    raise TypeError(f"expected a (Closed)Jaxpr, got {type(target).__name__}")


def while_trip_count(eqn) -> int | None:
    """Static trip count of a ``while`` equation, parsed from its condition.

    Mirrors the HLO-side ``_trip_count`` in :mod:`repro.launch.hlo_analysis`:
    the bound is the integer constant the induction variable is compared
    against; conditions are tiny, so the largest scalar int constant in the
    condition jaxpr (consts + literals) is the bound, with a floor of 1
    (:func:`repro.launch.hlo_analysis.fallback_trip` — the shared policy).
    A condition with NO int constants (a data-dependent bound) returns
    ``None``: the trip is unknown, and loop-weighted counts through it
    raise :class:`UnknownTripError` instead of silently under-counting.
    ``fori_loop`` with static bounds lowers to ``scan`` and never gets here.
    """
    cond = eqn.params.get("cond_jaxpr")
    if cond is None:
        return 1
    ints: list[int] = []
    for c in getattr(cond, "consts", ()):
        arr = np.asarray(c)
        if arr.ndim == 0 and np.issubdtype(arr.dtype, np.integer):
            ints.append(int(arr))
    for sub in _as_jaxpr(cond).eqns:
        for v in sub.invars:
            if isinstance(v, Literal):
                arr = np.asarray(v.val)
                if arr.ndim == 0 and np.issubdtype(arr.dtype, np.integer):
                    ints.append(int(arr))
    return fallback_trip(ints)


def _sub_jaxprs(eqn) -> Iterator[tuple[Jaxpr, float, bool, str]]:
    """Yield (sub_jaxpr, extra_multiplier, is_loop_body, label) for every
    sub-jaxpr carried by ``eqn``'s params."""
    name = eqn.primitive.name
    if name == "scan":
        yield (_as_jaxpr(eqn.params["jaxpr"]),
               float(eqn.params.get("length", 1)), True, "scan")
        return
    if name == "while":
        trip = while_trip_count(eqn)
        factor = float("nan") if trip is None else float(trip)
        yield _as_jaxpr(eqn.params["cond_jaxpr"]), factor, True, "while_cond"
        yield _as_jaxpr(eqn.params["body_jaxpr"]), factor, True, "while_body"
        return
    if name == "cond":
        for i, branch in enumerate(eqn.params["branches"]):
            yield _as_jaxpr(branch), 1.0, False, f"cond_branch{i}"
        return
    for key, val in eqn.params.items():
        for item in (val if isinstance(val, (list, tuple)) else [val]):
            if isinstance(item, (Jaxpr, ClosedJaxpr)):
                yield _as_jaxpr(item), 1.0, False, f"{name}:{key}"


def iter_eqns(target, *, _mult: float = 1.0, _depth: int = 0,
              _path: tuple[str, ...] = ()) -> Iterator[EqnSite]:
    """Depth-first walk over every equation reachable from ``target``
    (a ClosedJaxpr, Jaxpr, or ``jax.make_jaxpr`` output), including all
    sub-jaxprs, with loop multipliers propagated down the path."""
    jaxpr = _as_jaxpr(target)
    for eqn in jaxpr.eqns:
        yield EqnSite(eqn=eqn, mult=_mult, loop_depth=_depth, path=_path)
        for sub, factor, is_loop, label in _sub_jaxprs(eqn):
            yield from iter_eqns(
                sub, _mult=_mult * factor,
                _depth=_depth + (1 if is_loop else 0),
                _path=_path + (label,))


def count_primitives(target, names: Iterable[str] | None = None, *,
                     loop_weighted: bool = False) -> dict[str, int]:
    """Primitive occurrence counts over the whole (recursive) jaxpr.

    ``loop_weighted=True`` multiplies each occurrence by its static loop
    multiplier (scan lengths × while trips along the path) — the per-RUN
    launch count rather than the per-TRACE count.  A counted primitive
    under a ``while`` with an unparseable trip raises
    :class:`UnknownTripError` (the count would be a silent under-estimate).
    """
    wanted = None if names is None else frozenset(names)
    acc: dict[str, int] = {}
    for site in iter_eqns(target):
        if wanted is not None and site.name not in wanted:
            continue
        if loop_weighted and not site.trip_known:
            raise UnknownTripError(
                f"{site.name} at {'/'.join(site.path) or '<entry>'} sits "
                "under a while loop with an unknown (data-dependent) trip "
                "count — a loop-weighted count needs an explicit bound")
        weight = int(site.mult) if loop_weighted else 1
        acc[site.name] = acc.get(site.name, 0) + weight
    return acc


def count_primitive(target, name: str, *, loop_weighted: bool = False) -> int:
    """Count one primitive (the drop-in form the test suites migrate to)."""
    return count_primitives(target, [name],
                            loop_weighted=loop_weighted).get(name, 0)


def _eqn_axes(eqn) -> tuple[str, ...]:
    """Mesh axis names a collective equation operates over."""
    axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if isinstance(a, str))


def collective_counts(target) -> dict[str, dict[str, int]]:
    """Per-mesh-axis collective counts: ``{axis: {primitive: count}}``."""
    out: dict[str, dict[str, int]] = {}
    for site in iter_eqns(target):
        if site.name not in COLLECTIVE_PRIMITIVES:
            continue
        for axis in _eqn_axes(site.eqn):
            out.setdefault(axis, {})
            out[axis][site.name] = out[axis].get(site.name, 0) + 1
    return out


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RuleReport:
    rule: str
    ok: bool
    detail: str


@dataclasses.dataclass(frozen=True)
class PrimitiveBudget:
    """Pin the occurrence count of one primitive (exact / max / min)."""

    primitive: str
    exact: int | None = None
    max: int | None = None
    min: int | None = None
    loop_weighted: bool = False

    @property
    def name(self) -> str:
        return f"budget:{self.primitive}"

    def check(self, target) -> RuleReport:
        try:
            n = count_primitive(target, self.primitive,
                                loop_weighted=self.loop_weighted)
        except UnknownTripError as e:
            return RuleReport(self.name, False, str(e))
        wants = []
        ok = True
        if self.exact is not None:
            ok &= n == self.exact
            wants.append(f"== {self.exact}")
        if self.max is not None:
            ok &= n <= self.max
            wants.append(f"<= {self.max}")
        if self.min is not None:
            ok &= n >= self.min
            wants.append(f">= {self.min}")
        return RuleReport(
            self.name, ok,
            f"{self.primitive} count {n} (want {' and '.join(wants)})")


@dataclasses.dataclass(frozen=True)
class CollectiveBudget:
    """Pin exact per-axis collective counts — the communication analogue of
    the paper's Table-1 budget, checked on the traced program.

    ``budgets`` is ``((primitive, exact_count), ...)`` for ``axis``; any
    other collective on that axis, and (``exclusive=True``) any collective
    on any OTHER axis, is a violation.
    """

    axis: str
    budgets: tuple[tuple[str, int], ...]
    exclusive: bool = True
    forbid_in_loops: bool = True

    @property
    def name(self) -> str:
        return f"collectives:{self.axis}"

    def check(self, target) -> RuleReport:
        got = collective_counts(target)
        on_axis = got.get(self.axis, {})
        problems = []
        for prim, want in self.budgets:
            have = on_axis.get(prim, 0)
            if have != want:
                problems.append(f"{prim} on {self.axis!r}: {have} != {want}")
        budgeted = {prim for prim, _ in self.budgets}
        for prim, have in sorted(on_axis.items()):
            if prim not in budgeted:
                problems.append(
                    f"unbudgeted {prim} x{have} on axis {self.axis!r}")
        if self.exclusive:
            for axis, prims in sorted(got.items()):
                if axis != self.axis:
                    problems.append(
                        f"collectives on unexpected axis {axis!r}: {prims}")
        if self.forbid_in_loops:
            for site in iter_eqns(target):
                if (site.name in COLLECTIVE_PRIMITIVES
                        and site.loop_depth > 0):
                    problems.append(
                        f"{site.name} inside loop body at "
                        f"{'/'.join(site.path)} (collectives must stay "
                        f"outside the streamed scan)")
        detail = "; ".join(problems) if problems else (
            f"axis {self.axis!r}: " + ", ".join(
                f"{p} x{c}" for p, c in self.budgets) + ", none elsewhere")
        return RuleReport(self.name, not problems, detail)


@dataclasses.dataclass(frozen=True)
class ForbidInLoops:
    """Zero occurrences of the given primitives inside scan/while bodies —
    the host-sync-free hot-loop claim (no staged transfers, no callbacks)."""

    primitives: frozenset = HOST_SYNC_PRIMITIVES
    everywhere: bool = False     # forbid outside loops too

    @property
    def name(self) -> str:
        return "forbid:" + ("program" if self.everywhere else "loops")

    def check(self, target) -> RuleReport:
        hits = []
        for site in iter_eqns(target):
            if site.name in self.primitives and (self.everywhere
                                                 or site.loop_depth > 0):
                where = "/".join(site.path) or "<entry>"
                hits.append(f"{site.name} at {where}")
        scope = "the program" if self.everywhere else "loop bodies"
        detail = "; ".join(hits) if hits else (
            f"none of {sorted(self.primitives)} in {scope}")
        return RuleReport(self.name, not hits, detail)


def _outvar_dtypes(site) -> Iterator[tuple[object, str]]:
    for v in site.eqn.outvars:
        aval = getattr(v, "aval", None)
        dtype = getattr(aval, "dtype", None)
        if dtype is not None:
            yield v, str(dtype)


@dataclasses.dataclass(frozen=True)
class NoF64:
    """No float64/complex128 value anywhere in the program — the repo's
    dtype floor (everything streams fp32 with optional bf16 tiles)."""

    @property
    def name(self) -> str:
        return "dtype:no-f64"

    def check(self, target) -> RuleReport:
        hits = []
        for site in iter_eqns(target):
            for _, dtype in _outvar_dtypes(site):
                if dtype in ("float64", "complex128"):
                    where = "/".join(site.path) or "<entry>"
                    hits.append(f"{site.name} -> {dtype} at {where}")
        detail = "; ".join(hits[:8]) if hits else "no f64/c128 values"
        return RuleReport(self.name, not hits, detail)


@dataclasses.dataclass(frozen=True)
class Fp32Accumulators:
    """The bf16 dtype policy (DESIGN.md Sec. 14): bfloat16 is a *tile*
    format, never an accumulator format.  Statically: no ``pallas_call``
    OUTPUT and no ``scan`` CARRY may be bfloat16 — kernels may load bf16
    tiles, but everything they emit and everything that persists across
    rounds must be fp32."""

    @property
    def name(self) -> str:
        return "dtype:fp32-accumulators"

    def check(self, target) -> RuleReport:
        hits = []
        for site in iter_eqns(target):
            if site.name == "pallas_call":
                for _, dtype in _outvar_dtypes(site):
                    if dtype == "bfloat16":
                        hits.append("pallas_call emits bfloat16 (outputs "
                                    "must accumulate in fp32)")
            elif site.name == "scan":
                sub = _as_jaxpr(site.eqn.params["jaxpr"])
                n_consts = site.eqn.params.get("num_consts", 0)
                n_carry = site.eqn.params.get("num_carry", 0)
                carries = sub.invars[n_consts:n_consts + n_carry]
                for v in carries:
                    dtype = str(getattr(v.aval, "dtype", ""))
                    if dtype == "bfloat16":
                        hits.append("scan carries bfloat16 state (carried "
                                    "state must stay fp32)")
        detail = "; ".join(sorted(set(hits))) if hits else (
            "pallas outputs and scan carries are fp32")
        return RuleReport(self.name, not hits, detail)
