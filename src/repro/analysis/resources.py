"""Static resource certifier: compile-time VMEM/HBM/wire-byte accounting.

PR 8 machine-checked *structural* invariants (launch counts, collective
counts, dtype rules); this module derives the *quantities* behind the
paper's Sec. 2.4 / Table-1 cost analysis from the traced program itself —
no execution, no compilation:

* per-``pallas_call`` **VMEM footprint** from the BlockSpecs the call was
  traced with (inputs + outputs, dtype-aware, x2 for Pallas double
  buffering), checked against the per-backend limit in
  :mod:`repro.launch.tiling`;
* per-entry **HBM traffic** under the Pallas fetch-on-change semantics: a
  block is (re)fetched exactly when its index-map output changes between
  consecutive grid steps (last grid axis fastest), so evaluating each
  operand's index map over the whole grid gives the exact read/write bytes
  — the "one tile-load per chunk" claim of the fused path becomes a
  checkable number instead of prose;
* per-kernel **flops** (from the kernel jaxpr: 2mnk per ``dot_general``,
  one per element for VPU arithmetic) and the resulting **arithmetic
  intensity** against the roofline constants shared with
  :mod:`repro.launch.hlo_analysis`;
* per-axis **collective wire bytes** from the merge collectives' operand
  shapes, priced by the same ring model the HLO parser uses
  (:func:`repro.launch.hlo_analysis.ring_wire_bytes`), and reconciled
  *exactly* against the packet ledger's booked merge record
  (:func:`repro.core.costs.merge_record_elems`) — booked == traced,
  extended from runtime tests to static certification.

Budgets are declarative rules in the :mod:`repro.analysis.jaxpr_lint`
style (``check(target) -> RuleReport``) so :mod:`repro.analysis.contracts`
binds them to entry points unchanged: :class:`VmemBudget`,
:class:`HbmTrafficBudget`, :class:`WireBytesBudget`.  Exact per-entry
quantities are pinned by ``analysis/baselines/resources.json`` and
surfaced through ``python -m repro.analysis.check`` with per-quantity
deltas (``--diff``, ``--bless-resources``).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Iterator, Mapping

import numpy as np

from repro.analysis.jaxpr_lint import (COLLECTIVE_PRIMITIVES, EqnSite,
                                       RuleReport, UnknownTripError,
                                       _as_jaxpr, iter_eqns)
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS, ring_wire_bytes

__all__ = ["KernelResources", "CollectiveResources", "EntryResources",
           "pallas_resources", "collective_resources", "entry_resources",
           "VmemBudget", "HbmTrafficBudget", "WireBytesBudget",
           "derive_all", "check_against_baseline", "QuantityResult",
           "baseline_path", "REF_REGIONS"]

# reference fleet size for the scaled wire-byte report: the wsn-1m target
# (1e6 sensors / ~1000 per region — DESIGN.md Sec. 13); traced meshes are
# 1-2 devices, so ring wire bytes are reported both at the traced group
# size and scaled to this one
REF_REGIONS = 1024

# grids above this size are not index-map-evaluated step by step; the
# conservative every-step-refetches bound is used instead (flagged in the
# per-operand record).  Contract grids are O(10) cells.
_MAX_EXACT_GRID = 65536


# ---------------------------------------------------------------------------
# Per-pallas_call derivation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class OperandTraffic:
    """One BlockSpec'd operand of one ``pallas_call``."""

    origin: str                  # e.g. "args[0]" / "outputs[1]"
    dtype: str
    block_shape: tuple           # ints; vmapped dims count as 1
    block_bytes: int             # one block residing in VMEM
    array_bytes: int             # the full (padded) operand in HBM
    fetches: int                 # index-map changes over the grid
    fetched_bytes: int           # fetches * block_bytes
    exact: bool                  # False when the grid was too big to walk

    @property
    def passes(self) -> float:
        """fetched bytes / one full pass over the operand."""
        return self.fetched_bytes / max(self.array_bytes, 1)


@dataclasses.dataclass(frozen=True)
class KernelResources:
    """Derived resource bill of one traced ``pallas_call``."""

    name: str                    # kernel function name
    path: str                    # walker path from the entry jaxpr
    mult: float                  # static execution multiplier (loop trips)
    grid: tuple
    inputs: tuple                # OperandTraffic rows
    outputs: tuple
    flops: int                   # one execution, all grid cells

    @property
    def vmem_block_bytes(self) -> int:
        """Single-buffered working set: every operand's live block."""
        return sum(o.block_bytes for o in self.inputs + self.outputs)

    @property
    def vmem_bytes(self) -> int:
        """Double-buffered footprint (Pallas overlaps fetch and compute)."""
        return 2 * self.vmem_block_bytes

    @property
    def hbm_read_bytes(self) -> int:
        return sum(o.fetched_bytes for o in self.inputs)

    @property
    def hbm_write_bytes(self) -> int:
        return sum(o.fetched_bytes for o in self.outputs)

    @property
    def nominal_read_bytes(self) -> int:
        """One full pass over every input operand."""
        return sum(o.array_bytes for o in self.inputs)

    @property
    def nominal_write_bytes(self) -> int:
        return sum(o.array_bytes for o in self.outputs)

    def bytes_by_dtype(self) -> dict[str, int]:
        """HBM traffic split by dtype — separates bf16 tile loads from
        fp32 accumulator traffic on the mixed-precision fused path."""
        acc: dict[str, int] = {}
        for o in self.inputs + self.outputs:
            acc[o.dtype] = acc.get(o.dtype, 0) + o.fetched_bytes
        return acc

    @property
    def intensity(self) -> float:
        """Arithmetic intensity (flops per HBM byte moved)."""
        return self.flops / max(self.hbm_read_bytes + self.hbm_write_bytes, 1)


def _block_elems(block_shape) -> int:
    """Elements in one block; non-int entries (the vmap `Mapped` sentinel)
    occupy a single slice and count as 1."""
    n = 1
    for d in block_shape:
        if isinstance(d, (int, np.integer)):
            n *= int(d)
    return n


def _grid_steps(grid: tuple) -> Iterator[tuple]:
    """Grid iteration order: row-major with the LAST axis fastest — the
    Pallas sequential-grid execution order that fetch-on-change depends on.
    """
    return np.ndindex(*(int(g) for g in grid))


def _index_map_fetches(bm, grid: tuple) -> tuple[int, bool]:
    """(number of block fetches, exact?) for one block mapping.

    Pallas re-fetches an operand block only when its index-map output
    changes between consecutive grid steps, so the fetch count is the
    number of value changes in the index-map sequence (first step counts).
    Falls back to the conservative one-fetch-per-step bound when the grid
    is too large to enumerate or the index map is not a plain
    grid-indices function.
    """
    from jax.core import eval_jaxpr

    cells = int(np.prod([int(g) for g in grid])) if grid else 1
    cj = getattr(bm, "index_map_jaxpr", None)
    if cj is None or cells > _MAX_EXACT_GRID:
        return cells, False
    if len(cj.jaxpr.invars) != len(grid):
        return cells, False            # scalar-prefetch args etc.
    fetches, prev = 0, None
    for step in _grid_steps(grid):
        out = eval_jaxpr(cj.jaxpr, cj.consts, *step)
        idx = tuple(int(v) for v in out)
        if idx != prev:
            fetches += 1
            prev = idx
    return fetches, True


# flop model for kernel jaxprs: one flop per output element for VPU
# arithmetic, one per input element for reductions, 2mnk for dot_general;
# moves/compares/selects are free (deterministic, documented — the same
# curve XLA's cost_analysis uses for elementwise ops)
_EW_FLOPS = frozenset({
    "add", "sub", "mul", "div", "rem", "max", "min", "neg", "abs", "sign",
    "exp", "exp2", "log", "log1p", "logistic", "tanh", "sqrt", "rsqrt",
    "pow", "integer_pow", "atan2", "erf", "cos", "sin", "floor", "ceil",
    "round", "square",
})
_REDUCE_FLOPS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "cumsum",
    "cumprod", "cummax", "cummin",
})


def _aval_elems(v) -> int:
    shape = getattr(getattr(v, "aval", None), "shape", ())
    return int(np.prod(shape)) if shape else 1


def _jaxpr_flops(target) -> int:
    """Static flop count of a (kernel) jaxpr under the model above; both
    ``cond`` branches count, matching the launch-budget convention."""
    total = 0.0
    for site in iter_eqns(target):
        m = site.mult if site.trip_known else 1.0
        if site.name in _EW_FLOPS:
            total += m * sum(_aval_elems(v) for v in site.eqn.outvars)
        elif site.name in _REDUCE_FLOPS:
            total += m * sum(_aval_elems(v) for v in site.eqn.invars)
        elif site.name == "dot_general":
            (lhs_c, _), _ = site.eqn.params["dimension_numbers"]
            lhs = site.eqn.invars[0]
            k = 1
            for d in lhs_c:
                k *= int(lhs.aval.shape[d])
            out = sum(_aval_elems(v) for v in site.eqn.outvars)
            total += m * 2 * out * k
    return int(total)


def _kernel_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    return getattr(info, "name", None) or str(eqn.params.get("name", "?"))


def pallas_resources(target) -> list[KernelResources]:
    """Derive the resource bill of every ``pallas_call`` reachable from
    ``target`` (a jaxpr / ``jax.make_jaxpr`` output), one record each."""
    out: list[KernelResources] = []
    for site in iter_eqns(target):
        if site.name != "pallas_call":
            continue
        gm = site.eqn.params["grid_mapping"]
        grid = tuple(int(g) for g in gm.grid)
        n_in = int(gm.num_inputs)
        rows: list[OperandTraffic] = []
        for bm in gm.block_mappings:
            sdt = bm.array_shape_dtype
            itemsize = int(np.dtype(sdt.dtype).itemsize)
            block_bytes = _block_elems(bm.block_shape) * itemsize
            array_bytes = int(np.prod(sdt.shape)) * itemsize
            fetches, exact = _index_map_fetches(bm, grid)
            rows.append(OperandTraffic(
                origin=str(bm.origin), dtype=str(np.dtype(sdt.dtype)),
                block_shape=tuple(bm.block_shape),
                block_bytes=block_bytes, array_bytes=array_bytes,
                fetches=fetches, fetched_bytes=fetches * block_bytes,
                exact=exact))
        cells = int(np.prod(grid)) if grid else 1
        flops = cells * _jaxpr_flops(site.eqn.params["jaxpr"])
        out.append(KernelResources(
            name=_kernel_name(site.eqn),
            path="/".join(site.path) or "<entry>",
            mult=site.mult, grid=grid,
            inputs=tuple(rows[:n_in]), outputs=tuple(rows[n_in:]),
            flops=flops))
    return out


# ---------------------------------------------------------------------------
# Per-axis collective derivation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CollectiveResources:
    """One collective equation's payload, as traced."""

    primitive: str
    axes: tuple[str, ...]
    mult: float
    payload_elems: int           # all operands, local shard
    payload_bytes: int
    scalar_operands: int         # rank-0 operands (e.g. a trace partial)
    records: int                 # leading-dim stack size of a tiled gather
    record_elems: int            # per-record payload of a gather
    group_size: int              # axis size as traced

    def wire_bytes_at(self, group: int) -> float:
        """Ring-model per-device wire bytes at fleet size ``group`` —
        gathers ship one record per peer, reductions the full payload."""
        if self.primitive in ("all_gather", "all_gather_invariant"):
            elem = self.payload_bytes / max(self.payload_elems, 1)
            full = self.record_elems * elem * group
            return ring_wire_bytes("all-gather", full, group)
        if self.primitive in ("psum_scatter", "reduce_scatter"):
            return ring_wire_bytes("reduce-scatter", self.payload_bytes,
                                   group)
        if self.primitive == "ppermute":
            return ring_wire_bytes("collective-permute", self.payload_bytes,
                                   group)
        if self.primitive == "all_to_all":
            return ring_wire_bytes("all-to-all", self.payload_bytes, group)
        return ring_wire_bytes("all-reduce", self.payload_bytes, group)


def _eqn_axes(eqn) -> tuple[str, ...]:
    axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if isinstance(a, str))


def _mesh_axis_sizes(target) -> dict[str, int]:
    """Axis sizes of every mesh visible in the jaxpr (shard_map params)."""
    sizes: dict[str, int] = {}
    for site in iter_eqns(target):
        mesh = site.eqn.params.get("mesh")
        shape = getattr(mesh, "shape", None)
        if isinstance(shape, Mapping):
            for axis, size in shape.items():
                if isinstance(axis, str):
                    sizes[axis] = int(size)
    return sizes


def collective_resources(target) -> list[CollectiveResources]:
    """Derive every collective's traced payload, per mesh axis."""
    sizes = _mesh_axis_sizes(target)
    out: list[CollectiveResources] = []
    for site in iter_eqns(target):
        if site.name not in COLLECTIVE_PRIMITIVES:
            continue
        eqn = site.eqn
        axes = _eqn_axes(eqn)
        elems = bytes_ = scalars = 0
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", None)
            dtype = getattr(aval, "dtype", None)
            if shape is None or dtype is None:
                continue
            n = int(np.prod(shape)) if shape else 1
            elems += n
            bytes_ += n * int(np.dtype(dtype).itemsize)
            if not shape:
                scalars += 1
        records = record_elems = 0
        if site.name in ("all_gather", "all_gather_invariant"):
            dim = int(eqn.params.get("all_gather_dimension", 0))
            aval = eqn.invars[0].aval
            if eqn.params.get("tiled", False) and aval.shape:
                records = int(aval.shape[dim])
                record_elems = elems // max(records, 1)
            else:               # untiled: the whole operand is one record
                records, record_elems = 1, elems
        group = int(eqn.params.get("axis_size", 0)) or max(
            (sizes.get(a, 1) for a in axes), default=1)
        out.append(CollectiveResources(
            primitive=site.name, axes=axes, mult=site.mult,
            payload_elems=elems, payload_bytes=bytes_,
            scalar_operands=scalars, records=records,
            record_elems=record_elems, group_size=group))
    return out


# ---------------------------------------------------------------------------
# Whole-entry aggregation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EntryResources:
    """Loop-weighted resource bill of one traced entry point."""

    kernels: tuple
    collectives: tuple

    def _mult(self, k) -> float:
        return k.mult if not math.isnan(k.mult) else 1.0

    @property
    def launches(self) -> int:
        return int(sum(self._mult(k) for k in self.kernels))

    @property
    def vmem_peak_bytes(self) -> int:
        return max((k.vmem_bytes for k in self.kernels), default=0)

    @property
    def hbm_read_bytes(self) -> int:
        return int(sum(self._mult(k) * k.hbm_read_bytes
                       for k in self.kernels))

    @property
    def hbm_write_bytes(self) -> int:
        return int(sum(self._mult(k) * k.hbm_write_bytes
                       for k in self.kernels))

    @property
    def hbm_passes(self) -> float:
        """Derived kernel traffic over one full pass per operand — the
        fused path books ~1 read pass; every extra round trip shows here."""
        nominal = sum(self._mult(k) * (k.nominal_read_bytes
                                       + k.nominal_write_bytes)
                      for k in self.kernels)
        derived = self.hbm_read_bytes + self.hbm_write_bytes
        return derived / nominal if nominal else 0.0

    @property
    def flops(self) -> int:
        return int(sum(self._mult(k) * k.flops for k in self.kernels))

    @property
    def intensity(self) -> float:
        return self.flops / max(self.hbm_read_bytes
                                + self.hbm_write_bytes, 1)

    @property
    def roofline_balance(self) -> float:
        """intensity / machine balance (>1: compute-bound on the target)."""
        return self.intensity / (PEAK_FLOPS / HBM_BW)

    def quantities(self) -> dict[str, float]:
        """Flat {quantity: value} map — the baseline/diff surface."""
        q: dict[str, float] = {
            "launches": self.launches,
            "vmem_peak_bytes": self.vmem_peak_bytes,
            "hbm_read_bytes": self.hbm_read_bytes,
            "hbm_write_bytes": self.hbm_write_bytes,
            "hbm_passes": round(self.hbm_passes, 4),
            "flops": self.flops,
            "intensity": round(self.intensity, 4),
        }
        per_axis: dict[str, list] = {}
        for c in self.collectives:
            for axis in c.axes:
                per_axis.setdefault(axis, []).append(c)
        for axis, colls in sorted(per_axis.items()):
            q[f"wire.{axis}.collectives"] = len(colls)
            q[f"wire.{axis}.payload_bytes"] = sum(c.payload_bytes
                                                  for c in colls)
            q[f"wire.{axis}.bytes_at_{REF_REGIONS}"] = int(sum(
                c.wire_bytes_at(REF_REGIONS) for c in colls))
        return q


def entry_resources(target) -> EntryResources:
    return EntryResources(kernels=tuple(pallas_resources(target)),
                          collectives=tuple(collective_resources(target)))


# ---------------------------------------------------------------------------
# Budget rules (jaxpr_lint form: check(target) -> RuleReport)
# ---------------------------------------------------------------------------
def _fmt_bytes(n: float) -> str:
    if n >= 2**20:
        return f"{n / 2**20:.2f}MiB"
    if n >= 2**10:
        return f"{n / 2**10:.2f}KiB"
    return f"{int(n)}B"


@dataclasses.dataclass(frozen=True)
class VmemBudget:
    """Every traced ``pallas_call``'s double-buffered working set must fit
    the backend VMEM limit (:data:`repro.launch.tiling.VMEM_BYTES` by
    default) — the compile-time guarantee that no kernel the wrappers can
    plan will spill on the TPU target."""

    limit_bytes: int | None = None
    double_buffered: bool = True

    @property
    def name(self) -> str:
        return "budget:vmem"

    def _limit(self) -> int:
        if self.limit_bytes is not None:
            return self.limit_bytes
        from repro.launch.tiling import VMEM_BYTES
        return VMEM_BYTES

    def check(self, target) -> RuleReport:
        limit = self._limit()
        kernels = pallas_resources(target)
        if not kernels:
            return RuleReport(self.name, False,
                              "no pallas_call in trace (nothing to certify)")
        over, worst = [], None
        for k in kernels:
            need = k.vmem_bytes if self.double_buffered else k.vmem_block_bytes
            if worst is None or need > worst[1]:
                worst = (k, need)
            if need > limit:
                over.append(f"{k.name} grid={k.grid} needs "
                            f"{_fmt_bytes(need)} > {_fmt_bytes(limit)} VMEM")
        if over:
            return RuleReport(self.name, False, "; ".join(over))
        k, need = worst
        return RuleReport(
            self.name, True,
            f"peak {k.name}: {_fmt_bytes(need)} of {_fmt_bytes(limit)} VMEM "
            f"({100 * need / limit:.1f}%, x2 double-buffered, "
            f"{len(kernels)} kernel(s))")


@dataclasses.dataclass(frozen=True)
class HbmTrafficBudget:
    """Cap the entry's derived HBM traffic as a multiple of one full pass
    over every kernel operand, and optionally pin named operands to be
    fetched exactly once (the fused path's one-tile-load claim for the
    chunk data).  An extra kernel round trip doubles the pass count and
    fails loudly."""

    max_passes: float
    single_pass: tuple[str, ...] = ()   # operand origins, e.g. ("args[0]",)

    @property
    def name(self) -> str:
        return "budget:hbm"

    def check(self, target) -> RuleReport:
        entry = entry_resources(target)
        if not entry.kernels:
            return RuleReport(self.name, False,
                              "no pallas_call in trace (nothing to certify)")
        for k in entry.kernels:
            if math.isnan(k.mult):
                return RuleReport(
                    self.name, False,
                    f"{k.name} at {k.path}: unknown while trip count — "
                    "HBM traffic cannot be certified without an explicit "
                    "bound (see UnknownTripError)")
        problems = []
        passes = entry.hbm_passes
        if passes > self.max_passes + 1e-9:
            problems.append(
                f"hbm traffic {_fmt_bytes(entry.hbm_read_bytes + entry.hbm_write_bytes)} "
                f"= {passes:.2f} passes over the operands "
                f"(budget <= {self.max_passes:.2f} — an extra kernel "
                f"round trip?)")
        for origin in self.single_pass:
            for k in entry.kernels:
                for o in k.inputs:
                    if o.origin == origin and o.fetched_bytes > o.array_bytes:
                        problems.append(
                            f"{k.name} operand {origin} fetched "
                            f"{o.passes:.2f}x (must be one tile-load: "
                            f"{_fmt_bytes(o.array_bytes)})")
        if problems:
            return RuleReport(self.name, False, "; ".join(problems))
        return RuleReport(
            self.name, True,
            f"hbm {_fmt_bytes(entry.hbm_read_bytes)} read + "
            f"{_fmt_bytes(entry.hbm_write_bytes)} written = {passes:.2f} "
            f"passes (<= {self.max_passes:.2f}); intensity "
            f"{entry.intensity:.2f} flops/B")


@dataclasses.dataclass(frozen=True)
class WireBytesBudget:
    """booked == traced for the hierarchical merge record: the per-region
    payload derived from the merge collectives' shapes must carry exactly
    :func:`repro.core.costs.merge_record_elems` elements — q local
    energies shipped by the tiled ``all_gather`` plus the scalar trace
    partial carried by the ``psum``.  A padded record (or a second
    collective smuggling extra payload) changes the traced count and fails
    with the delta; non-scalar psum operands are per-fleet bookkeeping
    (refresh flags), reported but not booked."""

    axis: str
    record_elems: int            # booked: costs.merge_record_elems(q)
    elem_bytes: int = 4
    at_regions: int = REF_REGIONS

    @property
    def name(self) -> str:
        return f"wire:{self.axis}"

    def check(self, target) -> RuleReport:
        colls = [c for c in collective_resources(target)
                 if self.axis in c.axes]
        if not colls:
            return RuleReport(self.name, False,
                              f"no collectives on axis {self.axis!r} "
                              "(nothing to certify)")
        gathered = sum(c.record_elems for c in colls
                       if c.primitive in ("all_gather",
                                          "all_gather_invariant"))
        reduced_scalars = sum(c.scalar_operands for c in colls
                              if c.primitive == "psum")
        traced = gathered + reduced_scalars
        bookkeeping = sum(
            c.payload_elems - c.scalar_operands for c in colls
            if c.primitive == "psum")
        wire = sum(c.wire_bytes_at(self.at_regions) for c in colls)
        detail = (
            f"merge record {traced} elems (gather {gathered} + psum "
            f"scalars {reduced_scalars}) vs booked {self.record_elems} "
            f"(merge_round_cost); +{bookkeeping} bookkeeping elems; "
            f"ring wire ~{_fmt_bytes(wire)}/device at "
            f"{self.at_regions} regions")
        return RuleReport(self.name, traced == self.record_elems, detail)


# ---------------------------------------------------------------------------
# Baseline: derive, compare, bless
# ---------------------------------------------------------------------------
def baseline_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baselines", "resources.json")


def derive_all(only: str | None = None) -> dict[str, dict[str, float]]:
    """``{"contract[variant]": quantities}`` for every registered contract
    that declares a trace — the full derived-resource surface."""
    from repro.analysis import contracts
    reg = contracts.load_entry_points()
    out: dict[str, dict[str, float]] = {}
    for cid in sorted(reg):
        c = reg[cid]
        if (only and only not in cid) or c.trace is None:
            continue
        for label, jx in c.trace().items():
            out[f"{cid}[{label}]"] = entry_resources(jx).quantities()
    return out


@dataclasses.dataclass(frozen=True)
class QuantityResult:
    """One derived quantity compared against the committed expectation."""

    entry: str                   # "contract[variant]"
    quantity: str
    ok: bool
    measured: float | None
    expected: float | None
    detail: str

    def rule(self) -> str:
        return f"resources:{self.quantity}"


def _values_match(measured, expected) -> bool:
    if isinstance(measured, float) or isinstance(expected, float):
        return math.isclose(float(measured), float(expected),
                            rel_tol=1e-3, abs_tol=1e-9)
    return measured == expected


def _delta(measured: float, expected: float) -> str:
    if expected:
        return f"{100 * (measured - expected) / expected:+.1f}%"
    return f"{measured - expected:+g}"


def check_against_baseline(derived: Mapping[str, Mapping[str, float]]
                           | None = None,
                           path: str | None = None,
                           only: str | None = None) -> list[QuantityResult]:
    """Compare derived quantities against the committed baseline, one
    :class:`QuantityResult` per (entry, quantity) — regressions carry the
    measured-vs-expected delta and the re-bless instruction lives in the
    check driver."""
    if derived is None:
        derived = derive_all(only=only)
    path = path or baseline_path()
    if not os.path.exists(path):
        return [QuantityResult(
            entry="<baseline>", quantity="file", ok=False,
            measured=None, expected=None,
            detail=f"missing baseline {path} — run "
                   "`python -m repro.analysis.check --bless-resources`")]
    with open(path) as fh:
        base = json.load(fh)
    results: list[QuantityResult] = []
    for entry in sorted(set(derived) | set(base)):
        if only and only not in entry:
            continue
        if entry not in base:
            results.append(QuantityResult(
                entry, "entry", False, None, None,
                "new entry not in the committed baseline"))
            continue
        if entry not in derived:
            results.append(QuantityResult(
                entry, "entry", False, None, None,
                "baseline entry no longer derived (contract removed?)"))
            continue
        mine, theirs = derived[entry], base[entry]
        for qty in sorted(set(mine) | set(theirs)):
            m, e = mine.get(qty), theirs.get(qty)
            if m is None or e is None:
                results.append(QuantityResult(
                    entry, qty, False, m, e,
                    "quantity " + ("added" if e is None else "dropped")
                    + " vs baseline"))
            elif not _values_match(m, e):
                results.append(QuantityResult(
                    entry, qty, False, m, e,
                    f"{m} != baseline {e} ({_delta(m, e)})"))
            else:
                results.append(QuantityResult(entry, qty, True, m, e,
                                              f"{m} == baseline"))
    return results


def bless(derived: Mapping[str, Mapping[str, float]] | None = None,
          path: str | None = None) -> str:
    """Write the derived quantities as the new committed expectation."""
    if derived is None:
        derived = derive_all()
    path = path or baseline_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(derived, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path
