"""``python -m repro.analysis.check`` — run every registered program
contract, the static resource certifier, and the repo source lints; print
a per-rule report; exit nonzero if anything is violated.

Options:
    --only SUBSTR       restrict to contracts whose id contains SUBSTR
                        (lints still run; --contracts-only/--lint-only/
                        the special value ``resources`` split further:
                        ``--only resources`` runs ONLY the resource
                        certifier section)
    --json PATH         also write the per-rule report as JSON (CI artifact)
    --list              list registered contracts and exit
    --diff PATH         derive the resource quantities and print only the
                        ones that CHANGED vs the given baseline (PR-review
                        mode; informational, always exits 0)
    --bless-resources   re-derive every quantity and overwrite the
                        committed ``analysis/baselines/resources.json``
                        (commit the result — that IS the review surface)
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _print_resources(results, rows: list[dict]) -> int:
    """Per-quantity PASS/FAIL lines; collapses all-green entries to one
    line per entry so a healthy run stays readable."""
    failed = 0
    by_entry: dict[str, list] = {}
    for r in results:
        by_entry.setdefault(r.entry, []).append(r)
    for entry, rs in sorted(by_entry.items()):
        bad = [r for r in rs if not r.ok]
        if not bad:
            qty = {r.quantity: r.measured for r in rs}
            summary = (f"vmem={qty.get('vmem_peak_bytes', 0)}B "
                       f"hbm={qty.get('hbm_read_bytes', 0)}+"
                       f"{qty.get('hbm_write_bytes', 0)}B "
                       f"passes={qty.get('hbm_passes', 0)} "
                       f"flops={qty.get('flops', 0)}")
            wire = {k: v for k, v in qty.items() if k.startswith("wire.")}
            if wire:
                summary += " " + " ".join(f"{k}={v}"
                                          for k, v in sorted(wire.items()))
            print(f"[PASS] {entry:<28s} {summary} == baseline "
                  f"({len(rs)} quantities)")
        for r in bad:
            print(f"[FAIL] {r.entry:<28s} {r.rule():<28s} {r.detail}")
            failed += 1
        for r in rs:
            rows.append({"contract": r.entry, "rule": r.rule(), "ok": r.ok,
                         "detail": r.detail})
    return failed


def resource_failures(only: str | None = None) -> list[tuple[str, str]]:
    """Structured ``(rule, detail)`` failure pairs from the resource
    certifier — the form ``benchmarks/run.py`` folds into its own FAIL
    lines (``run.py/FAIL,resources:...``)."""
    from repro.analysis import resources
    return [(f"{r.entry}/{r.rule()}", r.detail)
            for r in resources.check_against_baseline(only=only)
            if not r.ok]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis.check")
    ap.add_argument("--only", help="substring filter on contract ids; the "
                                   "special value 'resources' runs only "
                                   "the resource-certifier section")
    ap.add_argument("--json", dest="json_path",
                    help="write the per-rule report to this path")
    ap.add_argument("--list", action="store_true",
                    help="list registered contracts and exit")
    ap.add_argument("--contracts-only", action="store_true")
    ap.add_argument("--lint-only", action="store_true")
    ap.add_argument("--diff", metavar="PATH",
                    help="print only resource quantities that changed vs "
                         "this baseline, then exit 0")
    ap.add_argument("--bless-resources", action="store_true",
                    help="overwrite the committed resources.json with the "
                         "currently derived quantities")
    args = ap.parse_args(argv)

    # tracing only — keep the CPU backend quiet and deterministic; set
    # before the first jax import (contracts trace, they never execute,
    # except the engine runtime check which runs a tiny interpret fleet)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.analysis import contracts, repolint, resources

    if args.list:
        for cid, c in sorted(contracts.load_entry_points().items()):
            print(f"{cid:<24s} {c.where:<44s} {c.claim}")
        return 0

    if args.bless_resources:
        path = resources.bless()
        print(f"blessed {sum(len(v) for v in resources.derive_all().values())}"
              f" quantities -> {path}")
        print("commit the updated baseline; the diff IS the review surface")
        return 0

    if args.diff:
        changed = [r for r in resources.check_against_baseline(
            path=args.diff) if not r.ok]
        if not changed:
            print(f"no resource quantities changed vs {args.diff}")
        for r in changed:
            print(f"{r.entry:<28s} {r.quantity:<24s} {r.detail}")
        return 0

    resources_only = args.only == "resources"
    only = None if resources_only else args.only

    rows: list[dict] = []
    failed = 0

    if not (args.lint_only or resources_only):
        print("== program contracts " + "=" * 46)
        for res in contracts.check_all(only=only):
            print(res.line())
            rows.append(dataclasses_dict(res))
            failed += 0 if res.ok else 1

    if not args.lint_only:
        print("== resource certifier (vs committed baseline) " + "=" * 21)
        failed += _print_resources(
            resources.check_against_baseline(only=only), rows)

    if not (args.contracts_only or resources_only):
        print("== repolint " + "=" * 55)
        findings = repolint.run_repolint()
        for f in findings:
            print(f"[FAIL] {f.text()}")
            rows.append({"contract": "repolint", "rule": f.rule, "ok": False,
                         "detail": f"{f.file}:{f.line}: {f.message}"})
            failed += 1
        if not findings:
            for rule in repolint.RULES:
                print(f"[PASS] repolint{' ':<17s} {rule:<28s} 0 violations")
                rows.append({"contract": "repolint", "rule": rule,
                             "ok": True, "detail": "0 violations"})

    if args.json_path:
        with open(args.json_path, "w") as fh:
            json.dump(rows, fh, indent=2)

    n_ok = sum(1 for r in rows if r["ok"])
    verdict = "FAILED" if failed else "OK"
    print(f"== {verdict}: {n_ok}/{len(rows)} rules pass"
          + (f", {failed} violation(s)" if failed else ""))
    if failed:
        bad = sorted({f"{r['contract']}/{r['rule']}"
                      for r in rows if not r["ok"]})
        print("violated: " + ", ".join(bad))
        if any(r["rule"].startswith("resources:") for r in rows
               if not r["ok"]):
            print("resource deltas that are intended: re-bless with "
                  "`PYTHONPATH=src python -m repro.analysis.check "
                  "--bless-resources` and commit the baseline")
    return 1 if failed else 0


def dataclasses_dict(res) -> dict:
    return {"contract": res.contract, "rule": res.rule, "ok": res.ok,
            "detail": res.detail}


if __name__ == "__main__":
    sys.exit(main())
