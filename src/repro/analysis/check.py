"""``python -m repro.analysis.check`` — run every registered program
contract plus the repo source lints; print a per-rule report; exit nonzero
if anything is violated.

Options:
    --only SUBSTR   restrict to contracts whose id contains SUBSTR
                    (lints still run; pass --contracts-only/--lint-only
                    to split)
    --json PATH     also write the per-rule report as JSON (the CI artifact)
    --list          list registered contracts and exit
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis.check")
    ap.add_argument("--only", help="substring filter on contract ids")
    ap.add_argument("--json", dest="json_path",
                    help="write the per-rule report to this path")
    ap.add_argument("--list", action="store_true",
                    help="list registered contracts and exit")
    ap.add_argument("--contracts-only", action="store_true")
    ap.add_argument("--lint-only", action="store_true")
    args = ap.parse_args(argv)

    # tracing only — keep the CPU backend quiet and deterministic; set
    # before the first jax import (contracts trace, they never execute,
    # except the engine runtime check which runs a tiny interpret fleet)
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.analysis import contracts, repolint

    if args.list:
        for cid, c in sorted(contracts.load_entry_points().items()):
            print(f"{cid:<24s} {c.where:<44s} {c.claim}")
        return 0

    rows: list[dict] = []
    failed = 0

    if not args.lint_only:
        print("== program contracts " + "=" * 46)
        for res in contracts.check_all(only=args.only):
            print(res.line())
            rows.append(dataclasses_dict(res))
            failed += 0 if res.ok else 1

    if not args.contracts_only:
        print("== repolint " + "=" * 55)
        findings = repolint.run_repolint()
        for f in findings:
            print(f"[FAIL] {f.text()}")
            rows.append({"contract": "repolint", "rule": f.rule, "ok": False,
                         "detail": f"{f.file}:{f.line}: {f.message}"})
            failed += 1
        if not findings:
            for rule in repolint.RULES:
                print(f"[PASS] repolint{' ':<17s} {rule:<28s} 0 violations")
                rows.append({"contract": "repolint", "rule": rule,
                             "ok": True, "detail": "0 violations"})

    if args.json_path:
        with open(args.json_path, "w") as fh:
            json.dump(rows, fh, indent=2)

    n_ok = sum(1 for r in rows if r["ok"])
    verdict = "FAILED" if failed else "OK"
    print(f"== {verdict}: {n_ok}/{len(rows)} rules pass"
          + (f", {failed} violation(s)" if failed else ""))
    if failed:
        bad = sorted({f"{r['contract']}/{r['rule']}"
                      for r in rows if not r["ok"]})
        print("violated: " + ", ".join(bad))
    return 1 if failed else 0


def dataclasses_dict(res) -> dict:
    return {"contract": res.contract, "rule": res.rule, "ok": res.ok,
            "detail": res.detail}


if __name__ == "__main__":
    sys.exit(main())
