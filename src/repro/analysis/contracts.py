"""Declarative program-contract registry (DESIGN.md Sec. 15).

A :class:`Contract` names one structural claim about one jitted entry point
and the rules that machine-check it.  The *records* are co-located with the
hot paths they describe — ``streaming/driver.py``, ``streaming/hierarchy.py``
and ``serve/engine.py`` call :func:`register` at import time with lazy
``trace`` builders, so declaring a contract costs nothing until
:func:`check_all` actually traces the entry point (``jax.make_jaxpr`` —
no execution, no compilation).

Contracts with claims a jaxpr cannot carry (buffer donation lives on the
lowered computation, retraces on the jit cache) add a ``runtime`` callable
evaluated alongside the static rules.

To declare a contract for a new entry point::

    from repro.analysis import contracts as _contracts
    from repro.analysis import jaxpr_lint as _jl

    def _trace_my_entry():
        cfg = ...tiny static config...
        args = ...tiny abstract-shape operands...
        return {"K=4": jax.make_jaxpr(lambda s, x: my_entry(cfg, s, x))(*args)}

    _contracts.register(_contracts.Contract(
        id="my.entry", where="repro.my.module.my_entry",
        claim="one pallas launch per dispatch",
        trace=_trace_my_entry,
        rules=(_jl.PrimitiveBudget("pallas_call", exact=1), _jl.NoF64()),
    ))

``python -m repro.analysis.check`` then enforces it in CI.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Mapping, Sequence

import jax

__all__ = ["Contract", "RuleResult", "register", "registry", "get_contract",
           "check_contract", "check_all", "load_entry_points",
           "donation_report", "ENTRY_POINT_MODULES"]

# importing these populates the registry (records live with the hot paths)
ENTRY_POINT_MODULES = (
    "repro.streaming.driver",
    "repro.streaming.hierarchy",
    "repro.serve.engine",
)


@dataclasses.dataclass(frozen=True)
class RuleResult:
    """One rule evaluated against one traced variant of one contract."""

    contract: str                # contract id
    rule: str                    # rule name (e.g. "budget:pallas_call")
    ok: bool
    detail: str                  # measured-vs-wanted, one line

    def line(self) -> str:
        flag = "PASS" if self.ok else "FAIL"
        return f"[{flag}] {self.contract:<24s} {self.rule:<28s} {self.detail}"


@dataclasses.dataclass(frozen=True)
class Contract:
    """One structural claim about one entry point.

    ``trace`` returns ``{variant_label: jaxpr}`` (``jax.make_jaxpr``
    outputs); every rule in ``rules`` runs against every variant.
    ``runtime``, if set, returns extra :class:`RuleResult` rows for claims
    that need the lowered/compiled artifact (donation, retrace counters).
    """

    id: str
    where: str                   # dotted path of the entry point described
    claim: str                   # the one-line structural claim docs cite
    trace: Callable[[], Mapping[str, object]] | None = None
    rules: tuple = ()
    runtime: Callable[[], Sequence[RuleResult]] | None = None


_REGISTRY: dict[str, Contract] = {}


def register(contract: Contract) -> Contract:
    """Add (or replace — idempotent re-imports) a contract by id."""
    _REGISTRY[contract.id] = contract
    return contract


def registry() -> dict[str, Contract]:
    return dict(_REGISTRY)


def get_contract(contract_id: str) -> Contract:
    if contract_id not in _REGISTRY:
        raise KeyError(
            f"no contract {contract_id!r}; registered: "
            f"{sorted(_REGISTRY)} (did you call load_entry_points()?)")
    return _REGISTRY[contract_id]


def load_entry_points() -> dict[str, Contract]:
    """Import every module that declares contracts; return the registry."""
    for mod in ENTRY_POINT_MODULES:
        importlib.import_module(mod)
    return registry()


def check_contract(contract: Contract) -> list[RuleResult]:
    """Evaluate one contract: trace its variants, run every rule on each,
    then any runtime checks.  A trace/runtime crash is itself a failure
    (the entry point's public surface moved under the contract)."""
    results: list[RuleResult] = []
    if contract.trace is not None:
        try:
            variants = contract.trace()
        except Exception as e:  # noqa: BLE001 — a broken trace IS a finding
            return [RuleResult(contract.id, "trace", False,
                               f"tracing raised {type(e).__name__}: {e}")]
        for label, jaxpr in variants.items():
            for rule in contract.rules:
                rep = rule.check(jaxpr)
                results.append(RuleResult(
                    contract.id, f"{rep.rule}[{label}]", rep.ok, rep.detail))
    if contract.runtime is not None:
        try:
            results.extend(contract.runtime())
        except Exception as e:  # noqa: BLE001
            results.append(RuleResult(contract.id, "runtime", False,
                                      f"raised {type(e).__name__}: {e}"))
    return results


def check_all(only: str | None = None) -> list[RuleResult]:
    """Evaluate every registered contract (id-substring filter optional)."""
    load_entry_points()
    results: list[RuleResult] = []
    for cid in sorted(_REGISTRY):
        if only and only not in cid:
            continue
        results.extend(check_contract(_REGISTRY[cid]))
    return results


# ---------------------------------------------------------------------------
# Runtime-rule helpers (shared by contracts and their negative tests)
# ---------------------------------------------------------------------------
def donation_report(jitted, *args, argnum: int = 0,
                    contract: str = "<adhoc>") -> RuleResult:
    """Check that EVERY leaf of ``args[argnum]`` is donated on the lowered
    computation — the in-place-update claim of an engine hot loop.  Reads
    ``lowered.args_info`` (requested donation at lowering; backend-
    independent, no compile, no execution)."""
    lowered = jitted.lower(*args)
    info = lowered.args_info[0][argnum]
    flags = [(bool(leaf.donated)) for leaf in jax.tree.leaves(info)]
    n_bad = sum(1 for f in flags if not f)
    return RuleResult(
        contract, "donation", n_bad == 0,
        f"{len(flags) - n_bad}/{len(flags)} leaves of arg {argnum} donated"
        + ("" if n_bad == 0 else " (donate_argnums missing/dropped)"))


def retrace_report(jitted, n_calls_made: int,
                   contract: str = "<adhoc>") -> RuleResult:
    """Check the jit cache holds exactly one entry after ``n_calls_made``
    same-shape calls — the no-retrace claim of a steady-state hot loop."""
    try:
        size = jitted._cache_size()
    except AttributeError:       # private counter moved; don't hard-fail
        return RuleResult(contract, "retrace", True,
                          "jit cache counter unavailable on this jax; "
                          "retrace check skipped")
    return RuleResult(
        contract, "retrace", size == 1,
        f"jit cache entries after {n_calls_made} same-shape steps: {size} "
        f"(want 1 — every extra entry is a retrace of the hot loop)")
