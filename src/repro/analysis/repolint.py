"""AST-based source lints for repo conventions (DESIGN.md Sec. 15).

Four rules, each guarding a convention the runtime cannot check for us:

* ``tracer-host-pull`` — no ``float(...)``/``int(...)``/``.item()`` inside
  jitted code paths (functions decorated with ``jax.jit`` /
  ``functools.partial(jax.jit, ...)``, or function/lambda expressions passed
  to a ``jax.jit(...)`` call, including through ``jax.vmap``).  A host pull
  inside traced code either crashes on a tracer or, worse, silently forces
  a device sync per call.
* ``import-time-jnp`` — no ``jnp.*`` computation at module import time
  (module or class scope).  Import-time jnp calls initialize the backend
  before launch code can set ``XLA_FLAGS`` (see ``launch/mesh.py``) and tax
  every ``import repro.*``.
* ``unreferenced-cost-helper`` — every public ``*_cost`` helper in
  ``core/costs.py`` must be referenced by at least one test file: the
  booked==counted discipline means a cost model nobody pins is a cost model
  free to drift from what the code actually books.
* ``pallas-call-hygiene`` — no literal ``interpret=True`` at a
  ``pallas_call`` site (interpret mode is a per-run decision threaded from
  config — see ``kernels/ops.py::_auto_interpret`` — a hard-coded ``True``
  silently runs the Python interpreter on real accelerators), and every
  ``ShapeDtypeStruct`` in a ``pallas_call``-containing scope must carry an
  explicit dtype (second positional arg or ``dtype=``): the resource
  certifier (``analysis/resources.py``) bills HBM/VMEM bytes off these
  dtypes, so an implicit one makes the bill untrustworthy.

A line ending in ``# repolint: ok`` is exempt (the escape hatch for the
rare deliberate host pull).  Findings carry exact ``file:line`` locations.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib

__all__ = ["LintFinding", "RULES", "lint_file", "lint_tree",
           "lint_cost_references", "run_repolint", "repo_paths"]

RULES = ("tracer-host-pull", "import-time-jnp", "unreferenced-cost-helper",
         "pallas-call-hygiene")

_HOST_PULLS = {"float", "int", "bool"}
_SUPPRESS = "# repolint: ok"


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    file: str
    line: int
    message: str

    def text(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.message}"


def _suppressed(src_lines: list[str], lineno: int) -> bool:
    return (0 < lineno <= len(src_lines)
            and _SUPPRESS in src_lines[lineno - 1])


def _is_jax_jit(node: ast.AST) -> bool:
    """True for the expression ``jax.jit`` (or a bare ``jit`` import)."""
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return True
    return isinstance(node, ast.Name) and node.id == "jit"


def _jit_decorated(fn: ast.AST) -> bool:
    """Decorator is jax.jit, partial(jax.jit, ...), or a jax.jit(...) call."""
    for dec in getattr(fn, "decorator_list", ()):
        if _is_jax_jit(dec):
            return True
        if isinstance(dec, ast.Call):
            if _is_jax_jit(dec.func):
                return True
            # functools.partial(jax.jit, ...)
            func = dec.func
            if (isinstance(func, ast.Attribute) and func.attr == "partial"
                    or isinstance(func, ast.Name) and func.id == "partial"):
                if any(_is_jax_jit(a) for a in dec.args):
                    return True
    return False


def _jit_regions(tree: ast.Module) -> list[ast.AST]:
    """Every AST subtree whose body is traced by jax.jit: decorated defs,
    plus any lambda/def reachable inside the arguments of a ``jax.jit(...)``
    call expression (covers ``jax.jit(jax.vmap(lambda ...))``)."""
    regions: list[ast.AST] = []
    local_defs: dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            local_defs.setdefault(node.name, node)
            if _jit_decorated(node):
                regions.append(node)
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func):
            for arg in node.args:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Lambda):
                        regions.append(sub)
                    elif (isinstance(sub, ast.Name)
                          and sub.id in local_defs):
                        regions.append(local_defs[sub.id])
    return regions


def _check_host_pulls(path: str, tree: ast.Module,
                      src_lines: list[str]) -> list[LintFinding]:
    findings = []
    seen: set[int] = set()
    for region in _jit_regions(tree):
        for node in ast.walk(region):
            if not isinstance(node, ast.Call) or id(node) in seen:
                continue
            bad = None
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                bad = ".item()"
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in _HOST_PULLS
                  and node.args
                  and not isinstance(node.args[0], ast.Constant)):
                bad = f"{node.func.id}(...)"
            if bad and not _suppressed(src_lines, node.lineno):
                seen.add(id(node))
                findings.append(LintFinding(
                    "tracer-host-pull", path, node.lineno,
                    f"{bad} on a traced value inside a jitted code path "
                    f"(host pull breaks tracing / forces a device sync)"))
    return findings


def _module_scope_statements(tree: ast.Module):
    """Statements executed at import: module body (recursing into if/try
    blocks) and class bodies — everything outside a def/lambda."""
    stack = list(tree.body)
    while stack:
        stmt = stack.pop()
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if isinstance(stmt, ast.ClassDef):
            stack.extend(stmt.body)
            continue
        if isinstance(stmt, (ast.If, ast.Try, ast.With, ast.For, ast.While)):
            for field in ("body", "orelse", "finalbody", "handlers"):
                for sub in getattr(stmt, field, []):
                    stack.extend(getattr(sub, "body", [sub])
                                 if isinstance(sub, ast.ExceptHandler)
                                 else [sub])
            continue
        yield stmt


def _is_jnp_call(node: ast.Call) -> bool:
    """Call whose callee path starts with jnp. / jax.numpy."""
    parts = []
    cur = node.func
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
    parts.reverse()
    return bool(parts) and (parts[0] == "jnp"
                            or parts[:2] == ["jax", "numpy"])


def _check_import_time_jnp(path: str, tree: ast.Module,
                           src_lines: list[str]) -> list[LintFinding]:
    findings = []

    def scan(node: ast.AST) -> None:
        # def/lambda bodies execute later, not at import — don't descend
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        if (isinstance(node, ast.Call) and _is_jnp_call(node)
                and not _suppressed(src_lines, node.lineno)):
            findings.append(LintFinding(
                "import-time-jnp", path, node.lineno,
                f"jnp computation at module import time "
                f"({ast.unparse(node.func)}(...)) — builds device "
                f"arrays before launch code can set XLA_FLAGS"))
        for child in ast.iter_child_nodes(node):
            scan(child)

    for stmt in _module_scope_statements(tree):
        scan(stmt)
    return findings


def _is_pallas_call(node: ast.Call) -> bool:
    """Call whose callee is ``pl.pallas_call`` (or bare ``pallas_call``)."""
    func = node.func
    if isinstance(func, ast.Attribute):
        return func.attr == "pallas_call"
    return isinstance(func, ast.Name) and func.id == "pallas_call"


def _scopes(tree: ast.Module):
    """(scope node, direct statements) pairs: the module plus every
    def/lambda, without descending into nested defs — each ShapeDtypeStruct
    is judged against the pallas_calls of its OWN scope."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            yield node


def _own_scope_walk(scope: ast.AST):
    """Walk a scope's body without crossing into nested def/lambda scopes."""
    roots = scope.body if isinstance(scope.body, list) else [scope.body]
    stack = list(roots)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue      # nested scope — judged by its own _scopes() entry
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _check_pallas_hygiene(path: str, tree: ast.Module,
                          src_lines: list[str]) -> list[LintFinding]:
    findings = []
    for scope in _scopes(tree):
        nodes = list(_own_scope_walk(scope))
        launches = [n for n in nodes
                    if isinstance(n, ast.Call) and _is_pallas_call(n)]
        if not launches:
            continue
        for call in launches:
            for kw in call.keywords:
                if (kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True
                        and not _suppressed(src_lines, kw.value.lineno)):
                    findings.append(LintFinding(
                        "pallas-call-hygiene", path, kw.value.lineno,
                        "pallas_call(interpret=True) hard-codes interpret "
                        "mode — thread it from config (ops._auto_interpret) "
                        "so real backends compile the kernel"))
        for node in nodes:
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, (ast.Attribute, ast.Name))):
                continue
            name = (node.func.attr if isinstance(node.func, ast.Attribute)
                    else node.func.id)
            if name != "ShapeDtypeStruct":
                continue
            has_dtype = (len(node.args) >= 2
                         or any(k.arg == "dtype" for k in node.keywords))
            if not has_dtype and not _suppressed(src_lines, node.lineno):
                findings.append(LintFinding(
                    "pallas-call-hygiene", path, node.lineno,
                    "ShapeDtypeStruct without an explicit dtype in a "
                    "pallas_call scope — the resource certifier bills "
                    "HBM/VMEM bytes off out_shape dtypes"))
    return findings


def lint_file(path: str | pathlib.Path) -> list[LintFinding]:
    """Run the per-file rules (host pulls, import-time jnp, pallas_call
    hygiene) on one source."""
    path = pathlib.Path(path)
    src = path.read_text()
    tree = ast.parse(src, filename=str(path))
    lines = src.splitlines()
    rel = str(path)
    return (_check_host_pulls(rel, tree, lines)
            + _check_import_time_jnp(rel, tree, lines)
            + _check_pallas_hygiene(rel, tree, lines))


def lint_tree(root: str | pathlib.Path) -> list[LintFinding]:
    """Per-file rules over every ``*.py`` under ``root``, sorted."""
    findings: list[LintFinding] = []
    for path in sorted(pathlib.Path(root).rglob("*.py")):
        findings.extend(lint_file(path))
    return sorted(findings, key=lambda f: (f.file, f.line))


def lint_cost_references(costs_path: str | pathlib.Path,
                         tests_dir: str | pathlib.Path) -> list[LintFinding]:
    """Every public top-level ``*_cost`` def in ``costs_path`` must appear
    in at least one file under ``tests_dir``."""
    costs_path = pathlib.Path(costs_path)
    tree = ast.parse(costs_path.read_text(), filename=str(costs_path))
    helpers = [(node.name, node.lineno) for node in tree.body
               if isinstance(node, ast.FunctionDef)
               and node.name.endswith("_cost")
               and not node.name.startswith("_")]
    corpus = "\n".join(p.read_text()
                       for p in sorted(pathlib.Path(tests_dir).glob("*.py")))
    return [LintFinding(
        "unreferenced-cost-helper", str(costs_path), lineno,
        f"costs.{name} is referenced by no test — a cost model nobody "
        f"pins is free to drift from what the code books")
        for name, lineno in helpers if name not in corpus]


def repo_paths() -> tuple[pathlib.Path, pathlib.Path, pathlib.Path]:
    """(src/repro package root, core/costs.py, tests dir) of this checkout."""
    import repro
    pkg = pathlib.Path(repro.__file__).resolve().parent
    return pkg, pkg / "core" / "costs.py", pkg.parents[1] / "tests"


def run_repolint() -> list[LintFinding]:
    """All rules against this checkout (tests-dir rule skipped when
    the package is installed without its test tree)."""
    pkg, costs_path, tests_dir = repo_paths()
    findings = lint_tree(pkg)
    if costs_path.exists() and tests_dir.is_dir():
        findings.extend(lint_cost_references(costs_path, tests_dir))
    return findings
