"""Program-contract static analysis (DESIGN.md Sec. 15).

The repo's performance story rests on *structural* claims about compiled
programs — "1 ``pallas_call`` per chunk body", "ONE cross-host
``all_gather``/``psum`` per hierarchy refresh", "host-sync-free engine hot
loop with donated buffers" — the same kind of per-epoch bookkeeping the
paper's Table 1 does for the WSN.  This package machine-checks those claims
instead of trusting prose:

* :mod:`repro.analysis.jaxpr_lint` — a reusable recursive jaxpr walker
  (descends into ``cond``/``scan``/``while``/``pjit``/``shard_map``
  sub-jaxprs, scan lengths and while trip counts multiplied through like
  the HLO-side loop correction in :mod:`repro.launch.hlo_analysis`) plus
  the rule vocabulary: primitive budgets, per-axis collective budgets,
  forbidden-in-loop ops, dtype policies.
* :mod:`repro.analysis.contracts` — the declarative contract registry.
  Contract *records* live next to the hot paths they describe
  (``streaming/driver.py``, ``streaming/hierarchy.py``,
  ``serve/engine.py`` register theirs at import); this module only holds
  the record type, the registry, and the evaluator.
* :mod:`repro.analysis.resources` — the static resource certifier
  (DESIGN.md Sec. 16): derives per-``pallas_call`` VMEM footprints,
  fetch-on-change HBM traffic, flops/arithmetic intensity and per-axis
  collective wire bytes from the traced program, checks them against
  declarative budgets (:class:`VmemBudget`, :class:`HbmTrafficBudget`,
  :class:`WireBytesBudget`) and the committed
  ``analysis/baselines/resources.json`` expectations.
* :mod:`repro.analysis.repolint` — AST-based source lints for repo
  conventions (no host pulls inside jitted code, no import-time ``jnp``
  computation, every ``costs.*_cost`` helper pinned by a test,
  ``pallas_call`` hygiene).

``python -m repro.analysis.check`` runs everything and fails loudly with a
per-rule report (the dedicated CI job).
"""

from repro.analysis.contracts import (Contract, RuleResult, check_all,
                                      get_contract, load_entry_points,
                                      register, registry)
from repro.analysis.jaxpr_lint import (CollectiveBudget, ForbidInLoops,
                                       Fp32Accumulators, NoF64,
                                       PrimitiveBudget, UnknownTripError,
                                       collective_counts, count_primitive,
                                       count_primitives, iter_eqns)
from repro.analysis.resources import (EntryResources, HbmTrafficBudget,
                                      VmemBudget, WireBytesBudget,
                                      collective_resources, derive_all,
                                      entry_resources, pallas_resources)

__all__ = [
    "Contract", "RuleResult", "register", "registry", "get_contract",
    "check_all", "load_entry_points",
    "iter_eqns", "count_primitive", "count_primitives", "collective_counts",
    "PrimitiveBudget", "CollectiveBudget", "ForbidInLoops", "NoF64",
    "Fp32Accumulators", "UnknownTripError",
    "EntryResources", "pallas_resources", "collective_resources",
    "entry_resources", "derive_all",
    "VmemBudget", "HbmTrafficBudget", "WireBytesBudget",
]
