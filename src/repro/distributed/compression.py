"""Low-rank gradient compression by distributed power iteration.

The paper's algorithm — distributed PIM with tree aggregation (Sec. 3.4) —
applied to the *gradient matrix* of data-parallel training.  This is the
PowerSGD scheme (Vogels et al., 2019), which is exactly one warm-started
iteration of Algorithm 1 per step with the A operation realized as a psum
over the data axis:

    P = G Q          (local matvec block — the 'Cv' step)
    P = A(P)         (aggregation: psum over replicas; q*r elements
                      instead of the full n*m gradient)
    P = orth(P)      (Gram-Cholesky orthonormalization — the paper's
                      normalization step, batched as in our beyond-paper
                      blocked orthogonal iteration)
    Q = G^T P ;  Q = A(Q)
    G_hat = P Q^T    (rank-r approximation; broadcast = fused F operation)

plus **error feedback**: the compression residual is added to the next
step's gradient, which is what makes the method converge to the uncompressed
optimum.  Communication per step drops from n*m to r*(n+m) per matrix.

Matrices with stacked leading dims (scan-over-layers: (L, n, m)) are handled
batched via vmap; small/1-D tensors (norms, biases) bypass compression and
are reduced exactly.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CompressorState", "init_compressor", "compress_gradients",
           "compression_ratio"]

Reduce = Callable[[jnp.ndarray], jnp.ndarray]


def _eligible(x: jnp.ndarray, rank: int) -> bool:
    if x.ndim < 2:
        return False
    n, m = x.shape[-2], x.shape[-1]
    # compress only when it actually shrinks traffic
    return n * m > 2 * rank * (n + m)


class CompressorState(NamedTuple):
    q: dict          # per-leaf Q factor (or None)
    error: dict      # per-leaf error-feedback buffer (or None)
    rank: int


def init_compressor(params, rank: int, key: jax.Array) -> CompressorState:
    leaves, treedef = jax.tree.flatten(params)
    keys = jax.random.split(key, len(leaves))

    def init_leaf(x, k):
        if not _eligible(x, rank):
            return None
        m = x.shape[-1]
        batch = x.shape[:-2]
        return jax.random.normal(k, (*batch, m, rank), jnp.float32)

    qs = [init_leaf(x, k) for x, k in zip(leaves, keys)]
    errs = [jnp.zeros_like(x, dtype=jnp.float32) if q is not None else None
            for x, q in zip(leaves, qs)]
    return CompressorState(q=jax.tree.unflatten(treedef, qs),
                           error=jax.tree.unflatten(treedef, errs),
                           rank=rank)


def _orthonormalize(p: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Gram-Cholesky orthonormalization of the columns of p (..., n, r)."""
    g = jnp.einsum("...nr,...ns->...rs", p, p)
    r = p.shape[-1]
    l = jnp.linalg.cholesky(g + eps * jnp.eye(r, dtype=p.dtype))
    return jax.lax.linalg.triangular_solve(l, p, left_side=False, lower=True,
                                           transpose_a=True)


def _compress_leaf(g: jnp.ndarray, q: jnp.ndarray, e: jnp.ndarray,
                   reduce_fn: Reduce):
    """One warm-started distributed power-iteration round on one matrix."""
    g32 = g.astype(jnp.float32) + e                   # error feedback
    p = jnp.einsum("...nm,...mr->...nr", g32, q)
    p = _orthonormalize(reduce_fn(p))                 # A op + normalization
    q_new = reduce_fn(jnp.einsum("...nm,...nr->...mr", g32, p))  # A op
    g_hat = jnp.einsum("...nr,...mr->...nm", p, q_new)
    e_new = g32 - g_hat                               # next-step feedback
    return g_hat.astype(g.dtype), q_new, e_new


def compress_gradients(grads, state: CompressorState,
                       reduce_fn: Reduce | None = None):
    """Compress + reduce a gradient pytree.

    ``reduce_fn`` averages across data-parallel replicas (e.g.
    ``lambda x: jax.lax.pmean(x, 'data')`` inside shard_map/jit, identity for
    single-process use).  Uncompressed leaves are passed through ``reduce_fn``
    exactly.  Returns (new_grads, new_state).
    """
    reduce_fn = reduce_fn or (lambda x: x)

    def per_leaf(g, q, e):
        if q is None:
            return reduce_fn(g), None, None
        return _compress_leaf(g, q, e, reduce_fn)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_q = treedef.flatten_up_to(state.q)
    flat_e = treedef.flatten_up_to(state.error)
    out = [per_leaf(g, q, e) for g, q, e in zip(flat_g, flat_q, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_q = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_g, CompressorState(q=new_q, error=new_e, rank=state.rank)


def compression_ratio(params, rank: int) -> float:
    """Bytes on the wire: compressed / uncompressed (lower is better)."""
    full = 0
    compressed = 0
    for x in jax.tree.leaves(params):
        n = x.size
        full += n
        if _eligible(x, rank):
            rows, cols = x.shape[-2], x.shape[-1]
            batch = n // (rows * cols)
            compressed += batch * rank * (rows + cols)
        else:
            compressed += n
    return compressed / max(full, 1)
