"""GPipe-style pipeline parallelism (optional axis for 1000+ node scaling).

The production mesh (DESIGN.md Sec. 5) does not need PP at 2 pods — FSDP+TP
covers 512 chips — but beyond ~4 pods the 'pod' axis becomes a natural stage
axis.  This module provides the schedule: stage-sharded layer stacks with a
microbatch ``lax.scan`` and collective-permute hand-offs between stages,
written against shard_map so it composes with the data/model sharding.

The schedule is the classic fill-drain (GPipe): with S stages and M
microbatches, bubble fraction = (S-1)/(M+S-1).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def pipeline_apply(layer_fn: Callable, stage_params, x: jnp.ndarray,
                   *, n_microbatches: int, axis_name: str = "pipe"):
    """Run ``layer_fn(params, x)`` as a pipeline over ``axis_name``.

    Must be called inside shard_map with ``axis_name`` in the mesh.
    stage_params: this stage's layer parameters (already stage-sharded).
    x: (B, ...) stage-0 input (other stages receive via permute); B must be
    divisible by n_microbatches.
    """
    n_stages = int(jax.lax.psum(1, axis_name))
    stage = jax.lax.axis_index(axis_name)
    B = x.shape[0]
    assert B % n_microbatches == 0
    mb = B // n_microbatches
    micro = x.reshape(n_microbatches, mb, *x.shape[1:])

    n_ticks = n_microbatches + n_stages - 1
    fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(carry, t):
        buf, out = carry
        # which microbatch enters stage 0 at this tick
        idx = jnp.clip(t, 0, n_microbatches - 1)
        inject = micro[idx]
        incoming = jnp.where(stage == 0, inject, buf)
        active = (t - stage >= 0) & (t - stage < n_microbatches)
        y = layer_fn(stage_params, incoming)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # last stage writes its finished microbatch to the output slot
        done_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
        is_done = (stage == n_stages - 1) & (t - stage >= 0) \
            & (t - stage < n_microbatches)
        idx0 = (done_idx,) + (0,) * y.ndim
        current = jax.lax.dynamic_slice(out, idx0, (1, *y.shape))
        out = jax.lax.dynamic_update_slice(
            out, jnp.where(is_done, y[None], current), idx0)
        nxt = jax.lax.ppermute(y, axis_name, fwd_perm)
        return (nxt, out), None

    buf0 = jnp.zeros_like(micro[0])
    out0 = jnp.zeros_like(micro)
    # newer jax requires the scan carry to be marked device-varying along
    # the manual axis before it meets the ppermute output; older versions
    # (<= 0.4.x) have no pvary and need no marking
    pvary = getattr(jax.lax, "pvary", None)
    if pvary is not None:
        buf0 = pvary(buf0, (axis_name,))
        out0 = pvary(out0, (axis_name,))
    (_, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(n_ticks))
    return out.reshape(B, *x.shape[1:])
