"""Distribution layer: sharding rules, collectives, gradient compression."""
