"""Sharding policy: logical-axis rules + activation constraint context.

Single source of truth for how logical axes map onto the production mesh
(DESIGN.md Sec. 5):

* parameters: FSDP over ("pod","data") on the embed dimension, TP over
  "model" on heads / mlp / vocab / experts;
* activations: batch over ("pod","data"), head/mlp/vocab over "model",
  optional sequence parallelism over "data" for long prefill.

Model code never names mesh axes: it calls :func:`shard_activation` with
logical axes; inside an :func:`activation_sharding` context this becomes a
``with_sharding_constraint``, outside (smoke tests, single device) it is a
no-op.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "PARAM_RULES", "ACT_RULES", "param_rules", "act_rules",
    "activation_sharding", "shard_activation", "logical_to_pspec",
    "network_axis_spec", "shard_networks",
    "region_axis_spec", "shard_regions",
]

# -- parameter logical axes -------------------------------------------------
# "embed" carries FSDP (ZeRO-3) sharding; everything wide goes to TP.
def param_rules(multi_pod: bool, fsdp: bool = True) -> dict:
    fsdp_axes = (("pod", "data") if multi_pod else ("data",)) if fsdp else None
    return {
        "embed": fsdp_axes,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "state": None,
        "conv": None,
        "layers": None,
        "expert_mlp": None,          # per-expert ffn dim (sharded via experts)
    }


def act_rules(multi_pod: bool, seq_shard: bool = False) -> dict:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch_axes,
        "seq": "data" if seq_shard else None,
        "kv_seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_experts": "model",
        "act_ssm_inner": "model",
        "act_ssm_heads": "model",
        "act_state": None,
        "capacity": None,
    }


PARAM_RULES = param_rules(multi_pod=False)
ACT_RULES = act_rules(multi_pod=False)


# -- activation constraint context ------------------------------------------
class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: dict | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: dict):
    """Enable with_sharding_constraint for shard_activation calls within."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def logical_to_pspec(axes: Sequence[str | None], rules: dict,
                     mesh: Mesh | None = None,
                     dims: Sequence[int] | None = None) -> PartitionSpec:
    """Map logical axis names to a PartitionSpec under divisibility checks."""
    entries = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    for i, ax in enumerate(axes):
        mesh_axes = rules.get(ax) if ax is not None else None
        if mesh_axes is None:
            entries.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        if dims is not None and sizes:
            total = 1
            for m in mesh_axes:
                total *= sizes.get(m, 1)
            if total == 0 or dims[i] % total != 0:
                entries.append(None)
                continue
        entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return PartitionSpec(*entries)


def current_mesh() -> Mesh | None:
    """The mesh of the active activation_sharding context (None outside)."""
    return _CTX.mesh


def network_axis_spec(mesh: Mesh, axis: str = "data") -> PartitionSpec:
    """PartitionSpec sharding the leading *networks* axis of a streaming batch.

    The streaming subsystem (DESIGN.md Sec. 8.3) is embarrassingly parallel
    across simulated sensor networks, so the batch axis maps onto the mesh
    data axis; every per-network pytree leaf (covariance band, basis, metrics)
    carries the networks axis first and shares this spec.
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}: {mesh.axis_names}")
    return PartitionSpec(axis)


def shard_networks(mesh: Mesh, tree, axis: str = "data"):
    """Device_put a networks-leading pytree with the streaming sharding."""
    sharding = NamedSharding(mesh, network_axis_spec(mesh, axis))
    return jax.tree.map(lambda x: jax.device_put(x, sharding), tree)


def region_axis_spec(mesh: Mesh, axis: str = "region") -> PartitionSpec:
    """PartitionSpec sharding the leading *regions* axis of a two-level fleet.

    The hierarchical decomposition (DESIGN.md Sec. 13) splits the
    million-sensor fleet into regions, each streaming its own banded
    covariance + basis (:func:`network_axis_spec` one level down); the
    regions axis maps onto the cross-host ``region`` mesh axis, and the ONLY
    traffic that crosses it is the per-refresh merge collective
    (``all_gather`` of the (q+1)-element energy records + ``psum`` of the
    trace partials — the fleet analogue of the paper's A/F tree ops).
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis!r}: {mesh.axis_names}")
    return PartitionSpec(axis)


def shard_regions(mesh: Mesh, tree, axis: str = "region"):
    """Device_put a regions-leading pytree with the hierarchy sharding."""
    sharding = NamedSharding(mesh, region_axis_spec(mesh, axis))
    return jax.tree.map(lambda x: jax.device_put(x, sharding), tree)


def shard_activation(x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    """Constrain an activation to its logical sharding (no-op outside ctx)."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = logical_to_pspec(axes, _CTX.rules, _CTX.mesh, dims=x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))
