"""repro — Distributed PCA for Wireless Sensor Networks (Le Borgne et al.)
as a production-grade multi-pod JAX training/inference framework.

Packages: core (the paper), sensors, models, kernels, distributed,
streaming, train, serve, data, configs, launch, runtime.
"""

__version__ = "0.1.0"
