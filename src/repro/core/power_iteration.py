"""Power iteration method (paper Sec. 3.4, Algorithms 1-3).

Paper-faithful pieces
---------------------
* :func:`power_iteration` — Algorithm 1: repeated ``v <- C v / ||C v||`` with
  the dual stopping rule (max iterations and/or update norm ``delta``).
* :func:`deflated_power_iteration` — Algorithm 2: q components by deflation
  (orthogonalize against previously found eigenvectors inside the loop), with
  the *sign criterion* for negative-eigenvalue detection
  ``sign( sum_i sign(v_t[i] * v_{t+1}[i]) )`` used as the stopping rule.
* All global reductions (norm, deflation dot products) are routed through an
  ``aggregate`` callable so the same code runs single-host (identity), on a
  simulated routing tree, or as ``jax.lax.psum`` over a mesh axis
  (Sec. 3.4.3-3.4.4: the A and F operations).

Beyond-paper piece (recorded separately in EXPERIMENTS.md)
----------------------------------------------------------
* :func:`orthogonal_iteration` — blocked subspace (simultaneous) iteration:
  ``V <- C V`` is a banded *matmul* (MXU-friendly) and orthonormalization uses
  a distributed Gram matrix + small replicated Cholesky, replacing the paper's
  q sequential deflated solves and its O(q^2) aggregation traffic with O(q^2)
  *elements in one* collective.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "PowerIterResult", "power_iteration", "eigenvalue_sign",
    "DeflationResult", "deflated_power_iteration",
    "orthogonal_iteration", "OrthoIterResult",
]

Aggregate = Callable[[jnp.ndarray], jnp.ndarray]


def _identity_aggregate(x: jnp.ndarray) -> jnp.ndarray:
    return x


class PowerIterResult(NamedTuple):
    v: jnp.ndarray           # (p,) eigenvector estimate (unit norm)
    eigenvalue: jnp.ndarray  # () signed eigenvalue estimate
    iterations: jnp.ndarray  # () int
    delta: jnp.ndarray       # () final update norm ||v_{t+1} - v_t||


def eigenvalue_sign(v_prev: jnp.ndarray, v_next: jnp.ndarray,
                    aggregate: Aggregate = _identity_aggregate) -> jnp.ndarray:
    """Paper's sign criterion: sign(sum_i sign(v_t[i] v_{t+1}[i])).

    A negative eigenvalue flips the sign of every component each iteration;
    averaging the per-component signs makes the estimate robust to numerical
    error.  ``aggregate`` sums the local partial sums (an A operation).
    """
    s = aggregate(jnp.sum(jnp.sign(v_prev * v_next)))
    return jnp.sign(s)


def power_iteration(matvec: Callable[[jnp.ndarray], jnp.ndarray],
                    v0: jnp.ndarray,
                    t_max: int = 50,
                    delta: float = 1e-3,
                    aggregate: Aggregate = _identity_aggregate,
                    orthogonal_to: jnp.ndarray | None = None) -> PowerIterResult:
    """Algorithm 1 (and the inner loop of Algorithm 2 when ``orthogonal_to``).

    Parameters
    ----------
    matvec: computes ``C v`` (locally; any required neighbor exchange happens
        inside, e.g. the banded halo exchange).
    v0: initial vector (must not be orthogonal to the principal eigenvector).
    aggregate: global-sum primitive (identity locally, psum on a mesh, tree
        aggregation in the WSN simulator).  Used for norms and dot products.
    orthogonal_to: optional (p, k) matrix of previously found eigenvectors —
        the deflation step of Algorithm 2.
    """
    p = v0.shape[0]
    W = orthogonal_to if orthogonal_to is not None else jnp.zeros((p, 0), v0.dtype)

    def project_out(v):
        if W.shape[1] == 0:
            return v
        # k-1 dot products — one A op with a vector-valued partial record
        coeff = aggregate(W.T @ v)
        return v - W @ coeff

    def norm(v):
        return jnp.sqrt(aggregate(jnp.sum(v * v)))

    v0n = v0 / jnp.maximum(norm(v0), 1e-30)

    def cond(carry):
        _, _, t, d, _ = carry
        return jnp.logical_and(t < t_max, d > delta)

    def body(carry):
        v, _, t, _, _ = carry
        cv = matvec(v)
        cv = project_out(cv)
        nrm = norm(cv)
        v_next = cv / jnp.maximum(nrm, 1e-30)
        sign = eigenvalue_sign(v, v_next, aggregate)
        # measure the update against the sign-aligned vector so that
        # negative-eigenvalue oscillation does not mask convergence
        d = jnp.sqrt(aggregate(jnp.sum((v_next * sign - v) ** 2)))
        return (v_next, sign * nrm, t + 1, d, sign)

    init = (v0n, jnp.zeros((), v0.dtype), jnp.zeros((), jnp.int32),
            jnp.array(jnp.inf, v0.dtype), jnp.ones((), v0.dtype))
    v, lam, t, d, _ = jax.lax.while_loop(cond, body, init)
    return PowerIterResult(v=v, eigenvalue=lam, iterations=t, delta=d)


class DeflationResult(NamedTuple):
    W: jnp.ndarray            # (p, q) eigenvector estimates, column k = w_{k+1}
    eigenvalues: jnp.ndarray  # (q,) signed eigenvalue estimates
    valid: jnp.ndarray        # (q,) bool — False from the first negative
    iterations: jnp.ndarray   # (q,) int iterations used per component


def deflated_power_iteration(matvec: Callable[[jnp.ndarray], jnp.ndarray],
                             p: int, q: int, key: jax.Array,
                             t_max: int = 50, delta: float = 1e-3,
                             aggregate: Aggregate = _identity_aggregate,
                             dtype=jnp.float32) -> DeflationResult:
    """Algorithm 2: q components by deflation + sign-criterion stopping.

    The per-component loop is a Python loop (q is a static, small number —
    the paper's regime); each component runs a jittable while_loop.  The
    paper's 'until k = q or lambda_k < 0' stop is realized as a validity mask:
    components at or after the first negative eigenvalue are flagged invalid
    (Sec. 3.3.1: discard eigenvectors with negative eigenvalues).
    """
    keys = jax.random.split(key, q)
    W = jnp.zeros((p, q), dtype)
    lams = jnp.zeros((q,), dtype)
    iters = jnp.zeros((q,), jnp.int32)
    valid = jnp.ones((q,), bool)
    alive = jnp.ones((), bool)
    for k in range(q):
        v0 = jax.random.normal(keys[k], (p,), dtype)
        res = power_iteration(matvec, v0, t_max=t_max, delta=delta,
                              aggregate=aggregate, orthogonal_to=W[:, :k])
        W = W.at[:, k].set(res.v)
        lams = lams.at[k].set(res.eigenvalue)
        iters = iters.at[k].set(res.iterations)
        alive = jnp.logical_and(alive, res.eigenvalue > 0)
        valid = valid.at[k].set(alive)
    return DeflationResult(W=W, eigenvalues=lams, valid=valid, iterations=iters)


class OrthoIterResult(NamedTuple):
    W: jnp.ndarray            # (p, q) orthonormal basis, Rayleigh-ordered
    eigenvalues: jnp.ndarray  # (q,) Rayleigh-quotient eigenvalue estimates
    iterations: jnp.ndarray   # () int


def orthogonal_iteration(matmul: Callable[[jnp.ndarray], jnp.ndarray],
                         p: int, q: int, key: jax.Array,
                         t_max: int = 50, delta: float = 1e-3,
                         aggregate: Aggregate = _identity_aggregate,
                         dtype=jnp.float32,
                         eps: float = 1e-8) -> OrthoIterResult:
    """Blocked subspace iteration (beyond-paper; see module docstring).

    One iteration:  ``V <- C V``;  Gram ``G = V^T V`` (ONE aggregation of a
    q x q record, versus the paper's k separate A/F rounds per component);
    ``V <- V chol(G)^{-T}``.  After convergence the small Rayleigh problem
    ``H = V^T (C V)`` is solved (replicated, q x q — the paper's 'base station
    computes the small problem' pattern) to order the basis.
    """
    v0 = jax.random.normal(key, (p, q), dtype)

    def orthonormalize(V):
        G = aggregate(V.T @ V)                       # one A+F op, q^2 elements
        L = jnp.linalg.cholesky(G + eps * jnp.eye(q, dtype=dtype))
        # V @ inv(L)^T: the inverse of the tiny replicated factor keeps the
        # update row-local on a sharded V (triangular_solve makes GSPMD
        # all-gather V — EXPERIMENTS.md Sec. Perf hillclimb 1)
        return V @ jnp.linalg.inv(L).T

    def cond(carry):
        _, t, d = carry
        return jnp.logical_and(t < t_max, d > delta)

    def body(carry):
        V, t, _ = carry
        V_next = orthonormalize(matmul(V))
        # subspace distance proxy: per-column update norm after sign alignment
        sign = jnp.sign(jnp.sum(V * V_next, axis=0))
        d = jnp.sqrt(aggregate(jnp.sum((V_next * sign - V) ** 2)) / q)
        return (V_next, t + 1, d)

    V0 = orthonormalize(v0)
    V, t, _ = jax.lax.while_loop(
        cond, body, (V0, jnp.zeros((), jnp.int32), jnp.array(jnp.inf, dtype)))

    CV = matmul(V)
    H = aggregate(V.T @ CV)                          # (q, q) Rayleigh matrix
    evals, U = jnp.linalg.eigh(H)                    # ascending
    order = jnp.argsort(-evals)
    return OrthoIterResult(W=V @ U[:, order], eigenvalues=evals[order],
                           iterations=t)
