"""Principal component aggregation & supervised compression (paper Sec. 2.3-2.4).

* :func:`pcag_primitives` — the exact aggregation primitives of Sec. 2.3:
  ``init(x_i) = <w_i1 x_i; ...; w_iq x_i>``, merge = elementwise sum.  Running
  them on the routing-tree simulator computes the scores *in-network*.
* :func:`scores` / :func:`reconstruct` — the linear algebra of Eq. (5)-(6).
* :class:`SupervisedCompressor` — the +/- epsilon guarantee of Sec. 2.4.1:
  scores are fed back (F op); every node reconstructs its own measurement
  approximation locally and raises a notification when the error exceeds
  epsilon; flagged nodes transmit their raw measurement so the sink is always
  within +/- epsilon of the truth.

Epsilon convention (shared with the device tier in kernels/pca_project.py
and streaming/compressor.py, so differential tests can compare exactly):
a node notifies on the *strict* ``err > eps``, hence every un-flagged entry
satisfies the *closed* bound ``|x - x_hat| <= eps`` — the guarantee is
always asserted as ``<= eps``.

This module is the host-side NumPy **oracle**: the serving hot loop runs the
fused Pallas tier (:func:`repro.kernels.ops.supervised_compress`); the
functions here define the semantics the device tier is tested against.
``dtype`` defaults to the input's dtype so the oracle can be evaluated at
fp32 for exact comparison with the device path (or at float64 for
reference-precision studies).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.aggregation import AggregationPrimitives, aggregate_tree
from repro.core.topology import RoutingTree

__all__ = ["pcag_primitives", "scores", "reconstruct", "SupervisedCompressor",
           "SupervisedResult"]


def _resolve_dtype(x: np.ndarray, dtype) -> np.dtype:
    """Input dtype for floating inputs, float64 otherwise (ints, lists)."""
    if dtype is not None:
        return np.dtype(dtype)
    if np.issubdtype(x.dtype, np.floating):
        return x.dtype
    return np.dtype(np.float64)


def pcag_primitives(W: np.ndarray) -> AggregationPrimitives:
    """Sec. 2.3 primitives.  ``W`` is (p, q); node i uses row W[i].

    ``init`` receives the pair (i, x_i) so each node can select its own row —
    in the real deployment the row is stored on the node (the initialization
    the paper's Sec. 3 distributes).
    """
    W = np.asarray(W, dtype=np.float64)

    return AggregationPrimitives(
        init=lambda ix: W[ix[0]] * ix[1],
        merge=lambda a, b: a + b,
        evaluate=lambda rec: rec,
    )


def scores(W: np.ndarray, x: np.ndarray, mean: np.ndarray | None = None,
           dtype=None) -> np.ndarray:
    """z = W^T (x - mean); x may be (p,) or (N, p).

    ``dtype`` defaults to x's dtype (float64 for non-float input), so an
    fp32 caller gets fp32 arithmetic — comparable with the device tier,
    and no silent float64 constant under jit without x64.
    """
    x = np.asarray(x)
    dt = _resolve_dtype(x, dtype)
    x = x.astype(dt, copy=False)
    if mean is not None:
        x = x - np.asarray(mean, dtype=dt)
    return x @ np.asarray(W, dtype=dt)


def reconstruct(W: np.ndarray, z: np.ndarray, mean: np.ndarray | None = None,
                dtype=None) -> np.ndarray:
    """x_hat = W z (+ mean); dtype defaults to z's dtype (see scores)."""
    z = np.asarray(z)
    dt = _resolve_dtype(z, dtype)
    out = z.astype(dt, copy=False) @ np.asarray(W, dtype=dt).T
    if mean is not None:
        out = out + np.asarray(mean, dtype=dt)
    return out


def scores_in_network(tree: RoutingTree, W: np.ndarray, x: np.ndarray,
                      mean: np.ndarray | None = None):
    """Compute z[t] by actually running the aggregation service (tests/bench).

    Returns (z, per-node packet counts)."""
    xc = np.asarray(x, dtype=np.float64)
    if mean is not None:
        xc = xc - mean
    prim = pcag_primitives(W)
    res = aggregate_tree(tree, [(i, xc[i]) for i in range(tree.p)], prim)
    return np.asarray(res.value), res.packets


@dataclasses.dataclass(frozen=True)
class SupervisedResult:
    x_hat: np.ndarray          # (N, p) sink-side reconstruction, epsilon-true
    flagged: np.ndarray        # (N, p) bool — nodes that raised a notification
    extra_packets: np.ndarray  # (p,) raw-measurement packets sent per node


class SupervisedCompressor:
    """Supervised compression (Sec. 2.4.1): guarantee |x_i - x_hat_i| <= eps.

    Protocol per epoch: scores are aggregated (A), fed back (F); node i
    locally computes x_hat_i = sum_k z_k w_ik + mean_i; if the error
    *strictly exceeds* eps it sends its raw measurement up the tree (counted
    in extra_packets), and the sink substitutes the exact value — so every
    sink entry satisfies the closed bound ``|x - x_hat| <= eps`` (the
    module-level epsilon convention, shared with the device tier).

    ``dtype`` defaults to W's dtype (float64 for non-float input): pass
    ``np.float32`` (or an fp32 basis) to make this oracle bit-comparable
    with the fused device path.
    """

    def __init__(self, W: np.ndarray, mean: np.ndarray, epsilon: float,
                 dtype=None):
        W = np.asarray(W)
        self.dtype = _resolve_dtype(W, dtype)
        self.W = W.astype(self.dtype, copy=False)
        self.mean = np.asarray(mean, dtype=self.dtype)
        self.epsilon = float(epsilon)

    def run(self, x: np.ndarray) -> SupervisedResult:
        x = np.asarray(x).astype(self.dtype, copy=False)
        z = scores(self.W, x, self.mean, dtype=self.dtype)
        x_hat = reconstruct(self.W, z, self.mean, dtype=self.dtype)
        err = np.abs(x - x_hat)
        flagged = err > self.epsilon
        x_out = np.where(flagged, x, x_hat)
        extra = flagged.sum(axis=0).astype(np.int64)
        return SupervisedResult(x_hat=x_out, flagged=flagged, extra_packets=extra)
