"""Spatiotemporal principal component aggregation (paper Sec. Conclusion).

The paper closes with: *"We plan to extend this work by showing that
spatiotemporal aggregation ... can also be formulated in the same
framework."*  This module provides that formulation.

Each node holds its own trailing window of ``w`` measurements (no extra
communication — the history is local).  The feature vector at epoch t is the
stacked window ``[x_1[t..t-w+1], ..., x_p[t..t-w+1]] in R^{p*w}``, and the
aggregation primitives generalize verbatim (Sec. 2.3):

    init_i(history_i) = < sum_tau W[(i,tau), k] * x_i[t - tau] >_k
    f = elementwise sum,  e = identity

— the partial state record is *still* q scalars per epoch, so the network
cost of spatiotemporal PCAg equals plain PCAg; only node-local compute/
memory grow by the factor w (each node stores its w x q weight block and w
recent samples).  The local covariance hypothesis extends as
``c_{(i,s),(j,tau)} = 0 unless j in N_i`` — a block mask: full temporal
coupling within a neighborhood, zero across distant sensors
(kron(spatial_mask, ones(w, w))).
"""

from __future__ import annotations

import numpy as np

from repro.core.aggregation import AggregationPrimitives, aggregate_tree
from repro.core.pca import DistributedPCA, PCAResult
from repro.core.topology import RoutingTree

__all__ = ["stack_windows", "spatiotemporal_mask", "SpatioTemporalPCA",
           "st_pcag_primitives", "st_scores_in_network"]


def stack_windows(x: np.ndarray, w: int) -> np.ndarray:
    """(N, p) epochs -> (N - w + 1, p * w) stacked windows.

    Column layout is sensor-major: features [i*w : (i+1)*w] belong to sensor
    i, ordered lag 0 (current) .. lag w-1 — each node owns a contiguous
    block, which is what makes the in-network formulation local."""
    n, p = x.shape
    if w < 1 or w > n:
        raise ValueError("window must be in [1, n_epochs]")
    out = np.empty((n - w + 1, p * w), dtype=x.dtype)
    for lag in range(w):
        sl = x[w - 1 - lag: n - lag]           # (N-w+1, p), lag steps back
        out[:, lag::w] = sl
    return out


def spatiotemporal_mask(spatial_mask: np.ndarray, w: int) -> np.ndarray:
    """Local covariance hypothesis on the stacked space: kron(mask, 1_wxw)."""
    return np.kron(spatial_mask, np.ones((w, w), dtype=bool))


class SpatioTemporalPCA:
    """DistributedPCA over stacked windows with the block-local mask."""

    def __init__(self, q: int, window: int, method: str = "eigh",
                 spatial_mask: np.ndarray | None = None, **kw):
        self.window = window
        mask = None
        cov_mode = "full"
        if spatial_mask is not None:
            mask = spatiotemporal_mask(np.asarray(spatial_mask, bool), window)
            cov_mode = "masked"
        self._pca = DistributedPCA(q=q, method=method, cov_mode=cov_mode,
                                   mask=mask, **kw)

    def fit(self, x: np.ndarray) -> PCAResult:
        return self._pca.fit(stack_windows(x, self.window))

    def transform(self, result: PCAResult, x: np.ndarray) -> np.ndarray:
        return DistributedPCA.transform(result, stack_windows(x, self.window))

    def reconstruct_current(self, result: PCAResult,
                            x: np.ndarray) -> np.ndarray:
        """Reconstruct the lag-0 (current-epoch) measurements only.

        The number of sensors is recovered from the fitted basis (the stacked
        feature space has ``p * window`` columns in sensor-major layout), so
        no shape argument is needed — the lag-0 slice ``full[:, 0::window]``
        is exactly the (N - w + 1, p) current-epoch block.
        """
        z = self.transform(result, x)
        full = DistributedPCA.inverse_transform(result, z)
        return full[:, 0::self.window]         # lag-0 columns, sensor-major


def st_pcag_primitives(W: np.ndarray, w: int) -> AggregationPrimitives:
    """In-network primitives: node i contributes its w-window projected
    through its (w, q) weight block; records stay q-dimensional."""
    W = np.asarray(W, dtype=np.float64)

    return AggregationPrimitives(
        init=lambda ih: W[ih[0] * w:(ih[0] + 1) * w].T @ ih[1],
        merge=lambda a, b: a + b,
        evaluate=lambda rec: rec,
    )


def st_scores_in_network(tree: RoutingTree, W: np.ndarray, histories,
                         w: int):
    """Compute spatiotemporal scores by running the aggregation service.

    histories: per-node arrays of shape (w,) — lag 0 first.
    Returns (scores (q,), per-node packet counts) — same packet counts as
    plain PCAg with the same q."""
    prim = st_pcag_primitives(W, w)
    res = aggregate_tree(tree, [(i, np.asarray(h, np.float64))
                                for i, h in enumerate(histories)], prim)
    return np.asarray(res.value), res.packets
