"""Aggregation service primitives (paper Sec. 2.1).

An aggregation service is defined by three primitives (Sec. 2.1.2):

* ``init``  — turn a local measurement into a partial state record,
* ``f``     — merge two partial state records (associative + commutative),
* ``e``     — evaluate the root record into the requested result.

This module provides

1. a faithful **routing-tree simulator** (:func:`aggregate_tree`) that executes
   init/f/e along a :class:`~repro.core.topology.RoutingTree` leaf-to-root and
   counts the packets each node processes (used to validate the cost models of
   Sec. 2.1.3 / Table 1 against actual packet counts), and

2. the **TPU mapping** of the D / A / F operations onto mesh collectives
   (:func:`a_op`, :func:`d_op`, :func:`f_op`, :func:`halo_exchange`) used by
   the production distributed path (DESIGN.md Sec. 2).  ``a_op`` fuses the
   paper's A (aggregate up) and F (flood down) because ``psum`` delivers the
   reduced value to every participant.

The classic example from Sec. 2.1.2 (Euclidean norm of the network's
measurement vector) is provided as :data:`NORM_PRIMITIVES` and used in tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import RoutingTree

__all__ = [
    "AggregationPrimitives", "NORM_PRIMITIVES", "aggregate_tree",
    "TreeAggregationResult", "LossyAggregationResult", "lossy_aggregate_tree",
    "a_op", "d_op", "f_op", "halo_exchange",
    "tree_aggregate_fn",
]


@dataclasses.dataclass(frozen=True)
class AggregationPrimitives:
    """The (init, f, e) triple of Sec. 2.1.2."""

    init: Callable[[Any], Any]
    merge: Callable[[Any, Any], Any]
    evaluate: Callable[[Any], Any]
    record_size: Callable[[Any], int] = lambda record: int(np.size(record))


NORM_PRIMITIVES = AggregationPrimitives(
    init=lambda x: np.asarray(x, dtype=np.float64) ** 2,
    merge=lambda a, b: a + b,
    evaluate=lambda rec: np.sqrt(rec),
)


@dataclasses.dataclass(frozen=True)
class TreeAggregationResult:
    value: Any                    # e(root record)
    packets: np.ndarray           # (p,) packets processed per node (rx + tx)
    record_sizes: np.ndarray      # (p,) size of the record each node sent


def aggregate_tree(tree: RoutingTree, values: Sequence[Any],
                   primitives: AggregationPrimitives) -> TreeAggregationResult:
    """Execute one epoch of the aggregation service on the routing tree.

    Nodes are processed deepest-first; each node merges its children's partial
    state records into its own ``init`` record and transmits the result to its
    parent (paper Fig. 2/3).  Packet accounting matches Sec. 2.1.3's A
    operation: node i transmits ``q`` packets (q = record size) and receives
    the records of its direct children.
    """
    p = tree.p
    records: list[Any] = [primitives.init(values[i]) for i in range(p)]
    rx = np.zeros(p, dtype=np.int64)
    tx = np.zeros(p, dtype=np.int64)
    sizes = np.zeros(p, dtype=np.int64)

    order = np.argsort(-tree.depth)          # deepest first
    for i in order:
        i = int(i)
        par = int(tree.parent[i])
        size = primitives.record_size(records[i])
        sizes[i] = size
        if par >= 0:
            records[par] = primitives.merge(records[par], records[i])
            tx[i] += size
            rx[par] += size
    # the root transmits the final record to the base station
    tx[tree.root] += sizes[tree.root]
    return TreeAggregationResult(
        value=primitives.evaluate(records[tree.root]),
        packets=rx + tx,
        record_sizes=sizes,
    )


# --------------------------------------------------------------------------
# Lossy links: the same epoch under per-hop Bernoulli loss + ARQ
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LossyAggregationResult:
    """One lossy epoch: value, packets (incl. retransmissions), delivery map.

    ``attempts[i]`` is the number of transmissions node i spent on its
    parent hop (0 for the root and for inactive nodes); ``delivered[i]``
    marks whether its record arrived within the retry budget.  A failed hop
    loses the node's *merged subtree record* — exactly the blast radius a
    real TAG epoch suffers.
    """

    value: Any
    packets: np.ndarray           # (p,) rx + tx per node, retransmissions incl.
    record_sizes: np.ndarray      # (p,) size of the record each node sent
    delivered: np.ndarray         # (p,) bool — record reached the parent
    attempts: np.ndarray          # (p,) transmissions spent on the parent hop
    active: np.ndarray            # (p,) bool — nodes that took part


def lossy_aggregate_tree(tree: RoutingTree, values: Sequence[Any],
                         primitives: AggregationPrimitives,
                         fault, rng: np.random.Generator,
                         active: np.ndarray | None = None,
                         ) -> LossyAggregationResult:
    """One epoch of the aggregation service over lossy links.

    Same deepest-first schedule as :func:`aggregate_tree`; every parent hop
    runs the :class:`repro.core.faults.FaultModel` ARQ policy
    (``fault.transmit``): each attempt books ``record_size`` tx packets at
    the sender, only the delivered attempt books rx packets at the parent
    (a lost packet never reaches the radio on the other side; acks are not
    counted).  ``active`` masks out dead / detached nodes — pass the
    ``attached`` mask from :func:`repro.core.topology.repair_tree` after a
    node-death wave, with the tree being the *repaired* tree.

    At ``fault.link_loss == 0`` and full ``active`` this is **bit-identical**
    to :func:`aggregate_tree` in value and packet counts (no randomness is
    consumed), which is the differential anchor in tests/test_faults.py.
    The root's uplink to the base station is wired, hence reliable.
    """
    p = tree.p
    if active is None:
        active = np.ones(p, dtype=bool)
    active = np.asarray(active, dtype=bool)
    if not active[tree.root]:
        raise ValueError("the root must be active")
    # fail fast on an inconsistent mask: an active node routing through a
    # dead/detached parent means the caller passed a raw alive mask where
    # the tree needs repair_tree's `attached` mask
    parents = tree.parent
    for i in range(p):
        if active[i] and i != tree.root and (
                parents[i] < 0 or not active[parents[i]]):
            raise ValueError(
                f"active node {i} has a dead or detached parent; repair the "
                f"tree first and pass repair_tree's `attached` mask")

    records: list[Any] = [primitives.init(values[i]) if active[i] else None
                          for i in range(p)]
    rx = np.zeros(p, dtype=np.int64)
    tx = np.zeros(p, dtype=np.int64)
    sizes = np.zeros(p, dtype=np.int64)
    delivered = np.zeros(p, dtype=bool)
    attempts = np.zeros(p, dtype=np.int64)

    order = np.argsort(-tree.depth)          # deepest first
    for i in order:
        i = int(i)
        if not active[i]:
            continue
        par = int(tree.parent[i])
        size = primitives.record_size(records[i])
        sizes[i] = size
        if par >= 0:
            ok, n_tries = fault.transmit(rng)
            attempts[i] = n_tries
            tx[i] += size * n_tries
            if ok:
                delivered[i] = True
                rx[par] += size
                records[par] = primitives.merge(records[par], records[i])
    # the root transmits the final record to the base station (wired uplink)
    delivered[tree.root] = True
    tx[tree.root] += sizes[tree.root]
    return LossyAggregationResult(
        value=primitives.evaluate(records[tree.root]),
        packets=rx + tx,
        record_sizes=sizes,
        delivered=delivered,
        attempts=attempts,
        active=active,
    )


def tree_aggregate_fn(tree: RoutingTree,
                      primitives: AggregationPrimitives) -> Callable:
    """An ``aggregate`` callable (for power_iteration) backed by the simulator.

    Takes a per-node array of local partial sums (axis 0 = node) and returns
    the tree-aggregated total, mimicking an A+F round trip.  Only used in the
    WSN simulation/tests — the production path uses :func:`a_op`.
    """

    def aggregate(local: np.ndarray) -> np.ndarray:
        res = aggregate_tree(tree, list(np.asarray(local)), primitives)
        return res.value

    return aggregate


# --------------------------------------------------------------------------
# TPU mapping: D / A / F operations as mesh collectives
# --------------------------------------------------------------------------
def a_op(x: jnp.ndarray, axis_name: str | tuple[str, ...]) -> jnp.ndarray:
    """A operation (+ fused F): global sum delivered to every device.

    XLA lowers ``psum`` to a reduction tree / bidirectional ring over the ICI
    links — the aggregation-tree structure of TAG, scheduled by the compiler.
    """
    return jax.lax.psum(x, axis_name)


def f_op(x: jnp.ndarray, axis_name: str, root: int = 0) -> jnp.ndarray:
    """F operation: flood the root's value to all devices on the axis.

    Realized as a masked psum (only the root contributes); with ``psum``'s
    all-reduce semantics every device receives the root record.
    """
    idx = jax.lax.axis_index(axis_name)
    contrib = jnp.where(idx == root, x, jnp.zeros_like(x))
    return jax.lax.psum(contrib, axis_name)


def d_op(x: jnp.ndarray, axis_name: str, tiled: bool = False) -> jnp.ndarray:
    """D operation (default collection): gather every device's raw record."""
    return jax.lax.all_gather(x, axis_name, tiled=tiled)


def halo_exchange(block: jnp.ndarray, halo: int, axis_name: str,
                  wrap: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Neighbor exchange of boundary columns over the device ring.

    The paper's 'node broadcasts v_t[i] and receives v_t[j], j in N_i'
    (Sec. 3.4.3) mapped onto ``lax.ppermute``: each device sends its right
    edge to the right neighbor and its left edge to the left neighbor.

    Parameters
    ----------
    block: (..., local_p) local shard of the feature axis.
    halo: number of boundary elements to exchange (>= covariance half-width
        remainder at the block edge).
    wrap: if False (default), the ring is broken at the ends (block boundary
        condition of a banded matrix); edge devices receive zeros.

    Returns
    -------
    (left_halo, right_halo): the ``halo`` elements received from the left and
    right neighbors, shaped (..., halo).
    """
    # jax.lax.axis_size is not available on this jax version; psum of a
    # unit per participant gives the axis size as a compile-time constant
    n = int(jax.lax.psum(1, axis_name))
    right_edge = block[..., -halo:]
    left_edge = block[..., :halo]

    def perm(shift):
        pairs = [(i, (i + shift) % n) for i in range(n)]
        if not wrap:
            pairs = [(s, d) for s, d in pairs if 0 <= s + shift < n]
        return pairs

    # send right edge rightward -> arrives as neighbor's left halo
    from_left = jax.lax.ppermute(right_edge, axis_name, perm(+1))
    # send left edge leftward -> arrives as neighbor's right halo
    from_right = jax.lax.ppermute(left_edge, axis_name, perm(-1))
    return from_left, from_right
