"""Distributed PCA orchestrator (the paper's end-to-end system).

Ties together the pieces of Sections 2-3:

1. estimate the covariance — centralized (Sec. 3.2) or under the local
   covariance hypothesis (Sec. 3.3, masked or banded),
2. extract q principal components — exact eigendecomposition (the paper's
   centralized QR baseline), the faithful deflated power iteration
   (Algorithm 2), or the beyond-paper blocked orthogonal iteration,
3. expose transform / inverse_transform (PCAg scores, Sec. 2.3) and
   retained-variance accounting (Eq. 4).

Everything here is single-process JAX operating on (N, p) matrices; the
sharded production path reuses the same covariance/power-iteration functions
with mesh aggregates (see repro/launch and repro/distributed).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import covariance as cov
from repro.core import power_iteration as pim

__all__ = ["PCAResult", "DistributedPCA", "retained_variance"]

Method = Literal["eigh", "power", "ortho"]
CovMode = Literal["full", "masked", "banded"]


@dataclasses.dataclass
class PCAResult:
    components: np.ndarray      # (p, q) columns = w_k
    eigenvalues: np.ndarray     # (q,)
    mean: np.ndarray            # (p,)
    valid: np.ndarray           # (q,) bool (sign-criterion mask, Alg. 2)
    iterations: np.ndarray | int
    total_variance: float       # trace of the (unmasked) sample covariance

    @property
    def q(self) -> int:
        return int(self.components.shape[1])

    def retained_fraction(self) -> np.ndarray:
        """Eq. (4) on the training covariance, cumulative over components."""
        lam = np.where(self.valid, np.maximum(self.eigenvalues, 0.0), 0.0)
        return np.cumsum(lam) / max(self.total_variance, 1e-30)


def retained_variance(x: np.ndarray, components: np.ndarray,
                      mean: np.ndarray | None = None) -> float:
    """Fraction of the variance of ``x`` retained by projecting on the basis.

    This is the paper's *test-set* metric (Sec. 4.3): 1 - ||x - x_hat||^2 /
    ||x - mean||^2 computed on held-out measurements.
    """
    x = np.asarray(x, dtype=np.float64)
    mu = x.mean(axis=0) if mean is None else np.asarray(mean, np.float64)
    xc = x - mu
    W = np.asarray(components, dtype=np.float64)
    z = xc @ W
    xhat = z @ W.T
    num = float(np.sum((xc - xhat) ** 2))
    den = float(np.sum(xc ** 2))
    return 1.0 - num / max(den, 1e-30)


class DistributedPCA:
    """fit/transform interface over the paper's algorithm variants.

    Parameters
    ----------
    q: number of principal components to extract.
    method: 'eigh' (centralized baseline), 'power' (faithful Algorithm 2),
        'ortho' (beyond-paper blocked orthogonal iteration).
    cov_mode: 'full' covariance, 'masked' (local covariance hypothesis with an
        explicit neighborhood mask), or 'banded' (bandwidth-regularized mask).
    mask: (p, p) bool — required for 'masked'.
    halfwidth: band half-width — required for 'banded'.
    t_max, delta: PIM stopping rule (Algorithm 1).
    """

    def __init__(self, q: int, method: Method = "power",
                 cov_mode: CovMode = "full",
                 mask: np.ndarray | None = None,
                 halfwidth: int | None = None,
                 t_max: int = 50, delta: float = 1e-3, seed: int = 0):
        if cov_mode == "masked" and mask is None:
            raise ValueError("cov_mode='masked' requires a neighborhood mask")
        if cov_mode == "banded" and halfwidth is None:
            raise ValueError("cov_mode='banded' requires halfwidth")
        self.q = q
        self.method = method
        self.cov_mode = cov_mode
        self.mask = mask
        self.halfwidth = halfwidth
        self.t_max = t_max
        self.delta = delta
        self.seed = seed

    # -- covariance --------------------------------------------------------
    def _estimate_cov(self, x: jnp.ndarray):
        p = x.shape[1]
        if self.cov_mode == "banded":
            state = cov.banded_init(p, self.halfwidth)
            state = cov.banded_update(state, x)
            band = cov.banded_estimate(state)
            return band, cov.band_to_dense(band)
        mask = None if self.cov_mode == "full" else self.mask
        state = cov.cov_init(p, mask=mask)
        state = cov.cov_update(state, x)
        c = cov.cov_estimate(state)
        return None, c

    # -- fit ----------------------------------------------------------------
    def fit(self, x: np.ndarray) -> PCAResult:
        x = jnp.asarray(x, dtype=jnp.float32)
        mean = x.mean(axis=0)
        band, c = self._estimate_cov(x)
        p = x.shape[1]
        total_var = float(jnp.trace(
            cov.cov_estimate(cov.cov_update(cov.cov_init(p), x))))
        key = jax.random.PRNGKey(self.seed)

        if self.method == "eigh":
            evals, evecs = jnp.linalg.eigh(c)
            order = jnp.argsort(-evals)[: self.q]
            W = evecs[:, order]
            lam = evals[order]
            valid = lam > 0
            iters = 0
        elif self.method == "power":
            if band is not None:
                matvec = lambda v: cov.banded_matvec_ref(band, v)
            else:
                matvec = lambda v: c @ v
            res = pim.deflated_power_iteration(
                matvec, p, self.q, key, t_max=self.t_max, delta=self.delta)
            W, lam, valid, iters = res.W, res.eigenvalues, res.valid, res.iterations
        elif self.method == "ortho":
            if band is not None:
                matmul = lambda V: cov.banded_matmul_ref(band, V)
            else:
                matmul = lambda V: c @ V
            res = pim.orthogonal_iteration(
                matmul, p, self.q, key, t_max=self.t_max, delta=self.delta)
            W, lam, iters = res.W, res.eigenvalues, res.iterations
            valid = lam > 0
        else:
            raise ValueError(f"unknown method {self.method!r}")

        return PCAResult(
            components=np.asarray(W, np.float64),
            eigenvalues=np.asarray(lam, np.float64),
            mean=np.asarray(mean, np.float64),
            valid=np.asarray(valid, bool),
            iterations=np.asarray(iters),
            total_variance=total_var,
        )

    # -- transform (PCAg scores, Sec. 2.3) ----------------------------------
    @staticmethod
    def transform(result: PCAResult, x: np.ndarray,
                  use_valid_only: bool = True) -> np.ndarray:
        W = result.components
        if use_valid_only:
            W = W * result.valid[None, :]
        return (np.asarray(x) - result.mean) @ W

    @staticmethod
    def inverse_transform(result: PCAResult, z: np.ndarray,
                          use_valid_only: bool = True) -> np.ndarray:
        W = result.components
        if use_valid_only:
            W = W * result.valid[None, :]
        return z @ W.T + result.mean
