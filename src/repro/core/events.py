"""Event detection on low-variance components (paper Sec. 2.4.3).

Low-variance principal components normally carry near-zero coordinates (they
account for sensor noise).  A network-scale event that is invisible at any
single node shows up as a significant coordinate on those components.  The
evaluator function is a statistical test on the standardized low-variance
scores:

    T[t] = sum_{k in low} z_k[t]^2 / lambda_k   ~   chi^2_{|low|}  under H0.

:class:`LowVarianceDetector` flags epochs where T exceeds the chi-square
quantile (normal-approximation threshold — no scipy dependency).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LowVarianceDetector", "DetectionResult"]


def _chi2_quantile(df: float, alpha: float) -> float:
    """Wilson-Hilferty approximation of the chi-square (1-alpha) quantile.

    ``df`` may be fractional (the moment-matched ``g * chi2_h`` thresholds of
    the streaming detector pass their effective degrees of freedom here).
    ``alpha`` outside (0, 1) is clamped into the open interval by
    :func:`_norm_quantile` — the helpers never return ±inf/NaN; the
    *validation* of a caller's alpha belongs to the caller (see
    :class:`LowVarianceDetector`).
    """
    # normal quantile via Acklam-style rational approximation (sufficient here)
    z = _norm_quantile(1.0 - alpha)
    a = 2.0 / (9.0 * df)
    return df * (1.0 - a + z * np.sqrt(a)) ** 3


def _norm_quantile(u: float) -> float:
    # Beasley-Springer-Moro.  The tail branches take log(u) / log(1-u), so
    # u is clamped into the open interval first: u = 0 or 1 would silently
    # produce ±inf and poison every threshold derived from it.
    u = float(np.clip(u, 1e-300, 1.0 - 1e-16))
    a = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
         1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
         6.680131188771972e+01, -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
         -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
         3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if u < plow:
        q = np.sqrt(-2 * np.log(u))
        return (((((c[0]*q+c[1])*q+c[2])*q+c[3])*q+c[4])*q+c[5]) / \
               ((((d[0]*q+d[1])*q+d[2])*q+d[3])*q+1)
    if u > phigh:
        return -_norm_quantile(1 - u)
    q = u - 0.5
    r = q * q
    return (((((a[0]*r+a[1])*r+a[2])*r+a[3])*r+a[4])*r+a[5])*q / \
           (((((b[0]*r+b[1])*r+b[2])*r+b[3])*r+b[4])*r+1)


@dataclasses.dataclass(frozen=True)
class DetectionResult:
    statistic: np.ndarray   # (N,) chi-square statistic per epoch
    threshold: float
    events: np.ndarray      # (N,) bool


class LowVarianceDetector:
    """Detector over the trailing (low-variance) components.

    Parameters
    ----------
    W_low: (p, m) low-variance components (e.g. columns q_lo..q_hi of the
        full basis).
    lambdas_low: (m,) their eigenvalues (estimated on healthy training data).
    alpha: false-alarm rate under H0.
    """

    def __init__(self, W_low: np.ndarray, lambdas_low: np.ndarray,
                 mean: np.ndarray, alpha: float = 1e-3,
                 min_lambda: float = 1e-9):
        if not 0.0 < alpha < 1.0:
            raise ValueError(
                f"alpha must be in the open interval (0, 1), got {alpha}")
        self.W = np.asarray(W_low, dtype=np.float64)
        self.lam = np.maximum(np.asarray(lambdas_low, np.float64), min_lambda)
        self.mean = np.asarray(mean, dtype=np.float64)
        self.alpha = alpha
        self.threshold = _chi2_quantile(self.W.shape[1], alpha)

    def statistic(self, x: np.ndarray) -> np.ndarray:
        xc = np.asarray(x, dtype=np.float64) - self.mean
        z = xc @ self.W                       # (N, m) low-variance scores
        return np.sum(z * z / self.lam[None, :], axis=1)

    def calibrate(self, x_healthy: np.ndarray) -> float:
        """Replace the chi-square threshold by the empirical (1-alpha)
        quantile on a healthy calibration window.

        The chi-square calibration assumes the deployment period is
        stationary w.r.t. the training block; on real (diurnal,
        non-stationary) traces the low-variance scores drift, so production
        deployments should re-calibrate on recent healthy data — this is the
        WSN analogue of recalibrating a fleet-telemetry alarm."""
        stat = self.statistic(x_healthy)
        self.threshold = float(np.quantile(stat, 1.0 - self.alpha))
        return self.threshold

    def detect(self, x: np.ndarray) -> DetectionResult:
        stat = self.statistic(x)
        return DetectionResult(statistic=stat, threshold=self.threshold,
                               events=stat > self.threshold)
