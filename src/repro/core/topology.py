"""Network topology: sensor layouts, radio neighborhoods, routing trees.

Implements the network model of the paper (Sec. 2.1 and 4.2):

* a static network of ``p`` sensors at fixed 2-D positions,
* a *radio range* ``r`` defining the neighborhood
  ``N_i = { j != i : ||pos_i - pos_j|| <= r }``,
* a shortest-path routing tree rooted at the sink-connected node, built exactly
  as in Sec. 4.2: starting from the root, sensors attach to the in-range parent
  that is closest (in hops, then distance) to the base station,
* per-node packet counts for the three network operations of Sec. 2.1.3:
  D (default collection), A (aggregation), F (feedback).

The TPU mapping (DESIGN.md Sec. 2) replaces the irregular neighborhood graph by
a banded layout; :func:`bandwidth_reduce` provides the (reverse Cuthill-McKee)
ordering that justifies that regularization for arbitrary sensor graphs.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

import numpy as np

__all__ = [
    "SensorTopology",
    "RoutingTree",
    "grid_layout",
    "berkeley_like_layout",
    "build_topology",
    "bandwidth_reduce",
    "repair_tree",
]


def grid_layout(rows: int, cols: int, spacing: float = 1.0, jitter: float = 0.0,
                seed: int = 0) -> np.ndarray:
    """Regular ``rows x cols`` sensor grid with optional positional jitter."""
    rng = np.random.default_rng(seed)
    xs, ys = np.meshgrid(np.arange(cols), np.arange(rows))
    pos = np.stack([xs.ravel(), ys.ravel()], axis=1).astype(np.float64) * spacing
    if jitter > 0:
        pos = pos + rng.uniform(-jitter, jitter, size=pos.shape)
    return pos


def berkeley_like_layout(p: int = 52, seed: int = 7) -> np.ndarray:
    """A 2-D layout statistically similar to the Intel-Berkeley lab deployment.

    The lab floorplan is roughly a 40 m x 30 m rectangle with sensors placed
    along walls/desk rows.  We generate a perturbed double-ring + interior rows
    layout in a 40x30 box.  The exact trace geometry is not redistributable
    offline (DESIGN.md Sec. 7); the surrogate preserves what the paper's
    analysis depends on: a connected graph at radio range >= ~6 m and distant
    pairs ~45 m apart.
    """
    rng = np.random.default_rng(seed)
    pos = []
    # perimeter ring
    n_ring = p // 2
    t = np.linspace(0, 1, n_ring, endpoint=False)
    ring = np.stack([
        20 + 19 * np.cos(2 * np.pi * t),
        15 + 13 * np.sin(2 * np.pi * t),
    ], axis=1)
    pos.append(ring)
    # interior desk rows
    n_rows = p - n_ring
    xs = rng.uniform(4, 36, size=n_rows)
    ys = np.tile(np.array([7.5, 15.0, 22.5]), n_rows // 3 + 1)[:n_rows]
    pos.append(np.stack([xs, ys], axis=1))
    out = np.concatenate(pos, axis=0)[:p]
    out = out + rng.uniform(-0.8, 0.8, size=out.shape)
    return out


@dataclasses.dataclass(frozen=True)
class RoutingTree:
    """Routing tree (paper Fig. 1/6): ``parent[i]`` is -1 for the root."""

    parent: np.ndarray          # (p,) int, parent[root] == -1
    root: int
    depth: np.ndarray           # (p,) int, hop distance to root

    @property
    def p(self) -> int:
        return int(self.parent.shape[0])

    def children_counts(self) -> np.ndarray:
        """C_i: number of direct children of node i."""
        counts = np.zeros(self.p, dtype=np.int64)
        for i, par in enumerate(self.parent):
            if par >= 0:
                counts[par] += 1
        return counts

    def subtree_sizes(self) -> np.ndarray:
        """RT_i: size of the subtree rooted at node i (including i)."""
        sizes = np.ones(self.p, dtype=np.int64)
        # process nodes from deepest to shallowest
        order = np.argsort(-self.depth)
        for i in order:
            par = self.parent[i]
            if par >= 0:
                sizes[par] += sizes[i]
        return sizes

    # ---- Packet accounting, paper Sec. 2.1.3 ------------------------------
    def load_default(self) -> np.ndarray:
        """D operation per-node load: 2*RT_i - 1 packets/epoch."""
        return 2 * self.subtree_sizes() - 1

    def load_aggregation(self, q: int = 1) -> np.ndarray:
        """A operation per-node load: q*(C_i + 1) packets/epoch."""
        return q * (self.children_counts() + 1)

    def load_feedback(self) -> np.ndarray:
        """F operation: 2 packets for non-leaves (recv+fwd), 1 for leaves."""
        counts = self.children_counts()
        load = np.where(counts > 0, 2, 1)
        load[self.root] = 1  # root only transmits downward (receives from sink)
        return load.astype(np.int64)


@dataclasses.dataclass(frozen=True)
class SensorTopology:
    """Sensor positions + radio-range neighborhood graph + routing tree."""

    positions: np.ndarray        # (p, 2)
    radio_range: float
    adjacency: np.ndarray        # (p, p) bool, no self loops
    tree: RoutingTree

    @property
    def p(self) -> int:
        return int(self.positions.shape[0])

    def neighborhoods(self) -> list[np.ndarray]:
        """N_i for every node (indices, excluding i)."""
        return [np.nonzero(self.adjacency[i])[0] for i in range(self.p)]

    def neighborhood_sizes(self) -> np.ndarray:
        return self.adjacency.sum(axis=1).astype(np.int64)

    def covariance_mask(self) -> np.ndarray:
        """Local covariance hypothesis mask: allowed (i, j) entries.

        c_ij is kept iff j in N_i or j == i (paper Sec. 3.3).
        """
        return self.adjacency | np.eye(self.p, dtype=bool)

    def load_covariance_update(self) -> np.ndarray:
        """Per-epoch load of the distributed covariance update (Sec. 3.3.2).

        Node i sends 1 packet (its measurement, local broadcast) and receives
        |N_i| packets.
        """
        return 1 + self.neighborhood_sizes()

    def load_pim_iteration(self, k: int = 1) -> np.ndarray:
        """Per-node load of one distributed PIM iteration for component k.

        Sec. 3.4.5: Cv needs 1 send + |N_i| receives;  the normalization is one
        A + one F op; the orthogonalization against the k-1 previous
        eigenvectors is k-1 A ops + k-1 F ops (partial state records of size
        k-1 counted element-wise, as in the paper's q^2 term).
        """
        halo = 1 + self.neighborhood_sizes()
        agg = self.tree.load_aggregation(q=1) + self.tree.load_feedback()
        return halo + k * agg

    def load_pim_total(self, q: int, iters_per_component: Sequence[int]) -> np.ndarray:
        """Total PIM load for extracting q components (paper Fig. 14)."""
        if len(iters_per_component) != q:
            raise ValueError("need one iteration count per component")
        total = np.zeros(self.p, dtype=np.int64)
        for k in range(1, q + 1):
            total += iters_per_component[k - 1] * self.load_pim_iteration(k=k)
        return total


def _bfs_depths(adj: np.ndarray, root: int) -> np.ndarray:
    p = adj.shape[0]
    depth = np.full(p, -1, dtype=np.int64)
    depth[root] = 0
    dq = deque([root])
    while dq:
        u = dq.popleft()
        for v in np.nonzero(adj[u])[0]:
            if depth[v] < 0:
                depth[v] = depth[u] + 1
                dq.append(v)
    return depth


def build_topology(positions: np.ndarray, radio_range: float,
                   root: int | None = None) -> SensorTopology:
    """Build the neighborhood graph and shortest-path routing tree (Sec. 4.2).

    The root defaults to the sensor closest to the top-right corner of the
    bounding box (the paper's sink-connected node in Fig. 6).
    Raises if the graph is disconnected at this radio range (the paper's
    minimum viable range is the smallest r that connects all sensors).
    """
    positions = np.asarray(positions, dtype=np.float64)
    p = positions.shape[0]
    d2 = ((positions[:, None, :] - positions[None, :, :]) ** 2).sum(-1)
    adj = d2 <= radio_range ** 2
    np.fill_diagonal(adj, False)

    if root is None:
        corner = positions.max(axis=0)
        root = int(np.argmin(((positions - corner) ** 2).sum(axis=1)))

    depth = _bfs_depths(adj, root)
    if (depth < 0).any():
        missing = int((depth < 0).sum())
        raise ValueError(
            f"radio range {radio_range} leaves {missing} sensors disconnected")

    # Shortest-path parent choice: in-range node with smallest depth, ties by
    # Euclidean distance to the root (Sec. 4.2's 'closest to the base station').
    parent = np.full(p, -1, dtype=np.int64)
    droot = ((positions - positions[root]) ** 2).sum(axis=1)
    for i in range(p):
        if i == root:
            continue
        nbrs = np.nonzero(adj[i])[0]
        up = nbrs[depth[nbrs] == depth[i] - 1]
        parent[i] = int(up[np.argmin(droot[up])])

    tree = RoutingTree(parent=parent, root=root, depth=depth)
    return SensorTopology(positions=positions, radio_range=float(radio_range),
                          adjacency=adj, tree=tree)


def repair_tree(topo: SensorTopology,
                alive: np.ndarray) -> tuple[RoutingTree, np.ndarray]:
    """Rebuild the routing tree on the alive subgraph (Sec. 4.2 re-run).

    When nodes die, the subtrees they carried are orphaned.  Repair re-applies
    the paper's tree-construction rule on the subgraph induced by ``alive``:
    BFS depths from the root over alive nodes only, then every alive node
    re-attaches to the in-range *alive* parent one hop closer to the root,
    ties broken by Euclidean distance to the root — exactly how the original
    tree was built, so a fault-free repair is a no-op.

    Returns ``(tree, attached)``.  ``attached[i]`` marks alive nodes with a
    radio path to the root; alive-but-unreachable nodes (their only routes
    ran through dead nodes) are *network-dead*: ``parent == -2``,
    ``depth == -1``, and they take no part in aggregation until a revival
    reconnects them.  Raises if the root itself is dead — there is no tree
    to repair, the network is gone.
    """
    alive = np.asarray(alive, dtype=bool)
    if alive.shape != (topo.p,):
        raise ValueError(f"alive mask shape {alive.shape} != ({topo.p},)")
    root = topo.tree.root
    if not alive[root]:
        raise ValueError("root (sink-connected node) is dead; no repair possible")

    adj = topo.adjacency & alive[None, :] & alive[:, None]
    depth = _bfs_depths(adj, root)
    attached = depth >= 0

    parent = np.full(topo.p, -2, dtype=np.int64)
    parent[root] = -1
    droot = ((topo.positions - topo.positions[root]) ** 2).sum(axis=1)
    for i in range(topo.p):
        if i == root or not attached[i]:
            continue
        nbrs = np.nonzero(adj[i])[0]
        up = nbrs[depth[nbrs] == depth[i] - 1]
        parent[i] = int(up[np.argmin(droot[up])])

    return RoutingTree(parent=parent, root=root, depth=depth), attached


def bandwidth_reduce(adjacency: np.ndarray) -> np.ndarray:
    """Reverse Cuthill-McKee ordering of a neighborhood graph.

    Returns a permutation ``perm`` such that relabelling sensors by ``perm``
    concentrates the covariance mask near the diagonal — this is the bridge
    from the paper's irregular WSN graph to the banded layout used by the TPU
    kernels (DESIGN.md Sec. 2.1).
    """
    p = adjacency.shape[0]
    degrees = adjacency.sum(axis=1)
    visited = np.zeros(p, dtype=bool)
    order: list[int] = []
    while len(order) < p:
        # lowest-degree unvisited seed
        seed = int(np.argmin(np.where(visited, p + 1, degrees)))
        visited[seed] = True
        dq = deque([seed])
        order.append(seed)
        while dq:
            u = dq.popleft()
            nbrs = np.nonzero(adjacency[u] & ~visited)[0]
            nbrs = nbrs[np.argsort(degrees[nbrs], kind="stable")]
            for v in nbrs:
                visited[v] = True
                order.append(int(v))
                dq.append(int(v))
    return np.array(order[::-1], dtype=np.int64)


def graph_bandwidth(adjacency: np.ndarray, perm: np.ndarray | None = None) -> int:
    """Bandwidth of the adjacency under an ordering (max |i-j| over edges)."""
    adj = adjacency
    if perm is not None:
        adj = adj[np.ix_(perm, perm)]
    ii, jj = np.nonzero(adj)
    if ii.size == 0:
        return 0
    return int(np.abs(ii - jj).max())
