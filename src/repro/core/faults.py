"""Fault models for the WSN simulation: lossy links, node churn, dropout.

The paper's aggregation-service framing (Sec. 2.1) assumes every D/A/F
packet arrives.  Real deployments do not: the Intel-Berkeley trace the paper
compresses is full of holes, and the faulty-sensor literature (Gupchup et
al.; Johard et al., PAPERS.md) treats packet loss and node death as the
normal operating regime.  This module is the single source of truth for the
three fault classes the reproduction simulates:

* **per-link packet loss** — each transmission on a radio link independently
  fails with probability ``link_loss``; senders retransmit up to
  ``max_retries`` times (per-hop ARQ, data packets counted, acks free);
* **node churn** — a :class:`NodeChurn` schedule of (round, node) deaths and
  revivals, materialized as a per-round boolean liveness matrix; dead nodes
  neither measure nor route (routing-tree repair:
  :func:`repro.core.topology.repair_tree`);
* **measurement dropout** — individual sensor readings missing at a given
  rate (a flaky ADC rather than a dead mote), masking single (epoch, sensor)
  entries of a measurement block.

Everything is driven by ``numpy.random.Generator`` streams seeded by the
caller, so a fault schedule is a pure function of its seed — the property
the engine-determinism test (tests/test_streaming.py) and the differential
tests (tests/test_faults.py) rely on.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

__all__ = ["FaultModel", "NodeChurn", "expected_transmissions",
           "death_wave", "dropout_mask"]


def expected_transmissions(link_loss: float, max_retries: int) -> float:
    """Mean transmissions per packet under per-hop ARQ with capped retries.

    Attempt k+1 happens iff the first k attempts all failed, so
    ``E = sum_{k=0}^{max_retries} link_loss^k = (1 - loss^(r+1)) / (1 - loss)``.
    This is the factor by which a lossy deployment's *booked* communication
    exceeds the reliable Table-1 figure (used by
    :func:`repro.core.costs.lossy_round_cost`).
    """
    if not 0.0 <= link_loss < 1.0:
        raise ValueError(f"link_loss must be in [0, 1), got {link_loss}")
    if link_loss == 0.0:
        return 1.0
    return float((1.0 - link_loss ** (max_retries + 1)) / (1.0 - link_loss))


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Per-link Bernoulli loss + retransmission policy + measurement dropout.

    ``link_loss`` is the per-transmission failure probability of one radio
    hop; ``max_retries`` caps retransmissions (so a packet is dropped for
    good with probability ``link_loss**(max_retries+1)``); ``dropout`` is the
    per-(epoch, sensor) probability that a measurement is missing.
    """

    link_loss: float = 0.0
    max_retries: int = 3
    dropout: float = 0.0

    def __post_init__(self) -> None:
        if not 0.0 <= self.link_loss < 1.0:
            raise ValueError(f"link_loss must be in [0, 1), got {self.link_loss}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not 0.0 <= self.dropout < 1.0:
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")

    @property
    def delivery_rate(self) -> float:
        """Probability a packet survives one hop within the retry budget."""
        return 1.0 - self.link_loss ** (self.max_retries + 1)

    def expected_transmissions(self) -> float:
        return expected_transmissions(self.link_loss, self.max_retries)

    def transmit(self, rng: np.random.Generator) -> tuple[bool, int]:
        """One hop: returns (delivered, attempts used).

        At ``link_loss == 0`` no randomness is consumed, so the zero-loss
        path is bit-identical to the reliable simulator (the differential
        test in tests/test_faults.py).
        """
        if self.link_loss == 0.0:
            return True, 1
        for attempt in range(1, self.max_retries + 2):
            if rng.random() >= self.link_loss:
                return True, attempt
        return False, self.max_retries + 1


@dataclasses.dataclass(frozen=True)
class NodeChurn:
    """Death/revival schedule: node ``i`` flips state at the listed round.

    ``deaths``/``revivals`` are (round, node) pairs; a node may die and
    revive repeatedly (battery swap).  Rounds are the streaming subsystem's
    epoch-synchronous unit (DESIGN.md Sec. 8.1).
    """

    deaths: tuple[tuple[int, int], ...] = ()
    revivals: tuple[tuple[int, int], ...] = ()

    def liveness(self, p: int, n_rounds: int) -> np.ndarray:
        """(n_rounds, p) boolean liveness matrix; all-alive before round 0."""
        alive = np.ones(p, dtype=bool)
        events: dict[int, list[tuple[int, bool]]] = {}
        for r, node in self.deaths:
            events.setdefault(r, []).append((node, False))
        for r, node in self.revivals:
            events.setdefault(r, []).append((node, True))
        out = np.empty((n_rounds, p), dtype=bool)
        for r in range(n_rounds):
            for node, state in events.get(r, ()):
                alive[node] = state
            out[r] = alive
        return out


def death_wave(rng: np.random.Generator, p: int, *, round: int,
               fraction: float, spare: Iterable[int] = (),
               revive_round: int | None = None) -> NodeChurn:
    """A correlated failure: ``fraction`` of the nodes die at ``round``.

    ``spare`` nodes (typically the routing root) never die.  If
    ``revive_round`` is given the wave's victims all come back then —
    the battery-swap scenario of examples/faulty_fleet.py.
    """
    spare_set = set(int(s) for s in spare)
    candidates = np.array([i for i in range(p) if i not in spare_set])
    n_dead = min(int(np.ceil(fraction * p)), candidates.size)
    victims = rng.choice(candidates, size=n_dead, replace=False)
    deaths = tuple((round, int(v)) for v in np.sort(victims))
    revivals = ()
    if revive_round is not None:
        if revive_round <= round:
            raise ValueError("revive_round must come after the wave")
        revivals = tuple((revive_round, int(v)) for v in np.sort(victims))
    return NodeChurn(deaths=deaths, revivals=revivals)


def dropout_mask(rng: np.random.Generator, shape: tuple[int, ...],
                 dropout: float) -> np.ndarray:
    """Boolean keep-mask for measurement dropout (True = reading present)."""
    if dropout == 0.0:
        return np.ones(shape, dtype=bool)
    return rng.random(shape) >= dropout
