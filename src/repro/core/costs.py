"""Cost models (paper Sec. 2.1.3, 3.2.1, 3.3.2, 3.4.5 and Table 1).

Closed-form communication / computation / memory costs for the centralized
and distributed variants, parameterized by

*  p      — network size,
*  T      — number of training epochs used for the covariance,
*  q      — number of principal components,
*  n_max  — |N_{i*}|, largest neighborhood size,
*  c_max  — C_{i*}, largest number of routing-tree children,
*  iters  — PIM iterations per component.

These formulas are validated against *actual packet counts* from the
routing-tree simulator in tests/test_costs.py, and drive the Fig. 9/10/12/14
benchmarks.  The TPU analogue of each quantity is noted inline.
"""

from __future__ import annotations

import dataclasses

__all__ = ["CostReport", "centralized_covariance", "distributed_covariance",
           "centralized_eigenvectors", "distributed_eigenvectors",
           "streaming_round_cost", "streaming_refresh_cost",
           "supervised_round_cost", "quantized_supervised_round_cost",
           "detection_round_cost", "merge_record_elems", "merge_round_cost",
           "lossy_merge_cost",
           "lossy_round_cost", "lossy_refresh_cost", "lossy_epoch_load",
           "pcag_epoch_load", "default_epoch_load", "table1"]


@dataclasses.dataclass(frozen=True)
class CostReport:
    communication: float   # highest per-node network load (packets)
    computation: float     # highest per-node flop count (order)
    memory: float          # highest per-node storage (scalars)


def centralized_covariance(p: int, T: int) -> CostReport:
    """Sec. 3.2.1: T default collections; O(T p^2) flops at the base station."""
    return CostReport(communication=T * p, computation=T * p * p, memory=p * p)


def distributed_covariance(n_max: int, T: int) -> CostReport:
    """Sec. 3.3.2: per epoch 1 send + |N_i| receives; O(|N_i|) flops/memory."""
    return CostReport(communication=T * (n_max + 1), computation=T * n_max,
                      memory=2 * n_max + 1)


def centralized_eigenvectors(p: int, q: int) -> CostReport:
    """Sec. 3.2.1: O(p^3) eigendecomposition; qp feedback packets."""
    return CostReport(communication=q * p, computation=p ** 3, memory=p * p)


def distributed_eigenvectors(p: int, q: int, n_max: int, c_max: int,
                             iters: int = 20) -> CostReport:
    """Sec. 3.4.5: per iteration of component k —
    Cv: 1 send + n_max receives;  normalization: 1 A + 1 F;
    orthogonalization: (k-1) A + (k-1) F   (record elements counted).
    Highest load O(q |N*| + q^2 C*); computation O(q(|N*| + C*));
    memory O(q + |N*|)."""
    comm = 0.0
    for k in range(1, q + 1):
        per_iter = (n_max + 1) + k * (c_max + 1 + 2)
        comm += iters * per_iter
    comp = iters * q * (n_max + q * c_max)
    mem = q + n_max
    return CostReport(communication=comm, computation=comp, memory=mem)


def streaming_round_cost(n_max: int, q: int, c_max: int) -> CostReport:
    """One streaming round (DESIGN.md Sec. 8.3): covariance fold + drift probe.

    Per round each node performs the Sec.-3.3 covariance exchange (1 send +
    |N_i| receives) and contributes to ONE aggregation of the drift statistic
    ``(trace(W^T C W), trace(C))`` — a (q+1)-element record up the tree plus
    the scalar verdict flooded back.
    """
    return CostReport(
        communication=(n_max + 1) + (q + 1) * (c_max + 1) + 1,
        computation=n_max + q * n_max,        # band fold + banded C W rows
        memory=2 * n_max + 1 + q,
    )


def streaming_refresh_cost(p: int, q: int, n_max: int, c_max: int,
                           iters: int) -> CostReport:
    """One scheduled basis refresh by blocked orthogonal iteration.

    Per iteration: CV for all q columns (q sends + q n_max receives, the
    neighbor broadcast carries the full q-vector), the Gram matrix as ONE
    aggregation of a q^2-element record (vs. Algorithm 2's k separate A/F
    rounds), and the flood of the q x q factor back down.  After convergence
    the new basis is flooded to the network: q p feedback packets total,
    q (C*+1) at the highest-loaded node (the PCAg feedback path, Eq. 7).
    """
    per_iter = q * (n_max + 1) + q * q * (c_max + 1) + q * q
    feedback = q * (c_max + 1)
    return CostReport(
        communication=iters * per_iter + feedback,
        computation=iters * q * (n_max + q * c_max) + q * q * p,
        memory=2 * q + n_max,
    )


def supervised_round_cost(q: int, c_max: int,
                          flagged: float = 0.0) -> CostReport:
    """One supervised-compression epoch (Sec. 2.4.1), highest-node load.

    The scores travel as one PCAg aggregation up the tree and one feedback
    flood back down — ``q (C* + 1)`` packets each at the highest-loaded
    node (Eq. 7 twice) — plus the flagged raw measurements.  ``flagged`` is
    the number of notifications this epoch: every flagged raw is forwarded
    to the sink, so the root (the highest-loaded node for extras) processes
    all of them.  Computation per node: q multiplies for the init record +
    q for the local reconstruction + the error test; memory: the node's
    basis row, the fed-back scores, its mean and eps.
    """
    return CostReport(
        communication=2 * q * (c_max + 1) + flagged,
        computation=2 * q + 1,
        memory=2 * q + 2,
    )


def quantized_supervised_round_cost(q: int, c_max: int, bits: int,
                                    word_bits: int = 32,
                                    flagged: float = 0.0) -> CostReport:
    """Supervised epoch with ``bits``-wide quantized scores (bit budget).

    The accuracy-vs-bits tradeoff of "Self-adaptive node-based PCA
    encodings" (PAPERS.md): each score on the A and F paths costs
    ``bits / word_bits`` of a full packet, while flagged raw measurements
    stay full-word.  The quantizer re-derives its q per-component scales
    from every round's scores, so the F flood additionally carries q
    full-precision scale words each round — ``q (C* + 1)`` word-packets at
    the highest-loaded node — which caps the useful width: quantization
    beats full precision only below ``word_bits / 2`` bits.  ``bits == 0``
    means unquantized and reproduces :func:`supervised_round_cost` exactly.
    """
    if bits == 0:
        return supervised_round_cost(q, c_max, flagged)
    base = supervised_round_cost(q, c_max, 0.0)
    scale_flood = q * (c_max + 1)
    return CostReport(
        communication=(base.communication * (bits / word_bits)
                       + scale_flood + flagged),
        computation=base.computation + 2 * q,   # encode + decode per node
        memory=base.memory + q,                 # per-component scales
    )


def detection_round_cost(q: int, c_max: int,
                         alarms: float = 0.0) -> CostReport:
    """One Sec.-2.4.3 monitoring epoch, highest-node load.

    The T²/SPE verdict rides the streaming drift probe: the per-round
    (q+1)-element A record of :func:`streaming_round_cost` grows by ONE
    scalar — the node-local residual-energy partial (T² needs only the
    scores already aggregated for the drift statistic) — so the marginal
    flag-free communication is one record element through ``C* + 1``
    packets at the highest-loaded node.  Each alarmed epoch additionally
    floods one F notification (a scalar alarm verdict) back down the tree:
    ``C* + 1`` more packets per alarm at the highest node.  ``alarms`` is
    the number of alarmed epochs this round (the per-event F flood — the
    extras analogue of :func:`supervised_round_cost`'s flagged raws).

    Computation per node: q multiplies against the fed-back inverse
    eigenvalue record plus the local residual square-and-add and the two
    threshold tests; memory: the q inverse eigenvalues plus the two
    thresholds.
    """
    return CostReport(
        communication=(c_max + 1) * (1.0 + alarms),
        computation=2 * q + 3,
        memory=q + 2,
    )


def merge_record_elems(q_local: int) -> int:
    """Elements of ONE region's merge record: its ``q_local`` per-component
    subspace energies ``diag(W^T C W)`` plus the total-variance partial
    ``trace(C)``.  This is the unit :func:`merge_round_cost` bills per
    aggregation packet AND the quantity the static resource certifier
    (:class:`repro.analysis.resources.WireBytesBudget`) reconciles against
    the traced merge collectives' shapes — booked == traced, so the packet
    ledger and the wire cannot drift apart silently."""
    return q_local + 1


def merge_round_cost(q_local: int, c_regions: int) -> CostReport:
    """One fleet-level merge epoch of the two-level hierarchy (DESIGN.md
    Sec. 13), highest-region-head load.

    The region heads aggregate ONE (q_local + 1)-element record up the
    region-level routing tree — the region's per-component subspace energies
    ``diag(W^T C W)`` plus its total-variance partial ``trace(C)``, exactly
    the quantities the intra-network drift probe already aggregates
    (:func:`streaming_round_cost`) one level down — and the sink floods one
    scalar back (the global selection threshold λ_min: a region keeps a
    component in the fleet basis iff its energy clears it).  So the
    highest-loaded region head processes ``(q_local + 1) (C_r* + 1)``
    aggregation packets plus the scalar verdict, the same shape as the
    intra-network round bill.

    Computation per region head: merging ``C_r*`` children records of
    ``q_local + 1`` elements; memory: its own record plus the threshold.
    """
    record = merge_record_elems(q_local)
    return CostReport(
        communication=record * (c_regions + 1) + 1,
        computation=record * c_regions,
        memory=record + 1,
    )


def lossy_merge_cost(q_local: int, c_regions: int, link_loss: float,
                     max_retries: int) -> CostReport:
    """Expected fleet-merge cost over lossy region-head links (the same ARQ
    scaling as :func:`lossy_round_cost`; zero loss books the reliable
    figure exactly)."""
    from repro.core.faults import expected_transmissions
    return _scale(merge_round_cost(q_local, c_regions),
                  expected_transmissions(link_loss, max_retries))


def _scale(report: CostReport, factor: float) -> CostReport:
    """Communication scaled by a retransmission factor; compute/memory keep
    their reliable-path order (ARQ costs radio, not flops)."""
    return CostReport(communication=report.communication * factor,
                      computation=report.computation,
                      memory=report.memory)


def lossy_round_cost(n_max: int, q: int, c_max: int, link_loss: float,
                     max_retries: int) -> CostReport:
    """Expected streaming-round cost over lossy links.

    Every data packet of the reliable round (:func:`streaming_round_cost`)
    is retransmitted per-hop until delivered or the retry budget runs out,
    so the expected bill is the reliable bill times
    ``E[transmissions] = (1 - loss^(r+1)) / (1 - loss)``
    (:func:`repro.core.faults.expected_transmissions`).  At ``loss == 0``
    this is exactly the reliable cost — the differential anchor.
    """
    from repro.core.faults import expected_transmissions
    return _scale(streaming_round_cost(n_max, q, c_max),
                  expected_transmissions(link_loss, max_retries))


def lossy_refresh_cost(p: int, q: int, n_max: int, c_max: int, iters: int,
                       link_loss: float, max_retries: int) -> CostReport:
    """Expected basis-refresh cost over lossy links (see lossy_round_cost)."""
    from repro.core.faults import expected_transmissions
    return _scale(streaming_refresh_cost(p, q, n_max, c_max, iters),
                  expected_transmissions(link_loss, max_retries))


def lossy_epoch_load(tree, record_sizes, attempts, delivered,
                     active) -> "np.ndarray":
    """Exact per-node packets of one lossy A epoch from its transcript.

    Books, per node: ``size_i * attempts_i`` transmissions on the parent hop
    plus ``size_c`` received packets for each *delivered* child ``c`` (failed
    attempts never reach the parent's radio), plus the root's wired uplink.
    By construction this equals the packet counts the simulator
    (:func:`repro.core.aggregation.lossy_aggregate_tree`) reports — the
    booked-equals-counted property in tests/test_properties.py; at zero loss
    with scalar records it collapses to ``q (C_i + 1)`` (Sec. 2.1.3).
    """
    import numpy as np
    record_sizes = np.asarray(record_sizes, dtype=np.int64)
    attempts = np.asarray(attempts, dtype=np.int64)
    delivered = np.asarray(delivered, dtype=bool)
    active = np.asarray(active, dtype=bool)
    load = record_sizes * attempts                       # tx on the parent hop
    for i in range(tree.p):
        par = int(tree.parent[i])
        if par >= 0 and active[i] and delivered[i]:
            load[par] += record_sizes[i]                 # rx at the parent
    load[tree.root] += record_sizes[tree.root]           # wired sink uplink
    return load


def default_epoch_load(p: int) -> int:
    """Highest per-node load of the D scheme: the root processes 2p-1."""
    return 2 * p - 1


def pcag_epoch_load(q: int, c_max: int) -> int:
    """Highest per-node load of the PCAg scheme: q (C* + 1)  (Eq. 7)."""
    return q * (c_max + 1)


def pcag_beats_default(q: int, c_max: int, p: int) -> bool:
    """Eq. (7): q (C* + 1) <= 2p - 1."""
    return pcag_epoch_load(q, c_max) <= default_epoch_load(p)


def table1(p: int, T: int, q: int, n_max: int, c_max: int,
           iters: int = 20) -> dict[str, CostReport]:
    """The four rows of Table 1."""
    return {
        "covariance/centralized": centralized_covariance(p, T),
        "covariance/distributed": distributed_covariance(n_max, T),
        "eigenvectors/centralized": centralized_eigenvectors(p, q),
        "eigenvectors/distributed": distributed_eigenvectors(p, q, n_max,
                                                             c_max, iters),
    }
