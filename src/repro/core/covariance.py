"""Streaming covariance estimation (paper Sec. 3.2-3.3).

Two layouts are provided:

* **Masked dense** (:class:`CovState`) — the paper's WSN formulation: the full
  ``p x p`` matrix with the *local covariance hypothesis* mask
  ``c_ij = 0 for j not in N_i`` (Sec. 3.3).  Used for the 52-sensor experiments
  and as the oracle for the banded kernels.
* **Banded** (:class:`BandedCovState`) — the TPU-native regularization
  (DESIGN.md Sec. 2.1): after a bandwidth-reducing relabelling, the mask is a
  band of half-width ``h`` and the matrix is stored as ``2h+1`` diagonals of
  length ``p``.  This is the layout consumed by ``repro.kernels.banded_matvec``
  and ``repro.kernels.cov_update`` and by the halo-exchange distributed path.

Both maintain the sufficient statistics of Eq. (9)-(10):
``t``, ``S_i = sum_tau x_i[tau]`` and ``S_ij = sum_tau x_i[tau] x_j[tau]``,
so the covariance estimate ``c_ij = S_ij/t - S_i S_j / t^2`` can be updated
from measurement batches of any size.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CovState", "cov_init", "cov_update", "cov_estimate",
    "BandedCovState", "banded_init", "banded_update", "banded_estimate",
    "band_to_dense", "dense_to_band", "banded_matvec_ref", "banded_matmul_ref",
    "mask_from_band",
]


# --------------------------------------------------------------------------
# Masked dense layout (paper-faithful)
# --------------------------------------------------------------------------
class CovState(NamedTuple):
    t: jnp.ndarray          # () scalar, number of epochs seen
    s: jnp.ndarray          # (p,)   S_i
    sxy: jnp.ndarray        # (p, p) S_ij, only entries allowed by the mask
    mask: jnp.ndarray       # (p, p) bool; True where c_ij may be nonzero


def cov_init(p: int, mask: np.ndarray | jnp.ndarray | None = None,
             dtype=jnp.float32) -> CovState:
    if mask is None:
        mask = jnp.ones((p, p), dtype=bool)
    mask = jnp.asarray(mask, dtype=bool)
    return CovState(
        t=jnp.zeros((), dtype=dtype),
        s=jnp.zeros((p,), dtype=dtype),
        sxy=jnp.zeros((p, p), dtype=dtype),
        mask=mask,
    )


def cov_update(state: CovState, x: jnp.ndarray) -> CovState:
    """Fold a batch ``x`` of shape (n, p) into the sufficient statistics.

    Equivalent to n applications of the paper's per-epoch recursion Eq. (10).
    The masked entries of S_ij are never materialized as communication in the
    distributed setting; here we compute the full outer product and mask, which
    is the correct oracle semantics.
    """
    x = jnp.asarray(x, dtype=state.s.dtype)
    n = x.shape[0]
    sxy = state.sxy + jnp.where(state.mask, x.T @ x, 0.0)
    return CovState(t=state.t + n, s=state.s + x.sum(axis=0), sxy=sxy,
                    mask=state.mask)


def cov_estimate(state: CovState) -> jnp.ndarray:
    """Eq. (9): c_ij = S_ij/t - S_i S_j / t^2, masked."""
    t = jnp.maximum(state.t, 1.0)
    c = state.sxy / t - jnp.outer(state.s, state.s) / (t * t)
    return jnp.where(state.mask, c, 0.0)


# --------------------------------------------------------------------------
# Banded layout (TPU-native)
# --------------------------------------------------------------------------
class BandedCovState(NamedTuple):
    t: jnp.ndarray          # ()
    s: jnp.ndarray          # (p,)
    band: jnp.ndarray       # (2h+1, p): band[k, i] = S_{i, i+k-h}
    halfwidth: int


def banded_init(p: int, halfwidth: int, dtype=jnp.float32) -> BandedCovState:
    return BandedCovState(
        t=jnp.zeros((), dtype=dtype),
        s=jnp.zeros((p,), dtype=dtype),
        band=jnp.zeros((2 * halfwidth + 1, p), dtype=dtype),
        halfwidth=halfwidth,
    )


def _shifted(x: jnp.ndarray, offset: int) -> jnp.ndarray:
    """Column j of result = x[:, j+offset], zero-padded out of range."""
    p = x.shape[-1]
    rolled = jnp.roll(x, -offset, axis=-1)
    j = jnp.arange(p)
    valid = (j + offset >= 0) & (j + offset < p)
    return jnp.where(valid, rolled, 0.0)


def banded_update(state: BandedCovState, x: jnp.ndarray) -> BandedCovState:
    """Banded version of Eq. (10): band[k,i] += sum_t x[t,i] x[t,i+k-h]."""
    x = jnp.asarray(x, dtype=state.s.dtype)
    h = state.halfwidth

    def one_offset(k):
        return jnp.sum(x * _shifted(x, k - h), axis=0)

    delta = jnp.stack([one_offset(k) for k in range(2 * h + 1)], axis=0)
    return BandedCovState(t=state.t + x.shape[0], s=state.s + x.sum(axis=0),
                          band=state.band + delta, halfwidth=h)


def banded_estimate(state: BandedCovState) -> jnp.ndarray:
    """Banded covariance diagonals: c_band[k,i] = C[i, i+k-h]."""
    t = jnp.maximum(state.t, 1.0)
    h = state.halfwidth
    mean_term = jnp.stack(
        [state.s * _shifted(state.s[None, :], k - h)[0] for k in range(2 * h + 1)],
        axis=0)
    band = state.band / t - mean_term / (t * t)
    # zero out-of-range entries explicitly
    p = state.s.shape[0]
    j = jnp.arange(p)[None, :]
    k = jnp.arange(2 * h + 1)[:, None]
    valid = (j + k - h >= 0) & (j + k - h < p)
    return jnp.where(valid, band, 0.0)


def band_to_dense(band: jnp.ndarray) -> jnp.ndarray:
    """(2h+1, p) diagonals -> dense (p, p)."""
    nb, p = band.shape
    h = (nb - 1) // 2
    out = jnp.zeros((p, p), dtype=band.dtype)
    for k in range(nb):
        off = k - h
        diag = band[k]
        i = jnp.arange(p)
        j = i + off
        valid = (j >= 0) & (j < p)
        out = out.at[i[valid], j[valid]].set(diag[valid])
    return out


def dense_to_band(c: jnp.ndarray, halfwidth: int) -> jnp.ndarray:
    """Dense (p, p) -> (2h+1, p) diagonals (entries outside the band dropped)."""
    p = c.shape[0]
    h = halfwidth
    rows = []
    i = jnp.arange(p)
    for k in range(2 * h + 1):
        j = i + (k - h)
        valid = (j >= 0) & (j < p)
        jc = jnp.clip(j, 0, p - 1)
        rows.append(jnp.where(valid, c[i, jc], 0.0))
    return jnp.stack(rows, axis=0)


def mask_from_band(p: int, halfwidth: int) -> np.ndarray:
    """Dense bool mask equivalent to a band of half-width h."""
    i = np.arange(p)
    return np.abs(i[:, None] - i[None, :]) <= halfwidth


def banded_matvec_ref(band: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """(Cv)[i] = sum_k band[k,i] * v[i+k-h] — the paper's neighbor-local Cv."""
    nb, p = band.shape
    h = (nb - 1) // 2
    acc = jnp.zeros_like(v)
    for k in range(nb):
        acc = acc + band[k] * _shifted(v[None, :], k - h)[0]
    return acc


def banded_matmul_ref(band: jnp.ndarray, V: jnp.ndarray) -> jnp.ndarray:
    """C @ V for V of shape (p, q) — the blocked orthogonal-iteration variant."""
    nb, p = band.shape
    h = (nb - 1) // 2
    acc = jnp.zeros_like(V)
    for k in range(nb):
        acc = acc + band[k][:, None] * _shifted(V.T, k - h).T
    return acc
