"""Production-scale distributed PCA steps (the paper's system on a pod).

The feature axis (p "virtual sensors" — e.g. per-channel telemetry streams
of a fleet) is sharded over every chip of the mesh; the banded covariance
(local covariance hypothesis after bandwidth reduction — DESIGN.md Sec. 2.1)
is stored as 2h+1 diagonals sharded the same way.

Under jit + GSPMD:
* the shifted products of the banded ops become **collective-permute** halo
  exchanges with the ±1 ring neighbors (the paper's neighbor broadcast),
* the Gram matrix / norms become **all-reduce** (the paper's A+F tree ops),
* nothing else crosses chips — exactly the paper's communication structure.

Step functions lowered by the dry-run:
    cov_update_step      Eq. (10) streaming update from an epoch batch
    pim_block_step       one blocked orthogonal-iteration round (optimized)
    pim_deflated_step    one deflated single-vector PIM round (paper-faithful)
    transform_step       PCAg scores for an epoch batch (Eq. 6)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import covariance as cov

__all__ = ["cov_update_step", "pim_block_step", "pim_deflated_step",
           "transform_step"]


def cov_update_step(state: cov.BandedCovState,
                    x: jnp.ndarray) -> cov.BandedCovState:
    """Fold an (n, p) epoch batch into the banded sufficient statistics."""
    return cov.banded_update(state, x)


def pim_block_step(band: jnp.ndarray, v: jnp.ndarray,
                   eps: float = 1e-8) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One blocked orthogonal-iteration round (beyond-paper variant).

    v: (p, q).  Returns (v_next orthonormal, Rayleigh eigenvalue estimates).
    The Gram matrix is the single A+F aggregation of the round (q^2 scalars).

    Perf note (EXPERIMENTS.md Sec. Perf, hillclimb 1): the orthonormalization
    is written as ``CV @ inv(L)^T`` with the inverse taken on the tiny
    replicated (q, q) Cholesky factor — a row-local matmul on the sharded
    feature axis.  The equivalent ``triangular_solve(L, CV^T)`` made GSPMD
    all-gather the full (p, q) iterate (128 MiB/device at p=1M), turning the
    paper's neighbor-local algorithm collective-bound.
    """
    q = v.shape[1]
    cv = cov.banded_matmul_ref(band, v)              # halo exchanges
    g = cv.T @ cv                                    # -> all-reduce (q x q)
    l = jnp.linalg.cholesky(g + eps * jnp.eye(q, dtype=v.dtype))
    l_inv = jnp.linalg.inv(l)                        # replicated small matrix
    v_next = cv @ l_inv.T                            # row-local
    rayleigh = jnp.diag(v.T @ cv)
    return v_next, rayleigh


def pim_deflated_step(band: jnp.ndarray, v: jnp.ndarray,
                      w_prev: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One paper-faithful Algorithm-2 inner iteration for one component.

    v: (p,); w_prev: (p, k-1) previously found components.  Performs
    Cv (halo exchange), deflation dot products + norm (the paper's k-1 A ops
    + 1 A op, fused by XLA into reductions), normalization.
    Returns (v_next, eigenvalue_estimate).
    """
    cv = cov.banded_matvec_ref(band, v)
    coeff = w_prev.T @ cv                            # k-1 scalar products
    cv = cv - w_prev @ coeff
    nrm = jnp.sqrt(jnp.sum(cv * cv))
    sign = jnp.sign(jnp.sum(jnp.sign(v * cv)))       # paper's sign criterion
    return cv / jnp.maximum(nrm, 1e-30), sign * nrm


def transform_step(w: jnp.ndarray, mean: jnp.ndarray,
                   x: jnp.ndarray) -> jnp.ndarray:
    """PCAg scores Z = (X - mean) W for an (n, p) epoch batch.

    The contraction over the sharded p axis is the in-network aggregation:
    XLA lowers it to partial products + one all-reduce of (n, q) scores."""
    return (x - mean[None, :]) @ w
