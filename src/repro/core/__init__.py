"""Core library: the paper's contribution (distributed PCA for WSN) in JAX.

Submodules
----------
topology         sensor layouts, radio neighborhoods, routing trees
aggregation      init/f/e primitives, tree simulator, mesh D/A/F collectives
covariance       streaming covariance (masked dense + banded layouts)
power_iteration  Algorithms 1-3 (+ beyond-paper blocked orthogonal iteration)
pca              fit/transform orchestrator
compression      PCAg scores + supervised (+/- eps) compression
events           low-variance-component event detection
costs            Table-1 cost models (+ lossy-link booking)
faults           fault models: lossy links, node churn, measurement dropout
"""

from repro.core.pca import DistributedPCA, PCAResult, retained_variance

__all__ = ["DistributedPCA", "PCAResult", "retained_variance"]
