"""Model zoo: dense GQA / MoE / Mamba-2 SSD / hybrid / enc-dec families."""

from repro.models.transformer import (model_schema, init_params, forward,
                                      lm_loss, init_decode_state, decode_step,
                                      encode, prefill)

__all__ = ["model_schema", "init_params", "forward", "lm_loss",
           "init_decode_state", "decode_step", "encode", "prefill"]
