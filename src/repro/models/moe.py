"""Top-k MoE with sort-free gather dispatch (GShard semantics, dropless-ish).

Routing: softmax router, top-k experts per token, per-expert capacity
``C = ceil(T * k * capacity_factor / E)``; tokens beyond capacity are dropped
(weight 0) as in GShard [arXiv:2006.16668].  Dispatch avoids the O(T*E*C)
one-hot tensors: positions within each expert are computed with a cumulative
count, dispatch is a scatter-add into the (E, C, d) expert buffer and combine
is a gather back — O(T*k) index arrays only, which is what makes the 1M-token
train_4k cells feasible.

Experts shard over the "model" mesh axis (EP); the scatter/gather between the
token-sharded and expert-sharded layouts is partitioned by GSPMD into the
all-to-all exchanges of standard expert parallelism.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro.distributed.sharding import current_mesh, shard_activation
from repro.models.params import P

__all__ = ["moe_schema", "moe_apply"]


def moe_schema(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": P((d, e), ("embed", "experts"), fan_in_axes=(0,)),
        "w_gate": P((e, d, f), ("experts", "embed", "expert_mlp"),
                    fan_in_axes=(1,)),
        "w_up": P((e, d, f), ("experts", "embed", "expert_mlp"),
                  fan_in_axes=(1,)),
        "w_down": P((e, f, d), ("experts", "expert_mlp", "embed"),
                    fan_in_axes=(1,),
                    scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def _moe_ep_shard_map(p, cfg, x, top_p, top_e, mesh, dp_axes, nm, g=None):
    """Expert-parallel dispatch with manual collectives (shard_map).

    Under global-view GSPMD the token->expert scatter lowers to replicated
    (E*cap, d) buffers + all-reduce (measured: TBs/step — EXPERIMENTS.md
    Sec. Perf hillclimb 3).  Here every data shard dispatches its own tokens
    into a *local* per-expert buffer (capacity is per data shard, GShard
    group semantics), each model shard runs its E/nm experts, and the only
    cross-device traffic is ONE psum of the (T_local, d) combine output over
    the model axis — the same wire class as the TP MLP all-reduce.
    """
    E, K = cfg.n_experts, cfg.top_k
    B, S, d = x.shape
    g = nm if g is None else g
    e_loc = E // g                       # experts per subgroup
    dup = nm // g                        # ranks sharing a subgroup
    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    w_spec = PartitionSpec("model", None, None) if g == nm \
        else PartitionSpec(None, None, None)

    def body(xb, tp, te, wg, wu, wd):
        Bl = xb.shape[0]
        Tl = Bl * S
        cap = int(math.ceil(Tl * K * cfg.capacity_factor / E))
        xt = xb.reshape(Tl, d)
        flat_e = te.reshape(Tl, K).T.reshape(K * Tl)          # k-major
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
        keep = pos < cap
        dest = jnp.where(keep, flat_e * cap + pos, E * cap)
        token_of_slot = jnp.tile(jnp.arange(Tl), K)

        buf = jnp.zeros((E * cap + 1, d), xb.dtype)
        buf = buf.at[dest].add(xt[token_of_slot])             # local scatter
        weights = (tp.reshape(Tl, K).T.reshape(K * Tl) * keep).astype(xb.dtype)
        w_slot = jnp.zeros((E * cap + 1,), xb.dtype).at[dest].set(weights)
        tok_slot = jnp.full((E * cap + 1,), Tl, jnp.int32).at[dest].set(
            token_of_slot)

        j = jax.lax.axis_index("model")
        block = j % g                    # this rank's expert subgroup
        if g == nm:
            # weights arrive model-sharded: local slice IS the subgroup
            wg_b, wu_b, wd_b = wg, wu, wd
        else:
            wg_b = jax.lax.dynamic_slice_in_dim(wg, block * e_loc, e_loc, 0)
            wu_b = jax.lax.dynamic_slice_in_dim(wu, block * e_loc, e_loc, 0)
            wd_b = jax.lax.dynamic_slice_in_dim(wd, block * e_loc, e_loc, 0)
        my = jax.lax.dynamic_slice_in_dim(
            buf[:-1].reshape(E, cap, d), block * e_loc, e_loc, axis=0)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", my, wg_b)) \
            * jnp.einsum("ecd,edf->ecf", my, wu_b)
        out = jnp.einsum("ecf,efd->ecd", h, wd_b)             # (e_loc,cap,d)

        w_my = jax.lax.dynamic_slice_in_dim(
            w_slot[:-1].reshape(E, cap), block * e_loc, e_loc, axis=0)
        t_my = jax.lax.dynamic_slice_in_dim(
            tok_slot[:-1].reshape(E, cap), block * e_loc, e_loc, axis=0)
        scale = jnp.asarray(1.0 / dup, xb.dtype)              # de-duplicate
        y = jnp.zeros((Tl + 1, d), xb.dtype).at[t_my.reshape(-1)].add(
            out.reshape(-1, d) * (w_my.reshape(-1, 1) * scale))
        y = jax.lax.psum(y[:-1], "model")                     # the ONE AR
        return y.reshape(Bl, S, d)

    return shard_map(
        body, mesh=mesh,
        in_specs=(PartitionSpec(dp_spec, None, None),
                  PartitionSpec(dp_spec, None, None),
                  PartitionSpec(dp_spec, None, None),
                  w_spec, w_spec, w_spec),
        out_specs=PartitionSpec(dp_spec, None, None),
        check_rep=False,
    )(x, top_p, top_e, p["w_gate"], p["w_up"], p["w_down"])


def moe_apply(p: dict, cfg, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    cap = int(math.ceil(T * K * cfg.capacity_factor / E))

    xt = x.reshape(T, d)
    logits = (xt @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                   # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)   # renormalize

    # load-balancing aux loss (Switch/GShard)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E

    # ---- expert-parallel shard_map path (Sec. Perf hillclimb 3 fix) --------
    mesh = current_mesh()
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        nm = sizes.get("model", 1)
        dp_axes = tuple(a for a in mesh.axis_names if a != "model")
        ndp = int(np.prod([sizes[a] for a in dp_axes])) or 1
        # gcd subgroups: when E doesn't divide the model axis (granite:
        # 40 over 16), shard experts over g = gcd(E, nm) subgroups; each
        # expert block runs on nm/g ranks and its combine contribution is
        # rescaled by g/nm so the psum stays exact.
        g = math.gcd(E, nm)
        if nm > 1 and g > 1 and B % ndp == 0:
            y = _moe_ep_shard_map(p, cfg, x,
                                  top_p.reshape(B, S, K),
                                  top_e.reshape(B, S, K), mesh, dp_axes,
                                  nm, g)
            return y, aux

    # ---- capacity positions: rank of each (token, slot) within its expert --
    flat_e = top_e.reshape(T * K)                            # slot-major? no:
    # order slots k-major so earlier k (higher gate) wins capacity first
    flat_e = top_e.T.reshape(K * T)                          # (K*T,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (K*T, E)
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot      # rank before me
    pos = jnp.take_along_axis(pos_in_expert, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    dest = jnp.where(keep, flat_e * cap + pos, E * cap)      # E*cap = dropped

    # ---- dispatch: scatter tokens into the (E*cap, d) expert buffer --------
    # Perf note (EXPERIMENTS.md Sec. Perf hillclimb 3): under global-view
    # GSPMD, both this scatter-add and the gather-based alternative
    # (index-scatter + row-gather; measured) materialize replicated buffers
    # and all-reduce them — the structural fix is a shard_map dispatch with
    # explicit all-to-alls, recorded as the identified next step.
    token_of_slot = jnp.tile(jnp.arange(T), K)               # (K*T,)
    buf = jnp.zeros((E * cap + 1, d), x.dtype)
    buf = buf.at[dest].add(xt[token_of_slot])                # dup slots: rare
    buf = buf[:-1].reshape(E, cap, d)
    buf = shard_activation(buf, ("act_experts", "capacity", "act_embed"))

    # ---- expert FFN (grouped SwiGLU over the expert axis) ------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out_buf = shard_activation(out_buf, ("act_experts", "capacity", "act_embed"))

    # ---- combine: gather each slot's expert output, weight, sum over k -----
    flat_out = out_buf.reshape(E * cap, d)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((1, d), x.dtype)], axis=0)
    slot_out = flat_out[dest]                                # (K*T, d)
    weights = (top_p.T.reshape(K * T) * keep).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[token_of_slot].add(
        slot_out * weights[:, None])
    y = y.reshape(B, S, d)
    return shard_activation(y, ("batch", "seq", "act_embed")), aux
