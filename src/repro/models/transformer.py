"""Model assembly: all assigned families behind one API.

Families (repro.configs.base.Family):
* dense  — pre-norm GQA transformer (llama3 / qwen2 / phi3 / chameleon)
* moe    — dense attention + top-k expert FFN (granite / moonshot)
* ssm    — Mamba-2 SSD stack, attention-free (mamba2-2.7b)
* hybrid — parallel attention+SSM heads per layer, meta tokens, SWA (hymba)
* encdec — encoder + cross-attending decoder (seamless-m4t)

Layers are stacked (leading ``L`` dim) and applied with ``lax.scan``; remat
wraps the scanned body.  Public entry points:

``model_schema / init_params``         parameters
``forward``                            full-sequence logits (train/prefill)
``lm_loss``                            next-token CE (the train step core)
``init_decode_state / decode_step``    single-token serving step
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard_activation
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.params import P, init_params as _init, param_pspecs

__all__ = ["model_schema", "init_params", "layer_windows", "forward",
           "lm_loss", "init_decode_state", "decode_step", "encode"]


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------
def _stack(schema: dict, n: int) -> dict:
    """Prepend a stacked 'layers' dimension to every leaf."""
    def bump(leaf: P) -> P:
        return P((n, *leaf.shape), ("layers", *leaf.axes), init=leaf.init,
                 fan_in_axes=tuple(a + 1 for a in leaf.fan_in_axes),
                 scale=leaf.scale)
    return jax.tree.map(bump, schema, is_leaf=lambda x: isinstance(x, P))


def _dense_layer_schema(cfg) -> dict:
    return {"ln1": P((cfg.d_model,), ("embed",), init="ones"),
            "attn": L.attention_schema(cfg),
            "ln2": P((cfg.d_model,), ("embed",), init="ones"),
            "mlp": L.mlp_schema(cfg)}


def _moe_layer_schema(cfg) -> dict:
    return {"ln1": P((cfg.d_model,), ("embed",), init="ones"),
            "attn": L.attention_schema(cfg),
            "ln2": P((cfg.d_model,), ("embed",), init="ones"),
            "moe": MOE.moe_schema(cfg)}


def _ssm_layer_schema(cfg) -> dict:
    return {"ln1": P((cfg.d_model,), ("embed",), init="ones"),
            "ssm": SSM.ssm_schema(cfg)}


def _hybrid_layer_schema(cfg) -> dict:
    return {"ln1": P((cfg.d_model,), ("embed",), init="ones"),
            "attn": L.attention_schema(cfg),
            "ssm": SSM.ssm_schema(cfg),
            "norm_attn": P((cfg.d_model,), ("embed",), init="ones"),
            "norm_ssm": P((cfg.d_model,), ("embed",), init="ones"),
            "ln2": P((cfg.d_model,), ("embed",), init="ones"),
            "mlp": L.mlp_schema(cfg)}


def _enc_layer_schema(cfg) -> dict:
    return _dense_layer_schema(cfg)


def _dec_layer_schema(cfg) -> dict:
    return {"ln1": P((cfg.d_model,), ("embed",), init="ones"),
            "attn": L.attention_schema(cfg),
            "ln_cross": P((cfg.d_model,), ("embed",), init="ones"),
            "cross": L.attention_schema(cfg),
            "ln2": P((cfg.d_model,), ("embed",), init="ones"),
            "mlp": L.mlp_schema(cfg)}


_LAYER_SCHEMAS = {"dense": _dense_layer_schema, "moe": _moe_layer_schema,
                  "ssm": _ssm_layer_schema, "hybrid": _hybrid_layer_schema,
                  "encdec": _dec_layer_schema}


def model_schema(cfg) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    sch: dict[str, Any] = {
        "embed": P((v, d), ("vocab", "embed"), fan_in_axes=(1,)),
        "out_head": P((d, v), ("embed", "vocab"), fan_in_axes=(0,)),
        "final_norm": P((d,), ("embed",), init="ones"),
        "layers": _stack(_LAYER_SCHEMAS[cfg.family](cfg), cfg.n_layers),
    }
    if cfg.family == "hybrid" and cfg.n_meta_tokens:
        sch["meta_tokens"] = P((cfg.n_meta_tokens, d), (None, "embed"),
                               fan_in_axes=(1,))
    if cfg.family == "encdec":
        sch["enc_layers"] = _stack(_enc_layer_schema(cfg), cfg.enc_layers)
        sch["enc_final_norm"] = P((d,), ("embed",), init="ones")
    return sch


def init_params(cfg, key: jax.Array, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    return _init(model_schema(cfg), key, dtype=dtype)


def layer_windows(cfg) -> np.ndarray:
    """Per-layer attention window (0 = full).  Hybrid: first/middle/last
    layers are global, the rest use cfg.swa_window (Hymba recipe)."""
    w = np.full(cfg.n_layers, cfg.swa_window, np.int32)
    if cfg.family == "hybrid" and cfg.n_global_layers > 0:
        idx = np.linspace(0, cfg.n_layers - 1, cfg.n_global_layers).astype(int)
        w[idx] = 0
    return w


# ---------------------------------------------------------------------------
# Layer bodies (full-sequence)
# ---------------------------------------------------------------------------
def _layer_fwd(cfg, h, lp, positions, window):
    """One layer, full sequence.  Returns (h, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        h = h + SSM.ssd_apply(lp["ssm"], cfg, L.rms_norm(h, lp["ln1"], cfg.norm_eps))
        return h, aux
    if cfg.family == "hybrid":
        xn = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
        a = L.attention_apply(lp["attn"], cfg, xn, positions, causal=True,
                              window=window)
        s = SSM.ssd_apply(lp["ssm"], cfg, xn)
        mixed = 0.5 * (L.rms_norm(a, lp["norm_attn"], cfg.norm_eps)
                       + L.rms_norm(s, lp["norm_ssm"], cfg.norm_eps))
        h = h + mixed
        h = h + L.mlp_apply(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, aux
    # dense / moe / encdec-decoder self-attention stack
    a = L.attention_apply(lp["attn"], cfg,
                          L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                          positions, causal=True, window=window)
    h = h + a
    if cfg.family == "moe":
        y, aux = MOE.moe_apply(lp["moe"], cfg,
                               L.rms_norm(h, lp["ln2"], cfg.norm_eps))
        h = h + y
    else:
        h = h + L.mlp_apply(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps))
    return h, aux


def _dec_layer_fwd(cfg, h, lp, positions, enc_out, enc_positions):
    a = L.attention_apply(lp["attn"], cfg,
                          L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                          positions, causal=True)
    h = h + a
    c = L.attention_apply(lp["cross"], cfg,
                          L.rms_norm(h, lp["ln_cross"], cfg.norm_eps),
                          positions, causal=False, kv_x=enc_out,
                          kv_positions=enc_positions)
    h = h + c
    h = h + L.mlp_apply(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps))
    return h


def _scan_layers(cfg, h, layers_params, body, xs_extra=None, remat=True,
                 remat_groups: int = 0):
    """Scan over stacked layers with single- or two-level rematerialization.

    ``remat_groups > 1`` enables nested remat: layers are grouped into
    G = remat_groups chunks; only the G group-boundary activations are
    stashed (instead of all L layer boundaries) and the inner layers are
    recomputed per group during backward — the classic sqrt(L) memory
    trade that buys smaller microbatch counts for the FSDP giants
    (EXPERIMENTS.md Sec. Perf hillclimb 2).
    """
    def step(carry, xs):
        hh, aux = carry
        hh, a = body(hh, xs)
        return (hh, aux + a), None

    xs = (layers_params,) if xs_extra is None else (layers_params, *xs_extra)
    n_layers = jax.tree.leaves(layers_params)[0].shape[0]

    if remat and remat_groups > 1 and n_layers % remat_groups == 0:
        per = n_layers // remat_groups
        grouped = jax.tree.map(
            lambda x: x.reshape(remat_groups, per, *x.shape[1:]), xs)

        inner_step = jax.checkpoint(
            step, policy=jax.checkpoint_policies.nothing_saveable)

        def group_step(carry, group_xs):
            out, _ = jax.lax.scan(inner_step, carry, group_xs)
            return out, None

        group_step = jax.checkpoint(
            group_step, policy=jax.checkpoint_policies.nothing_saveable)
        (h, aux), _ = jax.lax.scan(group_step,
                                   (h, jnp.zeros((), jnp.float32)), grouped)
        return h, aux

    if remat:
        step = jax.checkpoint(step,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(step, (h, jnp.zeros((), jnp.float32)), xs)
    return h, aux


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------
def encode(params, cfg, enc_input: jnp.ndarray, remat: bool = True,
           remat_groups: int = 0):
    """Encoder stack over precomputed frame embeddings (stub frontend)."""
    Se = enc_input.shape[1]
    pos = jnp.arange(Se)
    h = enc_input

    def body(hh, xs):
        (lp,) = xs
        a = L.attention_apply(lp["attn"], cfg,
                              L.rms_norm(hh, lp["ln1"], cfg.norm_eps),
                              pos, causal=False)
        hh = hh + a
        hh = hh + L.mlp_apply(lp["mlp"], L.rms_norm(hh, lp["ln2"], cfg.norm_eps))
        return hh, jnp.zeros((), jnp.float32)

    h, _ = _scan_layers(cfg, h, params["enc_layers"], body, remat=remat,
                        remat_groups=remat_groups)
    return L.rms_norm(h, params["enc_final_norm"], cfg.norm_eps)


def forward(params, cfg, tokens: jnp.ndarray,
            enc_input: jnp.ndarray | None = None,
            remat: bool = True,
            remat_groups: int = 0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence logits.

    tokens: (B, S) int32 (decoder tokens for encdec).
    enc_input: (B, Se, d) stub frontend embeddings (encdec only).
    Returns (logits (B, S, V) fp32, aux_loss scalar).
    """
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    h = shard_activation(h, ("batch", "seq", "act_embed"))

    n_meta = cfg.n_meta_tokens if cfg.family == "hybrid" else 0
    if n_meta:
        meta = jnp.broadcast_to(params["meta_tokens"][None],
                                (B, n_meta, cfg.d_model)).astype(h.dtype)
        h = jnp.concatenate([meta, h], axis=1)
    positions = jnp.arange(h.shape[1])
    windows = jnp.asarray(layer_windows(cfg))

    if cfg.family == "encdec":
        assert enc_input is not None
        enc_out = encode(params, cfg, enc_input, remat=remat,
                         remat_groups=remat_groups)
        enc_pos = jnp.arange(enc_out.shape[1])

        def body(hh, xs):
            (lp,) = xs
            return _dec_layer_fwd(cfg, hh, lp, positions, enc_out, enc_pos), \
                jnp.zeros((), jnp.float32)

        h, aux = _scan_layers(cfg, h, params["layers"], body, remat=remat,
                              remat_groups=remat_groups)
    else:
        def body(hh, xs):
            lp, w = xs
            return _layer_fwd(cfg, hh, lp, positions, w)

        h, aux = _scan_layers(cfg, h, params["layers"], body,
                              xs_extra=(windows,), remat=remat,
                              remat_groups=remat_groups)

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if n_meta:
        h = h[:, n_meta:]
    # cast-based fp32 (cotangents convert back to bf16 at the casts)
    logits = jnp.einsum("bsd,dv->bsv", h.astype(jnp.float32),
                        params["out_head"].astype(jnp.float32))
    logits = shard_activation(logits, ("batch", "seq", "act_vocab"))
    return logits, aux


def lm_loss(params, cfg, batch: dict, remat: bool = True,
            remat_groups: int = 0):
    """Next-token cross entropy.  batch: tokens (B,S) [+ enc_input]."""
    tokens = batch["tokens"]
    logits, aux = forward(params, cfg, tokens,
                          enc_input=batch.get("enc_input"), remat=remat,
                          remat_groups=remat_groups)
    labels = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    loss = ce + 0.01 * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill (populate decode state from a prompt)
# ---------------------------------------------------------------------------
def _write_prefix(cache, k, v, positions):
    """Write full-sequence K/V into cache slots [0, S) (linear layout)."""
    B = k.shape[0]
    ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                      (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                      (0, 0, 0, 0))
    pos_b = jnp.broadcast_to(positions.astype(jnp.int32)[None, :],
                             (B, positions.shape[0]))
    cpos = jax.lax.dynamic_update_slice(cache.pos, pos_b, (0, 0))
    return cache._replace(k=ck, v=cv, pos=cpos)


def prefill(params, cfg, tokens: jnp.ndarray, state: "DecodeState",
            enc_input: jnp.ndarray | None = None,
            valid_len: jnp.ndarray | None = None):
    """Process a prompt, populating the decode state.

    tokens: (B, S) prompt (content tokens; hybrid meta tokens are handled
    internally and occupy cache slots [0, n_meta)).
    Returns (last-position logits (B, V) fp32, new state).  Decoding then
    continues from t = S (content position).

    ``valid_len`` (traced scalar), if given, marks only the first
    ``valid_len`` content tokens as real: the prompt may be zero-padded to
    a bucketed length S so ONE compiled program serves every prompt in the
    bucket (the serving engine pads to power-of-two buckets — compile
    count O(log max_len) instead of one trace per distinct length).
    Causality means padded future positions never influence the real
    prefix; their cache slots are written with position -1, which every
    decode-time attention mask already excludes, and the returned logits
    are read at content position ``valid_len - 1``.  Only meaningful when
    the pad suffix is truly inert — dense attention with position-indexed
    caches.  An SSM scan state would absorb the pad tokens, and MoE
    routing counts them against expert capacity (a pad token's top-1 slot
    can evict a real token's lower choice), so callers keep exact lengths
    for those families.
    """
    B, S = tokens.shape
    h = jnp.take(params["embed"], tokens, axis=0)
    n_meta = cfg.n_meta_tokens if cfg.family == "hybrid" else 0
    if n_meta:
        meta = jnp.broadcast_to(params["meta_tokens"][None],
                                (B, n_meta, cfg.d_model)).astype(h.dtype)
        h = jnp.concatenate([meta, h], axis=1)
    positions = jnp.arange(h.shape[1])
    pos_write = positions if valid_len is None else jnp.where(
        positions < n_meta + valid_len, positions, -1)
    windows = jnp.asarray(layer_windows(cfg))

    if cfg.family == "ssm":
        def body(hh, xs):
            lp, cache = xs
            xn = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            y, new_cache = SSM.ssd_apply(lp["ssm"], cfg, xn,
                                         chunk=min(128, hh.shape[1]),
                                         return_state=True)
            return hh + y, new_cache

        h, new_ssm = jax.lax.scan(lambda c, xs: body(c, xs), h,
                                  (params["layers"], state.ssm))
        state = state._replace(ssm=new_ssm)

    elif cfg.family == "hybrid":
        def body(hh, xs):
            lp, w, acache, _scache = xs
            xn = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            a, (k, v) = L.attention_apply(lp["attn"], cfg, xn, positions,
                                          causal=True, window=w,
                                          return_kv=True)
            new_a = _write_prefix(acache, k, v, pos_write)
            s, new_s = SSM.ssd_apply(lp["ssm"], cfg, xn,
                                     chunk=min(128, hh.shape[1]),
                                     return_state=True)
            mixed = 0.5 * (L.rms_norm(a, lp["norm_attn"], cfg.norm_eps)
                           + L.rms_norm(s, lp["norm_ssm"], cfg.norm_eps))
            hh = hh + mixed
            hh = hh + L.mlp_apply(lp["mlp"],
                                  L.rms_norm(hh, lp["ln2"], cfg.norm_eps))
            return hh, (new_a, new_s)

        h, (new_attn, new_ssm) = jax.lax.scan(
            lambda c, xs: body(c, xs), h,
            (params["layers"], windows, state.attn, state.ssm))
        state = state._replace(attn=new_attn, ssm=new_ssm)

    elif cfg.family == "encdec":
        assert enc_input is not None
        enc_out = encode(params, cfg, enc_input, remat=False)
        enc_pos = jnp.arange(enc_out.shape[1])

        def body(hh, xs):
            lp, acache = xs
            xn = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            a, (k, v) = L.attention_apply(lp["attn"], cfg, xn, positions,
                                          causal=True, return_kv=True)
            new_a = _write_prefix(acache, k, v, pos_write)
            hh = hh + a
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"])
            if cfg.qkv_bias:
                ck, cv = ck + lp["cross"]["bk"], cv + lp["cross"]["bv"]
            if cfg.qk_norm:
                ck = L.rms_norm(ck, lp["cross"]["k_norm"], cfg.norm_eps)
            ck = L.rope(ck, enc_pos, cfg.rope_theta)
            c = L.attention_apply(lp["cross"], cfg,
                                  L.rms_norm(hh, lp["ln_cross"], cfg.norm_eps),
                                  positions, causal=False, kv_x=enc_out,
                                  kv_positions=enc_pos)
            hh = hh + c
            hh = hh + L.mlp_apply(lp["mlp"],
                                  L.rms_norm(hh, lp["ln2"], cfg.norm_eps))
            return hh, (new_a, ck.astype(acache.k.dtype),
                        cv.astype(acache.v.dtype))

        h, (new_attn, cks, cvs) = jax.lax.scan(
            lambda c, xs: body(c, xs), h, (params["layers"], state.attn))
        state = state._replace(attn=new_attn, cross_k=cks, cross_v=cvs)

    else:  # dense / moe
        def body(hh, xs):
            lp, w, acache = xs
            xn = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            a, (k, v) = L.attention_apply(lp["attn"], cfg, xn, positions,
                                          causal=True, window=w,
                                          return_kv=True)
            new_a = _write_prefix(acache, k, v, pos_write)
            hh = hh + a
            if cfg.family == "moe":
                y, _ = MOE.moe_apply(lp["moe"], cfg,
                                     L.rms_norm(hh, lp["ln2"], cfg.norm_eps))
                hh = hh + y
            else:
                hh = hh + L.mlp_apply(lp["mlp"],
                                      L.rms_norm(hh, lp["ln2"], cfg.norm_eps))
            return hh, new_a

        h, new_attn = jax.lax.scan(lambda c, xs: body(c, xs), h,
                                   (params["layers"], windows, state.attn))
        state = state._replace(attn=new_attn)

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    # last REAL content position: the bucket's pad suffix carries no signal
    last = h[:, -1] if valid_len is None \
        else jnp.take(h, n_meta + valid_len - 1, axis=1)
    logits = jnp.einsum("bd,dv->bv", last, params["out_head"],
                        preferred_element_type=jnp.float32)
    return logits, state


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------
class DecodeState(NamedTuple):
    """Per-model decode state; unused fields are empty pytrees."""
    attn: Any          # PosCache stacked over layers (or ())
    ssm: Any           # SSMCache stacked over layers (or ())
    cross_k: Any       # (L, B, Se, K, Dh) encdec only (or ())
    cross_v: Any


def _stacked_pos_cache(cfg, n_layers, batch, cache_len, dtype):
    shape = (n_layers, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    return L.PosCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                      pos=jnp.full((n_layers, batch, cache_len), -1,
                                   jnp.int32))


def _stacked_ssm_cache(cfg, n_layers, batch, dtype=jnp.float32):
    di = cfg.d_inner
    nh = di // cfg.ssm_headdim
    conv_dim = di + 2 * cfg.d_state
    return SSM.SSMCache(
        h=jnp.zeros((n_layers, batch, nh, cfg.ssm_headdim, cfg.d_state),
                    dtype),
        conv=jnp.zeros((n_layers, batch, cfg.d_conv - 1, conv_dim), dtype))


def init_decode_state(cfg, batch: int, cache_len: int,
                      dtype=jnp.bfloat16, enc_len: int = 0) -> DecodeState:
    attn: Any = ()
    ssm: Any = ()
    ck: Any = ()
    cv: Any = ()
    total_len = cache_len + (cfg.n_meta_tokens if cfg.family == "hybrid" else 0)
    if cfg.family in ("dense", "moe", "hybrid", "encdec"):
        attn = _stacked_pos_cache(cfg, cfg.n_layers, batch, total_len, dtype)
    if cfg.family in ("ssm", "hybrid"):
        ssm = _stacked_ssm_cache(cfg, cfg.n_layers, batch)
    if cfg.family == "encdec":
        shape = (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        ck = jnp.zeros(shape, dtype)
        cv = jnp.zeros(shape, dtype)
    return DecodeState(attn=attn, ssm=ssm, cross_k=ck, cross_v=cv)


def decode_step(params, cfg, tokens: jnp.ndarray, state: DecodeState,
                t: jnp.ndarray) -> tuple[jnp.ndarray, DecodeState]:
    """One serving step: tokens (B, 1) at absolute position t (scalar).

    For hybrid models t indexes the *content* stream; the meta-token prefix
    occupies cache slots [0, n_meta) and position t maps to slot n_meta + t.
    Returns (logits (B, V) fp32, new state).
    """
    B = tokens.shape[0]
    h = jnp.take(params["embed"], tokens, axis=0)       # (B, 1, d)
    n_meta = cfg.n_meta_tokens if cfg.family == "hybrid" else 0
    t_abs = t + n_meta
    windows = jnp.asarray(layer_windows(cfg))

    if cfg.family == "ssm":
        def body(hh, xs):
            lp, cache = xs
            xn = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            y, new_cache = SSM.ssd_decode_step(lp["ssm"], cfg, xn, cache)
            return hh + y, new_cache

        h, new_ssm = jax.lax.scan(lambda c, xs: body(c, xs), h,
                                  (params["layers"], state.ssm))
        new_state = state._replace(ssm=new_ssm)

    elif cfg.family == "hybrid":
        def body(hh, xs):
            lp, w, acache, scache = xs
            xn = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            a, new_a = L.attention_cached(lp["attn"], cfg, xn, t_abs, acache,
                                          window=w)
            s, new_s = SSM.ssd_decode_step(lp["ssm"], cfg, xn, scache)
            mixed = 0.5 * (L.rms_norm(a, lp["norm_attn"], cfg.norm_eps)
                           + L.rms_norm(s, lp["norm_ssm"], cfg.norm_eps))
            hh = hh + mixed
            hh = hh + L.mlp_apply(lp["mlp"],
                                  L.rms_norm(hh, lp["ln2"], cfg.norm_eps))
            return hh, (new_a, new_s)

        h, (new_attn, new_ssm) = jax.lax.scan(
            lambda c, xs: body(c, xs), h,
            (params["layers"], windows, state.attn, state.ssm))
        new_state = state._replace(attn=new_attn, ssm=new_ssm)

    elif cfg.family == "encdec":
        def body(hh, xs):
            lp, acache, ek, ev = xs
            a, new_a = L.attention_cached(lp["attn"], cfg,
                                          L.rms_norm(hh, lp["ln1"],
                                                     cfg.norm_eps),
                                          t_abs, acache)
            hh = hh + a
            c = L.cross_attention_cached(lp["cross"], cfg,
                                         L.rms_norm(hh, lp["ln_cross"],
                                                    cfg.norm_eps),
                                         t_abs, ek, ev)
            hh = hh + c
            hh = hh + L.mlp_apply(lp["mlp"],
                                  L.rms_norm(hh, lp["ln2"], cfg.norm_eps))
            return hh, new_a

        h, new_attn = jax.lax.scan(
            lambda c, xs: body(c, xs), h,
            (params["layers"], state.attn, state.cross_k, state.cross_v))
        new_state = state._replace(attn=new_attn)

    else:  # dense / moe
        def body(hh, xs):
            lp, w, acache = xs
            a, new_a = L.attention_cached(lp["attn"], cfg,
                                          L.rms_norm(hh, lp["ln1"],
                                                     cfg.norm_eps),
                                          t_abs, acache, window=w)
            hh = hh + a
            if cfg.family == "moe":
                y, _ = MOE.moe_apply(lp["moe"], cfg,
                                     L.rms_norm(hh, lp["ln2"], cfg.norm_eps))
                hh = hh + y
            else:
                hh = hh + L.mlp_apply(lp["mlp"],
                                      L.rms_norm(hh, lp["ln2"], cfg.norm_eps))
            return hh, new_a

        h, new_attn = jax.lax.scan(lambda c, xs: body(c, xs), h,
                                   (params["layers"], windows, state.attn))
        new_state = state._replace(attn=new_attn)

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["out_head"],
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, new_state
