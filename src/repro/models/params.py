"""Parameter schema system.

A model's parameters are described once as a nested dict of :class:`P`
descriptors (shape + logical axis names + init law).  From the schema we
derive (a) materialized arrays (:func:`init_params`) and (b) a matching
PartitionSpec pytree (:func:`param_pspecs`) for any mesh/rule set — keeping
model code and distribution policy decoupled (DESIGN.md Sec. 5).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

__all__ = ["P", "init_params", "param_pspecs", "tree_size"]


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter leaf: shape + logical axes + initialization."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names, len == ndim
    init: str = "normal"                  # normal | zeros | ones
    fan_in_axes: tuple[int, ...] = ()     # dims whose product is fan-in
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _materialize(leaf: P, key: jax.Array, dtype) -> jnp.ndarray:
    if leaf.init == "zeros":
        return jnp.zeros(leaf.shape, dtype)
    if leaf.init == "ones":
        return jnp.ones(leaf.shape, dtype)
    fan_in = 1
    for ax in leaf.fan_in_axes:
        fan_in *= leaf.shape[ax]
    std = leaf.scale / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, leaf.shape, jnp.float32) * std).astype(dtype)


def init_params(schema: dict, key: jax.Array, dtype=jnp.float32) -> dict:
    """Materialize a schema into arrays (deterministic per leaf path)."""
    leaves, treedef = jax.tree.flatten(
        schema, is_leaf=lambda x: isinstance(x, P))
    keys = jax.random.split(key, len(leaves))
    arrays = [_materialize(leaf, k, dtype) for leaf, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrays)


def param_pspecs(schema: dict,
                 rules: dict[str, str | tuple[str, ...] | None],
                 mesh_axis_sizes: dict[str, int] | None = None) -> dict:
    """PartitionSpec pytree from logical-axis rules.

    ``rules`` maps logical axis name -> mesh axis (or tuple / None).  When
    ``mesh_axis_sizes`` is given, a mapping is dropped (replicated) if the
    dimension size is not divisible by the mesh-axis-product — e.g. 4 KV
    heads cannot shard over a 16-way model axis, so they replicate.
    """

    def spec_for(leaf: P) -> PartitionSpec:
        entries = []
        for dim, axis in zip(leaf.shape, leaf.axes):
            mesh_axes = rules.get(axis) if axis is not None else None
            if mesh_axes is None:
                entries.append(None)
                continue
            if isinstance(mesh_axes, str):
                mesh_axes = (mesh_axes,)
            if mesh_axis_sizes is not None:
                total = 1
                for m in mesh_axes:
                    total *= mesh_axis_sizes.get(m, 1)
                if total == 0 or dim % total != 0:
                    entries.append(None)
                    continue
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        return PartitionSpec(*entries)

    return jax.tree.map(spec_for, schema,
                        is_leaf=lambda x: isinstance(x, P))


def tree_size(tree: Any) -> int:
    """Total number of elements in a pytree of arrays or P descriptors."""
    def leaf_size(x):
        if isinstance(x, P):
            return math.prod(x.shape)
        return x.size
    return sum(leaf_size(x) for x in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, P)))
