"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD for train/prefill (intra-chunk quadratic form + inter-chunk
recurrence carried by ``lax.scan``) and an O(1)-per-token recurrent decode
step.  Geometry follows the paper: ``d_inner = expand * d_model`` split into
heads of ``ssm_headdim``; scalar decay per head (``A``), shared B/C of size
``d_state`` (one group), depthwise causal conv over (x, B, C), gated RMSNorm
before the output projection.

TPU notes: heads shard over the model axis (TP); the intra-chunk term is a
(Q x Q) masked matmul per head — MXU work; the inter-chunk scan carries the
(B, H, P, N) state, which for decode is the *entire* context summary
(the reason this family runs the long_500k cell).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_activation
from repro.models.params import P

__all__ = ["ssm_schema", "ssd_apply", "ssd_decode_step", "SSMCache",
           "init_ssm_cache"]


def _dims(cfg):
    di = cfg.d_inner
    nh = di // cfg.ssm_headdim
    n = cfg.d_state
    conv_dim = di + 2 * n
    return di, nh, n, conv_dim


def ssm_schema(cfg) -> dict:
    d = cfg.d_model
    di, nh, n, conv_dim = _dims(cfg)
    proj_out = 2 * di + 2 * n + nh           # z, x, B, C, dt
    return {
        "in_proj": P((d, proj_out), ("embed", "ssm_inner"), fan_in_axes=(0,)),
        "conv_w": P((cfg.d_conv, conv_dim), ("conv", "ssm_inner"),
                    fan_in_axes=(0,)),
        "conv_b": P((conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": P((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": P((nh,), ("ssm_heads",), init="zeros"),
        "d_skip": P((nh,), ("ssm_heads",), init="ones"),
        "norm": P((di,), ("ssm_inner",), init="ones"),
        "out_proj": P((di, d), ("ssm_inner", "embed"), fan_in_axes=(0,),
                      scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


class SSMCache(NamedTuple):
    h: jnp.ndarray           # (B, nh, hd, N) recurrent state
    conv: jnp.ndarray        # (B, d_conv - 1, conv_dim) conv history


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32) -> SSMCache:
    di, nh, n, conv_dim = _dims(cfg)
    return SSMCache(
        h=jnp.zeros((batch, nh, cfg.ssm_headdim, n), dtype),
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
    )


def _split_proj(cfg, zxbcdt):
    di, nh, n, _ = _dims(cfg)
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, xin, bmat, cmat, dt


def _conv_causal(u, w, b):
    """Depthwise causal conv.  u: (B,S,Cd), w: (dc,Cd), b: (Cd,)."""
    dc = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (dc - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(dc):                       # dc static (=4): unrolled taps
        out = out + pad[:, i:i + u.shape[1], :] * w[i]
    return out + b


def ssd_apply(p: dict, cfg, x: jnp.ndarray, *, chunk: int = 128,
              return_state: bool = False):
    """Chunked SSD forward.  x: (B, S, d) -> y: (B, S, d)."""
    B, S, d = x.shape
    di, nh, n, conv_dim = _dims(cfg)
    hd = cfg.ssm_headdim
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    zxbcdt = x @ p["in_proj"]
    z, xin, bmat, cmat, dt_raw = _split_proj(cfg, zxbcdt)
    u = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out = jax.nn.silu(_conv_causal(u, p["conv_w"], p["conv_b"]))
    xin, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    xin = shard_activation(xin, ("batch", "seq", "act_ssm_inner"))
    xh = xin.reshape(B, nc, Q, nh, hd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    dt = dt.reshape(B, nc, Q, nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                      # (nh,)
    da = dt * a                                                       # <= 0
    la = jnp.cumsum(da, axis=2)                                       # (B,nc,Q,nh)
    bm = bmat.reshape(B, nc, Q, n).astype(jnp.float32)
    cm = cmat.reshape(B, nc, Q, n).astype(jnp.float32)
    xf = xh.astype(jnp.float32)

    # ---- intra-chunk (quadratic in Q, MXU) --------------------------------
    cb = jnp.einsum("bcqn,bcjn->bcqj", cm, bm)                 # (B,nc,Q,Q)
    qi = jnp.arange(Q)
    causal = qi[:, None] >= qi[None, :]                        # j <= q
    diff = la[:, :, :, None, :] - la[:, :, None, :, :]         # (B,nc,Q,Q,nh)
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], diff, -jnp.inf))
    m = cb[..., None] * decay * dt[:, :, None, :, :]           # (B,nc,Q,Q,nh)
    y_intra = jnp.einsum("bcqjh,bcjhp->bcqhp", m, xf)

    # ---- chunk states ------------------------------------------------------
    rem = jnp.exp(la[:, :, -1:, :] - la)                       # (B,nc,Q,nh)
    sc = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bm, rem * dt, xf)  # (B,nc,nh,hd,n)

    # ---- inter-chunk recurrence (scan over chunks) -------------------------
    chunk_decay = jnp.exp(la[:, :, -1, :])                     # (B,nc,nh)

    def step(h_prev, inputs):
        s_c, dec_c = inputs                                    # (B,nh,hd,n), (B,nh)
        h_new = dec_c[:, :, None, None] * h_prev + s_c
        return h_new, h_prev

    h0 = jnp.zeros((B, nh, hd, n), jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                      # (B,nc,nh,hd,n)

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         cm, jnp.exp(la), h_prevs)
    y = y_intra + y_inter + p["d_skip"][:, None] * xf          # (B,nc,Q,nh,hd)
    y = y.reshape(B, S, di).astype(x.dtype)

    # gated RMSNorm + output projection
    y32 = y.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(y32 * y32, -1, keepdims=True) + cfg.norm_eps)
    y = (y32 * scale).astype(x.dtype) * p["norm"] * jax.nn.silu(z)
    out = y @ p["out_proj"]
    out = shard_activation(out, ("batch", "seq", "act_embed"))

    if return_state:
        conv_state = jnp.pad(u, ((0, 0), (cfg.d_conv - 1, 0), (0, 0)))[
            :, -(cfg.d_conv - 1):, :]
        return out, SSMCache(h=h_last, conv=conv_state)
    return out


def ssd_decode_step(p: dict, cfg, x: jnp.ndarray,
                    cache: SSMCache) -> tuple[jnp.ndarray, SSMCache]:
    """One-token recurrent update.  x: (B, 1, d) -> (B, 1, d)."""
    B = x.shape[0]
    di, nh, n, conv_dim = _dims(cfg)
    hd = cfg.ssm_headdim

    zxbcdt = x[:, 0] @ p["in_proj"]                       # (B, proj)
    z, xin, bmat, cmat, dt_raw = _split_proj(cfg, zxbcdt)
    u_t = jnp.concatenate([xin, bmat, cmat], axis=-1)     # (B, conv_dim)
    full = jnp.concatenate([cache.conv, u_t[:, None]], axis=1)  # (B,dc,Cd)
    conv_out = jnp.einsum("bdc,dc->bc", full.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xin, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)
    new_conv = full[:, 1:]

    xt = xin.reshape(B, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                               # (B,nh)
    dbx = jnp.einsum("bh,bhp,bn->bhpn", dt, xt, bmat.astype(jnp.float32))
    h = decay[:, :, None, None] * cache.h + dbx
    y = jnp.einsum("bhpn,bn->bhp", h, cmat.astype(jnp.float32)) \
        + p["d_skip"][:, None] * xt                       # (B,nh,hd)
    y = y.reshape(B, di)

    scale = jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + cfg.norm_eps)
    y = (y * scale).astype(x.dtype) * p["norm"] * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None, :]
    return out, SSMCache(h=h, conv=new_conv)
