"""Shared transformer building blocks: RMSNorm, RoPE, GQA attention, SwiGLU.

All functions are pure; parameters come from the schema system
(repro.models.params).  Attention supports: causal/bidirectional, GQA,
sliding windows with an always-visible meta-token prefix (Hymba), QK-norm
(Chameleon), QKV bias (Qwen2), and decode against a position-tracking KV
cache (:class:`PosCache`) that supports both linear and ring-buffer layouts.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_activation
from repro.models.params import P

__all__ = ["rms_norm", "rope", "attention_schema", "attention_apply",
           "attention_cached", "mlp_schema", "mlp_apply", "PosCache",
           "init_pos_cache"]

_NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale).astype(x.dtype) * gamma


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (B, S, H, Dh); positions: (S,) or (B, S).

    fp32 math between *explicit* casts on both boundaries: without the input
    cast, ``bf16 * f32`` promotion leaks fp32 cotangents into the projection
    backward and doubles every TP all-reduce (EXPERIMENTS.md Sec. Perf)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = positions if positions.ndim == 2 else positions[None, :]
    angles = pos[..., None].astype(jnp.float32) * freqs       # (B?, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., :half], x32[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attention_schema(cfg) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    sch = {
        "wq": P((d, H, hd), ("embed", "heads", "head_dim"), fan_in_axes=(0,)),
        "wk": P((d, K, hd), ("embed", "kv_heads", "head_dim"), fan_in_axes=(0,)),
        "wv": P((d, K, hd), ("embed", "kv_heads", "head_dim"), fan_in_axes=(0,)),
        "wo": P((H, hd, d), ("heads", "head_dim", "embed"), fan_in_axes=(0, 1),
                scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    if cfg.qkv_bias:
        sch["bq"] = P((H, hd), ("heads", "head_dim"), init="zeros")
        sch["bk"] = P((K, hd), ("kv_heads", "head_dim"), init="zeros")
        sch["bv"] = P((K, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        sch["q_norm"] = P((hd,), ("head_dim",), init="ones")
        sch["k_norm"] = P((hd,), ("head_dim",), init="ones")
    return sch


class PosCache(NamedTuple):
    """KV cache that records the absolute position held in every slot.

    ``pos[b, s] == -1`` marks an empty slot.  Linear layout writes slot = t;
    a ring layout writes slot = meta + (t - meta) % window — the mask logic
    is identical because it only consults the stored positions.  Positions
    are per batch row, so continuous batching can run unaligned requests.
    """
    k: jnp.ndarray      # (B, Cl, K, Dh)
    v: jnp.ndarray      # (B, Cl, K, Dh)
    pos: jnp.ndarray    # (B, Cl) int32


def init_pos_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16) -> PosCache:
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    return PosCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                    pos=jnp.full((batch, cache_len), -1, jnp.int32))


def _project_qkv(p, cfg, x, kv_src):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _gqa_attend(q, k, v, mask, head_dim):
    """q: (B,Sq,H,Dh); k,v: (B,Sk,K,Dh); mask broadcastable to
    (B,K,G,Sq,Sk) or None."""
    B, Sq, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, Dh)
    # fp32 via explicit casts (NOT preferred_element_type): the cast
    # boundaries convert the backward cotangents back to bf16, preventing
    # fp32 dq/dk/dW chains that double the TP all-reduce wire
    # (EXPERIMENTS.md Sec. Perf hillclimb 2, move 3)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = scores / math.sqrt(head_dim)
    if mask is not None:
        scores = jnp.where(mask, scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, Dh)


# Sequences at or above this length take the online-softmax chunked path —
# full (Sq, Sk) score materialization at 32k+ would need tens of GB/device.
CHUNKED_ATTN_THRESHOLD = 8192
_Q_CHUNK = 1024
_KV_CHUNK = 1024


def _largest_divisor(n: int, target: int) -> int:
    for c in range(target, 0, -1):
        if n % c == 0:
            return c
    return 1


def _chunked_attend(q, k, v, q_pos, kv_pos, *, causal, window, n_meta,
                    head_dim, q_chunk=_Q_CHUNK, kv_chunk=_KV_CHUNK):
    """Flash-style attention: never materializes the (Sq, Sk) score matrix.

    Outer loop over query chunks (lax.map), inner lax.scan over KV chunks
    carrying the online-softmax state (running max m, normalizer l, weighted
    accumulator acc).  Live memory is O(q_chunk * kv_chunk) per head instead
    of O(Sq * Sk).  Masking (causal / sliding window / meta prefix) is
    evaluated per chunk pair from the position arrays.
    """
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    G = H // K
    q_chunk = _largest_divisor(Sq, min(q_chunk, Sq))
    kv_chunk = _largest_divisor(Sk, min(kv_chunk, Sk))
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    scale = 1.0 / math.sqrt(head_dim)
    w = jnp.asarray(window)

    qr = jnp.moveaxis(q.reshape(B, nq, q_chunk, K, G, Dh), 1, 0)
    qpr = q_pos.reshape(nq, q_chunk)
    kr = jnp.moveaxis(k.reshape(B, nk, kv_chunk, K, Dh), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, kv_chunk, K, Dh), 1, 0)
    kpr = kv_pos.reshape(nk, kv_chunk)

    def one_q(args):
        qc, qp = args                                  # (B,qc,K,G,Dh), (qc,)

        def kv_step(carry, inputs):
            acc, m, l = carry
            kc, vc, kp = inputs
            s = jnp.einsum("bqkgd,bskd->bkgqs", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            if causal:
                qpb = qp[:, None]
                kpb = kp[None, :]
                allowed = kpb <= qpb
                in_w = jnp.where(w > 0, (qpb - kpb) < w, True)
                if n_meta > 0:
                    in_w = in_w | (kpb < n_meta)
                s = jnp.where((allowed & in_w)[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p_, vc.astype(jnp.float32))
            l = l * alpha + p_.sum(axis=-1)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, K, G, q_chunk, Dh), jnp.float32)
        m0 = jnp.full((B, K, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kr, vr, kpr))
        out = acc / jnp.maximum(l[..., None], 1e-30)   # (B,K,G,qc,Dh)
        return jnp.moveaxis(out, 3, 1).reshape(B, q_chunk, H, Dh)

    out = jax.lax.map(one_q, (qr, qpr))                # (nq,B,qc,H,Dh)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, Dh).astype(q.dtype)


def attention_apply(p: dict, cfg, x: jnp.ndarray, positions: jnp.ndarray,
                    *, causal: bool = True, window: jnp.ndarray | int = 0,
                    kv_x: jnp.ndarray | None = None,
                    kv_positions: jnp.ndarray | None = None,
                    use_rope: bool = True, return_kv: bool = False):
    """Full-sequence GQA attention (train / prefill / encoder / cross).

    ``window`` <= 0 means full attention; meta-token positions
    (< cfg.n_meta_tokens) are always visible under a window (Hymba).
    """
    kv_src = x if kv_x is None else kv_x
    q, k, v = _project_qkv(p, cfg, x, kv_src)
    kv_pos = positions if kv_positions is None else kv_positions
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)
    q = shard_activation(q, ("batch", "seq", "act_heads", None))
    k = shard_activation(k, ("batch", "seq", "act_kv_heads", None))

    if max(q.shape[1], k.shape[1]) >= CHUNKED_ATTN_THRESHOLD:
        out = _chunked_attend(q, k, v, positions, kv_pos, causal=causal,
                              window=window, n_meta=cfg.n_meta_tokens,
                              head_dim=cfg.head_dim)
    else:
        mask = None
        if causal:
            qp = positions[:, None]
            kp = kv_pos[None, :]
            mask = kp <= qp
            w = jnp.asarray(window)
            in_window = jnp.where(w > 0, (qp - kp) < w, True)
            if cfg.n_meta_tokens > 0:
                in_window = in_window | (kp < cfg.n_meta_tokens)
            mask = (mask & in_window)[None, None, None]  # (1,1,1,Sq,Sk)
        out = _gqa_attend(q, k, v, mask, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = shard_activation(y, ("batch", "seq", "act_embed"))
    if return_kv:
        return y, (k, v)
    return y


def attention_cached(p: dict, cfg, x: jnp.ndarray, t: jnp.ndarray,
                     cache: PosCache, *, window: jnp.ndarray | int = 0,
                     write_slot: jnp.ndarray | None = None,
                     use_rope: bool = True) -> tuple[jnp.ndarray, PosCache]:
    """Single-token decode against a PosCache.

    x: (B, 1, d); t: scalar or (B,) absolute position(s) of this token —
    per-row positions support unaligned continuous batching.
    ``write_slot`` defaults to t (linear cache); pass the ring-buffer slot
    for windowed layers.
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x, x)
    tv = jnp.broadcast_to(jnp.atleast_1d(t), (B,)).astype(jnp.int32)
    if use_rope:
        q = rope(q, tv[:, None], cfg.rope_theta)
        k = rope(k, tv[:, None], cfg.rope_theta)

    slot = tv if write_slot is None else \
        jnp.broadcast_to(jnp.atleast_1d(write_slot), (B,)).astype(jnp.int32)
    bidx = jnp.arange(B)
    ck = cache.k.at[bidx, slot].set(k[:, 0].astype(cache.k.dtype))
    cv = cache.v.at[bidx, slot].set(v[:, 0].astype(cache.v.dtype))
    cpos = cache.pos.at[bidx, slot].set(tv)
    new_cache = PosCache(k=ck, v=cv, pos=cpos)

    kp = cpos                                            # (B, Cl)
    tb = tv[:, None]
    mask = (kp >= 0) & (kp <= tb)
    w = jnp.asarray(window)
    in_window = jnp.where(w > 0, (tb - kp) < w, True)
    if cfg.n_meta_tokens > 0:
        in_window = in_window | ((kp < cfg.n_meta_tokens) & (kp >= 0))
    mask = (mask & in_window)[:, None, None, None, :]    # (B,1,1,1,Cl)

    out = _gqa_attend(q, ck, cv, mask, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def cross_attention_cached(p: dict, cfg, x: jnp.ndarray, t: jnp.ndarray,
                           enc_k: jnp.ndarray, enc_v: jnp.ndarray) -> jnp.ndarray:
    """Decode-time cross attention against precomputed (roped) encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = rope(q, jnp.reshape(t, (1,)).astype(jnp.int32), cfg.rope_theta)
    out = _gqa_attend(q, enc_k, enc_v, None, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_schema(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": P((d, f), ("embed", "mlp"), fan_in_axes=(0,)),
        "w_up": P((d, f), ("embed", "mlp"), fan_in_axes=(0,)),
        "w_down": P((f, d), ("mlp", "embed"), fan_in_axes=(0,),
                    scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
    }


def mlp_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard_activation(h, ("batch", "seq", "act_mlp"))
    y = h @ p["w_down"]
    return shard_activation(y, ("batch", "seq", "act_embed"))
