"""Deterministic, resumable, host-shardable synthetic token pipeline.

Production data loading concerns implemented here:
* **Determinism**: batch ``i`` is a pure function of (seed, i) — restart at
  any step reproduces the exact token stream (required for bitwise resume).
* **Resumability**: the iterator state is a single integer (next batch idx),
  checkpointed alongside the model.
* **Host sharding**: each host materializes only its slice of the global
  batch (``host_id / n_hosts``).
* **Structure**: tokens follow an order-k Markov chain over a power-law
  unigram prior (zipf), so a language model has learnable structure and the
  training loss decreases — a pure-noise stream would not separate broken
  training from working training.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenPipeline"]


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    next_index: int = 0          # checkpointable cursor

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # zipf unigram prior
        self._prior = 1.0 / np.arange(1, v + 1) ** 1.1
        self._prior /= self._prior.sum()
        # a sparse deterministic bigram kernel: each token prefers a few
        # successors (mixture with the prior keeps entropy reasonable)
        self._succ = rng.integers(0, v, size=(v, 4))

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch_at(self, index: int) -> np.ndarray:
        """The (local_batch, seq_len) int32 tokens of global batch ``index``."""
        out = np.empty((self.local_batch, self.seq_len), np.int32)
        for row in range(self.local_batch):
            global_row = self.host_id * self.local_batch + row
            rng = np.random.default_rng(
                (self.seed, index, global_row))
            toks = np.empty(self.seq_len, np.int32)
            toks[0] = rng.choice(self.vocab_size, p=self._prior)
            # vectorized Markov walk: pre-draw choices and mixture flags
            mix = rng.random(self.seq_len) < 0.75
            pick = rng.integers(0, 4, size=self.seq_len)
            fallback = rng.choice(self.vocab_size, size=self.seq_len,
                                  p=self._prior)
            for t in range(1, self.seq_len):
                toks[t] = self._succ[toks[t - 1], pick[t]] if mix[t] \
                    else fallback[t]
            out[row] = toks
        return out

    def __iter__(self):
        return self

    def __next__(self) -> np.ndarray:
        batch = self.batch_at(self.next_index)
        self.next_index += 1
        return batch

    # -- checkpoint integration ---------------------------------------------
    def state_dict(self) -> dict:
        return {"next_index": self.next_index, "seed": self.seed}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.seed, "data seed mismatch on resume"
        self.next_index = int(state["next_index"])
