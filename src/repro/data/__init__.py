"""Data pipelines: deterministic resumable token streams."""
